"""Fig. 3 — mu2 stabilizes federated learning under bad communication.

Row 1: accuracy-curve jitter vs mu2 at low CSR (paper: mu2 = 0.005
suppresses the concussion of the curve).
Row 2: MSE of the testing-accuracy curve to the centralized-learning
reference (paper: with mu2 = 0.005 at CSR = 10% the curve is almost the
same as learning with CSR = 90%).

Both rows declare their grids as ``ScenarioSpec`` lists and run through
the vmapped sweep engine: the (mu2 × seed) row and the good-CSR reference
share one compiled program (csr/mu2 are batched scalars); the long-horizon
MSE trio is its own group (different round count = different scan length).
"""
from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from benchmarks import metrics
from benchmarks.common import RESULTS_DIR, base_spec, bench_scale, \
    build_pipeline, csv_row, run_cells, seed_variants
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.core.scenario import ScenarioSpec

MU2S = (0.0, 0.001, 0.005, 0.02)
CSR_BAD = 0.2
CSR_GOOD = 0.9
MU1 = 0.001
LAR = 5
# same drift regime as fig2 — where low CSR makes the curve "concuss"
E, LR = 3, 0.15
N_SEEDS = 2
MSE_ROUNDS = 40   # the paper's converging regime (CSR = 10%, long horizon)


def _cell(csr: float, mu2: float, *, rounds: int, seed: int,
          local_epochs: int = E, lr: float = LR) -> List[ScenarioSpec]:
    return seed_variants(base_spec(
        hp=H2FedParams(mu1=MU1, mu2=mu2, lar=LAR, local_epochs=local_epochs,
                       lr=lr),
        het=HeterogeneityModel(csr=csr, scd=1, lar=LAR),
        rounds=rounds, seed=seed), N_SEEDS)


def _centralized_reference(pipe, n_points: int):
    """Centralized SGD on the pooled fleet data — Fig. 3's reference curve."""
    from repro.fedsim.pretrain import train_centralized
    _, hist = train_centralized(
        pipe.pre_params, pipe.fed_pool, lr=0.1, epochs=2,
        x_test=pipe.test.x, y_test=pipe.test.y, eval_every=25)
    acc = hist["acc"]
    # resample to n_points so curves are comparable round-for-round
    idx = np.linspace(0, len(acc) - 1, n_points).round().astype(int)
    return acc[idx]


def run(n_rounds: int | None = None, seed: int = 0) -> List[str]:
    rounds = n_rounds or bench_scale()["rounds"]
    rows: List[str] = []
    results = {}

    # --- Fig. 3 row 1: one sweep over (mu2 grid + good-CSR ref) × seeds
    cells = [(f"mu2_{mu2}", _cell(CSR_BAD, mu2, rounds=rounds, seed=seed))
             for mu2 in MU2S]
    cells.append(("good_ref", _cell(CSR_GOOD, 0.0, rounds=rounds,
                                    seed=seed)))
    pipe = build_pipeline(cells[0][1][0])
    curves, _, wall = run_cells(cells)
    per_curve = wall / len(cells)

    for mu2 in MU2S:
        acc = curves[f"mu2_{mu2}"]
        rows.append(csv_row(f"fig3/csr{CSR_BAD}/mu2_{mu2}",
                            per_curve / len(acc) * 1e6,
                            f"jitter={metrics.jitter(acc, tail=12):.4f}"))
    acc_good = curves.pop("good_ref")
    rows.append(csv_row(f"fig3/csr{CSR_GOOD}/mu2_0.0",
                        per_curve / len(acc_good) * 1e6,
                        f"jitter={metrics.jitter(acc_good, tail=12):.4f}"))

    for mu2 in MU2S:
        acc = curves[f"mu2_{mu2}"]
        results[f"mu2_{mu2}"] = {"acc": acc.tolist(),
                                 "jitter": metrics.jitter(acc, tail=12)}

    # --- Fig. 3 row 2: MSE to the centralized reference — one sweep over
    # the (csr, mu2) trio × seeds at the long horizon.
    trio = (("bad_mu2_0", 0.1, 0.0), ("bad_mu2_0.005", 0.1, 0.005),
            ("good", 0.9, 0.0))
    mse_curves, _, _ = run_cells(
        [(f"mse_{tag}", _cell(csr, mu2, rounds=n_rounds or MSE_ROUNDS,
                              seed=seed, local_epochs=2, lr=0.1))
         for tag, csr, mu2 in trio])
    curves.update(mse_curves)
    ref = _centralized_reference(pipe, len(curves["mse_good"]))
    mse_good = metrics.mse_to_reference(curves["mse_good"], ref)
    results["csr_good"] = {"acc": curves["mse_good"].tolist(),
                           "mse": mse_good}
    for tag in ("bad_mu2_0", "bad_mu2_0.005"):
        mse = metrics.mse_to_reference(curves[f"mse_{tag}"], ref)
        results[f"mse_{tag}"] = {"acc": curves[f"mse_{tag}"].tolist(),
                                 "mse": mse}
        rows.append(csv_row(f"fig3/mse/{tag}", 0.0,
                            f"mse={mse:.5f} (good-csr ref mse={mse_good:.5f})"))

    out = os.path.join(RESULTS_DIR, "fig3_mu2_stability.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"pre_acc": pipe.pre_acc, "results": results,
                   "centralized_ref": ref.tolist()}, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
