"""Fig. 3 — mu2 stabilizes federated learning under bad communication.

Row 1: accuracy-curve jitter vs mu2 at low CSR (paper: mu2 = 0.005
suppresses the concussion of the curve).
Row 2: MSE of the testing-accuracy curve to the centralized-learning
reference (paper: with mu2 = 0.005 at CSR = 10% the curve is almost the
same as learning with CSR = 90%).
"""
from __future__ import annotations

import json
import os
import time
from typing import List

import numpy as np

from benchmarks import metrics
from benchmarks.common import (RESULTS_DIR, build_pipeline, csv_row,
                               federated_partition, run_fed_avg_seeds)
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.fedsim.pretrain import train_centralized

MU2S = (0.0, 0.001, 0.005, 0.02)
CSR_BAD = 0.2
CSR_GOOD = 0.9
MU1 = 0.001
LAR = 5
# same drift regime as fig2 — where low CSR makes the curve "concuss"
E, LR = 3, 0.15
N_SEEDS = 2


def _centralized_reference(pipe, n_points: int):
    """Centralized SGD on the pooled fleet data — Fig. 3's reference curve."""
    _, hist = train_centralized(
        pipe.pre_params, pipe.fed_pool, lr=0.1, epochs=2,
        x_test=pipe.test.x, y_test=pipe.test.y, eval_every=25)
    acc = hist["acc"]
    # resample to n_points so curves are comparable round-for-round
    idx = np.linspace(0, len(acc) - 1, n_points).round().astype(int)
    return acc[idx]


def run(n_rounds: int | None = None, seed: int = 0) -> List[str]:
    pipe = build_pipeline(seed)
    federated_partition(2, seed)
    rows: List[str] = []
    results = {}

    curves = {}
    for mu2 in MU2S:
        hp = H2FedParams(mu1=MU1, mu2=mu2, lar=LAR, local_epochs=E, lr=LR)
        het = HeterogeneityModel(csr=CSR_BAD, scd=1, lar=LAR)
        t0 = time.perf_counter()
        _, acc, wall = run_fed_avg_seeds(hp, het, scenario=2,
                                         n_rounds=n_rounds, seed=seed,
                                         n_seeds=N_SEEDS)
        curves[f"mu2_{mu2}"] = acc
        rows.append(csv_row(f"fig3/csr{CSR_BAD}/mu2_{mu2}",
                            wall / len(acc) * 1e6,
                            f"jitter={metrics.jitter(acc, tail=12):.4f}"))

    # the good-communication reference the paper compares against
    hp = H2FedParams(mu1=MU1, mu2=0.0, lar=LAR, local_epochs=E, lr=LR)
    het = HeterogeneityModel(csr=CSR_GOOD, scd=1, lar=LAR)
    _, acc_good, wall = run_fed_avg_seeds(hp, het, scenario=2,
                                          n_rounds=n_rounds, seed=seed,
                                          n_seeds=N_SEEDS)
    rows.append(csv_row(f"fig3/csr{CSR_GOOD}/mu2_0.0",
                        wall / len(acc_good) * 1e6,
                        f"jitter={metrics.jitter(acc_good, tail=12):.4f}"))

    for mu2 in MU2S:
        acc = curves[f"mu2_{mu2}"]
        results[f"mu2_{mu2}"] = {"acc": acc.tolist(),
                                 "jitter": metrics.jitter(acc, tail=12)}

    # --- Fig. 3 row 2: MSE to the centralized reference, in the paper's
    # converging regime (CSR = 10%, long horizon): with mu2 = 0.005 the
    # low-CSR curve should come close to the CSR = 90% one.
    MSE_ROUNDS = 40
    for tag, csr, mu2 in (("bad_mu2_0", 0.1, 0.0),
                          ("bad_mu2_0.005", 0.1, 0.005),
                          ("good", 0.9, 0.0)):
        hp = H2FedParams(mu1=MU1, mu2=mu2, lar=LAR, local_epochs=2, lr=0.1)
        het = HeterogeneityModel(csr=csr, scd=1, lar=LAR)
        _, acc, _ = run_fed_avg_seeds(hp, het, scenario=2,
                                      n_rounds=n_rounds or MSE_ROUNDS,
                                      seed=seed, n_seeds=N_SEEDS)
        curves[f"mse_{tag}"] = acc
    ref = _centralized_reference(pipe, len(curves["mse_good"]))
    mse_good = metrics.mse_to_reference(curves["mse_good"], ref)
    results["csr_good"] = {"acc": curves["mse_good"].tolist(),
                           "mse": mse_good}
    for tag in ("bad_mu2_0", "bad_mu2_0.005"):
        mse = metrics.mse_to_reference(curves[f"mse_{tag}"], ref)
        results[f"mse_{tag}"] = {"acc": curves[f"mse_{tag}"].tolist(),
                                 "mse": mse}
        rows.append(csv_row(f"fig3/mse/{tag}", 0.0,
                            f"mse={mse:.5f} (good-csr ref mse={mse_good:.5f})"))

    out = os.path.join(RESULTS_DIR, "fig3_mu2_stability.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"pre_acc": pipe.pre_acc, "results": results,
                   "centralized_ref": ref.tolist()}, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
