"""Round-latency benchmark for the simulation engines (DESIGN.md §3–4).

Measures one compiled global round of the SAME federated workload under:

  tree     — per-leaf jax.tree.map aggregation (the reference engine)
  flat     — flat-buffer engine: Pallas aggregation matmuls on (A, N)
  sharded  — flat engine with the agent axis shard_map'd over the mesh

and records tree-vs-flat and 1-vs-N-host-device latency into the BENCH json
flow (one record per device count under results/bench/).  Because the device
count must be fixed before jax initializes, the multi-device cells run as
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=N — the
same mechanism launch/dryrun.py uses.

Standalone:
  PYTHONPATH=src python -m benchmarks.sharded_round --devices 8 \
      [--agents 16 --rsus 4 --rounds 2 --out results/bench]

Via the harness (spawns the 1- and 8-device cells):
  PYTHONPATH=src python -m benchmarks.run --only sharded
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import List

DEFAULT_DEVICES = (1, 8)


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = use what's there)")
    ap.add_argument("--agents", type=int, default=40)
    ap.add_argument("--rsus", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=2, help="timed rounds")
    ap.add_argument("--lar", type=int, default=2)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _time_rounds(round_fn, state, n: int) -> float:
    """Mean per-round wall seconds, compile excluded.  Two warmup rounds:
    the first output's device layout differs from the host-built initial
    state, so round 2 triggers a second compile for the steady-state
    signature."""
    import jax
    state = round_fn(round_fn(state))            # compile x2 + warmup
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(n):
        state = round_fn(state)
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) / n


def run_cell(args) -> dict:
    """Benchmark all three engines at the current device count."""
    import jax

    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core import flatten
    from repro.core.baselines import h2fed
    from repro.core.heterogeneity import HeterogeneityModel
    from repro.data.partition import scenario_two
    from repro.data.synthetic import mnist_class_task
    from repro.fedsim import sharded
    from repro.fedsim.simulator import (SimConfig, init_flat_state,
                                        init_state, make_flat_global_round,
                                        make_global_round)
    from repro.models import mlp

    n_dev = len(jax.devices())
    train, _ = mnist_class_task(n_train=args.n_train, n_test=100, seed=0)
    fed = scenario_two(train, n_agents=args.agents, n_rsus=args.rsus, seed=0)
    cfg = SimConfig(n_agents=args.agents, n_rsus=args.rsus, batch=16, seed=0)
    hp = h2fed(mu1=0.01, mu2=0.005, lar=args.lar, lr=0.1)
    het = HeterogeneityModel(csr=0.8, lar=hp.lar)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    spec = flatten.spec_of(params)

    # fresh key per engine: the flat/sharded round jits donate their input
    # state, so a shared key buffer would be consumed by the first engine
    def key():
        return jax.random.key(cfg.seed)

    timings = {}
    # tree reference
    tree_round = make_global_round(cfg, hp, het, fed, engine="tree")
    timings["tree"] = _time_rounds(tree_round,
                                   init_state(cfg, params, key()),
                                   args.rounds)
    # flat Pallas engine
    flat_round = make_flat_global_round(cfg, hp, het, fed, spec)
    timings["flat"] = _time_rounds(
        flat_round, init_flat_state(cfg, spec, params, key()), args.rounds)
    # sharded flat engine over the fleet mesh
    mesh = sharded.make_fleet_mesh()
    sh_round = sharded.make_sharded_global_round(cfg, hp, het, fed, spec,
                                                 mesh)
    with mesh:
        timings["sharded"] = _time_rounds(
            sh_round, init_flat_state(cfg, spec, params, key()),
            args.rounds)

    # compute-vs-collective split: the compute leg is the sharded round's
    # PER-DEVICE workload (A/n_dev agents, same R and N) run through the
    # single-device flat engine — no collectives, same training scan and
    # (R, N) blend.  What the sharded round spends beyond that is its
    # collective + shard_map overhead.  Single-device engines are all
    # compute by construction.
    import dataclasses
    time_split = {e: {"compute_s": timings[e], "collective_s": 0.0}
                  for e in ("tree", "flat")}
    compute_s = timings["sharded"]
    if n_dev > 1:
        a_loc = max(args.agents // n_dev, 1)
        cfg_loc = dataclasses.replace(cfg, n_agents=a_loc)
        fed_loc = dataclasses.replace(
            fed, x=fed.x[:a_loc], y=fed.y[:a_loc],
            n_per_agent=fed.n_per_agent[:a_loc],
            rsu_assign=fed.rsu_assign[:a_loc])
        loc_round = make_flat_global_round(cfg_loc, hp, het, fed_loc, spec)
        compute_s = _time_rounds(
            loc_round, init_flat_state(cfg_loc, spec, params, key()),
            args.rounds)
    coll_s = max(timings["sharded"] - compute_s, 0.0)
    time_split["sharded"] = {
        "compute_s": compute_s, "collective_s": coll_s,
        "collective_frac": coll_s / max(timings["sharded"], 1e-12)}

    return {
        "bench": "sharded_round",
        "n_devices": n_dev,
        "mesh": dict(mesh.shape),
        "n_agents": args.agents,
        "n_rsus": args.rsus,
        "lar": args.lar,
        "n_params": spec.n,
        "round_s": timings,
        "time_split": time_split,
        "flat_vs_tree": timings["tree"] / max(timings["flat"], 1e-12),
        "sharded_vs_flat": timings["flat"] / max(timings["sharded"], 1e-12),
    }


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    d = rec["n_devices"]
    rows = [csv_row(f"sharded_round/{eng}/d{d}", s * 1e6,
                    f"A{rec['n_agents']}xR{rec['n_rsus']}")
            for eng, s in rec["round_s"].items()]
    rows.append(csv_row(f"sharded_round/flat_vs_tree/d{d}",
                        rec["round_s"]["flat"] * 1e6,
                        f"speedup={rec['flat_vs_tree']:.2f}x"))
    sh = rec["time_split"]["sharded"]
    rows.append(csv_row(f"sharded_round/collective_s/d{d}",
                        sh["collective_s"] * 1e6,
                        f"frac={sh.get('collective_frac', 0.0):.2f}"))
    return rows


def run() -> List[str]:
    """Harness entry (benchmarks.run): spawn one subprocess per device
    count so each cell gets a fresh jax with the forced device count."""
    rows: List[str] = []
    here = Path(__file__).resolve().parents[1]
    for n_dev in DEFAULT_DEVICES:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count={n_dev}")
        env["PYTHONPATH"] = str(here / "src") + os.pathsep \
            + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.sharded_round",
             "--devices", str(n_dev)],
            capture_output=True, text=True, timeout=1200, env=env,
            cwd=str(here))
        if out.returncode != 0:
            raise RuntimeError(f"d{n_dev} cell failed:\n{out.stderr[-2000:]}")
        rows.extend(ln for ln in out.stdout.splitlines()
                    if ln.startswith("sharded_round/"))
    return rows


def main():
    args = _parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    rec = run_cell(args)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"sharded_round__d{rec['n_devices']}.json"
    path.write_text(json.dumps(rec, indent=1))
    for row in _csv_rows(rec):
        print(row)
    print(f"[json] {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
