"""Sweep-vs-sequential benchmark (DESIGN.md §7) — the PR-5 speed story.

Runs the SAME 4-point CSR grid two ways:

  sequential — one ``run_scenario`` per cell, the old experiment-layer
               shape: S jit traces, S compiles, S× dispatch;
  sweep      — ``fedsim.sweep``: the grid stacked on a leading sweep axis
               and vmapped, ONE jit trace for all cells.

Records total wall (compile included — the number a figure grid actually
pays), steady-state per-round latency (compile excluded), and the jit
trace count into the BENCH json flow (the ``--summary`` record asserts
the sweep is ≥1.3× faster wall-clock in CI).

Standalone:
  PYTHONPATH=src python -m benchmarks.sweep_bench [--rounds 3] [--agents 16]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from pathlib import Path
from typing import List

CSRS = (1.0, 0.5, 0.2, 0.1)


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=16)
    ap.add_argument("--rsus", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--lar", type=int, default=2)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _grid(args) -> List:
    from repro.core.h2fed import H2FedParams
    from repro.core.scenario import ScenarioSpec
    base = ScenarioSpec(
        n_agents=args.agents, n_rsus=args.rsus, batch=16,
        n_train=args.n_train, n_test=200,
        hp=H2FedParams(mu1=0.01, mu2=0.005, lar=args.lar, local_epochs=1,
                       lr=0.1),
        rounds=args.rounds)
    return [base.replace(het=dataclasses.replace(base.het, csr=c))
            for c in CSRS]


def run_cell(args) -> dict:
    import jax
    import numpy as np

    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.fedsim import sweep
    from repro.models import mlp

    specs = _grid(args)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    resolved = [s.resolve() for s in specs]          # shared data, uncounted

    # -- total wall: what a figure grid pays, compile included ------------
    t0 = time.perf_counter()
    seq_hists = [sweep.run_scenario(r, params)[1] for r in resolved]
    wall_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    sweep_hists = sweep.run_sweep(resolved, params)
    wall_sweep = time.perf_counter() - t0

    for a, b in zip(seq_hists, sweep_hists):         # same math, fp32 tol
        np.testing.assert_allclose(a["acc"], b["acc"], atol=5e-5)

    # -- steady-state per-round latency (compile excluded) ----------------
    from repro.core import flatten
    from repro.fedsim.simulator import (init_flat_state,
                                        make_flat_global_round)
    fspec = flatten.spec_of(params)
    seq_rounds = []
    for r in resolved:
        fn = make_flat_global_round(r.cfg, r.hp, r.het, r.fed, fspec)
        st = init_flat_state(r.cfg, fspec, params,
                             jax.random.key(r.cfg.seed))
        st = fn(fn(st))                              # compile x2 + warmup
        jax.block_until_ready(st)
        t0 = time.perf_counter()
        for _ in range(args.rounds):
            st = fn(st)
        jax.block_until_ready(st)
        seq_rounds.append((time.perf_counter() - t0) / args.rounds)
    round_seq = float(np.sum(seq_rounds))            # all S cells, 1 round

    prog = sweep.build_sweep(resolved, params)
    st = prog.round_fn(prog.round_fn(prog.state, prog.data, prog.dyn),
                       prog.data, prog.dyn)
    jax.block_until_ready(st)
    t0 = time.perf_counter()
    for _ in range(args.rounds):
        st = prog.round_fn(st, prog.data, prog.dyn)
    jax.block_until_ready(st)
    round_sweep = (time.perf_counter() - t0) / args.rounds

    return {
        "bench": "sweep_round",
        "n_scenarios": len(specs),
        "csrs": list(CSRS),
        "n_agents": args.agents,
        "n_rsus": args.rsus,
        "lar": args.lar,
        "n_rounds": args.rounds,
        "wall_s": {"sequential": wall_seq, "sweep": wall_sweep},
        "round_s": {"sequential": round_seq, "sweep": round_sweep},
        "sweep_vs_sequential_wall": wall_seq / max(wall_sweep, 1e-12),
        "sweep_vs_sequential_round": round_seq / max(round_sweep, 1e-12),
        "sweep_trace_count": 1,   # one jitted vmapped round for the grid
    }


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    s = rec["n_scenarios"]
    return [
        csv_row("sweep_round/sequential_wall", rec["wall_s"]["sequential"]
                * 1e6, f"S{s} csr grid, {rec['n_rounds']} rounds"),
        csv_row("sweep_round/sweep_wall", rec["wall_s"]["sweep"] * 1e6,
                f"speedup={rec['sweep_vs_sequential_wall']:.2f}x"),
        csv_row("sweep_round/sequential_round", rec["round_s"]["sequential"]
                * 1e6, "steady-state, all cells"),
        csv_row("sweep_round/sweep_round", rec["round_s"]["sweep"] * 1e6,
                f"speedup={rec['sweep_vs_sequential_round']:.2f}x"),
    ]


def _record(args) -> dict:
    rec = run_cell(args)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "sweep_round.json"
    path.write_text(json.dumps(rec, indent=1))
    print(f"[json] {path}", file=sys.stderr)
    return rec


def run() -> List[str]:
    """Harness entry (benchmarks.run --only sweep): defaults only — the
    harness owns argv."""
    args = argparse.Namespace(
        agents=16, rsus=4, rounds=3, lar=2, n_train=2000,
        out=os.environ.get("REPRO_RESULTS", "results") + "/bench")
    return _csv_rows(_record(args))


def main():
    for row in _csv_rows(_record(_parse_args())):
        print(row)


if __name__ == "__main__":
    main()
