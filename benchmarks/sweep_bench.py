"""Sweep-vs-sequential benchmark (DESIGN.md §7) — the PR-5 speed story,
extended with the PR-8 compile-time story (DESIGN.md §10).

Runs the SAME 4-point CSR grid two ways:

  sequential — one ``run_scenario`` per cell, the old experiment-layer
               shape: S jit traces, S compiles, S× dispatch;
  sweep      — ``fedsim.sweep``: the grid stacked on a leading sweep axis
               and vmapped, ONE jit trace for all cells.

Records total wall (compile included — the number a figure grid actually
pays), steady-state per-round latency (compile excluded), and the jit
trace count into the BENCH json flow (the ``--summary`` record asserts
the sweep is ≥1.3× faster wall-clock in CI).

Two PR-8 cells ride in the same record:

  mixed_cadence — a lar × local_epochs × cloud_every async grid that the
                  widened static_key keeps in ONE group: walls, actual
                  trace count (``core.program_cache`` counters; CI pins 1)
                  and equivalence vs sequential;
  cold_warm     — the same small grid run in two fresh subprocesses
                  sharing one ``REPRO_CACHE_DIR``: the first pays XLA
                  compilation and populates the persistent cache, the
                  second loads from disk — ``cold_vs_warm_wall`` is the
                  ratio CI asserts ≥ 2×.

Standalone:
  PYTHONPATH=src python -m benchmarks.sweep_bench [--rounds 3] [--agents 16]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import subprocess
import sys
import tempfile
import textwrap
import time
from pathlib import Path
from typing import List

CSRS = (1.0, 0.5, 0.2, 0.1)
CADENCES = ((2, 1, 0), (3, 2, 2), (1, 2, 3))   # (lar, local_epochs, ce)


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=16)
    ap.add_argument("--rsus", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--lar", type=int, default=2)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _grid(args) -> List:
    from repro.core.h2fed import H2FedParams
    from repro.core.scenario import ScenarioSpec
    base = ScenarioSpec(
        n_agents=args.agents, n_rsus=args.rsus, batch=16,
        n_train=args.n_train, n_test=200,
        hp=H2FedParams(mu1=0.01, mu2=0.005, lar=args.lar, local_epochs=1,
                       lr=0.1),
        rounds=args.rounds)
    return [base.replace(het=dataclasses.replace(base.het, csr=c))
            for c in CSRS]


def _mixed_grid(args) -> List:
    """lar × local_epochs × cloud_every all varying in ONE async group —
    pre-PR-8 this grid was 3 groups (3 traces, 3 compiles)."""
    from repro.core.h2fed import H2FedParams
    from repro.core.heterogeneity import HeterogeneityModel
    from repro.core.scenario import ScenarioSpec
    base = ScenarioSpec(
        n_agents=args.agents, n_rsus=args.rsus, batch=16,
        n_train=args.n_train, n_test=200, engine="async",
        het=HeterogeneityModel(csr=0.8, scd=1, max_delay=2, delay_p=0.4),
        staleness_decay=0.6, buffer_keep=0.25,
        hp=H2FedParams(mu1=0.01, mu2=0.005, lar=2, local_epochs=1, lr=0.1),
        rounds=args.rounds)
    return [base.replace(
        hp=dataclasses.replace(base.hp, lar=l, local_epochs=e),
        cloud_every=ce) for (l, e, ce) in CADENCES]


def run_cell(args) -> dict:
    import jax
    import numpy as np

    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core import program_cache
    from repro.fedsim import sweep
    from repro.models import mlp

    specs = _grid(args)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    resolved = [s.resolve() for s in specs]          # shared data, uncounted
    program_cache.clear()                            # honest trace counts

    # -- total wall: what a figure grid pays, compile included ------------
    t0 = time.perf_counter()
    seq_hists = [sweep.run_scenario(r, params)[1] for r in resolved]
    wall_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    sweep_hists = sweep.run_sweep(resolved, params)
    wall_sweep = time.perf_counter() - t0
    sweep_traces = program_cache.trace_count("sweep_round")

    for a, b in zip(seq_hists, sweep_hists):         # same math, fp32 tol
        np.testing.assert_allclose(a["acc"], b["acc"], atol=5e-5)

    # -- steady-state per-round latency (compile excluded) ----------------
    from repro.core import flatten
    from repro.fedsim.simulator import (init_flat_state,
                                        make_flat_global_round)
    fspec = flatten.spec_of(params)
    seq_rounds = []
    for r in resolved:
        fn = make_flat_global_round(r.cfg, r.hp, r.het, r.fed, fspec)
        st = init_flat_state(r.cfg, fspec, params,
                             jax.random.key(r.cfg.seed))
        st = fn(fn(st))                              # compile x2 + warmup
        jax.block_until_ready(st)
        t0 = time.perf_counter()
        for _ in range(args.rounds):
            st = fn(st)
        jax.block_until_ready(st)
        seq_rounds.append((time.perf_counter() - t0) / args.rounds)
    round_seq = float(np.sum(seq_rounds))            # all S cells, 1 round

    prog = sweep.build_sweep(resolved, params)
    st = prog.round_fn(prog.round_fn(prog.state, prog.data, prog.dyn),
                       prog.data, prog.dyn)
    jax.block_until_ready(st)
    t0 = time.perf_counter()
    for _ in range(args.rounds):
        st = prog.round_fn(st, prog.data, prog.dyn)
    jax.block_until_ready(st)
    round_sweep = (time.perf_counter() - t0) / args.rounds

    return {
        "bench": "sweep_round",
        "n_scenarios": len(specs),
        "csrs": list(CSRS),
        "n_agents": args.agents,
        "n_rsus": args.rsus,
        "lar": args.lar,
        "n_rounds": args.rounds,
        "wall_s": {"sequential": wall_seq, "sweep": wall_sweep},
        "round_s": {"sequential": round_seq, "sweep": round_sweep},
        "sweep_vs_sequential_wall": wall_seq / max(wall_sweep, 1e-12),
        "sweep_vs_sequential_round": round_seq / max(round_sweep, 1e-12),
        "sweep_trace_count": sweep_traces,
    }


def run_mixed(args) -> dict:
    """The mixed-cadence cell: one group, one trace, sequential-equal."""
    import jax
    import numpy as np

    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core import program_cache
    from repro.fedsim import sweep
    from repro.models import mlp

    specs = _mixed_grid(args)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    resolved = [s.resolve() for s in specs]

    t0 = time.perf_counter()
    seq = [sweep.run_scenario(r, params)[1] for r in resolved]
    wall_seq = time.perf_counter() - t0

    program_cache.clear()
    t0 = time.perf_counter()
    hists = sweep.run_scenarios(specs, params)
    wall_sweep = time.perf_counter() - t0
    traces = program_cache.trace_count("sweep_round")

    diff = max(float(np.max(np.abs(a["acc"] - b["acc"])))
               for a, b in zip(seq, hists))
    assert diff <= 5e-5, f"mixed-cadence sweep diverged: {diff}"
    return {
        "cadences": [list(c) for c in CADENCES],
        "wall_s": {"sequential": wall_seq, "sweep": wall_sweep},
        "mixed_cadence_vs_sequential_wall":
            wall_seq / max(wall_sweep, 1e-12),
        "trace_count": traces,
        "max_abs_acc_diff": diff,
    }


_COLD_WARM_CHILD = textwrap.dedent("""
    import dataclasses, json, sys, time
    import jax
    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core.h2fed import H2FedParams
    from repro.core.heterogeneity import HeterogeneityModel
    from repro.core.scenario import ScenarioSpec
    from repro.fedsim import sweep
    from repro.models import mlp

    # the async mixed-cadence grid: the compile-heaviest one-trace program
    # (tick scan + staleness buffers), so the measured wall is dominated by
    # exactly the compilation the persistent cache elides
    agents, rounds = int(sys.argv[1]), int(sys.argv[2])
    base = ScenarioSpec(
        n_agents=agents, n_rsus=4, batch=16, n_train=400, n_test=100,
        engine="async",
        het=HeterogeneityModel(csr=0.8, scd=1, max_delay=2, delay_p=0.4),
        staleness_decay=0.6, buffer_keep=0.25,
        hp=H2FedParams(mu1=0.01, mu2=0.005, lar=2, local_epochs=1, lr=0.1),
        rounds=rounds)
    specs = [base.replace(
        hp=dataclasses.replace(base.hp, lar=l, local_epochs=e),
        cloud_every=ce) for (l, e, ce) in ((2, 1, 0), (3, 2, 2), (1, 2, 3))]
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    [s.resolve() for s in specs]              # data generation, uncounted
    t0 = time.perf_counter()
    hists = sweep.run_scenarios(specs, params)
    print(json.dumps({"wall": time.perf_counter() - t0,
                      "acc": float(hists[0]["acc"][-1])}))
""")


def run_cold_warm(args) -> dict:
    """Persistent-compilation-cache story: the same sweep in two fresh
    processes sharing one ``REPRO_CACHE_DIR``.  The first (cold) pays XLA
    compilation and writes the disk cache; the second (warm) re-traces but
    loads the compiled executables.  The cache dir is wiped first so the
    cold run is genuinely cold even under CI's restored cache volume."""
    cache_dir = Path(tempfile.mkdtemp(prefix="repro-coldwarm-"))
    env = dict(os.environ, REPRO_CACHE_DIR=str(cache_dir))
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    walls, accs = [], []
    try:
        for _ in ("cold", "warm"):
            out = subprocess.run(     # 1 round: the wall IS compile time
                [sys.executable, "-c", _COLD_WARM_CHILD,
                 str(args.agents), "1"],
                env=env, capture_output=True, text=True, check=True)
            rec = json.loads(out.stdout.strip().splitlines()[-1])
            walls.append(rec["wall"])
            accs.append(rec["acc"])
        entries = sum(1 for _ in cache_dir.iterdir())
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    assert accs[0] == accs[1], "cached program changed the math"
    return {
        "cold_s": walls[0],
        "warm_s": walls[1],
        "cold_vs_warm_wall": walls[0] / max(walls[1], 1e-12),
        "cache_entries": entries,
    }


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    s = rec["n_scenarios"]
    rows = [
        csv_row("sweep_round/sequential_wall", rec["wall_s"]["sequential"]
                * 1e6, f"S{s} csr grid, {rec['n_rounds']} rounds"),
        csv_row("sweep_round/sweep_wall", rec["wall_s"]["sweep"] * 1e6,
                f"speedup={rec['sweep_vs_sequential_wall']:.2f}x"),
        csv_row("sweep_round/sequential_round", rec["round_s"]["sequential"]
                * 1e6, "steady-state, all cells"),
        csv_row("sweep_round/sweep_round", rec["round_s"]["sweep"] * 1e6,
                f"speedup={rec['sweep_vs_sequential_round']:.2f}x"),
    ]
    mc, cw = rec.get("mixed_cadence"), rec.get("cold_warm")
    if mc:
        rows += [
            csv_row("sweep_round/mixed_cadence_wall",
                    mc["wall_s"]["sweep"] * 1e6,
                    f"traces={mc['trace_count']} "
                    f"speedup={mc['mixed_cadence_vs_sequential_wall']:.2f}x"),
        ]
    if cw:
        rows += [
            csv_row("sweep_round/cold_wall", cw["cold_s"] * 1e6,
                    "fresh process, empty REPRO_CACHE_DIR"),
            csv_row("sweep_round/warm_wall", cw["warm_s"] * 1e6,
                    f"cold/warm={cw['cold_vs_warm_wall']:.2f}x"),
        ]
    return rows


def _record(args) -> dict:
    rec = run_cell(args)
    rec["mixed_cadence"] = run_mixed(args)
    rec["cold_warm"] = run_cold_warm(args)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "sweep_round.json"
    path.write_text(json.dumps(rec, indent=1))
    print(f"[json] {path}", file=sys.stderr)
    return rec


def run() -> List[str]:
    """Harness entry (benchmarks.run --only sweep): defaults only — the
    harness owns argv."""
    args = argparse.Namespace(
        agents=16, rsus=4, rounds=3, lar=2, n_train=2000,
        out=os.environ.get("REPRO_RESULTS", "results") + "/bench")
    return _csv_rows(_record(args))


def main():
    for row in _csv_rows(_record(_parse_args())):
        print(row)


if __name__ == "__main__":
    main()
