"""Pallas-kernel microbenchmarks.

On this CPU container the kernels execute in interpret mode, so wall time
is NOT a TPU prediction — the derived column therefore reports the jnp
oracle's wall time (the deploy path on CPU) and the max|Δ| between kernel
and oracle, proving the kernels are drop-in.  Shapes chosen at the paper's
working point (130 kB MLP fleet) and one transformer-block-sized case.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.kernels import ops, ref


def _timeit(fn, *args, n=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n, out


def run() -> List[str]:
    rows: List[str] = []
    key = jax.random.key(0)

    # --- dual_proximal_sgd: the paper's Eq. 6 inner update, fused ---------
    for n in (32_768, 1 << 20):
        ks = jax.random.split(key, 4)
        w, g, a1, a2 = (jax.random.normal(k, (n,), jnp.float32) for k in ks)
        kern = jax.jit(lambda w, g, a1, a2: ops.dual_proximal_sgd(
            w, g, a1, a2, lr=0.05, mu1=0.01, mu2=0.005))
        orac = jax.jit(lambda w, g, a1, a2: ref.dual_proximal_sgd_ref(
            w, g, a1, a2, lr=0.05, mu1=0.01, mu2=0.005))
        tk, yk = _timeit(kern, w, g, a1, a2)
        tr, yr = _timeit(orac, w, g, a1, a2)
        err = float(jnp.max(jnp.abs(yk - yr)))
        rows.append(csv_row(f"kernels/dual_proximal_sgd/n{n}", tr * 1e6,
                            f"interp_us={tk*1e6:.0f} maxerr={err:.2e}"))

    # --- masked_hier_agg: CSR-masked weighted RSU aggregation -------------
    A, R, D = 100, 10, 31_810          # the paper's fleet x 130 kB model
    ks = jax.random.split(key, 3)
    stacked = jax.random.normal(ks[0], (A, D), jnp.float32)
    weights = jax.random.uniform(ks[1], (A,), jnp.float32)
    mask = (jax.random.uniform(ks[2], (A,)) < 0.5).astype(jnp.float32)
    assign = jnp.arange(A, dtype=jnp.int32) % R
    # call the kernel module directly: off-TPU the ops facade routes this
    # aggregation to the XLA dot (the deploy path); the microbench's job is
    # the kernel itself — Mosaic on TPU, interpret elsewhere.
    from repro.kernels import masked_hier_agg as mha
    interp = jax.default_backend() != "tpu"
    kern = jax.jit(lambda s, w, m: mha.masked_hier_agg(s, w, m, assign, R,
                                                       interpret=interp))
    orac = jax.jit(lambda s, w, m: ref.masked_hier_agg_ref(s, w, m, assign, R))
    tk, yk = _timeit(kern, stacked, weights, mask)
    tr, yr = _timeit(orac, stacked, weights, mask)
    err = float(jnp.max(jnp.abs(yk[0] - yr[0])))
    rows.append(csv_row(f"kernels/masked_hier_agg/A{A}xD{D}", tr * 1e6,
                        f"interp_us={tk*1e6:.0f} maxerr={err:.2e}"))

    # --- fused aggregate-and-blend (one-pass rounds, DESIGN.md §3) --------
    from repro.launch.hlo_analysis import round_cost
    prev = jax.random.normal(jax.random.key(7), (R, D), jnp.float32)
    kern = jax.jit(lambda s, w, m, p: mha.agg_blend(s, w, m, assign, R, p,
                                                    interpret=interp))
    orac = jax.jit(lambda s, w, m, p: ref.agg_blend_ref(s, w, m, assign,
                                                        R, p))
    tk, yk = _timeit(kern, stacked, weights, mask, prev)
    tr, yr = _timeit(orac, stacked, weights, mask, prev)
    err = float(jnp.max(jnp.abs(yk[0] - yr[0])))
    mb = round_cost(orac, stacked, weights, mask, prev)["bytes"] / 1e6
    rows.append(csv_row(f"kernels/agg_blend/A{A}xD{D}", tr * 1e6,
                        f"interp_us={tk*1e6:.0f} maxerr={err:.2e} "
                        f"mb={mb:.1f}"))

    # --- fused scatter-absorb: the semi-async tick's RSU layer ------------
    ks2 = jax.random.split(jax.random.key(9), 3)
    pend = jax.random.normal(ks2[0], (A, D), jnp.float32)
    w_due = jax.random.uniform(ks2[1], (A,), jnp.float32) \
        * (jax.random.uniform(ks2[2], (A,)) < 0.4)
    bmass = jnp.abs(weights[:R]) * 3.0
    w_imm = weights * mask
    # operands passed as jit ARGUMENTS (not closed-over constants) so the
    # compiled program matches what the engines run — nothing folds away
    kern = jax.jit(lambda s, wi, p, wd, pr, bm: mha.agg_absorb(
        ((s, wi), (p, wd)), assign, R, pr, bm, keep=0.5, interpret=interp))
    orac = jax.jit(lambda s, wi, p, wd, pr, bm: ref.agg_absorb_ref(
        ((s, wi), (p, wd)), assign, R, pr, bm, keep=0.5))
    tk, yk = _timeit(kern, stacked, w_imm, pend, w_due, prev, bmass)
    tr, yr = _timeit(orac, stacked, w_imm, pend, w_due, prev, bmass)
    err = float(jnp.max(jnp.abs(yk[0] - yr[0])))
    mb = round_cost(orac, stacked, w_imm, pend, w_due, prev,
                    bmass)["bytes"] / 1e6
    rows.append(csv_row(f"kernels/agg_absorb/A{A}x2xD{D}", tr * 1e6,
                        f"interp_us={tk*1e6:.0f} maxerr={err:.2e} "
                        f"mb={mb:.1f}"))

    # --- flash_attention: chunked online-softmax prefill -------------------
    B, H, S, P = 1, 4, 512, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, S, P), jnp.float32) * P ** -0.5
    k_ = jax.random.normal(ks[1], (B, H, S, P), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, P), jnp.float32)
    kern = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, causal=True))
    orac = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v,
                                                           causal=True))
    tk, yk = _timeit(kern, q, k_, v, n=1)
    tr, yr = _timeit(orac, q, k_, v)
    err = float(jnp.max(jnp.abs(yk - yr)))
    rows.append(csv_row(f"kernels/flash_attention/B{B}H{H}S{S}P{P}", tr * 1e6,
                        f"interp_us={tk*1e6:.0f} maxerr={err:.2e}"))

    # --- slstm_scan: fused recurrent scan, weights VMEM-resident -----------
    B, S, H, P = 2, 256, 4, 64
    d = H * P
    ks = jax.random.split(key, 3)
    wx = jax.random.normal(ks[0], (B, S, 4 * d), jnp.float32)
    r = jax.random.normal(ks[1], (H, P, 4 * P), jnp.float32) * P ** -0.5
    bg = jax.random.normal(ks[2], (4 * d,), jnp.float32) * 0.1
    kern = jax.jit(lambda wx, r, bg: ops.slstm_scan(wx, r, bg, block_s=64))
    orac = jax.jit(lambda wx, r, bg: ref.slstm_scan_ref(wx, r, bg))
    tk, yk = _timeit(kern, wx, r, bg, n=1)
    tr, yr = _timeit(orac, wx, r, bg)
    err = float(jnp.max(jnp.abs(yk - yr)))
    rows.append(csv_row(f"kernels/slstm_scan/B{B}S{S}d{d}", tr * 1e6,
                        f"interp_us={tk*1e6:.0f} maxerr={err:.2e}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
