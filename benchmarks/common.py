"""Shared experiment pipeline for the paper-figure benchmarks.

Builds (once, cached on disk) the paper's Sec.-VI setup:
  dataset -> OEM pretrain pool (labels 6-9 excluded) -> pre-trained model
  at ~68% test accuracy -> federated fleet partitions (Scenario I / II).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Tuple

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.data.partition import (FederatedData, pretrain_split, scenario_one,
                                  scenario_two)
from repro.data.synthetic import Dataset, mnist_class_task
from repro.fedsim.pretrain import pretrain_to_target
from repro.fedsim.simulator import SimConfig, run_simulation
from repro.models import mlp

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")
# "the first 10 agents exclude a few labels" (Sec. VI).  Excluding 3 of 10
# classes ceilings the biased model at ~70%, making the paper's 68%
# pre-trained accuracy reachable; 4 exclusions would cap it at 60%.
EXCLUDED_LABELS = (7, 8, 9)

# Fast mode (CI-scale) vs full mode (paper-scale).  REPRO_BENCH_FULL=1
# switches to the paper's 100 agents x 10 RSUs.
FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
N_AGENTS = 100 if FULL else 40
N_RSUS = 10 if FULL else 8
N_TRAIN = 22_000 if FULL else 9_000
N_TEST = 4_000 if FULL else 1_500
N_ROUNDS = 60 if FULL else 24


@dataclasses.dataclass(frozen=True)
class Pipeline:
    train: Dataset
    test: Dataset
    fed_pool: Dataset           # public-fleet pool (pre-partition)
    pre_params: dict            # the biased pre-trained model (the "68%")
    pre_acc: float


_CACHE: Dict[str, object] = {}


def build_pipeline(seed: int = 0) -> Pipeline:
    if "pipe" in _CACHE:
        return _CACHE["pipe"]  # type: ignore[return-value]
    ck_dir = os.path.join(RESULTS_DIR, "bench_cache",
                          f"pretrain_{N_TRAIN}_{seed}")
    # noise=0.8 puts the task in the paper's regime: the biased pre-trained
    # model sits at ~0.67, heterogeneous federated training is unstable
    # enough that the proximal terms visibly matter, ceiling ~0.95.
    train, test = mnist_class_task(n_train=N_TRAIN, n_test=N_TEST,
                                   noise=0.8, seed=seed)
    pre_ds, fed_pool = pretrain_split(train, EXCLUDED_LABELS, frac=0.12,
                                      seed=seed)
    if ckpt.latest_step(ck_dir) is not None:
        blob = ckpt.restore(ck_dir)
        pre_params, pre_acc = blob["params"], float(blob["acc"])
    else:
        params = mlp.init_params(MLP_CFG, jax.random.key(seed))
        pre_params, pre_acc = pretrain_to_target(
            params, pre_ds, test.x, test.y, target_acc=0.68, max_epochs=40,
            seed=seed)
        ckpt.save(ck_dir, 0, {"params": pre_params, "acc": np.float32(pre_acc)})
    pipe = Pipeline(train=train, test=test, fed_pool=fed_pool,
                    pre_params=pre_params, pre_acc=pre_acc)
    _CACHE["pipe"] = pipe
    return pipe


def federated_partition(scenario: int, seed: int = 0) -> FederatedData:
    key = f"fed_{scenario}_{seed}"
    if key not in _CACHE:
        pipe = build_pipeline(seed)
        fn = scenario_one if scenario == 1 else scenario_two
        _CACHE[key] = fn(pipe.fed_pool, n_agents=N_AGENTS, n_rsus=N_RSUS,
                         seed=seed)
    return _CACHE[key]  # type: ignore[return-value]


def run_fed(hp: H2FedParams, het: HeterogeneityModel, *, scenario: int = 2,
            n_rounds: int = None, seed: int = 0, sim_seed: int = 0
            ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Run one federated experiment; returns (rounds, accs, wall_s).

    ``seed`` fixes the data/partition/pretrain; ``sim_seed`` varies only the
    connectivity/FSR draws so seed-averaged comparisons share the dataset.
    """
    pipe = build_pipeline(seed)
    fed = federated_partition(scenario, seed)
    cfg = SimConfig(n_agents=N_AGENTS, n_rsus=N_RSUS, batch=32,
                    seed=seed * 1000 + sim_seed)
    t0 = time.perf_counter()
    _, hist = run_simulation(cfg, hp, het, fed, pipe.pre_params,
                             n_rounds or N_ROUNDS,
                             x_test=pipe.test.x, y_test=pipe.test.y)
    wall = time.perf_counter() - t0
    return hist["round"], hist["acc"], wall


def run_fed_avg_seeds(hp: H2FedParams, het: HeterogeneityModel, *,
                      scenario: int = 2, n_rounds: int = None, seed: int = 0,
                      n_seeds: int = 2):
    """Seed-averaged accuracy curve over connectivity realizations."""
    curves, wall = [], 0.0
    for s in range(n_seeds):
        r, acc, w = run_fed(hp, het, scenario=scenario, n_rounds=n_rounds,
                            seed=seed, sim_seed=s)
        curves.append(acc)
        wall += w
    return r, np.mean(np.stack(curves), axis=0), wall


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
