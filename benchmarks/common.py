"""Shared experiment pipeline for the paper-figure benchmarks.

Every figure cell is a declarative ``core.scenario.ScenarioSpec``
(DESIGN.md §7); this module only provides

  * ``base_spec()`` — the paper's Sec.-VI setup at bench scale (fast
    CI-scale by default; ``REPRO_BENCH_FULL=1`` switches to the paper's
    100 agents × 10 RSUs — read at call time, not import time),
  * ``build_pipeline(spec)`` — the OEM pretrain stage (dataset → label-
    excluded pretrain pool → ~68% biased model), disk- and memory-cached
    per ``spec.dataset_key`` so a second seed can never be served the
    first seed's model (the old ``_CACHE["pipe"]`` bug),
  * ``run_fed`` / ``run_fed_avg_seeds`` / ``run_specs`` — thin wrappers
    over ``fedsim.sweep``: grids and seed-averages run as ONE vmapped
    sweep program instead of sequential Python loops.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core.scenario import ScenarioSpec
from repro.data.synthetic import Dataset
from repro.fedsim import sweep
from repro.fedsim.pretrain import pretrain_to_target
from repro.models import mlp

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def bench_scale() -> Dict[str, int]:
    """Fast (CI) vs full (paper) experiment scale — read per call so
    ``REPRO_BENCH_FULL`` can be set after import (examples do)."""
    full = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
    return dict(n_agents=100 if full else 40,
                n_rsus=10 if full else 8,
                n_train=22_000 if full else 9_000,
                n_test=4_000 if full else 1_500,
                rounds=60 if full else 24)


def base_spec(**overrides) -> ScenarioSpec:
    """The paper's Sec.-VI experiment cell at bench scale.

    noise=0.8 puts the task in the paper's regime: the biased pre-trained
    model sits at ~0.67, heterogeneous federated training is unstable
    enough that the proximal terms visibly matter, ceiling ~0.95.
    Excluding 3 of 10 classes ("the first 10 agents exclude a few labels",
    Sec. VI) ceilings the biased model at ~70%, making the paper's 68%
    pre-trained accuracy reachable; 4 exclusions would cap it at 60%.
    """
    kw = dict(bench_scale(), batch=32, noise=0.8,
              excluded_labels=(7, 8, 9), pretrain_frac=0.12,
              pretrain_target=0.68, partition="scenario_two")
    kw.update(overrides)
    return ScenarioSpec(**kw).validate()


@dataclasses.dataclass(frozen=True)
class Pipeline:
    train: Dataset
    test: Dataset
    fed_pool: Dataset           # public-fleet pool (pre-partition)
    pre_params: dict            # the biased pre-trained model (the "68%")
    pre_acc: float


_PIPE_CACHE: Dict[str, Pipeline] = {}


def build_pipeline(spec: ScenarioSpec) -> Pipeline:
    """Dataset + OEM-pretrained model for a spec, cached (memory + disk)
    per ``spec.dataset_key`` — specs differing only in het/hp/engine share
    it; specs differing in seed or data shape never alias."""
    dk = spec.dataset_key
    if dk in _PIPE_CACHE:
        return _PIPE_CACHE[dk]
    res = spec.resolve()
    ck_dir = os.path.join(RESULTS_DIR, "bench_cache", f"pretrain_{dk}")
    if ckpt.latest_step(ck_dir) is not None:
        blob = ckpt.restore(ck_dir)
        pre_params, pre_acc = blob["params"], float(blob["acc"])
    else:
        params = mlp.init_params(MLP_CFG, jax.random.key(spec.seed))
        pre_params, pre_acc = pretrain_to_target(
            params, res.pretrain_pool, res.test.x, res.test.y,
            target_acc=spec.pretrain_target, max_epochs=40, seed=spec.seed)
        ckpt.save(ck_dir, 0, {"params": pre_params,
                              "acc": np.float32(pre_acc)})
    pipe = Pipeline(train=res.train, test=res.test, fed_pool=res.fed_pool,
                    pre_params=pre_params, pre_acc=pre_acc)
    _PIPE_CACHE[dk] = pipe
    return pipe


def pretrained_params(spec: ScenarioSpec) -> dict:
    """``init_params`` hook for ``fedsim.sweep.run_scenarios``."""
    return build_pipeline(spec).pre_params


def run_fed(spec: ScenarioSpec) -> Tuple[np.ndarray, np.ndarray, float]:
    """Run one scenario from the pretrained model; returns
    (rounds, accs, wall_s).  ``spec.seed`` fixes data/partition/pretrain;
    ``spec.sim_seed`` varies only the connectivity/FSR draws so
    seed-averaged comparisons share the dataset."""
    pre = pretrained_params(spec)
    t0 = time.perf_counter()
    _, hist = sweep.run_scenario(spec.resolve(), pre)
    wall = time.perf_counter() - t0
    return hist["round"], hist["acc"], wall


def run_specs(specs: Sequence[ScenarioSpec], *, max_sweep: int = 16,
              ) -> Tuple[List[Dict[str, np.ndarray]], float]:
    """Run a grid of specs through the sweep engine (one compiled program
    per static-compatible group); returns (histories in input order,
    total wall seconds).  Pretrained models resolve per dataset_key."""
    pres = [pretrained_params(s) for s in specs]   # outside the timed wall
    t0 = time.perf_counter()
    hists = sweep.run_scenarios(list(specs), pres, max_sweep=max_sweep)
    return hists, time.perf_counter() - t0


def seed_variants(spec: ScenarioSpec, n_seeds: int) -> List[ScenarioSpec]:
    """The spec's seed-average family: n_seeds consecutive connectivity
    realizations STARTING at the spec's own sim_seed (so two families with
    different base sim_seeds stay independent)."""
    return [spec.replace(sim_seed=spec.sim_seed + s) for s in range(n_seeds)]


def run_cells(cells: Sequence[Tuple], *, max_sweep: int = 16,
              ) -> Tuple[Dict, np.ndarray, float]:
    """Run labeled grid cells — ``cells`` is ``[(label, [spec, ...])]``
    with one spec per seed — through ONE ``run_specs`` call and seed-mean
    each cell.  Returns ({label: mean acc curve}, rounds, wall seconds).

    Figures consume results by LABEL, so the grid's declaration order is
    not an implicit contract between builder and consumer.
    """
    flat = [s for _, specs in cells for s in specs]
    assert len({(s.rounds, s.eval_every) for s in flat}) == 1, \
        "run_cells cells must share one eval grid (split mixed-horizon " \
        "grids into separate calls so the returned rounds match every cell)"
    hists, wall = run_specs(flat, max_sweep=max_sweep)
    out, i, rounds = {}, 0, None
    for label, specs in cells:
        cell = hists[i:i + len(specs)]
        i += len(specs)
        out[label] = np.mean(np.stack([h["acc"] for h in cell]), axis=0)
        rounds = cell[0]["round"]
    return out, rounds, wall


def run_fed_avg_seeds(spec: ScenarioSpec, *, n_seeds: int = 2,
                      ) -> Tuple[np.ndarray, np.ndarray, float]:
    """Seed-averaged accuracy curve over connectivity realizations — the
    S-seed Python loop of old, now ONE vmapped sweep."""
    curves, rounds, wall = run_cells([("cell", seed_variants(spec, n_seeds))])
    return rounds, curves["cell"], wall


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
