"""Fig. 2 — AED (accuracy-enhancement degree, Eq. 7) of mu1 > 0 under
heterogeneous communication quality (CSR sweep), for fixed mu2 values.

Paper claims reproduced here:
  * AED is overall positive after convergence at CSR = 100%;
  * AED grows markedly as CSR drops (up to ~20% at CSR = 20%);
  * increasing mu1 raises AED;
  * positive mu2 reduces AED somewhat (the stability/accuracy trade-off).

The whole (CSR × mu2 × mu1 × seed) grid is declared as ``ScenarioSpec``s
and executed through the vmapped sweep engine (``fedsim/sweep``): every
cell differs only in batched scalars, so the grid compiles ONCE.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from benchmarks import metrics
from benchmarks.common import RESULTS_DIR, base_spec, bench_scale, \
    build_pipeline, csv_row, run_cells, seed_variants
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel

MU1S = (0.0, 0.001, 0.004, 0.007)
MU2S = (0.0, 0.001)
CSRS = (1.0, 0.5, 0.2)
LAR = 5
TAIL = 8   # rounds averaged for the "after convergence" accuracy
# Drift regime (E=3 local epochs, lr=0.15): local training drifts far enough
# per LAR round that the paper-scale mu1 pulls visibly matter — matching the
# paper's long-horizon dynamics (thousands of sidelink rounds) at CPU scale.
E, LR = 3, 0.15
N_SEEDS = 3


def grid(n_rounds: int | None = None, seed: int = 0) -> List:
    """The figure's grid as labeled cells: ((csr, mu2, mu1), seed specs)."""
    rounds = n_rounds or bench_scale()["rounds"]
    return [((csr, mu2, mu1), seed_variants(base_spec(
        hp=H2FedParams(mu1=mu1, mu2=mu2, lar=LAR, local_epochs=E, lr=LR),
        het=HeterogeneityModel(csr=csr, scd=1, lar=LAR),
        rounds=rounds, seed=seed), N_SEEDS))
        for csr in CSRS for mu2 in MU2S for mu1 in MU1S]


def run(n_rounds: int | None = None, seed: int = 0) -> List[str]:
    cells = grid(n_rounds, seed)
    pipe = build_pipeline(cells[0][1][0])
    curves, _, wall = run_cells(cells)
    per_cell = wall / max(len(cells), 1)

    rows: List[str] = []
    grid_out: Dict[str, Dict] = {}
    for csr in CSRS:
        for mu2 in MU2S:
            accs = {}
            for mu1 in MU1S:
                acc = curves[(csr, mu2, mu1)]
                accs[mu1] = acc
                us = per_cell / len(acc) * 1e6
                rows.append(csv_row(
                    f"fig2/csr{csr}/mu2_{mu2}/mu1_{mu1}", us,
                    f"acc_final={np.mean(acc[-TAIL:]):.4f}"))
            base = float(np.mean(accs[0.0][-TAIL:]))
            for mu1 in MU1S[1:]:
                a = float(np.mean(accs[mu1][-TAIL:]))
                aed = metrics.aed(a, base, acc_pre=pipe.pre_acc)
                grid_out[f"csr={csr},mu2={mu2},mu1={mu1}"] = {
                    "acc": a, "acc_mu1_0": base, "aed": aed}
                rows.append(csv_row(f"fig2/aed/csr{csr}/mu2_{mu2}/mu1_{mu1}",
                                    0.0, f"aed={aed:+.4f}"))
    out = os.path.join(RESULTS_DIR, "fig2_mu1_csr.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"pre_acc": pipe.pre_acc, "grid": grid_out}, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
