"""Chaos benchmark (DESIGN.md §11) — the PR-9 robustness story.

The paper's headline claim is that with 90% of agents timely
disconnected the pre-trained model still converges stably.  This suite
injects that regime — and worse — through the deterministic fault plan
and pins the recovery properties in-bench:

  convergence — the paper cell (pretrained ~68% model, Sec.-VI fleet)
      run clean and under a chaos plan: 90% of the fleet dark at every
      tick (a fresh seeded draw per tick — "timely disconnected",
      not a fixed 10% subfleet), one RSU out for the middle third of
      the run (with recovery re-anchor), and NaN updates injected into
      10% of submissions every tick.  Asserts: every poisoned update is
      quarantined (counted, never absorbed — the whole faulted history
      and final master stay finite), the cloud master stays in the
      clean run's norm band (the weight-mask folds conserve mass — a
      leaking guard shows up here as drift), and the faulted final
      accuracy lands within ``--tol`` (3 points) of the clean run and
      above the pre-trained baseline.

  serving — the same plan family through the event-driven serve loop
      (churn + NaN + duplicate admissions + clock skew): the event-
      conservation identity must hold exactly —
      generated == absorbed + coalesced + dropped + lost_churn +
      stale_rejected — with duplicates inflating ``generated``, and the
      quarantine counter must be live.

Record: ``results/bench/chaos.json`` with ``faulted_vs_clean_final_acc``
(signed gap, faulted − clean) and ``quarantined_updates`` — surfaced as
top-level keys in the ``--summary`` (BENCH_PR9.json) for CI to assert.

Standalone:
  PYTHONPATH=src python -m benchmarks.chaos [--rounds 24] [--tol 0.03]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import List

DISCONNECT_FRAC = 0.9


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=0,
                    help="override the bench-scale round count (0 = keep)")
    ap.add_argument("--tol", type=float, default=0.03,
                    help="max allowed clean-vs-faulted final-acc gap")
    ap.add_argument("--faulted-horizon", type=int, default=3,
                    help="rounds multiplier for the faulted run (90%% "
                         "disconnect trains on ~10%% of the fleet per "
                         "tick, so convergence needs a longer horizon)")
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _chaos_plan(rounds: int, lar: int, n_rsus: int):
    """90%-disconnect + mid-run RSU outage + NaN injection, on the
    round engines' tick clock (rounds × lar).  The disconnected 90% is
    a fresh seeded draw EVERY TICK — the paper's "timely disconnected"
    fleet, where any instant sees 10% connectivity but membership
    churns — not a fixed 10% subfleet."""
    from repro.core.faults import (ChurnWindow, CorruptSpec, FaultPlan,
                                   RsuOutage)
    T = rounds * lar
    churn = tuple(ChurnWindow(frac=DISCONNECT_FRAC, start=t, stop=t + 1,
                              seed=t)
                  for t in range(T))
    return FaultPlan(
        churn=churn,
        outages=(RsuOutage(rsu=0, start=T // 3, stop=2 * T // 3),),
        corrupt=(CorruptSpec(kind="nan", frac=0.1),),
        guard_nonfinite=True).validate(n_rsus)


def convergence_cell(args) -> dict:
    import numpy as np

    from benchmarks import common

    spec = common.base_spec()
    if args.rounds:
        spec = spec.replace(rounds=args.rounds)
    # the faulted fleet trains on ~10% of the data per tick, so its
    # stable convergence plays out over a longer horizon (paper Sec. VI:
    # slower but stable) — compare converged-vs-converged, and record
    # the same-horizon accuracy alongside
    rounds_f = spec.rounds * max(1, args.faulted_horizon)
    spec_f = spec.replace(rounds=rounds_f)
    plan = _chaos_plan(rounds_f, spec.hp.lar, spec.n_rsus)
    pipe = common.build_pipeline(spec)

    from repro.fedsim import sweep
    t0 = time.perf_counter()
    st_c, hist_clean = sweep.run_scenario(spec.resolve(), pipe.pre_params)
    wall_clean = time.perf_counter() - t0

    t0 = time.perf_counter()
    st_f, hist_f = sweep.run_scenario(
        spec_f.replace(faults=plan).resolve(), pipe.pre_params)
    wall_faulted = time.perf_counter() - t0

    clean_acc = float(hist_clean["acc"][-1])
    faulted_acc = float(hist_f["acc"][-1])
    gap = faulted_acc - clean_acc
    at_clean = np.searchsorted(hist_f["round"], hist_clean["round"][-1])
    faulted_same_horizon = float(
        hist_f["acc"][min(at_clean, len(hist_f["acc"]) - 1)])
    quarantined = int(np.sum(hist_f["quarantined"]))

    # counted: the NaN injections really happened and really got caught
    assert quarantined > 0, "chaos plan injected NaNs but none quarantined"
    # never absorbed: one poisoned row reaching a blend NaNs the master
    # and the whole accuracy history after it
    assert np.isfinite(hist_f["acc"]).all(), hist_f["acc"]
    def _cloud_vec(st):
        import jax
        leaves = jax.tree_util.tree_leaves(st.cloud_params)
        return np.concatenate([np.asarray(x, np.float32).ravel()
                               for x in leaves])

    cloud_f = _cloud_vec(st_f)
    assert np.isfinite(cloud_f).all(), "non-finite cloud master"
    # mass conserved: quarantine folds renormalize the blend weights, so
    # the faulted master stays a convex combination of sane updates — a
    # guard that leaked poisoned mass (or dropped weight without
    # renormalizing) drifts out of the clean run's norm band
    norm_c = float(np.linalg.norm(_cloud_vec(st_c)))
    norm_f = float(np.linalg.norm(cloud_f))
    assert 0.5 * norm_c < norm_f < 2.0 * norm_c, \
        f"faulted master norm {norm_f:.2f} left clean band ({norm_c:.2f})"

    return {
        "spec": {"n_agents": spec.n_agents, "n_rsus": spec.n_rsus,
                 "rounds": spec.rounds, "rounds_faulted": rounds_f,
                 "lar": spec.hp.lar},
        "pretrain_acc": float(pipe.pre_acc),
        "clean_final_acc": clean_acc,
        "faulted_final_acc": faulted_acc,
        "faulted_acc_at_clean_horizon": faulted_same_horizon,
        "faulted_vs_clean_final_acc": gap,
        "quarantined_updates": quarantined,
        "round_s": {"chaos_clean": wall_clean / spec.rounds,
                    "chaos_faulted": wall_faulted / rounds_f},
    }


def serving_cell(args) -> dict:
    from repro.core.faults import ChurnWindow, CorruptSpec, FaultPlan
    from repro.core.h2fed import H2FedParams
    from repro.core.scenario import ScenarioSpec
    from repro.fedsim.serving import run_serve_loop

    A = 24
    plan = FaultPlan(
        churn=(ChurnWindow(frac=0.5, start=2, stop=8),),
        corrupt=(CorruptSpec(kind="nan", frac=0.3, start=1),),
        dup_frac=0.25, clock_skew=0.05, guard_nonfinite=True)
    spec = ScenarioSpec(
        n_agents=A, n_rsus=4, batch=16, n_train=2400, n_test=400,
        hp=H2FedParams(mu1=0.01, mu2=0.005, lar=2, local_epochs=1, lr=0.1),
        engine="async", staleness_decay=1.0, rounds=2,
        serve_events=A * 8, arrival_rate=1.0, tick_trigger="auto",
        queue_capacity=4 * A, faults=plan).validate()
    _, _, stats, _ = run_serve_loop(spec.resolve())
    s = stats.summary()
    sinks = (stats.events_absorbed + stats.events_coalesced
             + stats.events_dropped + stats.events_lost_churn
             + stats.events_stale_rejected)
    assert stats.events_generated == sinks, \
        f"event mass leaked: {stats.events_generated} != {sinks}"
    assert stats.events_duplicated > 0 and stats.events_lost_churn > 0
    assert stats.quarantined_updates > 0
    return {"serving_chaos": {
        "events_generated": stats.events_generated,
        "events_absorbed": stats.events_absorbed,
        "events_coalesced": stats.events_coalesced,
        "events_dropped": stats.events_dropped,
        "events_lost_churn": stats.events_lost_churn,
        "events_duplicated": stats.events_duplicated,
        "events_stale_rejected": stats.events_stale_rejected,
        "quarantined_updates": stats.quarantined_updates,
        "final_acc": s.get("final_acc"),
    }, "fault_accounting_identity": True}


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    sc = rec["serving_chaos"]
    return [
        csv_row("chaos/faulted-vs-clean",
                rec["faulted_vs_clean_final_acc"] * 1e3,
                f"acc {rec['clean_final_acc']:.3f} -> "
                f"{rec['faulted_final_acc']:.3f} under 90% disconnect "
                f"+ RSU outage + NaN (pretrain "
                f"{rec['pretrain_acc']:.3f})"),
        csv_row("chaos/quarantined", rec["quarantined_updates"],
                "poisoned updates caught (counted, never absorbed)"),
        csv_row("chaos/serving-conservation",
                sc["events_generated"],
                f"== absorbed {sc['events_absorbed']} + coalesced "
                f"{sc['events_coalesced']} + dropped {sc['events_dropped']}"
                f" + churned {sc['events_lost_churn']} + stale "
                f"{sc['events_stale_rejected']}; "
                f"{sc['quarantined_updates']} quarantined, "
                f"{sc['events_duplicated']} dups injected"),
    ]


def _record(args) -> dict:
    rec = {"bench": "chaos", "disconnect_frac": DISCONNECT_FRAC,
           "tol": args.tol}
    rec.update(convergence_cell(args))
    rec.update(serving_cell(args))
    # the paper's headline, asserted where the numbers are made: the
    # faulted run must land within tol of clean and above the pretrained
    # baseline ("the pre-trained model still converges stably")
    assert rec["faulted_vs_clean_final_acc"] >= -args.tol, \
        (f"faulted final acc {rec['faulted_final_acc']:.3f} more than "
         f"{args.tol:.0%} below clean {rec['clean_final_acc']:.3f}")
    assert rec["faulted_final_acc"] > rec["pretrain_acc"], \
        "faulted run did not improve on the pre-trained model"
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "chaos.json"
    path.write_text(json.dumps(rec, indent=1))
    print(f"[json] {path}", file=sys.stderr)
    return rec


def run() -> List[str]:
    """Harness entry (benchmarks.run --only chaos): defaults only —
    the harness owns argv."""
    args = argparse.Namespace(
        rounds=0, tol=0.03, faulted_horizon=3,
        out=os.environ.get("REPRO_RESULTS", "results") + "/bench")
    return _csv_rows(_record(args))


def main():
    for row in _csv_rows(_record(_parse_args())):
        print(row)


if __name__ == "__main__":
    main()
