"""Beyond-paper ablation: dynamic (adaptive) mu vs fixed mu under a
time-varying CSR schedule — the paper's stated future work
(core/orchestrator.py).

Scenario: the network degrades mid-training (CSR 0.9 -> 0.1 -> 0.5).
A fixed mu2 must be chosen for the worst phase (slowing the good phases)
or for the good phase (unstable in the bad one).  The adaptive controller
observes per-round connectivity and interpolates.

The experiment setup (fleet / dataset / partition / pretrain) is declared
by a ``ScenarioSpec`` (benchmarks.common.base_spec); the per-round
feedback loop itself cannot batch into the sweep engine — mu reacts to
the realized connectivity — so it drives ``make_global_round`` directly
on the spec-resolved arrays.

Run: PYTHONPATH=src python -m benchmarks.ablation_adaptive
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import metrics
from benchmarks.common import RESULTS_DIR, base_spec, build_pipeline, \
    csv_row
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.core import orchestrator as orch
from repro.fedsim.simulator import init_state, make_global_round
from repro.models import mlp

# (rounds, csr) phases: good -> collapse -> partial recovery
SCHEDULE: Tuple[Tuple[int, float], ...] = ((8, 0.9), (12, 0.2), (8, 0.5))
# drift regime (cf. fig2): local training drifts enough per round that the
# proximal terms matter; stable regimes make every policy equivalent
LAR, E, LR = 5, 3, 0.15

# quantized mu levels so each (mu1, mu2, csr) compiles once and is cached
MU1_LEVELS = (0.0, 0.001, 0.002, 0.004)
MU2_LEVELS = (0.0, 0.005, 0.01, 0.02)


def _quantize(x: float, levels) -> float:
    return min(levels, key=lambda lv: abs(lv - x))


def _spec(seed: int):
    """The ablation's experiment cell (rounds = the schedule's total)."""
    return base_spec(
        hp=H2FedParams(mu1=0.001, mu2=0.005, lar=LAR, local_epochs=E,
                       lr=LR),
        rounds=sum(r for r, _ in SCHEDULE), seed=seed)


def _run(policy: str, seed: int = 0) -> Dict:
    """policy: 'fixed0' | 'fixed_paper' | 'fixed_worstcase' | 'adaptive'."""
    spec = _spec(seed)
    pipe = build_pipeline(spec)
    res = spec.resolve()
    cfg, fed = res.cfg, res.fed
    x_test, y_test = jnp.asarray(pipe.test.x), jnp.asarray(pipe.test.y)
    eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_test, y_test))

    round_cache: Dict[Tuple[float, float, float], object] = {}

    def round_fn(mu1, mu2, csr):
        key = (mu1, mu2, csr)
        if key not in round_cache:
            hp = H2FedParams(mu1=mu1, mu2=mu2, lar=LAR, local_epochs=E,
                             lr=LR)
            het = HeterogeneityModel(csr=csr, scd=1, lar=LAR)
            round_cache[key] = make_global_round(cfg, hp, het, fed)
        return round_cache[key]

    actrl = orch.AdaptiveMuConfig()
    astate = orch.init_state()
    base = spec.hp

    state = init_state(cfg, pipe.pre_params, jax.random.key(cfg.seed))
    accs, mus = [], []
    for phase_rounds, csr in SCHEDULE:
        for _ in range(phase_rounds):
            if policy == "fixed0":
                mu1, mu2 = 0.0, 0.0
            elif policy == "fixed_paper":
                mu1, mu2 = 0.001, 0.005
            elif policy == "fixed_worstcase":
                mu1, mu2 = 0.004, 0.02
            else:  # adaptive
                hp, _ = orch.schedule(astate, actrl, base)
                mu1 = _quantize(hp.mu1, MU1_LEVELS)
                mu2 = _quantize(hp.mu2, MU2_LEVELS)
            state = round_fn(mu1, mu2, csr)(state)
            # observe realized connectivity (what the cloud actually saw)
            connected = float(jnp.mean((state.conn.remaining > 0)
                                       .astype(jnp.float32)))
            astate = orch.observe_csr(astate, actrl, connected, 1.0)
            accs.append(float(eval_fn(state.cloud_params)))
            mus.append((mu1, mu2))
    return {"acc": accs, "mus": mus}


def run(seed: int = 0) -> List[str]:
    rows = []
    out = {}
    for policy in ("fixed0", "fixed_paper", "fixed_worstcase", "adaptive"):
        r = _run(policy, seed)
        acc = np.asarray(r["acc"])
        # phase-2 (collapse) window
        lo, hi = SCHEDULE[0][0], SCHEDULE[0][0] + SCHEDULE[1][0]
        bad_phase = acc[lo:hi]
        out[policy] = r
        rows.append(csv_row(
            f"adaptive_mu/{policy}", 0.0,
            f"final={np.mean(acc[-4:]):.4f} "
            f"bad_phase_min={bad_phase.min():.4f} "
            f"bad_phase_jitter={metrics.jitter(bad_phase):.4f}"))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "ablation_adaptive.json"), "w") as f:
        json.dump(out, f, indent=1)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
