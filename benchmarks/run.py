"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for:
  fig2  — AED vs mu1 × CSR grid               (paper Fig. 2)
  fig3  — mu2 stabilization + MSE-to-central  (paper Fig. 3)
  fig4  — H²-Fed vs FedProx/HierFAVG/FedAvg   (paper Fig. 4)
  kernels — Pallas-kernel microbenchmarks (interpret mode vs jnp oracle)
  roofline — dry-run roofline terms           (deliverable g)
  sharded — engine round latency: tree vs flat vs shard_map, 1 vs 8 devices
  async   — sync-vs-async round latency + 90%-disconnect convergence record
  topology — replicated vs RSU-sharded round latency at large R (2x4 mesh)
  sweep   — vmapped multi-scenario sweep vs sequential runs (DESIGN.md §7)
  streaming — cohort-streamed host-fleet round vs resident + million-agent
              fleet cell (DESIGN.md §8)
  serving — continuous-serving event loop: Poisson load, overload policies,
            batch↔serving anchor + trace-replay determinism (DESIGN.md §9)
  chaos   — deterministic fault injection: 90%-disconnect + RSU outage +
            NaN convergence vs clean, quarantine counters, serve-loop
            event-conservation identity (DESIGN.md §11)
  nshard  — N-sharded fleet buffers: per-device fleet bytes + cross-pod
            collective bytes at model_shards 1 vs 2, and the ~1e7-param
            two-axis streamed round (DESIGN.md §12)

Usage:  PYTHONPATH=src python -m benchmarks.run [--only fig2,roofline]
                                                [--json results/bench/bench.json]
                                                [--summary BENCH_PR7.json]
Env:    REPRO_BENCH_FULL=1 for the paper-scale (100 agents) runs.

``--json`` additionally writes every row (and any suite failures) to one
JSON record — the artifact CI uploads per PR so the perf trajectory is
tracked over time.  ``--summary`` distills the per-suite records under
``results/bench/`` into one top-level perf summary (engine round
latencies, bytes/round, achieved HBM GB/s, and the fused+bf16 byte
reduction) so the trajectory is legible at a glance per PR.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def bench_fig2():
    from benchmarks import fig2_mu1_csr
    return fig2_mu1_csr.run()


def bench_fig3():
    from benchmarks import fig3_mu2_stability
    return fig3_mu2_stability.run()


def bench_fig4():
    from benchmarks import fig4_baselines
    return fig4_baselines.run()


def bench_kernels():
    from benchmarks import kernels_micro
    return kernels_micro.run()


def bench_roofline():
    from benchmarks import roofline
    return roofline.run()


def bench_adaptive():
    from benchmarks import ablation_adaptive
    return ablation_adaptive.run()


def bench_sharded():
    from benchmarks import sharded_round
    return sharded_round.run()


def bench_async():
    from benchmarks import async_round
    return async_round.run()


def bench_topology():
    from benchmarks import topology_round
    return topology_round.run()


def bench_sweep():
    from benchmarks import sweep_bench
    return sweep_bench.run()


def bench_streaming():
    from benchmarks import streaming_round
    return streaming_round.run()


def bench_serving():
    from benchmarks import serving_loop
    return serving_loop.run()


def bench_chaos():
    from benchmarks import chaos
    return chaos.run()


def bench_nshard():
    from benchmarks import nshard_round
    return nshard_round.run()


SUITES = {
    "fig2": bench_fig2,
    "fig3": bench_fig3,
    "fig4": bench_fig4,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "adaptive": bench_adaptive,
    "sharded": bench_sharded,
    "async": bench_async,
    "topology": bench_topology,
    "sweep": bench_sweep,
    "streaming": bench_streaming,
    "serving": bench_serving,
    "chaos": bench_chaos,
    "nshard": bench_nshard,
}


def write_summary(path: Path, bench_dir: Path, since: float) -> None:
    """Distill results/bench/*.json into the top-level perf summary
    (engine round latency, bytes/round, GB/s — the PR perf trajectory).

    Only records (re)written by THIS invocation (mtime >= ``since``) are
    merged — stale records from earlier runs or different configs must
    not masquerade as current numbers."""
    summary = {"latency_s": {}, "bytes_per_round": {}, "hbm_gbps": {}}

    def merge(rec: dict, prefix: str):
        for k, v in rec.get("round_s", {}).items():
            summary["latency_s"][f"{prefix}/{k}"] = v
        for k, v in rec.get("bytes_per_round", {}).items():
            summary["bytes_per_round"][f"{prefix}/{k}"] = v
        for k, v in rec.get("hbm_gbps", {}).items():
            summary["hbm_gbps"][f"{prefix}/{k}"] = v

    for f in sorted(bench_dir.glob("*.json")):
        try:
            if f.stat().st_mtime < since:
                continue
            rec = json.loads(f.read_text())
        except (json.JSONDecodeError, OSError):
            continue
        name = rec.get("bench")
        if name == "async_round":
            merge(rec, "async_round")
            summary["fused_bf16_vs_unfused_f32_bytes"] = \
                rec.get("fused_bf16_vs_unfused_f32_bytes")
            summary["tick_fused_bf16_vs_unfused_f32_bytes"] = \
                rec.get("tick_fused_bf16_vs_unfused_f32_bytes")
        elif name == "topology_round":
            merge(rec, f"topology_round/d{rec.get('n_devices')}")
            summary["flat_fused_vs_unfused_latency"] = \
                rec.get("flat_fused_vs_unfused")
        elif name == "sharded_round":
            d = f"d{rec.get('n_devices')}"
            merge(rec, f"sharded_round/{d}")
            # PR-10: shard_map cost surfaced per device count — the
            # sharded/flat latency ratio plus the measured
            # compute-vs-collective split of the sharded round
            summary.setdefault("sharded_vs_flat_latency", {})[d] = \
                rec.get("sharded_vs_flat")
            sh = rec.get("time_split", {}).get("sharded", {})
            summary.setdefault("sharded_time_split", {})[d] = sh
        elif name == "sweep_round":
            merge(rec, "sweep_round")
            for k in ("sweep_vs_sequential_wall",
                      "sweep_vs_sequential_round", "sweep_trace_count"):
                summary[k] = rec.get(k)
            mc, cw = rec.get("mixed_cadence"), rec.get("cold_warm")
            if mc:       # PR-8: cadence-as-data, one trace for the grid
                summary["mixed_cadence_trace_count"] = mc.get("trace_count")
                summary["mixed_cadence_vs_sequential_wall"] = \
                    mc.get("mixed_cadence_vs_sequential_wall")
            if cw:       # PR-8: persistent compilation cache, warm start
                summary["cold_vs_warm_wall"] = cw.get("cold_vs_warm_wall")
                summary["cold_warm_wall_s"] = {
                    "cold": cw.get("cold_s"), "warm": cw.get("warm_s")}
        elif name == "streaming_round":
            merge(rec, "streaming_round")
            summary["streaming_agents_per_s"] = rec.get("agents_per_s")
            for k in ("streamed_equals_resident",
                      "host_device_bytes_per_round",
                      "peak_device_working_set_bytes",
                      "working_set_bounded_by_chunk",
                      "fleet_n_agents", "fleet_round_s",
                      "fleet_agents_per_s", "fleet_host_store_bytes",
                      "fleet_device_working_set_bytes"):
                summary[k] = rec.get(k)
        elif name == "serving_loop":
            merge(rec, "serving_loop")
            summary["serving"] = {k: rec.get(k) for k in (
                "updates_per_s", "tick_p50_ms", "tick_p99_ms",
                "queue_depth_mean", "queue_depth_max",
                "events_dropped_nominal", "event_wait_mean",
                "model_staleness_mean", "serve_p50_ms", "final_acc",
                "serving_equals_async", "trace_replay_deterministic")}
            summary["serving_overload"] = rec.get("overload")
        elif name == "chaos":
            merge(rec, "chaos")
            # PR-9: the robustness headline — faulted-vs-clean accuracy
            # gap + quarantine counter, asserted by CI from the summary
            for k in ("faulted_vs_clean_final_acc", "quarantined_updates",
                      "clean_final_acc", "faulted_final_acc",
                      "faulted_acc_at_clean_horizon", "pretrain_acc",
                      "disconnect_frac", "fault_accounting_identity"):
                summary[k] = rec.get(k)
            summary["serving_chaos"] = rec.get("serving_chaos")
        elif name == "nshard_round":
            merge(rec, "nshard_round")
            # PR-10: the N-sharding headline — per-device fleet-state
            # shrink and the cross-pod (DCI) byte split, CI-asserted
            summary["nshard_fleet_bytes_ratio"] = \
                rec.get("fleet_bytes_ratio")
            summary["nshard_fleet_bytes_per_device"] = {
                m: rec.get(m, {}).get("fleet_bytes_per_device")
                for m in ("replicated", "nsharded")}
            summary["nshard_crosspod_bytes"] = rec.get("crosspod_bytes")
            summary["nshard_crosspod_ratio"] = rec.get("crosspod_ratio")
            summary["nshard_big_n"] = rec.get("big_n")
    path.write_text(json.dumps(summary, indent=1))
    print(f"[summary] {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SUITES))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + failures to one JSON record")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="write a top-level perf summary (e.g. "
                         "BENCH_PR6.json) distilled from the bench "
                         "records THIS run produced")
    args = ap.parse_args()
    t_start = time.time()
    names = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    all_rows, errors = [], []
    for name in names:
        t0 = time.perf_counter()
        try:
            for row in SUITES[name]():
                all_rows.append(row)
                print(row)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            errors.append(f"{name}:{type(e).__name__}:{e}")
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
        wall = f"{name}/total,{(time.perf_counter() - t0) * 1e6:.0f},wall"
        all_rows.append(wall)
        print(wall, flush=True)

    if args.json:
        path = Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {"suites": names, "rows": all_rows, "failures": errors},
            indent=1))
        print(f"[json] {path}", file=sys.stderr)
    if args.summary:
        import os
        bench_dir = Path(os.environ.get("REPRO_RESULTS",
                                        "results")) / "bench"
        if bench_dir.exists():
            write_summary(Path(args.summary), bench_dir, t_start)
    if errors:
        raise SystemExit(f"{len(errors)} benchmark suites failed")


if __name__ == "__main__":
    main()
