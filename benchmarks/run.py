"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for:
  fig2  — AED vs mu1 × CSR grid               (paper Fig. 2)
  fig3  — mu2 stabilization + MSE-to-central  (paper Fig. 3)
  fig4  — H²-Fed vs FedProx/HierFAVG/FedAvg   (paper Fig. 4)
  kernels — Pallas-kernel microbenchmarks (interpret mode vs jnp oracle)
  roofline — dry-run roofline terms           (deliverable g)

Usage:  PYTHONPATH=src python -m benchmarks.run [--only fig2,roofline]
Env:    REPRO_BENCH_FULL=1 for the paper-scale (100 agents) runs.
"""
from __future__ import annotations

import argparse
import sys
import time


def bench_fig2():
    from benchmarks import fig2_mu1_csr
    return fig2_mu1_csr.run()


def bench_fig3():
    from benchmarks import fig3_mu2_stability
    return fig3_mu2_stability.run()


def bench_fig4():
    from benchmarks import fig4_baselines
    return fig4_baselines.run()


def bench_kernels():
    from benchmarks import kernels_micro
    return kernels_micro.run()


def bench_roofline():
    from benchmarks import roofline
    return roofline.run()


def bench_adaptive():
    from benchmarks import ablation_adaptive
    return ablation_adaptive.run()


SUITES = {
    "fig2": bench_fig2,
    "fig3": bench_fig3,
    "fig4": bench_fig4,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "adaptive": bench_adaptive,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(SUITES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.perf_counter()
        try:
            for row in SUITES[name]():
                print(row)
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}",
                  file=sys.stderr)
        print(f"{name}/total,{(time.perf_counter() - t0) * 1e6:.0f},wall",
              flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark suites failed")


if __name__ == "__main__":
    main()
