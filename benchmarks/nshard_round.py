"""N-sharding benchmark: per-device fleet bytes + cross-pod collective
bytes of the model-sharded engine (DESIGN.md §12, BENCH_PR10).

Two claims, both measured from compiled artifacts (never estimated):

  fleet bytes — ``hlo_analysis.memory_footprint`` OUTPUT bytes of the
      compiled round program are the per-device persistent fleet state:
      the round's output IS the next round's FlatSimState (agent rows +
      (R, N) staleness buffer + cloud master).  At ``model_shards=2`` the
      (R, N) staleness buffer and the fp32 cloud master live half-N per
      device, so fleet bytes must shrink ≥1.8x vs the model-replicated
      round on the SAME 8 devices (CI asserts from BENCH_PR10.json).

  cross-pod bytes — ``hlo_analysis.collective_axis_bytes`` attributes
      every collective in the round HLO to the mesh axes its replica
      groups span.  Bytes spanning ``pod`` ride the cross-pod DCI links;
      the N-sharded round's cloud layer reduces 1/shards-sized slices, so
      its pod-axis bytes must not exceed the replicated baseline's (the
      round-opening reference all-gather spans only the ``model`` axis —
      intra-pod ICI by construction).

Plus the big-N cell: a ~1e7-parameter MLP (hidden 12000) streamed through
``run_scenario`` with TWO-AXIS chunking (agent chunks x N-tiles), pinning
that the device working set is bounded by (chunk x N) + (R x tile), not
(A x N) + (R x N).

Standalone:
  PYTHONPATH=src python -m benchmarks.nshard_round --devices 8
Via the harness:
  PYTHONPATH=src python -m benchmarks.run --only nshard
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import List

BIG_HIDDEN = 12000       # 784-12000-10 MLP -> N = 9.55e6 (~1e7) params


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--rsus", type=int, default=128)
    ap.add_argument("--rounds", type=int, default=2, help="timed rounds")
    ap.add_argument("--n-train", type=int, default=80)
    ap.add_argument("--big-hidden", type=int, default=BIG_HIDDEN)
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _sharded_cell(args, model_shards: int) -> dict:
    """Compile + time one sharded round at the given model_shards on the
    current device count; read fleet bytes and per-axis collective bytes
    off the compiled artifact."""
    import jax

    from benchmarks.sharded_round import _time_rounds
    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core import flatten
    from repro.core.baselines import h2fed
    from repro.core.heterogeneity import HeterogeneityModel
    from repro.data.partition import scenario_two
    from repro.data.synthetic import mnist_class_task
    from repro.fedsim import sharded
    from repro.fedsim.simulator import SimConfig, init_flat_state
    from repro.launch import hlo_analysis
    from repro.models import mlp

    import numpy as np
    train, _ = mnist_class_task(n_train=args.n_train, n_test=100, seed=0)
    fed = scenario_two(train, n_agents=args.agents, n_rsus=args.rsus,
                       seed=0)
    # spread the small cohort's RSUs evenly across the id range so the
    # pod blocks are balanced (rsu_sharded needs equal agents per pod;
    # scenario_two's round-robin parks A<R cohorts all in pod 0)
    fed = dataclasses.replace(
        fed, rsu_assign=np.arange(args.agents, dtype=np.int32)
        * (args.rsus // args.agents))
    cfg = SimConfig(n_agents=args.agents, n_rsus=args.rsus, batch=8, seed=0)
    hp = h2fed(mu1=0.01, mu2=0.005, lar=2, lr=0.1)
    het = HeterogeneityModel(csr=0.8, lar=hp.lar)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    spec = flatten.spec_of(params)

    mesh = sharded.make_fleet_mesh(n_model_shards=model_shards)
    # rsu_sharded on BOTH sides: the cloud layer is the round's one
    # explicit cross-pod collective, so pod-axis attribution compares the
    # same contract (DESIGN.md §4) at model_shards 1 vs S
    topo = sharded.resolve_topology(cfg, fed, mesh, rsu_sharded=True)
    round_fn = sharded.make_sharded_global_round(cfg, hp, het, fed, spec,
                                                 topo)
    state = init_flat_state(cfg, spec, params, jax.random.key(cfg.seed))
    state = sharded.pad_model_axis(state, topo, spec.n)
    with mesh:
        lowered = round_fn.lower(state)
        mem = hlo_analysis.memory_footprint(round_fn, state)
        axes = list(zip(mesh.axis_names, mesh.devices.shape))
        coll = hlo_analysis.collective_axis_bytes(
            lowered.compile().as_text(), axes)
        if topo.rsu_sharded:
            state = state._replace(
                agent_flat=topo.permute_agents(state.agent_flat))
        round_s = _time_rounds(round_fn, state, args.rounds)
    return {
        "model_shards": model_shards,
        "mesh": dict(mesh.shape),
        "n_params": spec.n,
        "n_params_padded": topo.model_pad(spec.n),
        "round_s": round_s,
        "fleet_bytes_per_device": mem["output_bytes"],
        "collective_bytes_per_axis": coll["per_axis"],
        "n_collectives": len(coll["entries"]),
    }


def _bign_cell(args) -> dict:
    """~1e7-param model through run_scenario under two-axis streaming;
    the device working set is pinned off the compiled chunk programs."""
    import jax
    import jax.numpy as jnp

    from repro.core.scenario import ScenarioSpec
    from repro.fedsim import run_scenario
    from repro.launch import hlo_analysis

    spec = ScenarioSpec(
        n_agents=8, n_rsus=4, batch=8, n_train=160, n_test=100, rounds=1,
        fleet_store="host", chunk_agents=4, chunk_params=1 << 20,
        fleet_dtype="bf16", hidden_dims=(args.big_hidden,))
    t0 = time.perf_counter()
    state, history = run_scenario(spec)
    wall = time.perf_counter() - t0

    # re-build the round to lower its chunk programs (run_scenario keeps
    # them internal); abstract args only — nothing big is allocated
    from repro.core import flatten
    from repro.fedsim import streaming
    from repro.models import mlp
    from repro.configs.mnist_mlp import CONFIG
    res = spec.resolve()
    cfg_model = dataclasses.replace(CONFIG, hidden_dims=spec.hidden_dims)
    params = mlp.init_params(cfg_model, jax.random.key(spec.seed))
    fspec = flatten.spec_of(
        params, storage_dtype=flatten.resolve_storage_dtype("bf16"))
    round_fn = streaming.make_streamed_twoaxis_round(
        res.cfg, spec.hp, spec.het, res.fed, fspec,
        chunk_agents=spec.chunk_agents, chunk_params=spec.chunk_params)
    plan, tiles = round_fn.plan, round_fn.tiles
    sds = jax.ShapeDtypeStruct
    import numpy as np
    x_np, y_np = np.asarray(res.fed.x), np.asarray(res.fed.y)
    samples = x_np.shape[1]
    train_mem = hlo_analysis.memory_footprint(
        round_fn.chunk_train,
        sds((plan.chunk, tiles.n_padded), fspec.storage_dtype),
        sds((tiles.n_padded,), jnp.float32),
        sds((plan.chunk, samples) + x_np.shape[2:], x_np.dtype),
        sds((plan.chunk, samples), y_np.dtype),
        sds((plan.chunk,), jnp.int32),
        sds((plan.chunk,), jnp.float32))
    agg_mem = hlo_analysis.memory_footprint(
        round_fn.tile_agg,
        sds((plan.chunk, tiles.tile), fspec.storage_dtype),
        sds((plan.chunk,), jnp.float32),
        sds((plan.chunk,), jnp.int32))
    n = fspec.n
    return {
        "n_params": n,
        "hidden": args.big_hidden,
        "chunk_agents": plan.chunk,
        "chunk_params": tiles.tile,
        "n_tiles": tiles.n_tiles,
        "round_wall_s": wall,
        "final_acc": float(history["acc"][-1]),
        "host_fleet_bytes": float(state.store.nbytes),
        "train_working_set_bytes": train_mem["total_bytes"],
        "agg_working_set_bytes": agg_mem["total_bytes"],
        # the bound the two-axis design promises: training is O(chunk*N)
        # (full-N per agent chunk — the gradient couples all params, so
        # this leg CAN'T tile on N), aggregation O(R*tile); the honest
        # comparator for the agg side is the f32 (R, N_pad) numerator a
        # one-axis streamed round materializes on device
        "rsu_numerator_bytes": spec.n_rsus * tiles.n_padded * 4.0,
        "fleet_full_bytes": float(state.store.nbytes)
        + spec.n_rsus * tiles.n_padded * 2 + tiles.n_padded * 4,
    }


def run_cell(args) -> dict:
    import jax
    n_dev = len(jax.devices())
    base = _sharded_cell(args, model_shards=1)
    nsh = _sharded_cell(args, model_shards=2)
    big = _bign_cell(args)
    fleet_ratio = (base["fleet_bytes_per_device"]
                   / max(nsh["fleet_bytes_per_device"], 1.0))
    pod_base = base["collective_bytes_per_axis"].get("pod", 0.0)
    pod_nsh = nsh["collective_bytes_per_axis"].get("pod", 0.0)
    return {
        "bench": "nshard_round",
        "n_devices": n_dev,
        "n_agents": args.agents,
        "n_rsus": args.rsus,
        "replicated": base,
        "nsharded": nsh,
        "big_n": big,
        "fleet_bytes_ratio": fleet_ratio,
        "crosspod_bytes": {"replicated": pod_base, "nsharded": pod_nsh},
        "crosspod_ratio": pod_nsh / max(pod_base, 1.0),
        "round_s": {"replicated": base["round_s"],
                    "nsharded": nsh["round_s"]},
    }


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    d = rec["n_devices"]
    rows = [csv_row(f"nshard_round/{k}/d{d}", v["round_s"] * 1e6,
                    f"fleet_bytes={v['fleet_bytes_per_device']:.0f}")
            for k, v in (("replicated", rec["replicated"]),
                         ("nsharded", rec["nsharded"]))]
    rows.append(csv_row(f"nshard_round/fleet_ratio/d{d}",
                        rec["nsharded"]["round_s"] * 1e6,
                        f"shrink={rec['fleet_bytes_ratio']:.2f}x"))
    rows.append(csv_row("nshard_round/big_n",
                        rec["big_n"]["round_wall_s"] * 1e6,
                        f"N={rec['big_n']['n_params']}"))
    return rows


def run() -> List[str]:
    """Harness entry: one 8-device subprocess (device count must be fixed
    before jax initializes, as in benchmarks/sharded_round)."""
    here = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = str(here / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.nshard_round", "--devices", "8"],
        capture_output=True, text=True, timeout=1800, env=env, cwd=str(here))
    if out.returncode != 0:
        raise RuntimeError(f"nshard cell failed:\n{out.stderr[-2000:]}")
    return [ln for ln in out.stdout.splitlines()
            if ln.startswith("nshard_round/")]


def main():
    args = _parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    rec = run_cell(args)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "nshard_round.json"
    path.write_text(json.dumps(rec, indent=1))
    for row in _csv_rows(rec):
        print(row)
    print(f"[json] {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
