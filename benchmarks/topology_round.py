"""Replicated vs RSU-sharded round latency at large R (DESIGN.md §4).

The RSU-sharded mode exists for exactly one reason: with a large RSU axis
the replicated engine makes every device hold and psum the full (R, N)
buffer, while the topology-first layout keeps each pod's (R_local, N) block
local and pays cross-pod traffic only at the cloud layer.  This benchmark
records one compiled global round of the SAME large-R federated workload
under both modes into the BENCH json flow:

  replicated   — (R, N) buffer on every device, RSU psum over all agent axes
  rsu_sharded  — (R/pods, N) block per pod, within-pod psum only

Because the device count must be fixed before jax initializes, the cell runs
as a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=N (the
launch/dryrun mechanism), on the 2 x N/2 ('pod','data') fleet mesh.

Standalone:
  PYTHONPATH=src python -m benchmarks.topology_round --devices 8 \
      [--agents 64 --rsus 32 --rounds 2 --out results/bench]

Via the harness (spawns the 8-device cell):
  PYTHONPATH=src python -m benchmarks.run --only topology
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import List

HARNESS_DEVICES = 8


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (0 = use what's there)")
    ap.add_argument("--agents", type=int, default=64)
    ap.add_argument("--rsus", type=int, default=32,
                    help="large R: the regime the RSU-sharded mode targets")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=2, help="timed rounds")
    ap.add_argument("--lar", type=int, default=2)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _time_rounds(round_fn, state, n: int) -> float:
    """Mean per-round wall seconds, compile + relayout warmup excluded.
    The round jits donate their input state, so every call rebinds."""
    import jax
    state = round_fn(round_fn(state))            # compile x2 + warmup
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(n):
        state = round_fn(state)
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) / n


def run_cell(args) -> dict:
    import jax

    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core import flatten
    from repro.core.baselines import h2fed
    from repro.core.heterogeneity import HeterogeneityModel
    from repro.data.partition import scenario_two
    from repro.data.synthetic import mnist_class_task
    from repro.fedsim.sharded import (make_fleet_mesh,
                                      make_sharded_global_round,
                                      resolve_topology)
    from repro.fedsim.simulator import SimConfig, init_flat_state
    from repro.models import mlp

    n_dev = len(jax.devices())
    train, _ = mnist_class_task(n_train=args.n_train, n_test=100, seed=0)
    fed = scenario_two(train, n_agents=args.agents, n_rsus=args.rsus,
                       seed=0)
    cfg = SimConfig(n_agents=args.agents, n_rsus=args.rsus, batch=16,
                    seed=0)
    hp = h2fed(mu1=0.01, mu2=0.005, lar=args.lar, lr=0.1)
    het = HeterogeneityModel(csr=0.8, lar=hp.lar)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    spec = flatten.spec_of(params)
    mesh = make_fleet_mesh(n_dev, n_pods=args.pods if n_dev > 1 else 1)

    def key():
        return jax.random.key(cfg.seed)

    from repro.fedsim.simulator import make_flat_global_round
    from repro.launch.hlo_analysis import round_cost

    timings, costs = {}, {}
    with mesh:
        for mode, rsu_sharded in (("replicated", False),
                                  ("rsu_sharded", True)):
            topo = resolve_topology(cfg, fed, mesh,
                                    rsu_sharded=rsu_sharded)
            rf = make_sharded_global_round(cfg, hp, het, fed, spec, topo)

            def state():
                s = init_flat_state(cfg, spec, params, key())
                if topo.rsu_sharded:
                    s = s._replace(
                        agent_flat=topo.permute_agents(s.agent_flat))
                return s

            if topo.rsu_sharded:
                rsu_per_pod = topo.rsu_per_pod      # as actually executed
            timings[mode] = _time_rounds(rf, state(), args.rounds)
            costs[mode] = round_cost(rf, state(), latency_s=timings[mode])

    # fused vs un-fused one-pass round (DESIGN.md §3) on this cell's flat
    # engine — the A/B the CI bench-smoke asserts on (the fused program
    # must not be slower; off-TPU both lower to the same XLA ops, so this
    # guards against regressions rather than measuring a kernel win).
    # Host-CPU wall time drifts by tens of percent over a cell, so the
    # variants are timed in INTERLEAVED batches and each takes its best
    # batch — per-variant drift cancels instead of biasing whichever ran
    # second.
    ab = {}
    for mode, fused in (("flat_fused", True), ("flat_unfused", False)):
        rf = make_flat_global_round(cfg, hp, het, fed, spec, fused=fused)
        state = init_flat_state(cfg, spec, params, key())
        state = rf(rf(state))                    # compile + warmup
        ab[mode] = {"rf": rf, "state": state, "best": float("inf")}
    batch = max(args.rounds, 4)
    for _ in range(5):
        for mode in ab:
            v = ab[mode]
            jax.block_until_ready(v["state"])
            t0 = time.perf_counter()
            for _ in range(batch):
                v["state"] = v["rf"](v["state"])
            jax.block_until_ready(v["state"])
            v["best"] = min(v["best"],
                            (time.perf_counter() - t0) / batch)
    for mode, fused in (("flat_fused", True), ("flat_unfused", False)):
        timings[mode] = ab[mode]["best"]
        costs[mode] = round_cost(
            ab[mode]["rf"], init_flat_state(cfg, spec, params, key()),
            latency_s=timings[mode])

    return {
        "bench": "topology_round",
        "n_devices": n_dev,
        "mesh": dict(mesh.shape),
        "n_agents": args.agents,
        "n_rsus": args.rsus,
        "rsu_per_pod": rsu_per_pod,
        "lar": args.lar,
        "n_params": spec.n,
        "round_s": timings,
        "bytes_per_round": {m: c["bytes"] for m, c in costs.items()},
        "collective_bytes_per_round":
            {m: c["collective_bytes"] for m, c in costs.items()},
        "hbm_gbps": {m: c["hbm_gbps"] for m, c in costs.items()},
        "rsu_sharded_vs_replicated":
            timings["replicated"] / max(timings["rsu_sharded"], 1e-12),
        "flat_fused_vs_unfused":
            timings["flat_unfused"] / max(timings["flat_fused"], 1e-12),
    }


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    d = rec["n_devices"]
    rows = [csv_row(f"topology_round/{mode}/d{d}", s * 1e6,
                    f"A{rec['n_agents']}xR{rec['n_rsus']}")
            for mode, s in rec["round_s"].items()]
    rows += [csv_row(f"topology_round/bytes/{mode}/d{d}", b / 1e6,
                     f"MB/round gbps={rec['hbm_gbps'][mode]:.2f}")
             for mode, b in rec["bytes_per_round"].items()]
    rows.append(csv_row(
        f"topology_round/rsu_sharded_vs_replicated/d{d}",
        rec["round_s"]["rsu_sharded"] * 1e6,
        f"speedup={rec['rsu_sharded_vs_replicated']:.2f}x"
        f"@R{rec['n_rsus']}"))
    rows.append(csv_row(
        f"topology_round/flat_fused_vs_unfused/d{d}",
        rec["round_s"]["flat_fused"] * 1e6,
        f"speedup={rec['flat_fused_vs_unfused']:.2f}x"))
    return rows


def run() -> List[str]:
    """Harness entry (benchmarks.run --only topology): spawn the
    multi-device cell as a subprocess so it gets a fresh jax with the
    forced device count."""
    here = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count="
                        + str(HARNESS_DEVICES))
    env["PYTHONPATH"] = str(here / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.topology_round",
         "--devices", str(HARNESS_DEVICES)],
        capture_output=True, text=True, timeout=1800, env=env,
        cwd=str(here))
    if out.returncode != 0:
        raise RuntimeError(
            f"topology d{HARNESS_DEVICES} cell failed:\n"
            f"{out.stderr[-2000:]}")
    return [ln for ln in out.stdout.splitlines()
            if ln.startswith("topology_round/")]


def main():
    args = _parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    rec = run_cell(args)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"topology_round__d{rec['n_devices']}.json"
    path.write_text(json.dumps(rec, indent=1))
    for row in _csv_rows(rec):
        print(row)
    print(f"[json] {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
