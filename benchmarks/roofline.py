"""Roofline report (deliverable g): per (arch × shape × mesh) the three
roofline terms, the dominant bottleneck, MODEL_FLOPS = 6·N·D (6·N_active·D
for MoE), and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

Reads the dry-run artifacts under results/dryrun/ (produced by
``python -m repro.launch.dryrun --all``) — no device allocation here.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.common import RESULTS_DIR, csv_row
from repro.configs.registry import get_config

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def model_flops(arch: str, shape: str, n_chips: int) -> float:
    """MODEL_FLOPS per device: 6·N·D train (fwd+bwd), 2·N·D inference,
    with N = active params for MoE.  D = tokens processed by the step."""
    cfg = get_config(arch)
    n = cfg.n_active_params() if cfg.moe is not None else cfg.n_params()
    info = SHAPES[shape]
    if info["kind"] == "train":
        toks, mult = info["batch"] * info["seq"], 6
    elif info["kind"] == "prefill":
        toks, mult = info["batch"] * info["seq"], 2
    else:  # decode: one new token per sequence
        toks, mult = info["batch"], 2
    return mult * n * toks / n_chips


def load_records(dryrun_dir: str = None) -> List[Dict]:
    d = Path(dryrun_dir or os.path.join(RESULTS_DIR, "dryrun"))
    recs = []
    for p in sorted(d.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def summarize(recs: Optional[List[Dict]] = None) -> List[Dict]:
    recs = recs if recs is not None else load_records()
    out = []
    for r in recs:
        if r.get("skipped"):
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "mesh": r["mesh"], "skipped": r["skipped"]})
            continue
        mf = model_flops(r["arch"], r["shape"], r["n_chips"])
        hlo_f = r["cost"]["flops_per_device"]
        rl = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "step": r.get("step", "default"),
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "model_flops_per_dev": mf, "hlo_flops_per_dev": hlo_f,
            "useful_ratio": (mf / hlo_f) if hlo_f else 0.0,
            "peak_bytes": r["memory"]["peak_bytes"],
        })
    return out


def markdown_table(rows: List[Dict], mesh: str = "16x16") -> str:
    """EXPERIMENTS.md §Roofline table for one mesh (single-pod baseline)."""
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful FLOP ratio | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("mesh") != mesh or r.get("step", "default") != "default":
            continue
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| {r['dominant'].replace('_s', '')} "
            f"| {min(r['useful_ratio'], 9.99):.3f} "
            f"| {(r['peak_bytes'] or 0) / 2**30:.1f} |")
    return "\n".join(lines)


def run() -> List[str]:
    rows = summarize()
    csv = []
    for r in rows:
        if r.get("skipped"):
            csv.append(csv_row(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                               0.0, "skipped"))
            continue
        dom_s = r[r["dominant"]]
        csv.append(csv_row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", dom_s * 1e6,
            f"dom={r['dominant'].replace('_s','')} "
            f"useful={r['useful_ratio']:.3f}"))
    out = os.path.join(RESULTS_DIR, "roofline_summary.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    return csv


if __name__ == "__main__":
    rows = summarize()
    print(markdown_table(rows))
