"""Cohort-streamed round benchmark (DESIGN.md §8) — the PR-6 story.

Two cells:

  equivalence/throughput — the SAME small-A scenario through the resident
      ``engine="flat"`` round and the host-streamed round
      (``fleet_store="host"``): asserts streamed == resident to fp32
      tolerance, records steady-state agents/sec both ways (CI asserts
      the streamed path keeps >= 0.7x of resident at small A, where the
      python chunk loop is ALL overhead), the analytic host<->device
      bytes/round, and the compiled chunk step's device working set at
      two fleet sizes (must be equal — the bounded-working-set claim);

  fleet — a fleet far beyond device residency for the real (A, N) MLP:
      A = 1e6 agents (``REPRO_BENCH_FULL=1``; 100k at CI scale) on a tiny
      linear task, the per-agent data a zero-copy ``np.broadcast_to``
      view.  One streamed global round end-to-end, recording agents/sec
      at scale and host-fleet vs device-working-set bytes.

Standalone:
  PYTHONPATH=src python -m benchmarks.streaming_round [--agents 64]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import List


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=64)
    ap.add_argument("--rsus", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--lar", type=int, default=2)
    ap.add_argument("--n-train", type=int, default=32000)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--fleet-agents", type=int, default=0,
                    help="fleet-cell size (0 = 1e6 full / 100k CI)")
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _spec(args):
    from repro.core.h2fed import H2FedParams
    from repro.core.scenario import ScenarioSpec
    return ScenarioSpec(
        n_agents=args.agents, n_rsus=args.rsus, batch=16,
        n_train=args.n_train, n_test=200,
        hp=H2FedParams(mu1=0.01, mu2=0.005, lar=args.lar, local_epochs=1,
                       lr=0.1),
        rounds=args.rounds)


def _interleaved_round_s(paths, n_rounds: int, reps: int = 3):
    """Steady-state per-round seconds for each (step, state) path —
    measured in alternating batches, best-of-``reps`` per path, so shared-
    CPU noise hits both paths alike instead of whichever ran last."""
    import jax
    states, best = [], [float("inf")] * len(paths)
    for step, state in paths:
        state = step(step(state))            # compile + warmup
        jax.block_until_ready(state.cloud_flat)
        states.append(state)
    for _ in range(reps):
        for i, (step, _) in enumerate(paths):
            t0 = time.perf_counter()
            for _ in range(n_rounds):
                states[i] = step(states[i])
            jax.block_until_ready(states[i].cloud_flat)
            best[i] = min(best[i], (time.perf_counter() - t0) / n_rounds)
    return best


def _chunk_step_footprint(round_fn, fed, fspec, n_rsus: int):
    """Device bytes of the compiled chunk step (ShapeDtypeStruct lowering
    — nothing is executed)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.hlo_analysis import memory_footprint
    plan = round_fn.plan
    xs, ys = np.asarray(fed.x), np.asarray(fed.y)
    S, R, n = jax.ShapeDtypeStruct, n_rsus, fspec.n
    args = (S((R, n), jnp.float32), S((R,), jnp.float32),
            S((R, n), fspec.storage_dtype), S((n,), jnp.float32),
            S((plan.chunk,) + xs.shape[1:], xs.dtype),
            S((plan.chunk,) + ys.shape[1:], ys.dtype),
            S((plan.chunk,), jnp.int32),
            S((plan.chunk,), jnp.float32),
            S((plan.chunk,), jnp.int32))
    return memory_footprint(round_fn.chunk_step, *args)


def equivalence_cell(args) -> dict:
    import jax
    import numpy as np

    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core import flatten
    from repro.fedsim import run_scenario
    from repro.fedsim.simulator import init_flat_state, make_flat_global_round
    from repro.fedsim.streaming import (init_stream_state,
                                        make_streamed_flat_round,
                                        streamed_transfer_bytes)
    from repro.models import mlp

    spec = _spec(args)
    res = spec.resolve()
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    fspec = flatten.spec_of(params)

    # -- streamed == resident (fp32 tol), through THE engine entry point --
    st_res, h_res = run_scenario(res, params)
    st_str, h_str = run_scenario(
        spec.replace(fleet_store="host", chunk_agents=args.chunk), params)
    np.testing.assert_allclose(h_str["acc"], h_res["acc"], rtol=0,
                               atol=5e-5)
    np.testing.assert_allclose(
        np.asarray(st_str.cloud_flat),
        np.asarray(flatten.spec_of(st_res.cloud_params)
                   .ravel(st_res.cloud_params)), rtol=0, atol=1e-5)

    # -- steady-state agents/sec: resident fused round vs streamed round --
    resident_fn = make_flat_global_round(res.cfg, res.hp, res.het, res.fed,
                                         fspec)
    streamed_fn = make_streamed_flat_round(res.cfg, res.hp, res.het,
                                           res.fed, fspec,
                                           chunk_agents=args.chunk)
    rs_resident, rs_streamed = _interleaved_round_s(
        [(resident_fn, init_flat_state(res.cfg, fspec, params,
                                       jax.random.key(res.cfg.seed))),
         (streamed_fn, init_stream_state(res.cfg, fspec, params,
                                         jax.random.key(res.cfg.seed)))],
        args.rounds)

    # -- bounded working set: chunk-step device bytes must not grow with A
    fp_small = _chunk_step_footprint(streamed_fn, res.fed, fspec, args.rsus)
    big = spec.replace(n_agents=3 * args.agents,
                       n_train=3 * args.n_train).resolve()
    fn_big = make_streamed_flat_round(big.cfg, big.hp, big.het, big.fed,
                                      fspec, chunk_agents=args.chunk)
    fp_big = _chunk_step_footprint(fn_big, big.fed, fspec, args.rsus)
    bounded = (fp_small["total_bytes"] == fp_big["total_bytes"]
               and fp_small["temp_bytes"] == fp_big["temp_bytes"])

    xfer = streamed_transfer_bytes(streamed_fn.plan, fspec, spec.hp,
                                   res.fed)
    A = args.agents
    return {
        "bench": "streaming_round",
        "n_agents": A, "n_rsus": args.rsus, "lar": args.lar,
        "chunk_agents": args.chunk, "n_rounds": args.rounds,
        "n_params": fspec.n,
        "round_s": {"resident": rs_resident, "streamed": rs_streamed},
        "agents_per_s": {"resident": A / max(rs_resident, 1e-12),
                         "streamed": A / max(rs_streamed, 1e-12)},
        "streamed_vs_resident_agents_per_s":
            rs_resident / max(rs_streamed, 1e-12),
        "streamed_equals_resident": True,     # the asserts above passed
        "bytes_per_round": {"streamed_h2d": xfer["h2d"],
                            "streamed_d2h": xfer["d2h"]},
        "host_device_bytes_per_round": xfer["total"],
        "peak_device_working_set_bytes": fp_small["total_bytes"],
        "working_set_bounded_by_chunk": bounded,
    }


# -- the fleet cell: one streamed round over a million-agent host fleet --

_FLEET_D, _FLEET_C, _FLEET_S = 16, 4, 4     # features, classes, samples


def _linear_loss(params, x, y):
    import jax
    import jax.numpy as jnp
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def fleet_cell(args) -> dict:
    import jax
    import numpy as np

    from repro.core import flatten
    from repro.core.h2fed import H2FedParams
    from repro.core.heterogeneity import HeterogeneityModel
    from repro.data.partition import FederatedData
    from repro.fedsim.simulator import SimConfig
    from repro.fedsim.streaming import (init_stream_state,
                                        make_streamed_flat_round)

    full = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
    A = args.fleet_agents or (1_000_000 if full else 100_000)
    R, chunk = 16, 16_384
    rng = np.random.default_rng(0)
    # every agent sees the same tiny shard — a zero-copy broadcast view,
    # so the host cost is the FLEET (A, N) buffer, not the data
    x1 = rng.normal(size=(1, _FLEET_S, _FLEET_D)).astype(np.float32)
    y1 = rng.integers(0, _FLEET_C, size=(1, _FLEET_S)).astype(np.int32)
    fed = FederatedData(
        x=np.broadcast_to(x1, (A, _FLEET_S, _FLEET_D)),
        y=np.broadcast_to(y1, (A, _FLEET_S)),
        n_per_agent=np.broadcast_to(np.int32(_FLEET_S), (A,)),
        rsu_assign=(np.arange(A, dtype=np.int32) % R))

    cfg = SimConfig(n_agents=A, n_rsus=R, batch=_FLEET_S, seed=0)
    hp = H2FedParams(mu1=0.01, mu2=0.005, lar=1, local_epochs=1, lr=0.1)
    het = HeterogeneityModel(csr=1.0)
    params = {"w": np.zeros((_FLEET_D, _FLEET_C), np.float32),
              "b": np.zeros((_FLEET_C,), np.float32)}
    fspec = flatten.spec_of(jax.tree.map(jax.numpy.asarray, params))

    round_fn = make_streamed_flat_round(cfg, hp, het, fed, fspec,
                                        _linear_loss, chunk_agents=chunk)
    state = init_stream_state(cfg, fspec, params, jax.random.key(0))
    fp = _chunk_step_footprint(round_fn, fed, fspec, R)

    t0 = time.perf_counter()
    state = round_fn(state)
    jax.block_until_ready(state.cloud_flat)
    wall = time.perf_counter() - t0
    assert np.isfinite(np.asarray(state.cloud_flat)).all()

    return {
        "fleet_n_agents": A,
        "fleet_chunk_agents": chunk,
        "fleet_n_chunks": round_fn.plan.n_chunks,
        "fleet_round_s": wall,
        "fleet_agents_per_s": A / max(wall, 1e-12),
        "fleet_host_store_bytes": state.store.nbytes,
        "fleet_device_working_set_bytes": fp["total_bytes"],
    }


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    return [
        csv_row("streaming_round/resident", rec["round_s"]["resident"]
                * 1e6, f"A{rec['n_agents']} "
                f"{rec['agents_per_s']['resident']:.0f} agents/s"),
        csv_row("streaming_round/streamed", rec["round_s"]["streamed"]
                * 1e6, f"chunk{rec['chunk_agents']} "
                f"{rec['agents_per_s']['streamed']:.0f} agents/s, "
                f"ratio={1 / rec['streamed_vs_resident_agents_per_s']:.2f}"),
        csv_row("streaming_round/h2d+d2h",
                rec["host_device_bytes_per_round"],
                "analytic host<->device bytes/round"),
        csv_row("streaming_round/fleet", rec["fleet_round_s"] * 1e6,
                f"A{rec['fleet_n_agents']} host fleet "
                f"{rec['fleet_host_store_bytes'] / 1e6:.0f}MB, device "
                f"{rec['fleet_device_working_set_bytes'] / 1e6:.1f}MB, "
                f"{rec['fleet_agents_per_s']:.0f} agents/s"),
    ]


def _record(args) -> dict:
    rec = equivalence_cell(args)
    rec.update(fleet_cell(args))
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "streaming_round.json"
    path.write_text(json.dumps(rec, indent=1))
    print(f"[json] {path}", file=sys.stderr)
    return rec


def run() -> List[str]:
    """Harness entry (benchmarks.run --only streaming): defaults only —
    the harness owns argv."""
    args = argparse.Namespace(
        agents=64, rsus=4, rounds=3, lar=2, n_train=32000, chunk=32,
        fleet_agents=0,
        out=os.environ.get("REPRO_RESULTS", "results") + "/bench")
    return _csv_rows(_record(args))


def main():
    for row in _csv_rows(_record(_parse_args())):
        print(row)


if __name__ == "__main__":
    main()
