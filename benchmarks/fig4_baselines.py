"""Fig. 4 — H²-Fed vs FedProx vs HierFAVG (+ FedAvg) at CSR = 10%, SCD = 1.

Scenario I:  Non-IID across RSUs (agents within an RSU share a distribution).
Scenario II: Non-IID across agents (each RSU cohort covers all labels).

Paper claims reproduced here:
  * H²-Fed enhances the pre-trained model stably from start to convergence,
    while HierFAVG's curve jitters visibly (Scenario I);
  * H²-Fed outperforms FedProx remarkably in Scenario II (pre-aggregation
    accelerates convergence).

The grid is declared as ``ScenarioSpec``s and run through the sweep
engine: methods sharing program structure (same LAR — h2fed/hierfavg and
fedprox/fedavg pairs) and partition batch into one compiled program each;
their mu values are (S,)-batched scalars.
"""
from __future__ import annotations

import json
import os
from typing import List

import numpy as np

from benchmarks import metrics
from benchmarks.common import RESULTS_DIR, base_spec, build_pipeline, \
    csv_row, run_cells, seed_variants
from repro.core.baselines import BASELINES
from repro.core.heterogeneity import HeterogeneityModel

CSR = 0.1
SCD = 1
LAR = 5
TAIL = 8
N_ROUNDS_FIG4 = 40   # the paper's CSR=10% runs need the longer horizon
N_SEEDS = 2

METHODS = {
    "h2fed": dict(mu1=0.001, mu2=0.005, lar=LAR, lr=0.1, local_epochs=2),
    "hierfavg": dict(lar=LAR, lr=0.1, local_epochs=2),
    "fedprox": dict(mu=0.001, lr=0.1, local_epochs=2),
    "fedavg": dict(lr=0.1, local_epochs=2),
}


def grid(n_rounds: int | None = None, seed: int = 0) -> List:
    """Labeled cells: ((scenario, method), seed specs)."""
    cells = []
    for scenario in (1, 2):
        for name, kw in METHODS.items():
            hp = BASELINES[name](**kw)
            cells.append(((scenario, name), seed_variants(base_spec(
                partition=scenario, hp=hp,
                het=HeterogeneityModel(csr=CSR, scd=SCD, lar=hp.lar),
                rounds=n_rounds or N_ROUNDS_FIG4, seed=seed), N_SEEDS)))
    return cells


def run(n_rounds: int | None = None, seed: int = 0) -> List[str]:
    cells = grid(n_rounds, seed)
    pipe = build_pipeline(cells[0][1][0])
    curves, _, wall = run_cells(cells)
    per_cell = wall / len(cells)

    rows: List[str] = []
    results = {}
    for scenario in (1, 2):
        for name in METHODS:
            acc = curves[(scenario, name)]
            tail_acc = float(np.mean(acc[-TAIL:]))
            jit = metrics.jitter(acc, tail=len(acc) // 2)
            results[f"s{scenario}/{name}"] = {
                "acc": np.asarray(acc).tolist(), "final": tail_acc,
                "jitter": jit}
            rows.append(csv_row(
                f"fig4/scenario{scenario}/{name}", per_cell / len(acc) * 1e6,
                f"final={tail_acc:.4f} jitter={jit:.4f}"))
    out = os.path.join(RESULTS_DIR, "fig4_baselines.json")
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"pre_acc": pipe.pre_acc, "results": results}, f, indent=1)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
