"""Metrics used by the paper's figures.

AED (Eq. 7): accuracy-enhancement degree of switching mu1 on,
relative to the mu1=0 enhancement over the pre-trained model.
"""
from __future__ import annotations

import numpy as np


def aed(acc_mu1: float, acc_mu1_zero: float, *, acc_pre: float) -> float:
    """AED = (dACC^{mu1>0} - dACC^{mu1=0}) / dACC^{mu1=0}  (paper Eq. 7)."""
    d_on = acc_mu1 - acc_pre
    d_off = acc_mu1_zero - acc_pre
    if d_off == 0.0:
        return 0.0 if d_on == d_off else float("inf") * np.sign(d_on - d_off)
    return (d_on - d_off) / d_off


def aed_curve(acc_on: np.ndarray, acc_off: np.ndarray,
              acc_pre: float) -> np.ndarray:
    """Vectorized AED over a per-round accuracy history."""
    d_on = np.asarray(acc_on) - acc_pre
    d_off = np.asarray(acc_off) - acc_pre
    with np.errstate(divide="ignore", invalid="ignore"):
        out = (d_on - d_off) / d_off
    return np.where(d_off == 0.0, 0.0, out)


def jitter(acc: np.ndarray, tail: int = 0) -> float:
    """Stability metric (Fig. 3): std of the round-to-round accuracy
    differences over the (optionally tail-windowed) history."""
    a = np.asarray(acc, np.float64)
    if tail:
        a = a[-tail:]
    if len(a) < 2:
        return 0.0
    return float(np.std(np.diff(a)))


def mse_to_reference(acc: np.ndarray, ref: np.ndarray) -> float:
    """MSE of the testing-accuracy curve to the centralized-learning
    reference curve (Fig. 3, second row)."""
    a, r = np.asarray(acc, np.float64), np.asarray(ref, np.float64)
    n = min(len(a), len(r))
    return float(np.mean((a[:n] - r[:n]) ** 2))
