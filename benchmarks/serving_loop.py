"""Continuous-serving loop benchmark (DESIGN.md §9) — the PR-7 story.

Four cells, one record (``BENCH_PR7.json`` via ``benchmarks.run
--summary``):

  nominal — a seeded Poisson load at the design rate (one update per agent
      per tick window) through the full event loop, with a live inference
      probe against the cloud snapshot every tick: sustained updates/sec,
      steady-state p50/p99 tick latency (tick 0 carries the jit compile
      and is excluded), queue depth, model staleness and the final
      accuracy.  CI asserts ZERO drops here — nominal load must not shed.

  anchor — the batch↔serving equivalence: an every-agent-once-per-window
      trace with decay disabled must reproduce ``engine="async"``'s final
      cloud master (``serving_equals_async``).

  overload — arrivals at several times the service rate into a one-fleet
      queue under ``deadline`` ticks and ``drop_oldest``: drop counters,
      drop rate, and staleness-under-load vs the nominal cell.

  replay — the determinism seam: dump the nominal Poisson schedule to
      JSONL, re-run from the trace, require the bit-identical final cloud
      master (``trace_replay_deterministic``).

Standalone:
  PYTHONPATH=src python -m benchmarks.serving_loop [--agents 24]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from pathlib import Path
from typing import List


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=24)
    ap.add_argument("--rsus", type=int, default=4)
    ap.add_argument("--windows", type=int, default=20,
                    help="nominal load length in tick windows")
    ap.add_argument("--n-train", type=int, default=2400)
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _spec(args, **kw):
    from repro.core.h2fed import H2FedParams
    from repro.core.scenario import ScenarioSpec
    return ScenarioSpec(
        n_agents=args.agents, n_rsus=args.rsus, batch=16,
        n_train=args.n_train, n_test=400,
        hp=H2FedParams(mu1=0.01, mu2=0.005, lar=2, local_epochs=1, lr=0.1),
        engine="async", staleness_decay=1.0, rounds=2, **kw)


def nominal_cell(args) -> dict:
    from repro.fedsim.serving import run_serve_loop

    A = args.agents
    spec = _spec(args, serve_events=A * args.windows, arrival_rate=1.0,
                 tick_trigger="auto", queue_capacity=4 * A)
    res = spec.resolve()
    t0 = time.perf_counter()
    state, hist, stats, server = run_serve_loop(res,
                                                probe_x=res.test.x[:64])
    wall = time.perf_counter() - t0
    s = stats.summary()
    return {
        "bench": "serving_loop",
        "n_agents": A, "n_rsus": args.rsus,
        "n_events": stats.events_generated,
        "n_ticks": stats.n_ticks,
        "round_s": {"serving_wall": wall},
        "updates_per_s": s["updates_per_s"],
        "tick_p50_ms": s["tick_p50_ms"],
        "tick_p99_ms": s["tick_p99_ms"],
        "queue_depth_mean": s["queue_depth_mean"],
        "queue_depth_max": s["queue_depth_max"],
        "events_dropped_nominal": stats.events_dropped,
        "events_coalesced": stats.events_coalesced,
        "event_wait_mean": s["event_wait_mean"],
        "model_staleness_mean": s["model_staleness_mean"],
        "serve_p50_ms": s["serve_p50_ms"],
        "serve_requests": stats.serve_requests,
        "final_acc": float(hist["acc"][-1]) if len(hist["acc"]) else None,
    }


def anchor_cell(args) -> dict:
    import numpy as np

    from repro.core.load_gen import every_agent_once_trace
    from repro.fedsim import run_scenario
    from repro.fedsim.serving import run_serve_loop

    A, rounds = args.agents, 3
    spec_a = _spec(args).replace(rounds=rounds)
    st_a, _ = run_scenario(spec_a)
    lar = spec_a.hp.lar
    spec_s = spec_a.replace(serve_events=A * lar * rounds,
                            tick_trigger=f"batch:{A}")
    st_s, _, _, _ = run_serve_loop(
        spec_s.resolve(), gen=every_agent_once_trace(A, lar * rounds))
    np.testing.assert_allclose(np.asarray(st_s.cloud_flat),
                               np.asarray(st_a.cloud_flat),
                               rtol=2e-5, atol=2e-6)
    return {"serving_equals_async": True}


def overload_cell(args) -> dict:
    """4x the nominal arrival rate into a one-fleet queue, both overload
    policies: ``drop_oldest`` sheds (a deadline longer than the queue's
    eviction horizon means sustained load keeps only the freshest fleet's
    worth), ``backpressure`` keeps everything and pays for it in deferred
    admissions and model staleness."""
    from repro.fedsim.serving import run_serve_loop

    A = args.agents
    base = dict(serve_events=A * args.windows, arrival_rate=4.0,
                queue_capacity=A)
    spec_d = _spec(args, tick_trigger="deadline:4.0",
                   overload_policy="drop_oldest", **base)
    _, _, sd, _ = run_serve_loop(spec_d.resolve())
    assert sd.events_generated == (sd.events_absorbed
                                   + sd.events_coalesced
                                   + sd.events_dropped)
    spec_b = _spec(args, tick_trigger=f"batch:{2 * A}",
                   overload_policy="backpressure", **base)
    _, _, sb, _ = run_serve_loop(spec_b.resolve())
    assert sb.events_dropped == 0
    assert sb.events_generated == sb.events_absorbed + sb.events_coalesced
    return {"overload": {
        "arrival_rate": 4.0,
        "queue_capacity": A,
        "events_dropped": sd.events_dropped,
        "drop_rate": sd.events_dropped / max(sd.events_generated, 1),
        "event_wait_mean": sd.summary()["event_wait_mean"],
        "queue_depth_max": sd.summary()["queue_depth_max"],
        "backpressure_deferred": sb.events_deferred,
        "backpressure_ticks": sb.n_ticks,
        "backpressure_wait_mean": sb.summary()["event_wait_mean"],
        "backpressure_staleness_mean":
            sb.summary()["model_staleness_mean"],
    }}


def replay_cell(args) -> dict:
    import numpy as np

    from repro.core.load_gen import (PoissonLoadGen, agent_rates,
                                     write_trace)
    from repro.fedsim.serving import run_serve_loop

    A = args.agents
    n_ev = A * args.windows // 2
    spec = _spec(args, serve_events=n_ev, arrival_rate=1.5,
                 tick_trigger=f"batch:{A // 2},deadline:2.0",
                 queue_capacity=2 * A)
    res = spec.resolve()
    st1, _, s1, _ = run_serve_loop(res)

    rates = agent_rates(spec.het, A, spec.arrival_rate, seed=res.cfg.seed)
    evs = PoissonLoadGen(rates, seed=res.cfg.seed, n_events=n_ev).take(n_ev)
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "trace.jsonl")
        write_trace(evs, p)
        st2, _, s2, _ = run_serve_loop(
            spec.replace(serve_trace=p).resolve())
    same_schedule = (s1.drain_sizes == s2.drain_sizes
                     and s1.queue_depth == s2.queue_depth)
    np.testing.assert_array_equal(np.asarray(st1.cloud_flat),
                                  np.asarray(st2.cloud_flat))
    return {"trace_replay_deterministic": bool(same_schedule)}


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    ov = rec["overload"]
    return [
        csv_row("serving_loop/tick", rec["tick_p50_ms"] * 1e3,
                f"p99={rec['tick_p99_ms']:.1f}ms "
                f"{rec['updates_per_s']:.0f} upd/s "
                f"depth<= {rec['queue_depth_max']}"),
        csv_row("serving_loop/nominal-drops",
                rec["events_dropped_nominal"],
                f"of {rec['n_events']} events (must be 0), "
                f"acc={rec['final_acc']}"),
        csv_row("serving_loop/overload-drops", ov["events_dropped"],
                f"rate x4 cap {ov['queue_capacity']}: "
                f"{100 * ov['drop_rate']:.0f}% shed; backpressure "
                f"deferred {ov['backpressure_deferred']} over "
                f"{ov['backpressure_ticks']} ticks"),
        csv_row("serving_loop/anchors",
                int(rec["serving_equals_async"])
                + int(rec["trace_replay_deterministic"]),
                "serving==async + replay-deterministic (want 2)"),
    ]


def _record(args) -> dict:
    rec = nominal_cell(args)
    rec.update(anchor_cell(args))
    rec.update(overload_cell(args))
    rec.update(replay_cell(args))
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "serving_loop.json"
    path.write_text(json.dumps(rec, indent=1))
    print(f"[json] {path}", file=sys.stderr)
    return rec


def run() -> List[str]:
    """Harness entry (benchmarks.run --only serving): defaults only —
    the harness owns argv."""
    args = argparse.Namespace(
        agents=24, rsus=4, windows=20, n_train=2400,
        out=os.environ.get("REPRO_RESULTS", "results") + "/bench")
    return _csv_rows(_record(args))


def main():
    for row in _csv_rows(_record(_parse_args())):
        print(row)


if __name__ == "__main__":
    main()
