"""Sync-vs-async engine benchmark (DESIGN.md §6) for the BENCH json flow.

Measurements on the SAME federated workload:

  * round latency — one compiled global round of engine="flat" (the
    synchronous barrier) vs engine="async" (staleness-weighted RSU buffers,
    in-flight delivery bookkeeping): what the semi-async machinery costs.
  * 90%-disconnect convergence — the paper's headline regime: only 10% of
    agents are TIMELY per tick.  The sync engine sees csr=0.1 and discards
    everything else; the async engine sees the same timely rate
    (csr_async · P(delay=0) == 0.1) but additionally merges the delayed
    majority late (staleness-decayed) instead of discarding their work.
    The record lands in the bench JSON artifact so the convergence
    trajectory is tracked per PR.
  * one-pass round program (DESIGN.md §3): bytes-per-round of the compiled
    async tick program via ``launch/hlo_analysis.round_cost`` — today's
    multi-pass fp32 program (``fused=False``) vs the fused
    aggregate-and-blend path vs fused + bf16 fleet storage — plus achieved
    HBM GB/s next to the round latency, and the headline
    ``fused_bf16_vs_unfused_f32_bytes`` reduction factor.

Standalone:
  PYTHONPATH=src python -m benchmarks.async_round \
      [--agents 20 --rsus 4 --rounds 2 --conv-rounds 6 --csr 0.1 \
       --max-delay 2 --out results/bench]

Via the harness:
  PYTHONPATH=src python -m benchmarks.run --only async
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path
from typing import List


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=20)
    ap.add_argument("--rsus", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=2, help="timed rounds")
    ap.add_argument("--conv-rounds", type=int, default=6,
                    help="convergence-record rounds")
    ap.add_argument("--lar", type=int, default=2)
    ap.add_argument("--csr", type=float, default=0.1,
                    help="connection success ratio (0.1 = 90%% disconnect)")
    ap.add_argument("--max-delay", type=int, default=2)
    ap.add_argument("--delay-p", type=float, default=0.6)
    ap.add_argument("--staleness-decay", type=float, default=0.5)
    ap.add_argument("--buffer-keep", type=float, default=0.5)
    ap.add_argument("--n-train", type=int, default=2000)
    ap.add_argument("--out", default=os.environ.get("REPRO_RESULTS",
                                                    "results") + "/bench")
    return ap.parse_args()


def _time_rounds(round_fn, state, n: int, unpack: bool = False) -> float:
    """Mean per-round wall seconds, compile + relayout warmup excluded.
    The round jits donate their input state, so every call rebinds."""
    import jax

    def step(s):
        out = round_fn(s)
        return out[0] if unpack else out

    state = step(step(state))                    # compile x2 + warmup
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(n):
        state = step(state)
    jax.block_until_ready(state)
    return (time.perf_counter() - t0) / n


def run_cell(args) -> dict:
    import jax

    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core import flatten
    from repro.core.baselines import h2fed
    from repro.core.heterogeneity import HeterogeneityModel
    from repro.data.partition import scenario_two
    from repro.data.synthetic import mnist_class_task
    from repro.fedsim.async_engine import (AsyncConfig, init_async_state,
                                           make_async_global_round)
    from repro.fedsim.simulator import (SimConfig, init_flat_state,
                                        make_flat_global_round)
    from repro.fedsim.sweep import adhoc_scenario, run_scenario
    from repro.models import mlp

    train, test = mnist_class_task(n_train=args.n_train, n_test=400, seed=0)
    fed = scenario_two(train, n_agents=args.agents, n_rsus=args.rsus,
                       seed=0)
    cfg = SimConfig(n_agents=args.agents, n_rsus=args.rsus, batch=16,
                    seed=0)
    hp = h2fed(mu1=0.1, mu2=0.005, lar=args.lar, lr=0.1)
    het_sync = HeterogeneityModel(csr=args.csr, lar=hp.lar)
    # same TIMELY participation as sync: csr_async·P(d=0) == csr; the
    # delayed majority is the straggler work async recovers late
    p_fresh = 1.0 - args.delay_p if args.max_delay else 1.0
    csr_async = min(1.0, args.csr / max(p_fresh, args.csr))
    het_async = HeterogeneityModel(csr=csr_async, lar=hp.lar,
                                   max_delay=args.max_delay,
                                   delay_p=args.delay_p)
    acfg = AsyncConfig(staleness_decay=args.staleness_decay,
                       buffer_keep=args.buffer_keep)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    spec = flatten.spec_of(params)

    # --- round latency: the barrier engine vs the semi-async engine ---
    # (fresh key per engine: the donated round jits consume their input
    # state, including the rng key buffer)
    def fstate():
        return init_flat_state(cfg, spec, params, jax.random.key(cfg.seed))

    def astate(s=spec):
        return init_async_state(cfg, s, params, jax.random.key(cfg.seed))

    flat_round = make_flat_global_round(cfg, hp, het_sync, fed, spec)
    t_flat = _time_rounds(flat_round, fstate(), args.rounds)
    async_round = make_async_global_round(cfg, hp, het_async, fed, spec,
                                          acfg)
    t_async = _time_rounds(async_round, astate(), args.rounds, unpack=True)

    # --- one-pass program A/B: fused vs the pre-fusion multi-pass round,
    # and the bf16 fleet-storage mode (DESIGN.md §3 dtype policy) ---
    spec16 = flatten.spec_of(params, storage_dtype="bfloat16")
    async_unfused = make_async_global_round(cfg, hp, het_async, fed, spec,
                                            acfg, fused=False)
    async_bf16 = make_async_global_round(cfg, hp, het_async, fed, spec16,
                                         acfg)
    t_async_unfused = _time_rounds(async_unfused, astate(), args.rounds,
                                   unpack=True)
    t_async_bf16 = _time_rounds(async_bf16, astate(spec16), args.rounds,
                                unpack=True)

    # bytes-per-round of the compiled programs (per-device HBM traffic,
    # trip counts applied) + achieved GB/s at the measured latency
    from repro.launch.hlo_analysis import round_cost
    costs = {
        "flat": round_cost(flat_round, fstate(), latency_s=t_flat),
        "async": round_cost(async_round, astate(), latency_s=t_async),
        "async_unfused_f32": round_cost(async_unfused, astate(),
                                        latency_s=t_async_unfused),
        "async_fused_bf16": round_cost(async_bf16, astate(spec16),
                                       latency_s=t_async_bf16),
    }
    bytes_ratio = (costs["async_unfused_f32"]["bytes"]
                   / max(costs["async_fused_bf16"]["bytes"], 1.0))

    # --- the tick's RSU layer in isolation (the part the fusion targets;
    # the full round above is dominated by the training scan at this tiny
    # model/steps ratio): today's multi-pass fp32 program — two
    # scatter-accumulates, numerator add, buffer_absorb re-read — vs the
    # fused one-pass aggregate-and-absorb on bf16 fleet buffers ---
    import jax.numpy as jnp
    import numpy as np
    from repro.core.aggregation import buffer_absorb
    from repro.kernels import ops
    rng_t = np.random.default_rng(0)
    A, R, N = cfg.n_agents, cfg.n_rsus, spec.n
    assign = jnp.asarray(fed.rsu_assign)

    def tick_args(dtype):
        return (jnp.asarray(rng_t.standard_normal((A, N)), dtype),
                jnp.asarray(rng_t.standard_normal((A, N)), dtype),
                jnp.asarray(rng_t.uniform(0, 2, A), jnp.float32),
                jnp.asarray(rng_t.uniform(0, 2, A), jnp.float32),
                jnp.asarray(rng_t.standard_normal((R, N)), dtype),
                jnp.asarray(rng_t.uniform(0, 5, R), jnp.float32))

    @jax.jit
    def tick_unfused(agent_flat, pend_x, w_imm, w_due, rsu, rsu_mass):
        num_i, m_i = ops.masked_scatter_accumulate(agent_flat, w_imm,
                                                   assign, R)
        num_d, m_d = ops.masked_scatter_accumulate(pend_x, w_due, assign, R)
        return buffer_absorb(rsu, rsu_mass, num_i + num_d, m_i + m_d,
                             keep=args.buffer_keep)

    @jax.jit
    def tick_fused(agent_flat, pend_x, w_imm, w_due, rsu, rsu_mass):
        out, total, _ = ops.agg_absorb(
            ((agent_flat, w_imm), (pend_x, w_due)), assign, R, rsu,
            rsu_mass, keep=args.buffer_keep)
        return out, total

    tick_costs = {
        "unfused_f32": round_cost(tick_unfused, *tick_args(jnp.float32)),
        "fused_bf16": round_cost(tick_fused, *tick_args(jnp.bfloat16)),
    }
    tick_ratio = (tick_costs["unfused_f32"]["bytes"]
                  / max(tick_costs["fused_bf16"]["bytes"], 1.0))

    # --- 90%-disconnect convergence record: sync barrier vs late merges ---
    _, h_sync = run_scenario(
        adhoc_scenario(cfg, hp, het_sync, fed, n_rounds=args.conv_rounds,
                       engine="flat", x_test=test.x, y_test=test.y), params)
    _, h_async = run_scenario(
        adhoc_scenario(cfg, hp, het_async, fed, n_rounds=args.conv_rounds,
                       engine="async", async_cfg=acfg, x_test=test.x,
                       y_test=test.y), params)

    return {
        "bench": "async_round",
        "n_devices": len(jax.devices()),
        "n_agents": args.agents,
        "n_rsus": args.rsus,
        "lar": args.lar,
        "n_params": spec.n,
        "csr": args.csr,
        "csr_async": csr_async,
        "max_delay": args.max_delay,
        "staleness_decay": args.staleness_decay,
        "buffer_keep": args.buffer_keep,
        "round_s": {"flat": t_flat, "async": t_async,
                    "async_unfused_f32": t_async_unfused,
                    "async_fused_bf16": t_async_bf16},
        "async_vs_flat": t_flat / max(t_async, 1e-12),
        "bytes_per_round": {k: c["bytes"] for k, c in costs.items()},
        "collective_bytes_per_round":
            {k: c["collective_bytes"] for k, c in costs.items()},
        "hbm_gbps": {k: c["hbm_gbps"] for k, c in costs.items()},
        "fused_bf16_vs_unfused_f32_bytes": bytes_ratio,
        "tick_bytes": {k: c["bytes"] for k, c in tick_costs.items()},
        "tick_fused_bf16_vs_unfused_f32_bytes": tick_ratio,
        "convergence": {
            "round": [int(r) for r in h_sync["round"]],
            "acc_sync": [float(a) for a in h_sync["acc"]],
            "acc_async": [float(a) for a in h_async["acc"]],
            "absorbed_mass_async":
                [float(m) for m in h_async["absorbed_mass"]],
            "pending_mass_async":
                [float(m) for m in h_async["pending_mass"]],
        },
    }


def _csv_rows(rec: dict) -> List[str]:
    from benchmarks.common import csv_row
    rows = [csv_row(f"async_round/{eng}", s * 1e6,
                    f"A{rec['n_agents']}xR{rec['n_rsus']}")
            for eng, s in rec["round_s"].items()]
    rows += [csv_row(f"async_round/bytes/{eng}", b / 1e6,
                     f"MB/round gbps={rec['hbm_gbps'][eng]:.2f}")
             for eng, b in rec["bytes_per_round"].items()]
    rows.append(csv_row(
        "async_round/fused_bf16_vs_unfused_f32_bytes",
        rec["fused_bf16_vs_unfused_f32_bytes"] * 1e6,
        f"{rec['fused_bf16_vs_unfused_f32_bytes']:.2f}x fewer HBM bytes"))
    rows.append(csv_row(
        "async_round/tick_fused_bf16_vs_unfused_f32_bytes",
        rec["tick_fused_bf16_vs_unfused_f32_bytes"] * 1e6,
        f"{rec['tick_fused_bf16_vs_unfused_f32_bytes']:.2f}x fewer "
        f"HBM bytes (tick RSU layer)"))
    conv = rec["convergence"]
    rows.append(csv_row("async_round/conv_final_sync",
                        conv["acc_sync"][-1] * 1e6,
                        f"csr={rec['csr']}"))
    rows.append(csv_row("async_round/conv_final_async",
                        conv["acc_async"][-1] * 1e6,
                        f"csr={rec['csr']} D={rec['max_delay']}"))
    return rows


def run() -> List[str]:
    """Harness entry (benchmarks.run --only async): one in-process cell —
    device count is irrelevant (both engines are single-jit programs)."""
    args = _parse_args_default()
    rec = run_cell(args)
    _write(rec, Path(args.out))
    return _csv_rows(rec)


def _parse_args_default():
    import sys
    argv, sys.argv = sys.argv, [sys.argv[0]]
    try:
        return _parse_args()
    finally:
        sys.argv = argv


def _write(rec: dict, out_dir: Path):
    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / "async_round.json"
    path.write_text(json.dumps(rec, indent=1))
    return path


def main():
    import sys
    args = _parse_args()
    rec = run_cell(args)
    path = _write(rec, Path(args.out))
    for row in _csv_rows(rec):
        print(row)
    print(f"[json] {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
