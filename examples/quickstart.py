"""Quickstart: enhance a biased pre-trained model with H²-Fed in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

The whole experiment is ONE declarative ``ScenarioSpec`` (core/scenario):
a synthetic 10-class task, an OEM pre-training pool with labels {7,8,9}
excluded (the deliberately biased "68%" model), a federated fleet of 20
traffic agents under 4 RSUs (Non-IID Scenario II), and the H²-Fed
hierarchical round with dual proximal terms under bad communication
(CSR = 30%).  ``fedsim.run_scenario`` is the single entry point for every
engine — ``engine="async"`` / ``"sharded"`` or cohort streaming
(``fleet_store="host"``) are one-field changes to the spec.
"""
import jax

from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core.baselines import h2fed
from repro.core.heterogeneity import HeterogeneityModel
from repro.core.scenario import ScenarioSpec
from repro.fedsim import pretrain_to_target, run_scenario
from repro.models import mlp


def main():
    # 1. the experiment cell: dataset + biased-pretrain recipe + partition
    #    + framework / heterogeneity knobs + engine choice, in one spec
    hp = h2fed(mu1=0.001, mu2=0.005, lar=4, lr=0.1)
    spec = ScenarioSpec(
        n_agents=20, n_rsus=4, batch=32,
        n_train=6_000, n_test=1_000,
        excluded_labels=(7, 8, 9), pretrain_frac=0.25,
        pretrain_target=0.62,
        partition="scenario_two",
        hp=hp, het=HeterogeneityModel(csr=0.3, scd=1, lar=hp.lar),
        rounds=10)
    res = spec.resolve()

    # 2. OEM pre-training on the label-censored pool -> the biased model
    params = mlp.init_params(MLP_CFG, jax.random.key(spec.seed))
    pre_params, pre_acc = pretrain_to_target(
        params, res.pretrain_pool, res.test.x, res.test.y,
        target_acc=spec.pretrain_target, max_epochs=10)
    print(f"pre-trained (biased) model accuracy: {pre_acc:.3f}")

    # 3. H²-Fed enhancement: dual proximal terms + hierarchical
    #    pre-aggregation, through THE engine entry point
    _, hist = run_scenario(res, pre_params)
    for r, a in zip(hist["round"], hist["acc"]):
        print(f"  global round {r:2d}: test acc {a:.3f}")
    print(f"enhanced: {pre_acc:.3f} -> {hist['acc'][-1]:.3f} "
          f"with 70% of agents disconnected")


if __name__ == "__main__":
    main()
