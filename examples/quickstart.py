"""Quickstart: enhance a biased pre-trained model with H²-Fed in ~1 minute.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's pipeline at miniature scale: a synthetic 10-class task,
an OEM pre-training pool with labels {7,8,9} excluded (the deliberately
biased "68%" model), then a federated fleet of 20 traffic agents under 4
RSUs running the H²-Fed hierarchical round with dual proximal terms under
bad communication (CSR = 30%).
"""
import jax

from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core.baselines import h2fed
from repro.core.heterogeneity import HeterogeneityModel
from repro.data.partition import pretrain_split, scenario_two
from repro.data.synthetic import mnist_class_task
from repro.fedsim.pretrain import pretrain_to_target
from repro.fedsim.simulator import SimConfig, run_simulation
from repro.models import mlp


def main():
    # 1. dataset + OEM pre-training pool (labels 7-9 excluded -> biased model)
    train, test = mnist_class_task(n_train=6_000, n_test=1_000, seed=0)
    pre_ds, fed_pool = pretrain_split(train, excluded_labels=[7, 8, 9],
                                      frac=0.25, seed=0)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    pre_params, pre_acc = pretrain_to_target(params, pre_ds, test.x, test.y,
                                             target_acc=0.62, max_epochs=10)
    print(f"pre-trained (biased) model accuracy: {pre_acc:.3f}")

    # 2. public fleet: 20 agents / 4 RSUs, Non-IID across agents (Scenario II)
    fed = scenario_two(fed_pool, n_agents=20, n_rsus=4, seed=0)

    # 3. H²-Fed: dual proximal terms + hierarchical pre-aggregation
    hp = h2fed(mu1=0.001, mu2=0.005, lar=4, lr=0.1)
    het = HeterogeneityModel(csr=0.3, scd=1, lar=hp.lar)

    cfg = SimConfig(n_agents=20, n_rsus=4, batch=32)
    _, hist = run_simulation(cfg, hp, het, fed, pre_params, n_rounds=10,
                             x_test=test.x, y_test=test.y)
    for r, a in zip(hist["round"], hist["acc"]):
        print(f"  global round {r:2d}: test acc {a:.3f}")
    print(f"enhanced: {pre_acc:.3f} -> {hist['acc'][-1]:.3f} "
          f"with 70% of agents disconnected")


if __name__ == "__main__":
    main()
