"""End-to-end reproduction of the paper's headline claim (Abstract / Sec. VI):

  "Even when 90% of the agents are timely disconnected, the pre-trained
   deep learning model can still be forced to converge stably, and its
   accuracy can be enhanced from 68% to over 90% after convergence."

    PYTHONPATH=src python examples/paper_reproduction.py [--full] [--rounds N]

Default runs a reduced fleet (40 agents / 8 RSUs) in a few minutes on CPU;
--full is the paper's 100 agents / 10 RSUs.
"""
import argparse
import os
import sys

import numpy as np

# allow `python examples/paper_reproduction.py` from anywhere
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import metrics  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale: 100 agents, 10 RSUs, 22k samples")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--csr", type=float, default=0.1)
    args = ap.parse_args()

    if args.full:
        os.environ["REPRO_BENCH_FULL"] = "1"
    from benchmarks.common import base_spec, bench_scale, build_pipeline, \
        run_fed
    from repro.core.baselines import h2fed
    from repro.core.heterogeneity import HeterogeneityModel

    hp = h2fed(mu1=0.001, mu2=0.005, lar=5, lr=0.1, local_epochs=2)
    het = HeterogeneityModel(csr=args.csr, scd=1, lar=hp.lar)
    n_rounds = args.rounds or max(bench_scale()["rounds"], 40)
    spec = base_spec(partition=2, hp=hp, het=het, rounds=n_rounds)

    pipe = build_pipeline(spec)
    print(f"[pretrain] biased OEM model: test acc {pipe.pre_acc:.3f} "
          f"(paper: ~0.68; labels {{7,8,9}} excluded)")

    print(f"[federate] CSR={args.csr:.0%} connected agents, LAR={hp.lar}, "
          f"mu1={hp.mu1}, mu2={hp.mu2}, {n_rounds} global rounds")
    rounds, acc, wall = run_fed(spec)
    for r, a in zip(rounds, acc):
        bar = "#" * int(a * 40)
        print(f"  round {r:3d}  acc {a:.3f}  {bar}")

    tail = float(np.mean(acc[-8:]))
    jit = metrics.jitter(acc, tail=max(len(acc) // 2, 2))
    print(f"\n[result] {pipe.pre_acc:.3f} -> {tail:.3f} after convergence "
          f"({wall:.0f}s wall, jitter {jit:.4f})")
    ok = tail > 0.90
    print("[claim]  enhanced to >90% with 90% of agents disconnected:",
          "REPRODUCED" if ok else "NOT MET")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
