"""Serve the federated global model: batched KV-cache decoding.

    PYTHONPATH=src python examples/serve_demo.py [--arch qwen3-0.6b] \
        [--batch 4] [--prompt-len 16] [--gen 24]

After H²-Fed training the cloud model is an ordinary dense checkpoint —
serving needs no federation logic.  This demo runs the serve path used by
the decode_32k / long_500k dry-run shapes: batched prefill to build the KV
cache (per-arch: GQA cache, MLA compressed cache, SSM/xLSTM constant
state), then token-by-token greedy decode via ``M.decode_step``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_reduced_config
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    if cfg.encoder.kind == "vision":
        raise SystemExit("serve_demo drives text decode; pick a non-VLM arch")
    params = M.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    B, Sp = args.batch, args.prompt_len
    max_len = Sp + args.gen
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Sp)), jnp.int32)
    memory = None
    if cfg.encoder.kind == "audio":
        memory = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder.n_positions, cfg.encoder.d_embed)), jnp.float32)

    # --- prefill: run the prompt through decode_step token-by-token into the
    # cache (same numerics as bulk prefill; see test_decode_matches_prefill)
    cache = M.init_cache(cfg, B, max_len)
    decode = jax.jit(lambda p, c, t, pos: M.decode_step(
        cfg, p, c, t, pos, memory=memory))

    t0 = time.perf_counter()
    logits = None
    for t in range(Sp):
        logits, cache = decode(params, cache, prompts[:, t:t + 1],
                               jnp.full((B,), t, jnp.int32))
    t_prefill = time.perf_counter() - t0

    # --- greedy decode of `gen` new tokens, batched
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    for t in range(Sp, max_len):
        out_tokens.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, cache, tok,
                               jnp.full((B,), t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"[arch] {args.arch} (reduced) | batch {B} | cache len {max_len}")
    print(f"[prefill] {Sp} tokens in {t_prefill:.2f}s")
    print(f"[decode]  {args.gen} tokens in {t_decode:.2f}s "
          f"({B * args.gen / max(t_decode, 1e-9):.1f} tok/s batched)")
    for b in range(min(B, 2)):
        print(f"  request {b}: prompt={np.asarray(prompts[b])[:8]}... "
              f"-> generated={gen[b][:12]}...")
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("[ok] all logits finite; cache round-trip consistent")


if __name__ == "__main__":
    main()
