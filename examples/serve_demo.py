"""Serve the LIVE H²-Fed cloud master while the fleet keeps training.

    PYTHONPATH=src python examples/serve_demo.py [--windows 16] \
        [--agents 24] [--batch 256] [--rate 1.0]

The continuous-serving subsystem (DESIGN.md §9) replaces the old
train-then-serve split: agent updates arrive as seeded Poisson events,
the event queue fires H²-Fed ticks on arrival pressure, and the fp32
cloud master is snapshotted after every cloud aggregation and served to
inference requests *during* ingestion — the demo probes it every tick
(``probe_x``), then replays a full request sweep against the final
snapshot.  No federation logic touches the serving path: the snapshot is
an ordinary dense checkpoint (``CloudModelServer.params()``).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.heterogeneity import HeterogeneityModel
from repro.core.scenario import ScenarioSpec
from repro.fedsim.serving import run_serve_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=16,
                    help="load length in tick windows (~1 fleet of "
                         "events each)")
    ap.add_argument("--agents", type=int, default=24)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="base Poisson arrival rate per agent")
    ap.add_argument("--batch", type=int, default=256,
                    help="serve-side request batch size")
    args = ap.parse_args()

    # --- one declarative serve-mode cell: events drive time, not rounds
    spec = ScenarioSpec(
        n_agents=args.agents, n_rsus=4, batch=32, n_train=4_000,
        n_test=800, het=HeterogeneityModel(csr=0.5), engine="async",
        serve_events=args.agents * args.windows, arrival_rate=args.rate,
        tick_trigger="auto", queue_capacity=4 * args.agents)
    res = spec.resolve()

    t0 = time.perf_counter()
    state, hist, stats, server = run_serve_loop(res,
                                                probe_x=res.test.x[:64])
    wall = time.perf_counter() - t0
    s = stats.summary()
    print(f"[loop] {spec.n_agents} agents / {spec.n_rsus} RSUs | "
          f"{s['events_generated']} events -> {s['n_ticks']} ticks / "
          f"{s['n_rounds']} virtual rounds in {wall:.2f}s "
          f"({s['updates_per_s']:.0f} upd/s, "
          f"p50 {s['tick_p50_ms']:.1f}ms p99 {s['tick_p99_ms']:.1f}ms)")
    print(f"[loop] dropped={s['events_dropped']} "
          f"coalesced={s['events_coalesced']} | model staleness mean "
          f"{s['model_staleness_mean']:.1f} ticks")
    print(f"[live] {s['serve_requests']} inference probes served DURING "
          f"ingestion, p50 {s['serve_p50_ms']:.2f}ms")
    if len(hist["acc"]):
        print(f"[acc] cloud accuracy {hist['acc'][0]:.3f} -> "
              f"{hist['acc'][-1]:.3f} while serving")

    # --- full request sweep against the final published snapshot
    B = args.batch
    x, y = np.asarray(res.test.x), np.asarray(res.test.y)
    reqs = [x[i:i + B] for i in range(0, len(x), B)]
    _ = server.request(reqs[0])                 # warm the compile cache
    preds, lat = [], []
    for xb in reqs:
        t0 = time.perf_counter()
        pb = np.asarray(server.request(xb))
        lat.append(time.perf_counter() - t0)
        preds.append(pb)
    pred = np.concatenate(preds)
    lat_ms = np.asarray(lat) * 1e3
    print(f"[serve] {len(x)} requests in {len(reqs)} batches of {B} | "
          f"acc {float((pred == y).mean()):.3f}")
    print(f"[serve] latency/batch: mean {lat_ms.mean():.2f}ms "
          f"p50 {np.percentile(lat_ms, 50):.2f}ms "
          f"max {lat_ms.max():.2f}ms "
          f"({len(x) / (lat_ms.sum() / 1e3):.0f} req/s)")
    assert np.isfinite(lat_ms).all() and pred.shape == y.shape
    assert s["events_dropped"] == 0             # nominal load must not shed
    print("[ok] live cloud master served concurrently with ingestion")


if __name__ == "__main__":
    main()
