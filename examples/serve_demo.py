"""Train with H²-Fed, then serve the federated global model.

    PYTHONPATH=src python examples/serve_demo.py [--rounds 6] \
        [--fleet-store host --chunk-agents 8] [--batch 256]

After H²-Fed training the cloud model is an ordinary dense checkpoint —
serving needs no federation logic.  The demo runs one declarative
``ScenarioSpec`` through ``fedsim.run_scenario`` (pass
``--fleet-store host`` to run the cohort-streamed engine, the
million-agent path at toy scale; DESIGN.md §8), unravels the cloud
master once, and serves batched classification requests with latency
stats.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core import flatten
from repro.core.heterogeneity import HeterogeneityModel
from repro.core.scenario import ScenarioSpec
from repro.fedsim import run_scenario
from repro.models import mlp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--batch", type=int, default=256,
                    help="serve-side request batch size")
    ap.add_argument("--fleet-store", default="device",
                    choices=("device", "host"),
                    help="'host' streams the (A, N) fleet from host memory "
                         "in cohort chunks (fedsim/streaming)")
    ap.add_argument("--chunk-agents", type=int, default=8,
                    help="agents per streamed chunk (with "
                         "--fleet-store host)")
    args = ap.parse_args()

    # --- train: one declarative cell through THE engine entry point
    spec = ScenarioSpec(
        n_agents=24, n_rsus=4, batch=32, n_train=4_000, n_test=800,
        het=HeterogeneityModel(csr=0.5),
        fleet_store=args.fleet_store,
        chunk_agents=(args.chunk_agents if args.fleet_store == "host"
                      else 0),
        rounds=args.rounds)
    res = spec.resolve()
    t0 = time.perf_counter()
    state, hist = run_scenario(res)
    t_train = time.perf_counter() - t0
    print(f"[train] engine={spec.engine} fleet_store={spec.fleet_store} | "
          f"{spec.rounds} rounds in {t_train:.2f}s | "
          f"final acc {hist['acc'][-1]:.3f}")

    # --- the cloud master is a dense checkpoint: pytree directly from the
    # resident engines, one unravel from the streamed flat buffer
    if hasattr(state, "cloud_params"):
        params = state.cloud_params
    else:
        fspec = flatten.spec_of(
            mlp.init_params(MLP_CFG, jax.random.key(spec.seed)))
        params = fspec.unravel(state.cloud_flat)

    # --- serve batched classification requests
    predict = jax.jit(lambda p, x: jnp.argmax(mlp.forward(p, x), axis=-1))
    B = args.batch
    x, y = np.asarray(res.test.x), np.asarray(res.test.y)
    reqs = [x[i:i + B] for i in range(0, len(x), B)]
    preds, lat = [], []
    _ = predict(params, jnp.asarray(reqs[0]))       # warm the compile cache
    for xb in reqs:
        t0 = time.perf_counter()
        pb = np.asarray(predict(params, jnp.asarray(xb)))
        lat.append(time.perf_counter() - t0)
        preds.append(pb)
    pred = np.concatenate(preds)
    lat_ms = np.asarray(lat) * 1e3
    print(f"[serve] {len(x)} requests in {len(reqs)} batches of {B} | "
          f"acc {float((pred == y).mean()):.3f}")
    print(f"[serve] latency/batch: mean {lat_ms.mean():.2f}ms "
          f"p50 {np.percentile(lat_ms, 50):.2f}ms "
          f"max {lat_ms.max():.2f}ms "
          f"({len(x) / (lat_ms.sum() / 1e3):.0f} req/s)")
    assert np.isfinite(lat_ms).all() and pred.shape == y.shape
    print("[ok] federated checkpoint served with plain dense inference")


if __name__ == "__main__":
    main()
