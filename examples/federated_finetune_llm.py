"""Federated LLM fine-tuning with the PRODUCTION hierarchical round.

    PYTHONPATH=src python examples/federated_finetune_llm.py \
        [--arch qwen3-0.6b] [--rounds 8] [--quantize-cloud]

This is the launch-path demo: the paper's Algorithms 1-3 compiled as ONE
SPMD program (jax.shard_map over a (pod=2, data=4, model=1) mesh of 8 host
devices — 2 RSUs x 4 traffic agents).  Each agent holds its own Markov
token shard (Non-IID), trains E local epochs with the dual-proximal
objective, RSUs psum over the `data` axis LAR times, the cloud psums over
`pod` once — optionally int8-quantized (the beyond-paper §Perf lever).

The model is the REDUCED variant of an assigned architecture (the full
configs need the real 256-chip pod; same code path).
"""
# Must precede any jax import: 8 host devices for the 2x4x1 example mesh.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import argparse      # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from repro.configs.registry import ARCH_IDS, get_reduced_config  # noqa: E402
from repro.core.h2fed import H2FedParams                         # noqa: E402
from repro.data.synthetic import lm_token_task                   # noqa: E402
from repro.launch import sharding as shard                       # noqa: E402
from repro.launch.h2fed_round import make_h2fed_round            # noqa: E402
from repro.models import model as M                              # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P       # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=ARCH_IDS)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--lar", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--csr", type=float, default=0.5)
    ap.add_argument("--quantize-cloud", action="store_true",
                    help="int8 cross-pod aggregation (beyond-paper)")
    args = ap.parse_args()

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4, 1), ("pod", "data", "model"))
    A = 8  # 2 pods (RSUs) x 4 agents
    cfg = get_reduced_config(args.arch)
    if cfg.encoder.kind != "none":
        raise SystemExit(f"{args.arch}: pick a text-only arch for this demo")
    hp = H2FedParams(mu1=0.001, mu2=0.005, lar=args.lar,
                     local_epochs=args.epochs, lr=0.1)

    params = M.init_params(cfg, jax.random.key(0))
    n_par = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"[model] {args.arch} (reduced): {n_par/1e6:.1f}M params, "
          f"vocab {cfg.vocab_size}")

    # Non-IID shards: each agent's Markov chain has its own transition table
    rng = np.random.default_rng(0)
    streams = [lm_token_task(vocab=min(cfg.vocab_size, 512),
                             n_tokens=args.lar * args.batch * (args.seq + 1),
                             seed=100 + a) for a in range(A)]

    def agent_batches(a, r):
        s = streams[a]
        n = args.batch * (args.seq + 1)
        off = (r * args.lar * n) % max(len(s) - n * args.lar, 1)
        out = []
        for l in range(args.lar):
            seg = np.resize(s[off + l * n: off + (l + 1) * n], n)
            seg = seg.reshape(args.batch, args.seq + 1)
            out.append((seg[:, :-1], seg[:, 1:]))
        return out

    round_fn = make_h2fed_round(cfg, hp, mesh,
                                quantize_cloud=args.quantize_cloud)
    p_shard = shard.param_shardings_model_only(
        jax.eval_shape(lambda: params), mesh)
    jitted = jax.jit(round_fn, in_shardings=(
        p_shard,
        {"tokens": NamedSharding(mesh, P(None, ("pod", "data"))),
         "labels": NamedSharding(mesh, P(None, ("pod", "data")))},
        NamedSharding(mesh, P(None, ("pod", "data"))),
        NamedSharding(mesh, P(("pod", "data")))))

    with mesh:
        cloud = jax.device_put(
            params, jax.tree.map(lambda _: shard.replicated(mesh), params))
        eval_batch = {
            "tokens": jnp.asarray(streams[0][: args.batch * args.seq]
                                  .reshape(args.batch, args.seq)),
            "labels": jnp.asarray(streams[0][1: args.batch * args.seq + 1]
                                  .reshape(args.batch, args.seq))}
        loss0 = float(M.loss_fn(cfg, cloud, eval_batch)[0])
        print(f"[init]  eval loss {loss0:.4f}")

        for r in range(args.rounds):
            toks = np.zeros((args.lar, A, args.batch, args.seq), np.int32)
            labs = np.zeros_like(toks)
            for a in range(A):
                for l, (x, y) in enumerate(agent_batches(a, r)):
                    toks[l, a], labs[l, a] = x, y
            mask = (rng.random((args.lar, A)) < args.csr).astype(np.float32)
            n_data = np.full((A,), float(args.batch * args.seq), np.float32)

            t0 = time.perf_counter()
            cloud, metrics = jitted(
                cloud, {"tokens": jnp.asarray(toks),
                        "labels": jnp.asarray(labs)},
                jnp.asarray(mask), jnp.asarray(n_data))
            loss = float(M.loss_fn(cfg, cloud, eval_batch)[0])
            print(f"[round {r+1:2d}] eval loss {loss:.4f}  "
                  f"surviving mass {float(metrics['surviving_mass']):.0f}  "
                  f"({time.perf_counter()-t0:.1f}s)")

    print(f"[done] loss {loss0:.4f} -> {loss:.4f} across {A} agents, "
          f"CSR={args.csr:.0%}"
          + (", int8 cloud aggregation" if args.quantize_cloud else ""))


if __name__ == "__main__":
    main()
