"""Proximal-aware SGD (+ momentum).

The H²-Fed penalty gradient is closed-form (mu1(w−w_k) + mu2(w−w)), so the
optimizer takes the two anchors directly instead of autodiffing the penalty
— one fused traversal per step (the Pallas kernel ``dual_proximal_sgd``
implements the same update for the TPU hot path; this module is the jnp
reference used everywhere else).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    """Scale grads so their global L2 norm is at most ``max_norm``."""
    scale = jnp.minimum(1.0, max_norm / (global_norm(grads) + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads)


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    lr: float = 0.05
    momentum: float = 0.0       # 0 = plain SGD (the paper's Alg. 1)
    weight_decay: float = 0.0


class SGDState(NamedTuple):
    momentum: Optional[PyTree]


def init(cfg: SGDConfig, params: PyTree) -> SGDState:
    if cfg.momentum:
        return SGDState(jax.tree.map(
            lambda l: jnp.zeros(l.shape, jnp.float32), params))
    return SGDState(None)


def step(cfg: SGDConfig, params: PyTree, grads: PyTree, state: SGDState,
         *, anchors: Tuple[Tuple[float, PyTree], ...] = ()
         ) -> Tuple[PyTree, SGDState]:
    """params ← params − lr·(g + Σ_l mu_l(params − anchor_l) + wd·params)."""

    def eff_grad(path_free_args):
        w, g, *anc = path_free_args
        gf = g.astype(jnp.float32)
        wf = w.astype(jnp.float32)
        for (mu, a) in zip([m for m, _ in anchors], anc):
            gf = gf + mu * (wf - a.astype(jnp.float32))
        if cfg.weight_decay:
            gf = gf + cfg.weight_decay * wf
        return gf

    anchor_trees = [a for _, a in anchors]

    if cfg.momentum and state.momentum is not None:
        def upd(w, g, m, *anc):
            gf = eff_grad((w, g, *anc))
            m_new = cfg.momentum * m + gf
            return ((w.astype(jnp.float32) - cfg.lr * m_new).astype(w.dtype),
                    m_new)
        pairs = jax.tree.map(upd, params, grads, state.momentum, *anchor_trees)
        new_p = jax.tree.map(lambda t: t[0], pairs,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], pairs,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, SGDState(new_m)

    def upd(w, g, *anc):
        gf = eff_grad((w, g, *anc))
        return (w.astype(jnp.float32) - cfg.lr * gf).astype(w.dtype)

    return (jax.tree.map(upd, params, grads, *anchor_trees), state)
