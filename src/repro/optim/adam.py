"""Proximal-aware Adam (AdamW) — used by the federated LLM-finetune example
and available as the agent optimizer in the production train step.

The dual proximal pull enters the *gradient* (before the moment updates), so
Adam sees the full H²-Fed objective gradient — equivalent to autodiff through
Eq. 6 but with no extra graph.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def init(cfg: AdamConfig, params: PyTree) -> AdamState:
    z = lambda: jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), params)
    return AdamState(mu=z(), nu=z(), count=jnp.zeros((), jnp.int32))


def step(cfg: AdamConfig, params: PyTree, grads: PyTree, state: AdamState,
         *, anchors: Tuple[Tuple[float, PyTree], ...] = ()
         ) -> Tuple[PyTree, AdamState]:
    count = state.count + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    anchor_trees = [a for _, a in anchors]
    mus = [m for m, _ in anchors]

    def upd(w, g, m, v, *anc):
        wf = w.astype(jnp.float32)
        gf = g.astype(jnp.float32)
        for mu_c, a in zip(mus, anc):
            gf = gf + mu_c * (wf - a.astype(jnp.float32))
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        upd_ = (m_new / c1) / (jnp.sqrt(v_new / c2) + cfg.eps)
        if cfg.weight_decay:
            upd_ = upd_ + cfg.weight_decay * wf
        return (wf - cfg.lr * upd_).astype(w.dtype), m_new, v_new

    trips = jax.tree.map(upd, params, grads, state.mu, state.nu, *anchor_trees)
    is3 = lambda t: isinstance(t, tuple) and len(t) == 3
    new_p = jax.tree.map(lambda t: t[0], trips, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], trips, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], trips, is_leaf=is3)
    return new_p, AdamState(mu=new_m, nu=new_v, count=count)
