from repro.optim import sgd, adam  # noqa: F401
