"""Semi-asynchronous flat-buffer simulation engine (DESIGN.md §6).

The synchronous engines (fedsim/simulator, DESIGN.md §3) enforce a global
round barrier: every local round, disconnected or slow agents are masked out
and their work is discarded — exactly the regime where semi-asynchronous
hierarchical FL (cf. arXiv:2110.09073) wins in C-ITS.  This engine drops the
barrier.  Time advances in sub-round TICKS (one tick == one local round of
the sync cadence); each agent's finished update *arrives* at its RSU
``d`` ticks after it was computed, with ``d`` drawn per agent per tick from
the heterogeneity latency model (``core.heterogeneity.sample_latency``):

  * an agent with an in-flight update is BUSY (still computing/uploading)
    and trains nothing new until it delivers — so at most one update per
    agent is pending and the in-flight buffer is three flat arrays:
    ``pending_x (A, N)``, ``pending_w (A,)``, ``pending_t (A,)``;
  * each tick the RSU layer absorbs whatever arrives — the zero-latency
    cohort plus due stragglers — via ONE masked scatter-accumulate on the
    ``(A, N)`` buffer (``kernels/ops.masked_scatter_accumulate``: Pallas
    MXU matmul on TPU, XLA segment_sum elsewhere), each arrival weighted
    ``n_a · mask_a · s(d)`` with the staleness schedule
    ``core.aggregation.staleness_weights``;
  * the RSU buffer merge is ``core.aggregation.buffer_absorb``: a running
    cohort-mass blend, so a late merge is a cheap rank-1/batched update on
    the ``(R, N)`` buffer, weights stay exactly normalized as stragglers
    trickle in, and ``buffer_keep=0`` reproduces the synchronous
    replace-on-arrivals semantics;
  * the cloud layer aggregates whatever RSU state exists at its less
    frequent cadence (every ``cloud_every`` ticks; 0 = once per global
    round like the sync engines), weighted by absorbed cohort mass.

Correctness anchor (test-pinned, tests/test_async.py): with zero latencies
(``max_delay=0``) and decay disabled (``staleness_decay=1``,
``buffer_keep=0``, ``cloud_every=0``) the tick loop runs the same draws with
the same key discipline as ``engine="flat"`` and reproduces it to fp32
tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatten
from repro.core.aggregation import buffer_absorb, staleness_weights
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import (ConnState, HeterogeneityModel,
                                      init_conn_state, sample_latency)
from repro.data.partition import FederatedData
from repro.kernels import ops
from repro.models import mlp
from repro.fedsim.simulator import (SimConfig, _fed_arrays,
                                    _local_train_flat, round_draws)

PyTree = Any

# key-discipline constant: the latency draw folds the per-tick round key so
# the conn/FSR draws stay bit-identical to engine="flat" (the sync anchor).
_LATENCY_FOLD = 7


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Staleness algebra + cadence knobs of the semi-async engine."""
    staleness_decay: float = 0.5   # s(τ) parameter (1.0 disables for "exp")
    schedule: str = "exp"          # "exp" | "poly" (core.staleness_weights)
    buffer_keep: float = 0.0       # RSU mass retained across ticks in [0,1]
    cloud_every: int = 0           # cloud cadence in ticks (0 = per round)

    def validate(self):
        assert self.schedule in ("exp", "poly")
        if self.schedule == "exp":
            assert 0.0 <= self.staleness_decay <= 1.0
        else:
            assert self.staleness_decay >= 0.0
        assert 0.0 <= self.buffer_keep <= 1.0
        assert self.cloud_every >= 0
        return self

    def weight(self, staleness):
        return staleness_weights(staleness, decay=self.staleness_decay,
                                 schedule=self.schedule)


class AsyncSimState(NamedTuple):
    """Flat-buffer fleet state plus the in-flight (pending) buffers."""
    agent_flat: jax.Array   # (A, N) latest local model per agent
    rsu_flat: jax.Array     # (R, N) staleness-buffer models
    rsu_mass: jax.Array     # (R,)   running absorbed cohort mass M
    cloud_flat: jax.Array   # (N,)
    pending_x: jax.Array    # (A, N) in-flight update (one per busy agent)
    pending_w: jax.Array    # (A,)   its decayed delivery weight n·m·s(d)
    pending_t: jax.Array    # (A,)   int32 ticks until delivery (0 = none)
    conn: ConnState
    rng: jax.Array


def init_async_state(cfg: SimConfig, spec: flatten.FlatSpec,
                     init_params: PyTree, key) -> AsyncSimState:
    vec = spec.ravel(init_params)
    a, n = cfg.n_agents, spec.n
    return AsyncSimState(
        agent_flat=jnp.broadcast_to(vec, (a, n)),
        rsu_flat=jnp.broadcast_to(vec, (cfg.n_rsus, n)),
        rsu_mass=jnp.zeros((cfg.n_rsus,), jnp.float32),
        cloud_flat=vec,
        pending_x=jnp.zeros((a, n), jnp.float32),
        pending_w=jnp.zeros((a,), jnp.float32),
        pending_t=jnp.zeros((a,), jnp.int32),
        conn=init_conn_state(a),
        rng=key)


def pending_mass(state: AsyncSimState) -> jax.Array:
    """Total decayed weight still in flight (conservation bookkeeping)."""
    return jnp.sum(state.pending_w * (state.pending_t > 0))


def _make_async_round_body(cfg: SimConfig, hp: H2FedParams,
                           het: HeterogeneityModel, fed: FederatedData,
                           spec: flatten.FlatSpec, acfg: AsyncConfig,
                           loss_fn: Callable = mlp.loss_fn):
    """The un-jitted semi-async global round:
    AsyncSimState -> (AsyncSimState, metrics)."""
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = \
        _fed_arrays(cfg, hp, fed)
    A, R, N = cfg.n_agents, cfg.n_rsus, spec.n

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    # cloud cadence gate per tick (static python bools -> traced array)
    ce = acfg.cloud_every
    do_cloud = jnp.asarray(
        [ce > 0 and (t + 1) % ce == 0 for t in range(hp.lar)], bool)

    def tick(carry, inp):
        (rsu_flat, rsu_mass, cloud_flat, conn, agent_flat,
         pend_x, pend_w, pend_t, cloud_macc) = carry
        key, cloud_now = inp

        # 1. in-flight countdown: due updates deliver this tick; agents
        #    still computing stay busy and train nothing new.
        in_flight = pend_t > 0
        pend_t = jnp.maximum(pend_t - 1, 0)
        due = in_flight & (pend_t == 0)
        busy = in_flight & ~due

        # 2. stochastic realization — identical conn/FSR key discipline to
        #    engine="flat"; the latency draw uses a folded key so it never
        #    perturbs the sync draws.
        conn, mask, active_steps = round_draws(key, conn, het, hp, A, spe)
        delays = sample_latency(jax.random.fold_in(key, _LATENCY_FOLD),
                                A, het)
        maskf = mask.astype(jnp.float32)
        free = ~busy                                  # may start new work

        # 3. training: every non-busy agent runs its drawn steps from the
        #    current RSU buffer model (busy agents keep their row).
        act = jnp.where(busy, 0, active_steps)
        w_start = jnp.take(rsu_flat, rsu_assign, axis=0)       # (A, N)
        trained = train_agents(x_all, y_all, w_start, w_start,
                               cloud_flat, act)
        agent_flat = jnp.where(busy[:, None], agent_flat, trained)

        # 4. arrivals: the zero-latency cohort (s(0) == 1) plus due
        #    stragglers — two masked scatter-accumulates on (A, N).
        w_imm = (n_per_agent * maskf * free
                 * (delays == 0).astype(jnp.float32))          # (A,)
        w_due = jnp.where(due, pend_w, 0.0)
        num_i, m_i = ops.masked_scatter_accumulate(
            agent_flat, w_imm, rsu_assign, R)
        num_d, m_d = ops.masked_scatter_accumulate(
            pend_x, w_due, rsu_assign, R)

        # 5. staleness-buffer merge with running cohort-mass accounting
        rsu_flat, rsu_mass = buffer_absorb(
            rsu_flat, rsu_mass, num_i + num_d, m_i + m_d,
            keep=acfg.buffer_keep)
        cloud_macc = cloud_macc + m_i + m_d

        # 6. enqueue new in-flight work (connected, trained, delayed);
        #    the delivery weight is decayed at enqueue — s(d) is known.
        enq = mask & free & (delays > 0)
        pend_x = jnp.where(enq[:, None], trained, pend_x)
        w_enq = n_per_agent * maskf * acfg.weight(delays)
        pend_w = jnp.where(enq, w_enq, pend_w)
        pend_t = jnp.where(enq, delays, pend_t)

        # 7. cloud cadence: aggregate whatever RSU state exists, weighted
        #    by the mass absorbed since the last cloud aggregation.
        new_cloud = ops.cloud_agg(rsu_flat, cloud_macc)
        take = cloud_now & (jnp.sum(cloud_macc) > 0)
        cloud_flat = jnp.where(take, new_cloud, cloud_flat)
        cloud_macc = jnp.where(cloud_now, jnp.zeros_like(cloud_macc),
                               cloud_macc)

        tick_metrics = {
            "absorbed_mass": m_i + m_d,                       # (R,)
            "immediate_mass": jnp.sum(m_i),
            "due_mass": jnp.sum(m_d),
            "enqueued_mass": jnp.sum(jnp.where(enq, w_enq, 0.0)),
        }
        carry = (rsu_flat, rsu_mass, cloud_flat, conn, agent_flat,
                 pend_x, pend_w, pend_t, cloud_macc)
        return carry, tick_metrics

    def global_round(state: AsyncSimState
                     ) -> Tuple[AsyncSimState, Dict[str, jax.Array]]:
        rng, k_rounds = jax.random.split(state.rng)
        keys = jax.random.split(k_rounds, hp.lar)
        # round start: RSUs re-anchor to the cloud model (Alg. 2 line 2)
        # and the staleness buffer restarts its mass accounting.
        rsu_flat = jnp.broadcast_to(state.cloud_flat, (R, N))
        carry = (rsu_flat, jnp.zeros((R,), jnp.float32), state.cloud_flat,
                 state.conn, state.agent_flat, state.pending_x,
                 state.pending_w, state.pending_t,
                 jnp.zeros((R,), jnp.float32))
        carry, ticks = jax.lax.scan(tick, carry, (keys, do_cloud))
        (rsu_flat, rsu_mass, cloud_flat, conn, agent_flat,
         pend_x, pend_w, pend_t, cloud_macc) = carry

        # round-end cloud aggregation over the not-yet-aggregated mass
        # (with cloud_every=0 this is exactly the sync Alg. 3 line 6).
        new_cloud = ops.cloud_agg(rsu_flat, cloud_macc)
        cloud_flat = jnp.where(jnp.sum(cloud_macc) > 0, new_cloud,
                               cloud_flat)

        out = AsyncSimState(agent_flat=agent_flat, rsu_flat=rsu_flat,
                            rsu_mass=rsu_mass, cloud_flat=cloud_flat,
                            pending_x=pend_x, pending_w=pend_w,
                            pending_t=pend_t, conn=conn, rng=rng)
        metrics = dict(ticks)
        metrics["pending_mass"] = pending_mass(out)
        return out, metrics

    return global_round


def make_async_global_round(cfg: SimConfig, hp: H2FedParams,
                            het: HeterogeneityModel, fed: FederatedData,
                            spec: flatten.FlatSpec,
                            acfg: Optional[AsyncConfig] = None,
                            loss_fn: Callable = mlp.loss_fn):
    """Build the jitted semi-async round: AsyncSimState -> (state, metrics).

    The input state's buffers are DONATED (updated in place at scale) —
    callers must rebind, ``state, m = round_fn(state)``, and never reuse the
    consumed input.
    """
    acfg = (acfg or AsyncConfig()).validate()
    body = _make_async_round_body(cfg, hp, het, fed, spec, acfg, loss_fn)
    return jax.jit(body, donate_argnums=(0,))


def run_async_simulation(cfg: SimConfig, hp: H2FedParams,
                         het: HeterogeneityModel, fed: FederatedData,
                         init_params: PyTree, n_rounds: int, *,
                         acfg: Optional[AsyncConfig] = None,
                         x_test=None, y_test=None,
                         loss_fn: Callable = mlp.loss_fn,
                         eval_fn: Optional[Callable] = None,
                         ) -> Tuple[AsyncSimState, Dict[str, np.ndarray]]:
    """Run ``n_rounds`` semi-async global rounds; returns final state +
    history (accuracy curve plus per-round absorbed/pending mass so the
    straggler economy is observable).  ``fedsim.simulator.run_simulation``
    dispatches here for ``engine="async"``.
    """
    hp.validate(), het.validate()
    acfg = (acfg or AsyncConfig()).validate()
    key = jax.random.key(cfg.seed)
    spec = flatten.spec_of(init_params)
    state = init_async_state(cfg, spec, init_params, key)
    round_fn = make_async_global_round(cfg, hp, het, fed, spec, acfg,
                                       loss_fn)
    if eval_fn is None and x_test is not None:
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_test, y_test))

    accs, rounds, absorbed, pending = [], [], [], []
    for r in range(n_rounds):
        state, metrics = round_fn(state)
        absorbed.append(float(jnp.sum(metrics["absorbed_mass"])))
        pending.append(float(metrics["pending_mass"]))
        if eval_fn is not None and (r % cfg.eval_every == 0
                                    or r == n_rounds - 1):
            accs.append(float(eval_fn(spec.unravel(state.cloud_flat))))
            rounds.append(r + 1)
    history = {"round": np.asarray(rounds), "acc": np.asarray(accs),
               "absorbed_mass": np.asarray(absorbed),
               "pending_mass": np.asarray(pending)}
    return state, history
