"""Semi-asynchronous flat-buffer simulation engine (DESIGN.md §6).

The synchronous engines (fedsim/simulator, DESIGN.md §3) enforce a global
round barrier: every local round, disconnected or slow agents are masked out
and their work is discarded — exactly the regime where semi-asynchronous
hierarchical FL (cf. arXiv:2110.09073) wins in C-ITS.  This engine drops the
barrier.  Time advances in sub-round TICKS (one tick == one local round of
the sync cadence); each agent's finished update *arrives* at its RSU
``d`` ticks after it was computed, with ``d`` drawn per agent per tick from
the heterogeneity latency model (``core.heterogeneity.sample_latency``):

  * an agent with an in-flight update is BUSY (still computing/uploading)
    and trains nothing new until it delivers — so at most one update per
    agent is pending and the in-flight buffer is three flat arrays:
    ``pending_x (A, N)``, ``pending_w (A,)``, ``pending_t (A,)``;
  * each tick the RSU layer absorbs whatever arrives — the zero-latency
    cohort plus due stragglers — via ONE masked scatter-accumulate on the
    ``(A, N)`` buffer (``kernels/ops.masked_scatter_accumulate``: Pallas
    MXU matmul on TPU, XLA segment_sum elsewhere), each arrival weighted
    ``n_a · mask_a · s(d)`` with the staleness schedule
    ``core.aggregation.staleness_weights`` — ``s`` may decay PER RSU
    (an (R,) decay vector in ``AsyncConfig.staleness_decay``; scalar
    broadcast keeps the uniform schedule);
  * the RSU buffer merge is ``core.aggregation.buffer_absorb``: a running
    cohort-mass blend, so a late merge is a cheap rank-1/batched update on
    the ``(R, N)`` buffer, weights stay exactly normalized as stragglers
    trickle in, and ``buffer_keep=0`` reproduces the synchronous
    replace-on-arrivals semantics;
  * the cloud layer aggregates whatever RSU state exists at its own, less
    frequent cadence: ``cloud_every`` ticks counted on a GLOBAL tick
    counter carried in the state, so the cadence spans global-round
    boundaries (a ``cloud_every=3`` schedule with LAR=2 fires at global
    ticks 3, 6, 9, ... — decoupled from the LAR scan).  Under a decoupled
    cadence the round boundary stops being special altogether: the RSU
    buffers, their running mass and the cloud accumulator all persist
    across rounds (no per-round re-anchor), so the mass the cloud
    aggregation weights by always accounts for content the buffers still
    hold.  ``cloud_every=0`` keeps the per-global-round re-anchor +
    aggregation of the sync engines (the sync-limit anchor).

RSU-sharded execution (DESIGN.md §4): ``make_sharded_async_global_round``
runs the same tick algebra under ``shard_map`` on a
``core.topology.HierarchyTopology`` — agents live on their RSU's pod, the
scatter-accumulate is the block-local ``kernels/ops.block_local_agg`` psum'd
over the within-pod data axis only, ``buffer_absorb`` runs on the local
``(R_local, N)`` shard, and only the cloud cadence pays a cross-pod
collective.

Correctness anchor (test-pinned, tests/test_async.py): with zero latencies
(``max_delay=0``) and decay disabled (``staleness_decay=1``,
``buffer_keep=0``, ``cloud_every=0``) the tick loop runs the same draws with
the same key discipline as ``engine="flat"`` and reproduces it to fp32
tolerance — in both the replicated and the RSU-sharded layout.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import faults as faults_mod
from repro.core import flatten
from repro.core.aggregation import (buffer_absorb, screen_updates,
                                    staleness_weights)
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import (ConnState, HeterogeneityModel,
                                      init_conn_state, sample_latency)
from repro.core.topology import HierarchyTopology
from repro.data.partition import FederatedData
from repro.kernels import ops
from repro.launch.mesh import shard_map
from repro.models import mlp
from repro.fedsim.simulator import (Cadence, SimConfig, _fed_arrays,
                                    _local_train_flat, round_draws,
                                    round_keys)

PyTree = Any

# key-discipline constant: the latency draw folds the per-tick round key so
# the conn/FSR draws stay bit-identical to engine="flat" (the sync anchor).
_LATENCY_FOLD = 7


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Staleness algebra + cadence knobs of the semi-async engine.

    ``staleness_decay`` and ``buffer_keep`` accept a scalar (uniform, the
    original behavior) or an (R,)-length tuple — per-RSU adaptive schedules
    (DESIGN.md §6), exposed on the CLI as a comma list
    (``--staleness-decay 0.9,0.5,...``).
    """
    staleness_decay: Union[float, Tuple[float, ...]] = 0.5
    schedule: str = "exp"          # "exp" | "poly" (core.staleness_weights)
    buffer_keep: Union[float, Tuple[float, ...]] = 0.0
    cloud_every: int = 0           # cloud cadence in GLOBAL ticks (0 = per
    #                                global round, the sync anchor)

    def validate(self):
        assert self.schedule in ("exp", "poly")
        dec = np.asarray(self.staleness_decay, np.float32)
        if self.schedule == "exp":
            assert ((0.0 <= dec) & (dec <= 1.0)).all()
        else:
            assert (dec >= 0.0).all()
        keep = np.asarray(self.buffer_keep, np.float32)
        assert ((0.0 <= keep) & (keep <= 1.0)).all()
        assert self.cloud_every >= 0
        return self

    def agent_decay(self, rsu_assign, n_rsus: int):
        """Per-agent decay rate: scalar pass-through, or the (R,) vector
        gathered through the agent → RSU assignment."""
        dec = np.asarray(self.staleness_decay, np.float32)
        if dec.ndim == 0:
            return float(dec)
        if dec.shape != (n_rsus,):
            raise ValueError(
                f"staleness_decay vector must have one entry per RSU "
                f"({n_rsus},), got {dec.shape}")
        return jnp.asarray(dec)[jnp.asarray(rsu_assign)]

    def rsu_keep(self, n_rsus: int):
        """Buffer retention per RSU: scalar or validated (R,) vector."""
        keep = np.asarray(self.buffer_keep, np.float32)
        if keep.ndim == 0:
            return float(keep)
        if keep.shape != (n_rsus,):
            raise ValueError(
                f"buffer_keep vector must have one entry per RSU "
                f"({n_rsus},), got {keep.shape}")
        return jnp.asarray(keep)

    def weight(self, staleness, decay=None):
        return staleness_weights(
            staleness,
            decay=self.staleness_decay if decay is None else decay,
            schedule=self.schedule)


class AsyncSimState(NamedTuple):
    """Flat-buffer fleet state plus the in-flight (pending) buffers.

    The (A, N)/(R, N) fleet buffers (``agent_flat``/``rsu_flat``/
    ``pending_x``) live in the spec's storage dtype (DESIGN.md §3 dtype
    policy); ``cloud_flat`` is always the fp32 master."""
    agent_flat: jax.Array   # (A, N) latest local model per agent (storage)
    rsu_flat: jax.Array     # (R, N) staleness-buffer models (storage)
    rsu_mass: jax.Array     # (R,)   running absorbed cohort mass M
    cloud_flat: jax.Array   # (N,)   fp32 master
    pending_x: jax.Array    # (A, N) in-flight update (one per busy agent)
    pending_w: jax.Array    # (A,)   its decayed delivery weight n·m·s(d)
    pending_t: jax.Array    # (A,)   int32 ticks until delivery (0 = none)
    conn: ConnState
    rng: jax.Array
    cloud_macc: jax.Array   # (R,)   mass absorbed since last cloud agg
    tick: jax.Array         # ()     int32 global tick counter (the cloud
    #                                cadence clock — spans round boundaries)


def init_async_state(cfg: SimConfig, spec: flatten.FlatSpec,
                     init_params: PyTree, key) -> AsyncSimState:
    vec = spec.ravel(init_params)
    sv = spec.to_storage(vec)
    a, n = cfg.n_agents, spec.n
    return AsyncSimState(
        agent_flat=jnp.broadcast_to(sv, (a, n)),
        rsu_flat=jnp.broadcast_to(sv, (cfg.n_rsus, n)),
        rsu_mass=jnp.zeros((cfg.n_rsus,), jnp.float32),
        cloud_flat=vec,
        pending_x=jnp.zeros((a, n), spec.storage_dtype),
        pending_w=jnp.zeros((a,), jnp.float32),
        pending_t=jnp.zeros((a,), jnp.int32),
        conn=init_conn_state(a),
        rng=key,
        cloud_macc=jnp.zeros((cfg.n_rsus,), jnp.float32),
        tick=jnp.zeros((), jnp.int32))


def pending_mass(state: AsyncSimState) -> jax.Array:
    """Total decayed weight still in flight (conservation bookkeeping)."""
    return jnp.sum(state.pending_w * (state.pending_t > 0))


def _make_async_round_body(cfg: SimConfig, hp: H2FedParams,
                           het: HeterogeneityModel, fed: FederatedData,
                           spec: flatten.FlatSpec, acfg: AsyncConfig,
                           loss_fn: Callable = mlp.loss_fn, *,
                           fused: bool = True,
                           cadence: Optional[Cadence] = None,
                           faults: Optional[faults_mod.FaultPlan] = None):
    """The un-jitted semi-async global round:
    AsyncSimState -> (AsyncSimState, metrics).

    ``fused=True`` (default) runs the tick's whole RSU layer — both
    arrival scatter-accumulates, the numerator add and the
    ``buffer_absorb`` merge — as ONE pass over the parameter axis
    (``ops.agg_absorb``); ``fused=False`` keeps the multi-pass program for
    A/B benchmarking (off-TPU both are the same XLA ops, fp32
    bit-compatible).

    ``cadence`` (sweep-only, DESIGN.md §7) pads the tick/minibatch scans to
    the group-wide static bounds so ``hp.lar``/``hp.local_epochs`` — and
    ``acfg.cloud_every`` — may be traced per-scenario scalars: dead padded
    ticks pass the whole carry through unchanged (zero metrics, frozen
    global-tick clock) and the cloud cadence becomes data (a ``where``-
    selected fire on ``gtick % cloud_every``, a ``where``-selected
    round-start re-anchor / round-end aggregation for the ``cloud_every=0``
    sync-cadence cells).

    ``faults`` (``core.faults.FaultPlan``) switches to the fault-gated
    tick algebra ``(state, fault_r) -> (state, metrics)`` with ``fault_r``
    a per-round dict of lowered (lar, A)/(lar, R) mask DATA
    (``FaultSchedule.round_slice``): churned agents hard-disconnect,
    uploads (immediate AND due deliveries) to a dark RSU are dropped
    (the in-flight slot still frees — that update is lost, counted in
    ``metrics["blocked_mass"]``), the dark buffer ages under
    ``buffer_keep`` and is excluded from cloud fires via its zeroed fire
    mass, then re-anchors to the cloud master on the recovery tick;
    corrupted submissions are injected post-training and screened by
    ``core.aggregation.screen_updates`` (scrubbed + weight-masked +
    barred from enqueue, so cohort-mass accounting stays conserved),
    counted in ``metrics["quarantined"]``.  Only the guard flags shape
    the program; the benign lowering is bitwise identical to the
    fault-free body (anchor-pinned in tests/test_faults.py)."""
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = _fed_arrays(
        cfg, hp, fed,
        epochs_bound=None if cadence is None else cadence.local_epochs)
    lar_bound = hp.lar if cadence is None else cadence.lar
    A, R, N = cfg.n_agents, cfg.n_rsus, spec.n
    decay = acfg.agent_decay(rsu_assign, R)     # scalar or (A,)
    keep = acfg.rsu_keep(R)                     # scalar or (R,)

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    ce = acfg.cloud_every           # cadence: python int, or a traced
    ce_static = isinstance(ce, (int, np.integer))  # scalar under the sweep

    def tick(carry, inp):
        key = inp if (cadence is None and faults is None) else inp[0]
        f = inp[-1] if faults is not None else None
        (rsu_flat, rsu_mass, cloud_flat, conn, agent_flat,
         pend_x, pend_w, pend_t, cloud_macc, gtick) = carry

        if faults is not None:
            # 0. outage recovery: a recovering RSU re-anchors to the
            #    current cloud master, its aged buffer content and any
            #    not-yet-aggregated mass discarded (benign lowering:
            #    reanchor == 0 everywhere — where(False, ...) identity).
            ra = f["reanchor"] > 0
            rsu_flat = jnp.where(
                ra[:, None],
                jnp.broadcast_to(spec.to_storage(cloud_flat), (R, N)),
                rsu_flat)
            rsu_mass = jnp.where(ra, 0.0, rsu_mass)
            cloud_macc = jnp.where(ra, 0.0, cloud_macc)

        # 1. in-flight countdown: due updates deliver this tick; agents
        #    still computing stay busy and train nothing new.
        in_flight = pend_t > 0
        pend_t = jnp.maximum(pend_t - 1, 0)
        due = in_flight & (pend_t == 0)
        busy = in_flight & ~due

        # 2. stochastic realization — identical conn/FSR key discipline to
        #    engine="flat"; the latency draw uses a folded key so it never
        #    perturbs the sync draws.
        conn, mask, active_steps = round_draws(key, conn, het, hp, A, spe)
        delays = sample_latency(jax.random.fold_in(key, _LATENCY_FOLD),
                                A, het)
        if faults is not None:
            # churned agents are hard-disconnected this tick
            mask = mask & (f["agent_up"] > 0)
        maskf = mask.astype(jnp.float32)
        free = ~busy                                  # may start new work

        # 3. training: every non-busy agent runs its drawn steps from the
        #    current RSU buffer model (busy agents keep their row).
        act = jnp.where(busy, 0, active_steps)
        w_start = jnp.take(rsu_flat, rsu_assign, axis=0)       # (A, N)
        trained = spec.to_storage(
            train_agents(x_all, y_all, w_start, w_start, cloud_flat, act))

        if faults is not None:
            # corrupted submissions (NaN/Inf, byzantine scale, stale
            # replay) enter post-training; the quarantine gate scrubs
            # rejected rows back to w_start and zeroes their weight —
            # they are never absorbed and never enqueue.
            up_a = jnp.take(f["rsu_up"], rsu_assign)           # (A,)
            trained = faults_mod.apply_corruption(trained, agent_flat, f)
            w_submit = (n_per_agent * maskf * free.astype(jnp.float32)
                        * up_a)
            trained, okf, nq = screen_updates(
                trained, w_start, w_submit,
                nonfinite=faults.guard_nonfinite,
                norm_clip=faults.norm_clip)
        agent_flat = jnp.where(busy[:, None], agent_flat, trained)

        # 4.+5. arrivals + staleness-buffer merge: the zero-latency cohort
        #    (s(0) == 1) plus due stragglers, absorbed with running
        #    cohort-mass accounting.  Fused: ONE pass over (A, N)/(R, N)
        #    (ops.agg_absorb); unfused: two scatter-accumulates, an add
        #    and the buffer_absorb re-read (the pre-fusion program).
        w_imm = (n_per_agent * maskf * free
                 * (delays == 0).astype(jnp.float32))          # (A,)
        w_due = jnp.where(due, pend_w, 0.0)
        if faults is not None:
            # uploads to a dark RSU are dropped — immediate arrivals AND
            # due deliveries (the in-flight slot frees regardless); the
            # full lost upload mass is observable as blocked_mass
            blocked = jnp.sum((w_imm + w_due) * (1.0 - up_a))
            w_imm = w_imm * up_a * okf
            w_due = w_due * up_a
        m_i = jax.ops.segment_sum(w_imm, rsu_assign, num_segments=R)
        m_d = jax.ops.segment_sum(w_due, rsu_assign, num_segments=R)
        if fused:
            rsu_flat, rsu_mass, _ = ops.agg_absorb(
                ((agent_flat, w_imm), (pend_x, w_due)), rsu_assign, R,
                rsu_flat, rsu_mass, keep=keep)
        else:
            num_i, _ = ops.masked_scatter_accumulate(
                agent_flat, w_imm, rsu_assign, R)
            num_d, _ = ops.masked_scatter_accumulate(
                pend_x, w_due, rsu_assign, R)
            rsu_flat, rsu_mass = buffer_absorb(
                rsu_flat, rsu_mass, num_i + num_d, m_i + m_d, keep=keep)
        cloud_macc = cloud_macc + m_i + m_d

        # 6. enqueue new in-flight work (connected, trained, delayed);
        #    the delivery weight is decayed at enqueue — s(d) is known and
        #    the rate may be per-RSU (gathered through rsu_assign).
        enq = mask & free & (delays > 0)
        if faults is not None:
            enq = enq & (okf > 0)      # quarantined rows never enqueue
        pend_x = jnp.where(enq[:, None], trained, pend_x)
        w_enq = n_per_agent * maskf * acfg.weight(delays, decay=decay)
        pend_w = jnp.where(enq, w_enq, pend_w)
        pend_t = jnp.where(enq, delays, pend_t)

        # 7. cloud cadence on the GLOBAL tick clock (spans round
        #    boundaries): aggregate whatever RSU state exists, weighted by
        #    the mass absorbed since the last cloud aggregation.  Static
        #    cadence runs under lax.cond so non-fire ticks pay nothing; a
        #    traced cadence (sweep) where-selects the fire so mixed-cadence
        #    cells share the one program.
        gtick = gtick + 1
        # a dark RSU's not-yet-aggregated mass is zeroed at fire time so
        # the mass-guard excludes it from the blend (benign: macc · 1.0)
        macc_fire = (cloud_macc if faults is None
                     else cloud_macc * f["rsu_up"])

        def _fire(args):
            rsu, maccf, cloud, macc_keep = args
            if fused:
                cloud = ops.cloud_blend(rsu, maccf, cloud)
            else:
                new_cloud = ops.cloud_agg(rsu, maccf)
                cloud = jnp.where(jnp.sum(maccf) > 0,
                                  new_cloud.astype(jnp.float32), cloud)
            return cloud, jnp.zeros_like(macc_keep)

        if ce_static and ce:
            def _hold(args):
                _, _, cloud, macc_keep = args
                return cloud, macc_keep

            cloud_flat, cloud_macc = jax.lax.cond(
                (gtick % ce) == 0, _fire, _hold,
                (rsu_flat, macc_fire, cloud_flat, cloud_macc))
        elif not ce_static:
            fire = (ce > 0) & ((gtick % jnp.maximum(ce, 1)) == 0)
            f_cloud, f_macc = _fire((rsu_flat, macc_fire, cloud_flat,
                                     cloud_macc))
            cloud_flat = jnp.where(fire, f_cloud, cloud_flat)
            cloud_macc = jnp.where(fire, f_macc, cloud_macc)

        tick_metrics = {
            "absorbed_mass": m_i + m_d,                       # (R,)
            "immediate_mass": jnp.sum(m_i),
            "due_mass": jnp.sum(m_d),
            "enqueued_mass": jnp.sum(jnp.where(enq, w_enq, 0.0)),
        }
        if faults is not None:
            tick_metrics["quarantined"] = nq
            tick_metrics["blocked_mass"] = blocked
        new_carry = (rsu_flat, rsu_mass, cloud_flat, conn, agent_flat,
                     pend_x, pend_w, pend_t, cloud_macc, gtick)
        if cadence is not None:
            # dead padded ticks: carry passes through untouched (the tick
            # clock does NOT advance) and metrics are zero
            live_i = inp[1]
            new_carry = jax.tree.map(
                lambda n, o: jnp.where(live_i, n, o), new_carry, carry)
            tick_metrics = jax.tree.map(
                lambda v: jnp.where(live_i, v, jnp.zeros_like(v)),
                tick_metrics)
        return new_carry, tick_metrics

    def global_round(state: AsyncSimState, fault_r=None
                     ) -> Tuple[AsyncSimState, Dict[str, jax.Array]]:
        rng, k_rounds = jax.random.split(state.rng)
        keys = round_keys(k_rounds, lar_bound)
        live = (None if cadence is None
                else jnp.arange(lar_bound) < hp.lar)     # (lar_bound,)
        # per-round cadence (ce == 0, the sync anchor): RSUs re-anchor to
        # the cloud model at round start (Alg. 2 line 2) and the buffer /
        # cloud-mass accounting restarts with them.  Decoupled cadence
        # (ce > 0): the round boundary is no longer special — RSU buffers,
        # their running mass AND the cloud accumulator all persist, so the
        # mass the eventual cloud aggregation weights by always accounts
        # for content the buffers still hold.  A traced cadence selects
        # between the two with ``where`` on ``anchor = (ce == 0)``.
        anchored = jnp.broadcast_to(spec.to_storage(state.cloud_flat),
                                    (R, N))
        zeros_r = jnp.zeros((R,), jnp.float32)
        if ce_static:
            if ce:
                rsu0, rmass0, macc0 = (state.rsu_flat, state.rsu_mass,
                                       state.cloud_macc)
            else:
                rsu0, rmass0, macc0 = anchored, zeros_r, zeros_r
        else:
            anchor = ce == 0
            rsu0 = jnp.where(anchor, anchored, state.rsu_flat)
            rmass0 = jnp.where(anchor, zeros_r, state.rsu_mass)
            macc0 = jnp.where(anchor, zeros_r, state.cloud_macc)
        carry = (rsu0, rmass0, state.cloud_flat,
                 state.conn, state.agent_flat, state.pending_x,
                 state.pending_w, state.pending_t, macc0, state.tick)
        if faults is None:
            xs = keys if cadence is None else (keys, live)
        else:
            xs = ((keys, fault_r) if cadence is None
                  else (keys, live, fault_r))
        carry, ticks = jax.lax.scan(tick, carry, xs)
        (rsu_flat, rsu_mass, cloud_flat, conn, agent_flat,
         pend_x, pend_w, pend_t, cloud_macc, gtick) = carry

        if faults is not None:
            # round-end fire mass excludes RSUs dark at the round's last
            # live tick (benign: an all-ones row — bitwise identity)
            up_last = fault_r["rsu_up"][hp.lar - 1]
            cloud_macc_end = cloud_macc * up_last
        else:
            cloud_macc_end = cloud_macc

        if ce_static and not ce:
            # per-round cadence: round-end cloud aggregation over the
            # not-yet-aggregated mass (exactly the sync Alg. 3 line 6).
            if fused:
                cloud_flat = ops.cloud_blend(rsu_flat, cloud_macc_end,
                                             cloud_flat)
            else:
                new_cloud = ops.cloud_agg(rsu_flat, cloud_macc_end)
                cloud_flat = jnp.where(jnp.sum(cloud_macc_end) > 0,
                                       new_cloud.astype(jnp.float32),
                                       cloud_flat)
            cloud_macc = jnp.zeros((R,), jnp.float32)
        elif not ce_static:
            if fused:
                blended = ops.cloud_blend(rsu_flat, cloud_macc_end,
                                          cloud_flat)
            else:
                new_cloud = ops.cloud_agg(rsu_flat, cloud_macc_end)
                blended = jnp.where(jnp.sum(cloud_macc_end) > 0,
                                    new_cloud.astype(jnp.float32),
                                    cloud_flat)
            cloud_flat = jnp.where(anchor, blended, cloud_flat)
            cloud_macc = jnp.where(anchor, zeros_r, cloud_macc)

        out = AsyncSimState(agent_flat=agent_flat, rsu_flat=rsu_flat,
                            rsu_mass=rsu_mass, cloud_flat=cloud_flat,
                            pending_x=pend_x, pending_w=pend_w,
                            pending_t=pend_t, conn=conn, rng=rng,
                            cloud_macc=cloud_macc, tick=gtick)
        metrics = dict(ticks)
        metrics["pending_mass"] = pending_mass(out)
        return out, metrics

    return global_round


def make_async_global_round(cfg: SimConfig, hp: H2FedParams,
                            het: HeterogeneityModel, fed: FederatedData,
                            spec: flatten.FlatSpec,
                            acfg: Optional[AsyncConfig] = None,
                            loss_fn: Callable = mlp.loss_fn, *,
                            fused: bool = True, faults=None):
    """Build the jitted semi-async round: AsyncSimState -> (state, metrics).

    The input state's buffers are DONATED (updated in place at scale) —
    callers must rebind, ``state, m = round_fn(state)``, and never reuse the
    consumed input.  ``fused=False`` keeps the multi-pass tick program for
    A/B benchmarking (benchmarks/async_round).  With ``faults`` the round
    is ``(state, fault_r) -> (state, metrics)`` (see
    ``_make_async_round_body``).
    """
    acfg = (acfg or AsyncConfig()).validate()
    body = _make_async_round_body(cfg, hp, het, fed, spec, acfg, loss_fn,
                                  fused=fused, faults=faults)
    return jax.jit(body, donate_argnums=(0,))


# --------------------------------------------------------------------------
# RSU-sharded semi-async round (DESIGN.md §4 x §6)
# --------------------------------------------------------------------------

def make_sharded_async_global_round(cfg: SimConfig, hp: H2FedParams,
                                    het: HeterogeneityModel,
                                    fed: FederatedData,
                                    spec: flatten.FlatSpec,
                                    topo: HierarchyTopology,
                                    acfg: Optional[AsyncConfig] = None,
                                    loss_fn: Callable = mlp.loss_fn):
    """The semi-async tick loop under ``shard_map`` on an RSU-sharded
    topology: in-flight buffers live with their agents, the per-tick
    scatter-accumulate is block-local (``kernels/ops.block_local_agg``,
    psum over the within-pod data axis only), ``buffer_absorb`` runs on the
    pod's ``(R_local, N)`` shard, and only the cloud cadence reduces over
    the pod axis.  State arrays use the topology's pod-block agent order
    (``run_sharded_async_simulation`` converts at the boundary); the global
    RSU order is untouched (pods own contiguous RSU blocks).
    """
    if not topo.rsu_sharded:
        raise ValueError("make_sharded_async_global_round needs an "
                         "rsu_sharded=True HierarchyTopology "
                         "(use make_async_global_round otherwise)")
    acfg = (acfg or AsyncConfig()).validate()
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = \
        _fed_arrays(cfg, hp, fed)
    A, R, N = cfg.n_agents, cfg.n_rsus, spec.n
    R_loc = topo.rsu_per_pod
    perm = jnp.asarray(topo.agent_perm)
    x_all = jnp.take(x_all, perm, axis=0)
    y_all = jnp.take(y_all, perm, axis=0)
    n_per_agent = jnp.take(n_per_agent, perm, axis=0)
    local_assign = jnp.asarray(topo.local_assign)
    # per-agent decay / per-RSU keep as full arrays so the shard_map specs
    # stay uniform (scalar knobs broadcast)
    decay = jnp.broadcast_to(
        jnp.asarray(acfg.agent_decay(rsu_assign, R), jnp.float32), (A,))
    decay = jnp.take(decay, perm, axis=0)
    keep = jnp.broadcast_to(
        jnp.asarray(acfg.rsu_keep(R), jnp.float32), (R,))
    data_ax = topo.data_shard_axes
    pod_ax = topo.pod_axis
    ce = acfg.cloud_every

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    storage = spec.storage_dtype
    # cross-pod (DCI) cloud reduction dtype: bf16 storage halves its bytes
    cloud_reduce = None if storage == jnp.dtype(jnp.float32) else storage

    def _pod_sum(v):
        return jax.lax.psum(v, data_ax) if data_ax is not None else v

    def _pod_sum_num(v):
        """Within-pod psum of an (R_local, N) numerator — reduced in the
        fleet storage dtype (halves ICI bytes at bf16; fp32 default is
        exact/no-op), widened back to fp32 for the merge."""
        if data_ax is None:
            return v
        return jax.lax.psum(v.astype(storage),
                            data_ax).astype(jnp.float32)

    def round_fn(cloud_flat, agent_flat, rsu_flat0, rsu_mass0, pend_x,
                 pend_w, pend_t, cloud_macc, gtick0, x, y, n_data, assign,
                 dec, keep_l, masks, steps, delays_all):
        """Shard-local: A_local agents of this pod's R_local RSUs."""
        if ce:
            # decoupled cadence: the (R_local, N) block and its running
            # mass persist across round boundaries (see the replicated
            # twin's global_round for the rationale)
            rsu_flat, rsu_mass = rsu_flat0, rsu_mass0
        else:
            rsu_flat = jnp.broadcast_to(cloud_flat.astype(storage),
                                        (R_loc, N))
            rsu_mass = jnp.zeros((R_loc,), jnp.float32)

        def tick(carry, inp):
            (rsu_flat, rsu_mass, cloud_flat, agent_flat,
             pend_x, pend_w, pend_t, cloud_macc, gtick) = carry
            maskf, act_steps, delays = inp

            in_flight = pend_t > 0
            pend_t = jnp.maximum(pend_t - 1, 0)
            due = in_flight & (pend_t == 0)
            busy = in_flight & ~due
            free = ~busy

            act = jnp.where(busy, 0, act_steps)
            w_start = jnp.take(rsu_flat, assign, axis=0)
            trained = train_agents(x, y, w_start, w_start, cloud_flat,
                                   act).astype(storage)
            agent_flat = jnp.where(busy[:, None], agent_flat, trained)

            # block-local arrivals; psum over the data axis only
            w_imm = (n_data * maskf * free
                     * (delays == 0).astype(jnp.float32))
            w_due = jnp.where(due, pend_w, 0.0)
            num_i, m_i = ops.block_local_agg(agent_flat, w_imm, assign,
                                             R_loc)
            num_d, m_d = ops.block_local_agg(pend_x, w_due, assign, R_loc)
            num = _pod_sum_num(num_i + num_d)
            m_new = _pod_sum(m_i + m_d)
            rsu_flat, rsu_mass = buffer_absorb(rsu_flat, rsu_mass, num,
                                               m_new, keep=keep_l)
            cloud_macc = cloud_macc + m_new

            enq = (maskf > 0) & free & (delays > 0)
            pend_x = jnp.where(enq[:, None], trained, pend_x)
            w_enq = n_data * maskf * staleness_weights(
                delays, decay=dec, schedule=acfg.schedule)
            pend_w = jnp.where(enq, w_enq, pend_w)
            pend_t = jnp.where(enq, delays, pend_t)

            gtick = gtick + 1
            if ce:
                # lax.cond keeps the cross-pod psum OFF non-fire ticks —
                # the RSU step stays pod-local except when the cadence
                # actually fires (every replica takes the same branch:
                # the tick clock is replicated)
                def _fire(args):
                    rsu, macc, cloud = args
                    cloud = topo.cloud_psum_mean(
                        macc, rsu, cloud, reduce_dtype=cloud_reduce)
                    return cloud, jnp.zeros_like(macc)

                def _hold(args):
                    _, macc, cloud = args
                    return cloud, macc

                cloud_flat, cloud_macc = jax.lax.cond(
                    (gtick % ce) == 0, _fire, _hold,
                    (rsu_flat, cloud_macc, cloud_flat))

            # per-pod metric partials ((1,)-shaped so the out spec can
            # carry the pod axis); summed to globals outside the shard_map
            tick_metrics = {
                "absorbed_mass": m_new,                       # (R_local,)
                "immediate_mass": _pod_sum(jnp.sum(m_i))[None],
                "due_mass": _pod_sum(jnp.sum(m_d))[None],
                "enqueued_mass":
                    _pod_sum(jnp.sum(jnp.where(enq, w_enq, 0.0)))[None],
            }
            carry = (rsu_flat, rsu_mass, cloud_flat, agent_flat,
                     pend_x, pend_w, pend_t, cloud_macc, gtick)
            return carry, tick_metrics

        carry = (rsu_flat, rsu_mass, cloud_flat, agent_flat,
                 pend_x, pend_w, pend_t, cloud_macc, gtick0)
        carry, ticks = jax.lax.scan(tick, carry,
                                    (masks, steps, delays_all))
        (rsu_flat, rsu_mass, cloud_flat, agent_flat,
         pend_x, pend_w, pend_t, cloud_macc, gtick) = carry

        if not ce:
            # per-round cadence: the round-end cloud aggregation is the
            # round's ONE cross-pod collective
            cloud_flat = topo.cloud_psum_mean(cloud_macc, rsu_flat,
                                              cloud_flat,
                                              reduce_dtype=cloud_reduce)
            cloud_macc = jnp.zeros_like(cloud_macc)

        return (cloud_flat, agent_flat, rsu_flat, rsu_mass,
                pend_x, pend_w, pend_t, cloud_macc, gtick, ticks)

    P_a, P_r, P_c = topo.agent_spec, topo.rsu_spec, topo.cloud_spec
    P_s = topo.stacked_spec()
    pod_stack = (P(None, pod_ax) if pod_ax is not None else P(None, None))
    smapped = shard_map(
        round_fn, topo.mesh,
        in_specs=(P_c, P_a, P_r, P_r, P_a, P_a, P_a, P_r, P_c, P_a, P_a,
                  P_a, P_a, P_a, P_r, P_s, P_s, P_s),
        out_specs=(P_c, P_a, P_r, P_r, P_a, P_a, P_a, P_r, P_c,
                   {"absorbed_mass": pod_stack, "immediate_mass": pod_stack,
                    "due_mass": pod_stack, "enqueued_mass": pod_stack}),
        axis_names=set(topo.agent_axes))

    def global_round(state: AsyncSimState
                     ) -> Tuple[AsyncSimState, Dict[str, jax.Array]]:
        rng, k_rounds = jax.random.split(state.rng)
        keys = round_keys(k_rounds, hp.lar)

        # draws + latencies on the replicated ORIGINAL agent order (the
        # flat-engine key discipline), permuted onto the pod-block layout
        def draw(conn, key):
            conn, mask, act = round_draws(key, conn, het, hp, A, spe)
            d = sample_latency(jax.random.fold_in(key, _LATENCY_FOLD),
                               A, het)
            return conn, (mask.astype(jnp.float32), act, d)

        conn, (masks, steps, delays) = jax.lax.scan(draw, state.conn, keys)
        masks = jnp.take(masks, perm, axis=1)
        steps = jnp.take(steps, perm, axis=1)
        delays = jnp.take(delays, perm, axis=1)

        macc0 = (state.cloud_macc if ce
                 else jnp.zeros((R,), jnp.float32))
        (cloud_flat, agent_flat, rsu_flat, rsu_mass, pend_x, pend_w,
         pend_t, cloud_macc, gtick, ticks) = smapped(
            state.cloud_flat, state.agent_flat, state.rsu_flat,
            state.rsu_mass, state.pending_x, state.pending_w,
            state.pending_t, macc0, state.tick,
            x_all, y_all, n_per_agent, local_assign, decay, keep,
            masks, steps, delays)

        out = AsyncSimState(agent_flat=agent_flat, rsu_flat=rsu_flat,
                            rsu_mass=rsu_mass, cloud_flat=cloud_flat,
                            pending_x=pend_x, pending_w=pend_w,
                            pending_t=pend_t, conn=conn, rng=rng,
                            cloud_macc=cloud_macc, tick=gtick)
        metrics = {"absorbed_mass": ticks["absorbed_mass"]}  # (LAR, R)
        for k in ("immediate_mass", "due_mass", "enqueued_mass"):
            metrics[k] = jnp.sum(ticks[k], axis=1)           # (LAR,)
        metrics["pending_mass"] = pending_mass(out)
        return out, metrics

    return jax.jit(global_round, donate_argnums=(0,))


def run_async_simulation(cfg: SimConfig, hp: H2FedParams,
                         het: HeterogeneityModel, fed: FederatedData,
                         init_params: PyTree, n_rounds: int, *,
                         acfg: Optional[AsyncConfig] = None,
                         topo: Optional[HierarchyTopology] = None,
                         x_test=None, y_test=None,
                         loss_fn: Callable = mlp.loss_fn,
                         eval_fn: Optional[Callable] = None,
                         fleet_dtype=None,
                         fused: bool = True,
                         ) -> Tuple[AsyncSimState, Dict[str, np.ndarray]]:
    """DEPRECATED: use ``fedsim.run_scenario`` with an
    ``engine="async"`` ``ScenarioSpec`` — the semi-async knobs (staleness
    schedule, buffer keep, cloud cadence) are spec fields (DESIGN.md §8).

    This wrapper builds an ad-hoc scenario around the pre-built arrays and
    delegates; numerics are unchanged (equivalence test-pinned in
    tests/test_api.py).  ``topo`` passes through to the RSU-sharded tick
    loop.
    """
    warnings.warn(
        "run_async_simulation is deprecated; use fedsim.run_scenario with "
        "an engine='async' ScenarioSpec (async knobs are spec fields)",
        DeprecationWarning, stacklevel=2)
    from repro.fedsim import sweep
    res = sweep.adhoc_scenario(
        cfg, hp, het, fed, n_rounds=n_rounds, engine="async",
        fleet_dtype=fleet_dtype, fused=fused, async_cfg=acfg,
        x_test=x_test, y_test=y_test)
    return sweep.run_scenario(res, init_params, loss_fn=loss_fn,
                              eval_fn=eval_fn, topo=topo)


def _run_async(res, init_params: PyTree, *,
               loss_fn: Callable = mlp.loss_fn,
               eval_fn: Optional[Callable] = None,
               topo: Optional[HierarchyTopology] = None,
               ) -> Tuple[AsyncSimState, Dict[str, np.ndarray]]:
    """``run_scenario``'s semi-async dispatch target: run the scenario's
    rounds through the tick engine; history carries the accuracy curve
    plus per-round absorbed/pending mass so the straggler economy is
    observable.  A ``topo`` (rsu-sharded HierarchyTopology) runs the tick
    loop sharded over its mesh, converting agent order on entry/exit."""
    s = res.spec
    cfg, hp, het, fed = res.cfg, s.hp, s.het, res.fed
    n_rounds, fleet_dtype, fused = s.rounds, s.fleet_dtype, s.fused
    x_test = res.test.x if res.test is not None else None
    y_test = res.test.y if res.test is not None else None
    hp.validate(), het.validate()
    acfg = AsyncConfig(staleness_decay=s.staleness_decay,
                       schedule=s.schedule, buffer_keep=s.buffer_keep,
                       cloud_every=s.cloud_every).validate()
    key = jax.random.key(cfg.seed)
    spec = flatten.spec_of(
        init_params, storage_dtype=flatten.resolve_storage_dtype(fleet_dtype))
    state = init_async_state(cfg, spec, init_params, key)
    if topo is not None:
        assert s.faults is None, \
            "fault injection is not threaded through the rsu-sharded path"
        round_fn = make_sharded_async_global_round(cfg, hp, het, fed, spec,
                                                   topo, acfg, loss_fn)
    else:
        round_fn = make_async_global_round(cfg, hp, het, fed, spec, acfg,
                                           loss_fn, fused=fused,
                                           faults=s.faults)
    if eval_fn is None and x_test is not None:
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_test, y_test))

    # fault schedules lower once to per-tick mask data over the global
    # tick clock (rounds x lar); each round consumes its slice as DATA
    sched = (None if s.faults is None
             else s.faults.lower(cfg.n_agents, cfg.n_rsus,
                                 n_rounds * hp.lar))

    def run_rounds(state):
        accs, rounds, absorbed, pending = [], [], [], []
        quarantined, blocked = [], []
        for r in range(n_rounds):
            if sched is None:
                state, metrics = round_fn(state)
            else:
                state, metrics = round_fn(state,
                                          sched.round_slice(r, hp.lar))
                quarantined.append(int(jnp.sum(metrics["quarantined"])))
                blocked.append(float(jnp.sum(metrics["blocked_mass"])))
            absorbed.append(float(jnp.sum(metrics["absorbed_mass"])))
            pending.append(float(metrics["pending_mass"]))
            if eval_fn is not None and (r % cfg.eval_every == 0
                                        or r == n_rounds - 1):
                accs.append(float(eval_fn(spec.unravel(state.cloud_flat))))
                rounds.append(r + 1)
        history = {"round": np.asarray(rounds), "acc": np.asarray(accs),
                   "absorbed_mass": np.asarray(absorbed),
                   "pending_mass": np.asarray(pending)}
        if sched is not None:
            history["quarantined"] = np.asarray(quarantined)
            history["blocked_mass"] = np.asarray(blocked)
        return state, history

    if topo is None:
        return run_rounds(state)
    with topo.mesh:
        state = state._replace(
            agent_flat=topo.permute_agents(state.agent_flat),
            pending_x=topo.permute_agents(state.pending_x),
            pending_w=topo.permute_agents(state.pending_w),
            pending_t=topo.permute_agents(state.pending_t))
        state, history = run_rounds(state)
        state = state._replace(
            agent_flat=topo.unpermute_agents(state.agent_flat),
            pending_x=topo.unpermute_agents(state.pending_x),
            pending_w=topo.unpermute_agents(state.pending_w),
            pending_t=topo.unpermute_agents(state.pending_t))
    return state, history
