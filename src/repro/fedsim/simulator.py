"""Paper-faithful H²-Fed simulator (Algorithms 1–3), fully vectorized.

One compiled ``global_round``:

  1. RSUs download the cloud model (Alg. 2 line 2): w_k ← w.
  2. LAR local rounds (lax.scan).  Per local round:
       a. connectivity draw (CSR/SCD) + FSR epoch draw  (Sec. III),
       b. every agent trains from its RSU model w_k for its completed
          epochs with the dual-proximal objective (Alg. 1, Eq. 6) —
          vmap over agents, scan over minibatch steps,
       c. CSR-masked, data-volume-weighted per-RSU aggregation
          (Alg. 2 line 8); RSUs with an empty cohort keep their model.
  3. Cloud aggregation over RSUs weighted by surviving data mass
     (Alg. 3 line 6); if nothing survived the cloud model is kept.

Two engines share this program structure (DESIGN.md §3):

  engine="flat" (default, the production hot path) — the fleet lives in
  contiguous fp32 buffers: agents (A, N), RSUs (R, N), cloud (N,)
  (core/flatten).  Both aggregation layers are single Pallas matmul calls
  (kernels/masked_hier_agg via kernels/ops) and the dual-proximal update is
  one fused vector expression; parameters are unraveled to pytrees only at
  eval/checkpoint boundaries.  fedsim/sharded.py partitions the same
  buffers' agent axis over a device mesh.

  engine="tree" (the reference) — per-leaf jax.tree.map aggregation
  (core/aggregation).  Property tests assert both engines agree to fp32
  tolerance (tests/test_flatten.py).

  engine="async" (fedsim/async_engine, DESIGN.md §6) — drops the global
  round barrier: agents deliver with drawn arrival latencies, RSU buffers
  absorb stragglers with staleness-decayed weights, and the cloud
  aggregates at its own cadence.  With zero latencies and decay disabled it
  reproduces engine="flat" to fp32 tolerance (tests/test_async.py).

Baseline equivalences (paper Sec. V) hold *exactly* by construction:
LAR=1 makes the RSU layer a pass-through (w_k == w at training time), so
mu=0 is FedAvg and mu1>0 is FedProx on the flat topology; mu=0 with LAR>1
is HierFAVG.  Property tests assert this numerically.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as faults_mod
from repro.core import flatten
from repro.core.aggregation import (blend_on_mass, broadcast_to_agents,
                                    gather_rsu_for_agents, masked_weighted_mean,
                                    rsu_aggregate, screen_updates)
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import (ConnState, HeterogeneityModel,
                                      init_conn_state, step_connectivity)
from repro.data.partition import FederatedData
from repro.data.pipeline import agent_minibatch
from repro.kernels import ops
from repro.models import mlp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_agents: int = 100
    n_rsus: int = 10
    batch: int = 32
    seed: int = 0
    eval_every: int = 1     # global rounds between test-set evaluations


class SimState(NamedTuple):
    """Pytree-view state (the eval/checkpoint boundary representation)."""
    agent_params: PyTree    # stacked (A, ...) — w_{i,k}
    rsu_params: PyTree      # stacked (R, ...) — w_k
    cloud_params: PyTree    # (...)            — w
    conn: ConnState
    rng: jax.Array


class FlatSimState(NamedTuple):
    """Flat-buffer state: the whole fleet as three contiguous buffers.

    agent_flat/rsu_flat live in the spec's STORAGE dtype (fp32 default;
    bf16 halves fleet HBM + collective bytes, DESIGN.md §3); cloud_flat is
    always the fp32 master."""
    agent_flat: jax.Array   # (A, N)  storage dtype
    rsu_flat: jax.Array     # (R, N)  storage dtype
    cloud_flat: jax.Array   # (N,)    fp32 master
    conn: ConnState
    rng: jax.Array


def init_state(cfg: SimConfig, init_params: PyTree, key) -> SimState:
    return SimState(
        agent_params=broadcast_to_agents(init_params, cfg.n_agents),
        rsu_params=broadcast_to_agents(init_params, cfg.n_rsus),
        cloud_params=init_params,
        conn=init_conn_state(cfg.n_agents),
        rng=key)


def init_flat_state(cfg: SimConfig, spec: flatten.FlatSpec,
                    init_params: PyTree, key) -> FlatSimState:
    vec = spec.ravel(init_params)
    sv = spec.to_storage(vec)
    return FlatSimState(
        agent_flat=jnp.broadcast_to(sv, (cfg.n_agents, spec.n)),
        rsu_flat=jnp.broadcast_to(sv, (cfg.n_rsus, spec.n)),
        cloud_flat=vec,
        conn=init_conn_state(cfg.n_agents),
        rng=key)


def to_flat_state(spec: flatten.FlatSpec, state: SimState) -> FlatSimState:
    return FlatSimState(
        agent_flat=spec.to_storage(spec.ravel_stacked(state.agent_params)),
        rsu_flat=spec.to_storage(spec.ravel_stacked(state.rsu_params)),
        cloud_flat=spec.ravel(state.cloud_params),
        conn=state.conn, rng=state.rng)


def from_flat_state(spec: flatten.FlatSpec, state: FlatSimState) -> SimState:
    return SimState(agent_params=spec.unravel_stacked(state.agent_flat),
                    rsu_params=spec.unravel_stacked(state.rsu_flat),
                    cloud_params=spec.unravel(state.cloud_flat),
                    conn=state.conn, rng=state.rng)


class Cadence(NamedTuple):
    """Static upper bounds for the cadence knobs (DESIGN.md §7/§10).

    When a round body receives a ``Cadence``, ``hp.lar``/``hp.local_epochs``
    may be traced per-scenario scalars: the LAR scan runs to ``lar`` and a
    per-iteration ``live = i < hp.lar`` mask makes padded iterations
    algebra-neutral (carry and metrics pass through unchanged), while the
    minibatch scan runs to ``local_epochs``·spe with the existing
    ``active_steps`` masking.  ``None`` keeps the fully static program."""
    lar: int
    local_epochs: int


def round_keys(k_rounds, n: int) -> jax.Array:
    """The ``n`` per-local-round draw keys, cadence-independent.

    Key i is ``fold_in(k_rounds, i)``, so the first k keys of a padded
    n-bound schedule equal the k keys a lar=k program draws —
    ``jax.random.split(k, lar)`` does NOT have this prefix property (its
    counter layout depends on lar).  Every engine derives its local-round
    keys here so the sweep's masked static-upper-bound padding reproduces
    sequential execution exactly (tests/test_sweep.py).
    """
    return jax.vmap(lambda i: jax.random.fold_in(k_rounds, i))(jnp.arange(n))


def _epoch_cap(local_epochs):
    """randint maxval for the FSR partial-epoch draw; trace-safe (the
    sweep batches ``local_epochs`` as data, so it may be a tracer)."""
    if isinstance(local_epochs, (int, np.integer)):
        return max(int(local_epochs), 1)
    return jnp.maximum(local_epochs, 1)


def round_draws(key, conn: ConnState, het: HeterogeneityModel,
                hp: H2FedParams, n_agents: int, spe: int):
    """One local round's stochastic realization, shared by every engine.

    Returns (conn', mask (A,) bool, active_steps (A,) int): the CSR/SCD
    connectivity draw and the FSR-drawn completed-epoch step counts
    (0 epochs == disconnected).
    """
    k_conn, k_fsr = jax.random.split(key)
    conn, connected = step_connectivity(k_conn, conn, het)
    full = jax.random.bernoulli(k_fsr, het.fsr, (n_agents,))
    epochs = jnp.where(full, hp.local_epochs,
                       jax.random.randint(jax.random.fold_in(k_fsr, 1),
                                          (n_agents,), 0,
                                          _epoch_cap(hp.local_epochs)))
    active_steps = epochs * spe
    mask = connected & (active_steps > 0)
    return conn, mask, active_steps


def _local_train(loss_fn: Callable, x, y, w0: PyTree, w_rsu: PyTree,
                 w_cloud: PyTree, hp: H2FedParams, n_steps: int,
                 active_steps: jax.Array, batch: int) -> PyTree:
    """One agent: ``active_steps`` proximal-SGD minibatch steps from w0.

    n_steps is the static bound (E_max · steps-per-epoch); active_steps the
    FSR-drawn dynamic count — steps beyond it are masked to identity.
    """

    def objective(w, xb, yb):
        return loss_fn(w, xb, yb)

    grad_fn = jax.grad(objective)

    def body(w, step):
        xb, yb = agent_minibatch(x, y, step, batch)
        g = grad_fn(w, xb, yb)
        live = (step < active_steps).astype(jnp.float32)

        def upd(wl, gl, a1, a2):
            step_v = gl + hp.mu1 * (wl - a1) + hp.mu2 * (wl - a2)
            return wl - hp.lr * live * step_v

        return jax.tree.map(upd, w, g, w_rsu, w_cloud), None

    w, _ = jax.lax.scan(body, w0, jnp.arange(n_steps))
    return w


def _local_train_flat(loss_fn: Callable, spec: flatten.FlatSpec, x, y,
                      w0: jax.Array, w_rsu: jax.Array, w_cloud: jax.Array,
                      hp: H2FedParams, n_steps: int,
                      active_steps: jax.Array, batch: int) -> jax.Array:
    """Flat-buffer twin of ``_local_train``: the whole model is one (N,)
    fp32 vector, so the dual-proximal update (Alg. 1, Eq. 6) is a single
    fused expression — no per-leaf tree traffic in the inner loop.

    Compute is always fp32: storage-dtype (bf16) inputs are widened at
    entry (a no-op under the fp32 default), so training precision is
    independent of the fleet-buffer storage dtype; the caller casts the
    returned fp32 vector back into storage when writing the buffer."""

    grad_fn = jax.grad(lambda wf, xb, yb: loss_fn(spec.unravel(wf), xb, yb))
    w_rsu = w_rsu.astype(jnp.float32)
    w_cloud = w_cloud.astype(jnp.float32)

    def body(w, step):
        xb, yb = agent_minibatch(x, y, step, batch)
        g = grad_fn(w, xb, yb)
        live = (step < active_steps).astype(jnp.float32)
        w = w - hp.lr * live * (g + hp.mu1 * (w - w_rsu)
                                + hp.mu2 * (w - w_cloud))
        return w, None

    w, _ = jax.lax.scan(body, w0.astype(jnp.float32), jnp.arange(n_steps))
    return w


def _fed_arrays(cfg: SimConfig, hp: H2FedParams, fed: FederatedData, *,
                epochs_bound: Optional[int] = None):
    x_all = jnp.asarray(fed.x)
    y_all = jnp.asarray(fed.y)
    n_per_agent = jnp.asarray(fed.n_per_agent, jnp.float32)
    rsu_assign = jnp.asarray(fed.rsu_assign)
    spe = max(int(fed.x.shape[1]) // cfg.batch, 1)       # steps per epoch
    # static bound on minibatch steps: when the sweep batches local_epochs
    # as data, the group-wide maximum (epochs_bound) sizes the scan and
    # ``active_steps`` masks the tail (DESIGN.md §7)
    epochs = hp.local_epochs if epochs_bound is None else epochs_bound
    n_steps = epochs * spe
    return x_all, y_all, n_per_agent, rsu_assign, spe, n_steps


def _make_flat_round_body(cfg: SimConfig, hp: H2FedParams,
                          het: HeterogeneityModel, fed: FederatedData,
                          spec: flatten.FlatSpec,
                          loss_fn: Callable = mlp.loss_fn, *,
                          fused: bool = True,
                          cadence: Optional[Cadence] = None,
                          faults: Optional[faults_mod.FaultPlan] = None):
    """The flat-buffer global round body: FlatSimState -> FlatSimState
    (un-jitted — callers compose and jit it).

    ``fused=True`` (default) runs the ONE-PASS round: both aggregation
    layers go through the fused aggregate-and-blend entry points
    (``ops.agg_blend`` / ``ops.cloud_blend``), so each (R, N) tile is read
    once and written once — no fresh numerator re-read by a separate
    mass-guard pass.  ``fused=False`` keeps the two-step program
    (aggregation matmul, then the blend) for A/B benchmarking; off-TPU
    both lower to the same XLA ops and are fp32 bit-compatible.  Fleet
    buffers live in ``spec.storage_dtype``; the cloud stays fp32.

    ``cadence`` (sweep-only) pads the LAR/minibatch scans to the group-wide
    static bounds so ``hp.lar``/``hp.local_epochs`` may be traced scalars:
    a per-iteration ``live`` mask gates the scan carry and zeroes the
    per-round masses, so padded iterations are exact no-ops and the padded
    program reproduces the static one bit-for-bit on live iterations.

    ``faults`` (a ``core.faults.FaultPlan``) switches to the fault-gated
    program ``(state, fault_r) -> (state, metrics)``: ``fault_r`` is a
    per-round dict of lowered (lar, A)/(lar, R) mask DATA
    (``FaultSchedule.round_slice``) — churn folds into the connectivity
    mask, RSU outages zero upload weights, corrupted payloads are
    injected post-training and screened by ``screen_updates`` (scrubbed
    + weight-masked, so cohort-mass accounting stays conserved), and
    ``metrics["quarantined"]`` counts rejected weighted rows.  Only the
    plan's guard flags shape the program; the benign lowering is
    bitwise identical to the fault-free body (anchor-pinned).
    """
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = _fed_arrays(
        cfg, hp, fed,
        epochs_bound=None if cadence is None else cadence.local_epochs)
    lar_bound = hp.lar if cadence is None else cadence.lar

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    def global_round(state: FlatSimState, fault_r=None):
        rng, k_rounds = jax.random.split(state.rng)
        # Alg. 2 line 2: RSUs replace w_k with the current cloud model
        rsu_flat = jnp.broadcast_to(spec.to_storage(state.cloud_flat),
                                    (cfg.n_rsus, spec.n))
        keys = round_keys(k_rounds, lar_bound)
        live = (None if cadence is None
                else jnp.arange(lar_bound) < hp.lar)     # (lar_bound,)

        def local_round(carry, inp):
            key = inp if (cadence is None and faults is None) else inp[0]
            f = inp[-1] if faults is not None else None
            rsu_prev, conn_prev, agent_prev = carry
            conn, mask, active_steps = round_draws(
                key, conn_prev, het, hp, cfg.n_agents, spe)
            if faults is not None:
                # churned agents are hard-disconnected this tick
                # (benign lowering: mask & True — identity)
                mask = mask & (f["agent_up"] > 0)
            maskf = mask.astype(jnp.float32)

            # Alg. 2 l.5 / Alg. 1 l.1: every agent starts from its RSU row
            w_start = jnp.take(rsu_prev, rsu_assign, axis=0)     # (A, N)
            agent_flat = spec.to_storage(
                train_agents(x_all, y_all, w_start, w_start,
                             state.cloud_flat, active_steps))

            nq = None
            if faults is not None:
                # corrupted submissions (NaN/Inf, byzantine scale, stale
                # replay) enter here, then the quarantine gate scrubs +
                # weight-masks them; uploads to a dark RSU are dropped
                agent_flat = faults_mod.apply_corruption(
                    agent_flat, agent_prev, f)
                up_a = jnp.take(f["rsu_up"], rsu_assign)         # (A,)
                w_pre = n_per_agent * maskf * up_a
                agent_flat, okf, nq = screen_updates(
                    agent_flat, w_start, w_pre,
                    nonfinite=faults.guard_nonfinite,
                    norm_clip=faults.norm_clip)
                maskf = maskf * up_a * okf

            # Alg. 2 line 8: one (R, A) @ (A, N) pass over the fleet
            if fused:
                rsu_flat, mass = ops.agg_blend(
                    agent_flat, n_per_agent, maskf,
                    rsu_assign, cfg.n_rsus, rsu_prev)
            else:
                new_rsu, mass = ops.masked_hier_agg(
                    agent_flat, n_per_agent, maskf,
                    rsu_assign, cfg.n_rsus)
                rsu_flat = jnp.where((mass > 0)[:, None], new_rsu,
                                     rsu_prev).astype(rsu_prev.dtype)
            if cadence is not None:
                # padded LAR iterations are exact no-ops: carry passes
                # through untouched and the round contributes zero mass
                live_i = inp[1]
                rsu_flat, conn, agent_flat = jax.tree.map(
                    lambda n, o: jnp.where(live_i, n, o),
                    (rsu_flat, conn, agent_flat),
                    (rsu_prev, conn_prev, agent_prev))
                mass = jnp.where(live_i, mass, 0.0)
                if nq is not None:
                    nq = jnp.where(live_i, nq, 0)
            out = mass if faults is None else (mass, nq)
            return (rsu_flat, conn, agent_flat), out

        if faults is None:
            xs = keys if cadence is None else (keys, live)
        else:
            xs = ((keys, fault_r) if cadence is None
                  else (keys, live, fault_r))
        (rsu_flat, conn, agent_flat), out = jax.lax.scan(
            local_round, (rsu_flat, state.conn, state.agent_flat), xs)
        masses = out if faults is None else out[0]

        # Alg. 3 line 6: cloud aggregation — the (1, R) @ (R, N) matmul
        total_mass = jnp.sum(masses, axis=0)                     # (R,)
        if fused:
            cloud_flat = ops.cloud_blend(rsu_flat, total_mass,
                                         state.cloud_flat)
        else:
            new_cloud = ops.cloud_agg(rsu_flat, total_mass)
            cloud_flat = jnp.where(jnp.sum(total_mass) > 0,
                                   new_cloud.astype(jnp.float32),
                                   state.cloud_flat)
        new_state = FlatSimState(agent_flat=agent_flat, rsu_flat=rsu_flat,
                                 cloud_flat=cloud_flat, conn=conn, rng=rng)
        if faults is None:
            return new_state
        return new_state, {"quarantined": jnp.sum(out[1])}

    return global_round


def make_flat_global_round(cfg: SimConfig, hp: H2FedParams,
                           het: HeterogeneityModel, fed: FederatedData,
                           spec: flatten.FlatSpec,
                           loss_fn: Callable = mlp.loss_fn, *,
                           fused: bool = True, faults=None):
    """The flat-buffer global round: FlatSimState -> FlatSimState, jitted.

    The input state's buffers are DONATED: the (A, N)/(R, N)/(N,) update is
    in-place at scale (no copy of the fleet per round; verified via the
    dry-run HLO alias analysis, launch/hlo_analysis.donated_params).
    Callers must rebind — ``state = round_fn(state)`` — and never touch the
    consumed input again.  ``fused=False`` keeps the two-pass aggregation
    program for A/B benchmarking (benchmarks/async_round, topology_round).
    With ``faults`` the round is ``(state, fault_r) -> (state, metrics)``
    (see ``_make_flat_round_body``).
    """
    return jax.jit(_make_flat_round_body(cfg, hp, het, fed, spec, loss_fn,
                                         fused=fused, faults=faults),
                   donate_argnums=(0,))


def _make_tree_global_round(cfg: SimConfig, hp: H2FedParams,
                            het: HeterogeneityModel, fed: FederatedData,
                            loss_fn: Callable):
    """The per-leaf tree-map reference round (the original engine)."""
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = \
        _fed_arrays(cfg, hp, fed)

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train(
            loss_fn, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    def local_round(carry, key):
        rsu_params, conn, cloud_params = carry
        conn, mask, active_steps = round_draws(
            key, conn, het, hp, cfg.n_agents, spe)

        # Alg. 2 line 5 / Alg. 1 line 1: every agent starts from its RSU model
        w_start = gather_rsu_for_agents(rsu_params, rsu_assign)
        agent_params = train_agents(x_all, y_all, w_start, w_start,
                                    cloud_params, active_steps)

        # Alg. 2 line 8: masked weighted per-RSU aggregation
        new_rsu, mass = rsu_aggregate(agent_params, n_per_agent,
                                      mask.astype(jnp.float32), rsu_assign,
                                      cfg.n_rsus)
        rsu_params = blend_on_mass(new_rsu, rsu_params, mass)
        return (rsu_params, conn, cloud_params), (mass, agent_params)

    def global_round(state: SimState) -> SimState:
        rng, k_rounds = jax.random.split(state.rng)
        # Alg. 2 line 2: RSUs replace w_k with the current cloud model
        rsu_params = broadcast_to_agents(state.cloud_params, cfg.n_rsus)
        keys = round_keys(k_rounds, hp.lar)
        (rsu_params, conn, _), (masses, agent_params) = jax.lax.scan(
            local_round, (rsu_params, state.conn, state.cloud_params), keys)
        # Alg. 3 line 6: cloud aggregation, weighted by surviving data mass
        total_mass = jnp.sum(masses, axis=0)              # (R,)
        new_cloud = masked_weighted_mean(rsu_params, total_mass)
        cloud_params = jax.tree.map(
            lambda n, o: jnp.where(jnp.sum(total_mass) > 0, n, o),
            new_cloud, state.cloud_params)
        last_agents = jax.tree.map(lambda l: l[-1], agent_params)
        return SimState(agent_params=last_agents, rsu_params=rsu_params,
                        cloud_params=cloud_params, conn=conn, rng=rng)

    return jax.jit(global_round)


def make_global_round(cfg: SimConfig, hp: H2FedParams,
                      het: HeterogeneityModel, fed: FederatedData,
                      loss_fn: Callable = mlp.loss_fn, *,
                      engine: str = "flat"):
    """Build the jitted SimState -> SimState global round.

    engine="flat" runs the Pallas flat-buffer path (ravel on entry, unravel
    on exit — the standalone ``make_flat_global_round`` avoids even that);
    engine="tree" is the per-leaf reference.
    """
    if engine == "tree":
        return _make_tree_global_round(cfg, hp, het, fed, loss_fn)
    if engine != "flat":
        raise ValueError(f"unknown engine {engine!r} (want 'flat'|'tree')")

    body_cache: Dict[flatten.FlatSpec, Callable] = {}

    @jax.jit
    def global_round(state: SimState) -> SimState:
        # one compiled program: ravel -> flat round -> unravel all fuse, so
        # per-round loops (benchmarks, tests) pay no eager conversion cost.
        # spec_of reads only static metadata, so it works on tracers and
        # the cache is keyed per parameter structure.
        spec = flatten.spec_of(state.cloud_params)
        if spec not in body_cache:
            body_cache[spec] = _make_flat_round_body(
                cfg, hp, het, fed, spec, loss_fn)
        out = body_cache[spec](to_flat_state(spec, state))
        return from_flat_state(spec, out)

    return global_round


def run_simulation(cfg: SimConfig, hp: H2FedParams, het: HeterogeneityModel,
                   fed: FederatedData, init_params: PyTree,
                   n_rounds: int, *, x_test=None, y_test=None,
                   loss_fn: Callable = mlp.loss_fn,
                   eval_fn: Optional[Callable] = None,
                   engine: str = "flat",
                   async_cfg=None,
                   fleet_dtype=None,
                   fused: bool = True,
                   ) -> Tuple[SimState, Dict[str, np.ndarray]]:
    """DEPRECATED: use ``fedsim.run_scenario`` with a ``ScenarioSpec`` —
    the one engine entry point with the shared knob surface (``engine``,
    ``fleet_dtype``, ``fused``, ``fleet_store``; DESIGN.md §8).

    This wrapper builds an ad-hoc scenario around the pre-built arrays and
    delegates; numerics are unchanged (same seed/key discipline,
    equivalence test-pinned in tests/test_api.py).
    """
    if engine not in ("flat", "tree", "async"):
        raise ValueError(
            f"unknown engine {engine!r} (want 'flat'|'tree'|'async')")
    warnings.warn(
        "run_simulation is deprecated; use fedsim.run_scenario with a "
        "ScenarioSpec (engine/fleet knobs are spec fields)",
        DeprecationWarning, stacklevel=2)
    from repro.fedsim import sweep
    res = sweep.adhoc_scenario(
        cfg, hp, het, fed, n_rounds=n_rounds, engine=engine,
        fleet_dtype=fleet_dtype, fused=fused, async_cfg=async_cfg,
        x_test=x_test, y_test=y_test)
    return sweep.run_scenario(res, init_params, loss_fn=loss_fn,
                              eval_fn=eval_fn)


def _run_sync(res, init_params: PyTree, *,
              loss_fn: Callable = mlp.loss_fn,
              eval_fn: Optional[Callable] = None,
              ) -> Tuple[SimState, Dict[str, np.ndarray]]:
    """``run_scenario``'s flat/tree dispatch target: run the scenario's
    rounds with the fleet resident in (A, N)/(R, N)/(N,) device buffers
    (pytrees materialize only for eval and the returned final state)."""
    s = res.spec
    cfg, hp, het, fed = res.cfg, s.hp, s.het, res.fed
    engine, fleet_dtype, fused, n_rounds = (s.engine, s.fleet_dtype,
                                            s.fused, s.rounds)
    x_test = res.test.x if res.test is not None else None
    y_test = res.test.y if res.test is not None else None
    hp.validate(), het.validate()
    key = jax.random.key(cfg.seed)
    if eval_fn is None and x_test is not None:
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_test, y_test))

    if engine == "flat":
        spec = flatten.spec_of(
            init_params,
            storage_dtype=flatten.resolve_storage_dtype(fleet_dtype))
        state = init_flat_state(cfg, spec, init_params, key)
        round_fn = make_flat_global_round(cfg, hp, het, fed, spec, loss_fn,
                                          fused=fused, faults=s.faults)
        # eval_fn is called eagerly (unravel is cheap outside jit) so
        # user-supplied non-traceable metrics keep working; the built-in
        # accuracy eval_fn above is already jitted.
        eval_state = (None if eval_fn is None else
                      (lambda s: eval_fn(spec.unravel(s.cloud_flat))))
        finalize = lambda s: from_flat_state(spec, s)        # noqa: E731
    elif engine == "tree":
        state = init_state(cfg, init_params, key)
        round_fn = _make_tree_global_round(cfg, hp, het, fed, loss_fn)
        eval_state = (None if eval_fn is None else
                      (lambda s: eval_fn(s.cloud_params)))
        finalize = lambda s: s                               # noqa: E731
    else:
        raise ValueError(
            f"unknown engine {engine!r} (want 'flat'|'tree'|'async')")

    # fault schedules lower once per run to per-tick mask data over the
    # global tick clock (rounds x lar); each round consumes its slice
    sched = None
    if s.faults is not None and engine == "flat":
        sched = s.faults.lower(cfg.n_agents, cfg.n_rsus, n_rounds * hp.lar)

    accs, rounds, quarantined = [], [], []
    for r in range(n_rounds):
        if sched is None:
            state = round_fn(state)
        else:
            state, fm = round_fn(state, sched.round_slice(r, hp.lar))
            quarantined.append(int(fm["quarantined"]))
        if eval_state is not None and (r % cfg.eval_every == 0
                                       or r == n_rounds - 1):
            accs.append(float(eval_state(state)))
            rounds.append(r + 1)
    history = {"round": np.asarray(rounds), "acc": np.asarray(accs)}
    if sched is not None:
        history["quarantined"] = np.asarray(quarantined)
    return finalize(state), history
