"""Paper-faithful H²-Fed simulator (Algorithms 1–3), fully vectorized.

One compiled ``global_round``:

  1. RSUs download the cloud model (Alg. 2 line 2): w_k ← w.
  2. LAR local rounds (lax.scan).  Per local round:
       a. connectivity draw (CSR/SCD) + FSR epoch draw  (Sec. III),
       b. every agent trains from its RSU model w_k for its completed
          epochs with the dual-proximal objective (Alg. 1, Eq. 6) —
          vmap over agents, scan over minibatch steps,
       c. CSR-masked, data-volume-weighted per-RSU aggregation
          (Alg. 2 line 8); RSUs with an empty cohort keep their model.
  3. Cloud aggregation over RSUs weighted by surviving data mass
     (Alg. 3 line 6); if nothing survived the cloud model is kept.

Baseline equivalences (paper Sec. V) hold *exactly* by construction:
LAR=1 makes the RSU layer a pass-through (w_k == w at training time), so
mu=0 is FedAvg and mu1>0 is FedProx on the flat topology; mu=0 with LAR>1
is HierFAVG.  Property tests assert this numerically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (blend_on_mass, broadcast_to_agents,
                                    gather_rsu_for_agents, masked_weighted_mean,
                                    rsu_aggregate)
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import (ConnState, HeterogeneityModel,
                                      init_conn_state, step_connectivity)
from repro.data.partition import FederatedData
from repro.data.pipeline import agent_minibatch
from repro.models import mlp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_agents: int = 100
    n_rsus: int = 10
    batch: int = 32
    seed: int = 0
    eval_every: int = 1     # global rounds between test-set evaluations


class SimState(NamedTuple):
    agent_params: PyTree    # stacked (A, ...) — w_{i,k}
    rsu_params: PyTree      # stacked (R, ...) — w_k
    cloud_params: PyTree    # (...)            — w
    conn: ConnState
    rng: jax.Array


def init_state(cfg: SimConfig, init_params: PyTree, key) -> SimState:
    return SimState(
        agent_params=broadcast_to_agents(init_params, cfg.n_agents),
        rsu_params=broadcast_to_agents(init_params, cfg.n_rsus),
        cloud_params=init_params,
        conn=init_conn_state(cfg.n_agents),
        rng=key)


def _local_train(loss_fn: Callable, x, y, w0: PyTree, w_rsu: PyTree,
                 w_cloud: PyTree, hp: H2FedParams, n_steps: int,
                 active_steps: jax.Array, batch: int) -> PyTree:
    """One agent: ``active_steps`` proximal-SGD minibatch steps from w0.

    n_steps is the static bound (E_max · steps-per-epoch); active_steps the
    FSR-drawn dynamic count — steps beyond it are masked to identity.
    """

    def objective(w, xb, yb):
        return loss_fn(w, xb, yb)

    grad_fn = jax.grad(objective)

    def body(w, step):
        xb, yb = agent_minibatch(x, y, step, batch)
        g = grad_fn(w, xb, yb)
        live = (step < active_steps).astype(jnp.float32)

        def upd(wl, gl, a1, a2):
            step_v = gl + hp.mu1 * (wl - a1) + hp.mu2 * (wl - a2)
            return wl - hp.lr * live * step_v

        return jax.tree.map(upd, w, g, w_rsu, w_cloud), None

    w, _ = jax.lax.scan(body, w0, jnp.arange(n_steps))
    return w


def make_global_round(cfg: SimConfig, hp: H2FedParams,
                      het: HeterogeneityModel, fed: FederatedData,
                      loss_fn: Callable = mlp.loss_fn):
    """Build the jitted global round for a fixed dataset/topology."""
    x_all = jnp.asarray(fed.x)
    y_all = jnp.asarray(fed.y)
    n_per_agent = jnp.asarray(fed.n_per_agent, jnp.float32)
    rsu_assign = jnp.asarray(fed.rsu_assign)
    spe = max(int(fed.x.shape[1]) // cfg.batch, 1)       # steps per epoch
    n_steps = hp.local_epochs * spe                      # static bound

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train(
            loss_fn, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    def local_round(carry, key):
        rsu_params, conn, cloud_params = carry
        k_conn, k_fsr = jax.random.split(key)
        conn, connected = step_connectivity(k_conn, conn, het)
        # FSR: completed epochs per agent (0 epochs == disconnected)
        full = jax.random.bernoulli(k_fsr, het.fsr, (cfg.n_agents,))
        epochs = jnp.where(full, hp.local_epochs,
                           jax.random.randint(jax.random.fold_in(k_fsr, 1),
                                              (cfg.n_agents,), 0,
                                              max(hp.local_epochs, 1)))
        active_steps = epochs * spe
        mask = connected & (active_steps > 0)

        # Alg. 2 line 5 / Alg. 1 line 1: every agent starts from its RSU model
        w_start = gather_rsu_for_agents(rsu_params, rsu_assign)
        agent_params = train_agents(x_all, y_all, w_start, w_start,
                                    cloud_params, active_steps)

        # Alg. 2 line 8: masked weighted per-RSU aggregation
        new_rsu, mass = rsu_aggregate(agent_params, n_per_agent,
                                      mask.astype(jnp.float32), rsu_assign,
                                      cfg.n_rsus)
        rsu_params = blend_on_mass(new_rsu, rsu_params, mass)
        return (rsu_params, conn, cloud_params), (mass, agent_params)

    def global_round(state: SimState) -> SimState:
        rng, k_rounds = jax.random.split(state.rng)
        # Alg. 2 line 2: RSUs replace w_k with the current cloud model
        rsu_params = broadcast_to_agents(state.cloud_params, cfg.n_rsus)
        keys = jax.random.split(k_rounds, hp.lar)
        (rsu_params, conn, _), (masses, agent_params) = jax.lax.scan(
            local_round, (rsu_params, state.conn, state.cloud_params), keys)
        # Alg. 3 line 6: cloud aggregation, weighted by surviving data mass
        total_mass = jnp.sum(masses, axis=0)              # (R,)
        new_cloud = masked_weighted_mean(rsu_params, total_mass)
        cloud_params = jax.tree.map(
            lambda n, o: jnp.where(jnp.sum(total_mass) > 0, n, o),
            new_cloud, state.cloud_params)
        last_agents = jax.tree.map(lambda l: l[-1], agent_params)
        return SimState(agent_params=last_agents, rsu_params=rsu_params,
                        cloud_params=cloud_params, conn=conn, rng=rng)

    return jax.jit(global_round)


def run_simulation(cfg: SimConfig, hp: H2FedParams, het: HeterogeneityModel,
                   fed: FederatedData, init_params: PyTree,
                   n_rounds: int, *, x_test=None, y_test=None,
                   loss_fn: Callable = mlp.loss_fn,
                   eval_fn: Optional[Callable] = None,
                   ) -> Tuple[SimState, Dict[str, np.ndarray]]:
    """Run ``n_rounds`` global rounds; returns final state + history."""
    hp.validate(), het.validate()
    key = jax.random.key(cfg.seed)
    state = init_state(cfg, init_params, key)
    round_fn = make_global_round(cfg, hp, het, fed, loss_fn)
    if eval_fn is None and x_test is not None:
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_test, y_test))

    accs, rounds = [], []
    for r in range(n_rounds):
        state = round_fn(state)
        if eval_fn is not None and (r % cfg.eval_every == 0
                                    or r == n_rounds - 1):
            accs.append(float(eval_fn(state.cloud_params)))
            rounds.append(r + 1)
    history = {"round": np.asarray(rounds), "acc": np.asarray(accs)}
    return state, history
