from repro.fedsim.simulator import SimConfig, SimState, run_simulation, make_global_round  # noqa: F401
from repro.fedsim.pretrain import pretrain_to_target, train_centralized  # noqa: F401
