from repro.fedsim.simulator import (SimConfig, SimState, FlatSimState,  # noqa: F401
                                    init_flat_state, make_flat_global_round,
                                    make_global_round, run_simulation)
from repro.fedsim.async_engine import (AsyncConfig, AsyncSimState,  # noqa: F401
                                       init_async_state,
                                       make_async_global_round,
                                       run_async_simulation)
from repro.fedsim.pretrain import pretrain_to_target, train_centralized  # noqa: F401
