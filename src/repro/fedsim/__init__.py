from repro.fedsim.simulator import (SimConfig, SimState, FlatSimState,  # noqa: F401
                                    init_flat_state, make_flat_global_round,
                                    make_global_round, run_simulation)
from repro.fedsim.async_engine import (AsyncConfig, AsyncSimState,  # noqa: F401
                                       init_async_state,
                                       make_async_global_round,
                                       run_async_simulation)
from repro.fedsim.pretrain import pretrain_to_target, train_centralized  # noqa: F401
# THE engine entry points (DESIGN.md §8): one scenario / a whole grid.
# run_simulation / run_async_simulation / run_sharded_simulation above are
# deprecated wrappers over run_scenario.
from repro.fedsim.sweep import (adhoc_scenario, run_scenario,  # noqa: F401
                                run_scenarios)
from repro.fedsim.streaming import run_streamed_simulation  # noqa: F401
# continuous serving (DESIGN.md §9): event-driven ticks + live model server
from repro.fedsim.serving import (CloudModelServer, EventQueue,  # noqa: F401
                                  ServeLoopStats, run_serve_loop)
