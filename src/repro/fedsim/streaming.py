"""Cohort-streamed round engines: million-agent fleets on fixed HBM
(DESIGN.md §8).

The resident engines (fedsim/simulator, async_engine) hold the whole fleet
as one device (A, N) buffer, so A is HBM-bound.  But the paper's
participation model is the opposite shape: a CSR-sized cohort of a huge
connected fleet does work each round, and ~90% of agents are
timely-disconnected.  This module makes the device-resident state the
*cohort chunk*, not the fleet:

  * agent rows live in a ``core.fleet_store.FleetStore`` — ``"host"``
    keeps the (A, N) fleet in host numpy memory in the FlatSpec storage
    dtype (fp32 | bf16), ``"device"`` keeps today's resident buffer but
    still bounds the per-step training working set to a chunk;
  * each local round streams the fleet in fixed-size agent chunks through
    ONE jitted ``chunk_step`` (compiled once — tails are zero-padded to
    the static chunk shape): gather the chunk's RSU start models, run the
    existing vmapped dual-proximal training scan, and reduce the chunk's
    arrivals with the chunk-shaped aggregation entry
    (``kernels/ops.chunk_agg``).  The (R, N)/(R,) numerator + mass
    accumulators are DONATED through the chunk loop, so the device
    working set per step is O(chunk·N + R·N), independent of A;
  * transfers are double-buffered: the next chunk's ``jax.device_put`` is
    dispatched BEFORE the current chunk's compute (jax dispatch is async,
    so the h2d copy overlaps the training scan), and the store writeback
    of chunk c-1 is deferred until after chunk c's step is dispatched, so
    the blocking d2h read also overlaps compute;
  * the aggregation ALGEBRA is unchanged: accumulated chunk partial sums
    + one ``normalize_blend`` per local round is exactly the partial-sum
    formulation the sharded engines psum (fedsim/sharded), which is
    test-pinned fp32-equivalent to the resident fused ``agg_blend`` path;
    the semi-async tick absorbs the accumulated arrivals with the same
    ``buffer_absorb`` merge the resident ``agg_absorb`` tick runs.

Both engines stream: ``make_streamed_flat_round`` (the synchronous LAR
round) and ``make_streamed_async_round`` (the semi-async tick loop, with
the in-flight pending rows in a second FleetStore and only the (A,)-sized
bookkeeping vectors device-resident).  Equivalence is test-pinned at
small A: streamed == resident to fp32 tolerance for both engines
(tests/test_streaming.py).

Entry points: ``fedsim.run_scenario`` dispatches here whenever the spec
sets ``fleet_store="host"`` or ``chunk_agents > 0``;
``run_streamed_simulation`` is the direct-call twin of
``run_simulation`` for callers with their own arrays (benchmarks).

Fault injection (DESIGN.md §11): streamed rounds accept a lowered
``FaultSchedule`` round slice.  Churn and RSU outages are *weight data* —
folded into the per-tick aggregation weights host-side (``agent_up`` and
the agent's RSU ``rsu_up`` multiply the draw weights), so dark agents/RSUs
contribute zero mass without touching the compiled chunk program — plus a
per-tick recovery re-anchor and an outage-masked cloud blend in the async
round.  The non-finite quarantine guard runs inside ``chunk_step`` (gated
by plan presence, like the resident engines).  Corrupted-update injection
is NOT supported here (``ScenarioSpec.validate`` rejects it): the streamed
store writebacks are row-masked host ops and cannot stage per-tick payload
corruption without materializing the fleet.  The benign schedule is a
bitwise no-op (``w * 1.0`` folds), pinned by the zero-fault anchor.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatten
from repro.core.aggregation import buffer_absorb, normalize_blend
from repro.core.fleet_store import (HostFleetStore, make_fleet_store,
                                    resolve_fleet_store)
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import (ConnState, HeterogeneityModel,
                                      init_conn_state, sample_latency)
from repro.data.partition import FederatedData
from repro.kernels import ops
from repro.models import mlp
from repro.fedsim.async_engine import _LATENCY_FOLD, AsyncConfig
from repro.fedsim.simulator import (SimConfig, _local_train_flat,
                                    round_draws, round_keys)

PyTree = Any

# auto chunk size when the spec leaves chunk_agents=0: big enough to feed
# the vmapped training scan, small enough that (chunk, N) stays a sliver
# of any fleet worth streaming
DEFAULT_CHUNK = 1024


class ChunkPlan(NamedTuple):
    """Static chunking of the agent axis: ``n_chunks`` chunks of ``chunk``
    rows; the last chunk carries ``pad`` zero rows (weight 0, 0 training
    steps) so every chunk shares ONE compiled chunk_step."""
    chunk: int
    n_chunks: int
    n_agents: int
    pad: int

    @property
    def n_padded(self) -> int:
        return self.n_chunks * self.chunk

    def bounds(self, c: int) -> Tuple[int, int]:
        """(row offset, valid rows) of chunk ``c``."""
        lo = c * self.chunk
        return lo, min(lo + self.chunk, self.n_agents) - lo


def make_chunk_plan(n_agents: int, chunk_agents: int = 0) -> ChunkPlan:
    chunk = chunk_agents if chunk_agents > 0 else DEFAULT_CHUNK
    chunk = max(1, min(chunk, n_agents))
    n_chunks = -(-n_agents // chunk)
    return ChunkPlan(chunk=chunk, n_chunks=n_chunks, n_agents=n_agents,
                     pad=n_chunks * chunk - n_agents)


class NTilePlan(NamedTuple):
    """Static tiling of the PARAMETER axis (DESIGN.md §12): ``n_tiles``
    lane-aligned tiles of ``tile`` columns; the buffers are zero-padded by
    ``pad`` trailing columns so every tile shares one compiled program
    (zero tails are algebra-neutral, exactly like the agent-axis pad)."""
    tile: int
    n_tiles: int
    n: int
    pad: int

    @property
    def n_padded(self) -> int:
        return self.n_tiles * self.tile

    def bounds(self, t: int) -> Tuple[int, int]:
        """(col_lo, col_hi) of tile ``t`` on the padded grid."""
        lo = t * self.tile
        return lo, lo + self.tile


def make_ntile_plan(n: int, chunk_params: int = 0) -> NTilePlan:
    """Tile N into ~``chunk_params``-column lane-aligned tiles
    (``chunk_params=0`` = one tile: the agent-axis-only streamed shape)."""
    from repro.kernels.masked_hier_agg import LANE
    tile = chunk_params if chunk_params > 0 else n
    tile = max(LANE, min(tile, n))
    tile = -(-tile // LANE) * LANE
    n_tiles = max(-(-n // tile), 1)
    return NTilePlan(tile=tile, n_tiles=n_tiles, n=n,
                     pad=n_tiles * tile - n)


def _data_chunks(fed: FederatedData, plan: ChunkPlan):
    """Host-side per-chunk (x, y, rsu_assign) tuples — views into the
    FederatedData arrays (zero-copy; broadcast fleets stay virtual) except
    the zero-padded tail chunk."""
    xs, ys = np.asarray(fed.x), np.asarray(fed.y)
    asg = np.asarray(fed.rsu_assign, np.int32)
    out = []
    for c in range(plan.n_chunks):
        lo, valid = plan.bounds(c)
        x, y, a = xs[lo:lo + valid], ys[lo:lo + valid], asg[lo:lo + valid]
        if valid < plan.chunk:
            p = plan.chunk - valid
            x = np.concatenate([x, np.zeros((p,) + x.shape[1:], x.dtype)])
            y = np.concatenate([y, np.zeros((p,) + y.shape[1:], y.dtype)])
            a = np.concatenate([a, np.zeros((p,), a.dtype)])
        out.append((x, y, a))
    return out


def _pad_tail(rows, chunk: int):
    """Zero-pad a gathered tail chunk of fleet rows to the static shape."""
    valid = rows.shape[0]
    if valid == chunk:
        return rows
    if isinstance(rows, np.ndarray):
        return np.concatenate(
            [rows, np.zeros((chunk - valid, rows.shape[1]), rows.dtype)])
    return jnp.pad(rows, ((0, chunk - valid), (0, 0)))


def streamed_transfer_bytes(plan: ChunkPlan, spec: flatten.FlatSpec,
                            hp: H2FedParams, fed: FederatedData, *,
                            engine: str = "flat",
                            fleet_store: str = "host") -> Dict[str, float]:
    """Analytic host↔device bytes per GLOBAL round of the streamed
    pipeline (the bench-flow / BENCH_PR6 accounting).  The device store
    pays no host traffic (gather/scatter are device slices); the host
    store pays per local round: data chunks up (x, y, assign), trained
    rows down, and — semi-async only — pending rows up plus enqueued rows
    down (counted as an upper bound: every agent could enqueue)."""
    if resolve_fleet_store(fleet_store) == "device":
        return {"h2d": 0.0, "d2h": 0.0, "total": 0.0}
    x, y = np.asarray(fed.x[:1]), np.asarray(fed.y[:1])
    per_agent_data = (x.dtype.itemsize * x[0].size
                     + y.dtype.itemsize * y[0].size + 4)      # + int32 assign
    rows = plan.n_padded * spec.n * jnp.dtype(spec.storage_dtype).itemsize
    h2d = hp.lar * plan.n_padded * per_agent_data
    d2h = hp.lar * rows
    if engine == "async":
        h2d += hp.lar * rows                                  # pending gather
        d2h += hp.lar * rows                                  # enqueue upper bound
    return {"h2d": float(h2d), "d2h": float(d2h), "total": float(h2d + d2h)}


# --------------------------------------------------------------------------
# synchronous (flat) streamed round
# --------------------------------------------------------------------------

class StreamSimState(NamedTuple):
    """Streamed-round state.  ``store`` is a host-side FleetStore object
    (never traced); only the RSU/cloud buffers and the (A,)-sized
    bookkeeping live on device."""
    store: Any              # FleetStore — (A, N) agent rows
    rsu_flat: jax.Array     # (R, N) storage dtype
    cloud_flat: jax.Array   # (N,)   fp32 master
    conn: ConnState
    rng: jax.Array


def init_stream_state(cfg: SimConfig, spec: flatten.FlatSpec,
                      init_params: PyTree, key, *,
                      fleet_store: str = "host") -> StreamSimState:
    vec = spec.ravel(init_params)
    return StreamSimState(
        store=make_fleet_store(fleet_store, vec, cfg.n_agents,
                               spec.storage_dtype),
        rsu_flat=jnp.broadcast_to(spec.to_storage(vec),
                                  (cfg.n_rsus, spec.n)),
        cloud_flat=vec,
        conn=init_conn_state(cfg.n_agents),
        rng=key)


def _fault_weight_fold(fault_r, rsu_assign_np, pad: int):
    """Host-side (lar, A_pad) weight multiplier from one round's fault
    slice: churned agents and agents behind a dark RSU contribute zero
    mass.  Benign schedules fold to all-ones (``w * 1.0`` is exact)."""
    up_a = fault_r["rsu_up"][:, rsu_assign_np]           # (lar, A)
    fold = fault_r["agent_up"] * up_a
    if pad:
        fold = np.pad(fold, ((0, 0), (0, pad)), constant_values=1.0)
    return jnp.asarray(fold, jnp.float32)


def _make_flat_draws_fn(cfg: SimConfig, hp: H2FedParams,
                        het: HeterogeneityModel, plan: ChunkPlan,
                        n_per_agent, spe: int):
    """One global round's stochastic realization, padded to the chunk
    grid: (conn', rng', weights (LAR, A_pad), steps (LAR, A_pad)) — the
    flat-engine key discipline shared by the one- and two-axis streamed
    rounds (they must draw identically to be equivalent)."""
    A = cfg.n_agents

    @jax.jit
    def draws_fn(conn, rng):
        rng, k_rounds = jax.random.split(rng)
        keys = round_keys(k_rounds, hp.lar)

        def draw(conn, key):
            conn, mask, act = round_draws(key, conn, het, hp, A, spe)
            return conn, (n_per_agent * mask.astype(jnp.float32), act)

        conn, (weights, steps) = jax.lax.scan(draw, conn, keys)
        if plan.pad:
            weights = jnp.pad(weights, ((0, 0), (0, plan.pad)))
            steps = jnp.pad(steps, ((0, 0), (0, plan.pad)))
        return conn, rng, weights, steps

    return draws_fn


def make_streamed_flat_round(cfg: SimConfig, hp: H2FedParams,
                             het: HeterogeneityModel, fed: FederatedData,
                             spec: flatten.FlatSpec,
                             loss_fn: Callable = mlp.loss_fn, *,
                             chunk_agents: int = 0, faults=None):
    """Build the streamed synchronous global round:
    StreamSimState -> StreamSimState.

    Same draws / key discipline as ``engine="flat"`` (the per-round scan
    of ``round_draws`` — drawn up-front exactly like the sharded engine);
    the LAR body streams the fleet chunk-by-chunk through one jitted,
    accumulator-donating ``chunk_step`` and closes each local round with
    ``normalize_blend``.  In the sync round agent rows are WRITE-only
    (training starts from RSU rows), so the store is never gathered —
    only the trained rows flow back.

    With ``faults`` (a ``FaultPlan``), ``global_round(state, fault_r)``
    takes one ``FaultSchedule.round_slice``: churn/outage fold into the
    draw weights host-side (see ``_fault_weight_fold``) and the
    non-finite guard screens each chunk inside ``chunk_step``; the round
    then also returns a ``{"quarantined": ...}`` metrics dict.
    """
    A, R, N = cfg.n_agents, cfg.n_rsus, spec.n
    spe = max(int(fed.x.shape[1]) // cfg.batch, 1)
    n_steps = hp.local_epochs * spe
    plan = make_chunk_plan(A, chunk_agents)
    chunks = _data_chunks(fed, plan)
    n_per_agent = jnp.asarray(np.asarray(fed.n_per_agent), jnp.float32)
    rsu_assign_np = np.asarray(fed.rsu_assign, np.int32)
    guard = faults is not None and faults.guard_nonfinite

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    draws_fn = _make_flat_draws_fn(cfg, hp, het, plan, n_per_agent, spe)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def chunk_step(num_acc, mass_acc, rsu_flat, cloud_flat, x_c, y_c,
                   assign_c, w_c, act_c):
        # Alg. 2 l.5 / Alg. 1 l.1: the chunk's agents start from their RSU
        # row; Alg. 2 l.8 becomes a chunk-shaped partial sum.
        w_start = jnp.take(rsu_flat, assign_c, axis=0)     # (chunk, N)
        stored = spec.to_storage(
            train_agents(x_c, y_c, w_start, w_start, cloud_flat, act_c))
        nq = jnp.zeros((), jnp.int32)
        if guard:
            # quarantine gate, chunk-shaped: non-finite rows are scrubbed
            # back to their RSU start and zero-weighted (benign data all
            # finite -> ok all-True, a bitwise no-op)
            ok = jnp.all(jnp.isfinite(stored.astype(jnp.float32)), axis=1)
            stored = jnp.where(ok[:, None], stored, w_start)
            nq = jnp.sum(((w_c > 0) & ~ok).astype(jnp.int32))
            w_c = w_c * ok.astype(jnp.float32)
        num, mass = ops.chunk_agg(stored, w_c, assign_c, R)
        return num_acc + num, mass_acc + mass, stored, nq

    @jax.jit
    def rsu_update(num_acc, mass_acc, rsu_flat):
        return normalize_blend(num_acc, mass_acc, rsu_flat)

    @jax.jit
    def cloud_update(rsu_flat, total_mass, cloud_flat):
        return ops.cloud_blend(rsu_flat, total_mass, cloud_flat)

    def put_chunk(c: int):
        return jax.device_put(chunks[c])

    def global_round(state: StreamSimState, fault_r=None):
        store = state.store
        conn, rng, weights, steps = draws_fn(state.conn, state.rng)
        if faults is not None:
            weights = weights * _fault_weight_fold(fault_r, rsu_assign_np,
                                                   plan.pad)
        # Alg. 2 line 2: RSUs re-anchor to the cloud model
        rsu_flat = jnp.broadcast_to(spec.to_storage(state.cloud_flat),
                                    (R, N))
        total_mass = jnp.zeros((R,), jnp.float32)
        n_quar = jnp.zeros((), jnp.int32)
        for l in range(hp.lar):
            num_acc = jnp.zeros((R, N), jnp.float32)
            mass_acc = jnp.zeros((R,), jnp.float32)
            nxt, wb = put_chunk(0), None
            for c in range(plan.n_chunks):
                lo, valid = plan.bounds(c)
                cur = nxt
                if c + 1 < plan.n_chunks:
                    # double buffering: dispatch the NEXT chunk's h2d copy
                    # before the current chunk's compute is enqueued
                    nxt = put_chunk(c + 1)
                sl = slice(c * plan.chunk, (c + 1) * plan.chunk)
                num_acc, mass_acc, stored, nq = chunk_step(
                    num_acc, mass_acc, rsu_flat, state.cloud_flat, *cur,
                    weights[l, sl], steps[l, sl])
                n_quar = n_quar + nq
                if wb is not None:
                    # deferred-by-one writeback: the (blocking) d2h read of
                    # chunk c-1 overlaps chunk c's dispatched compute
                    store.scatter(*wb)
                wb = (lo, stored if valid == plan.chunk else stored[:valid])
            if wb is not None:
                store.scatter(*wb)
            rsu_flat = rsu_update(num_acc, mass_acc, rsu_flat)
            total_mass = total_mass + mass_acc
        # Alg. 3 line 6: cloud aggregation over the surviving mass
        cloud_flat = cloud_update(rsu_flat, total_mass, state.cloud_flat)
        out = StreamSimState(store=store, rsu_flat=rsu_flat,
                             cloud_flat=cloud_flat, conn=conn, rng=rng)
        if faults is not None:
            return out, {"quarantined": n_quar}
        return out

    global_round.plan = plan
    global_round.chunk_step = chunk_step
    return global_round


# --------------------------------------------------------------------------
# semi-asynchronous streamed round
# --------------------------------------------------------------------------

class AsyncStreamState(NamedTuple):
    """Streamed semi-async state: the two (A, N) row sets (latest local
    models + in-flight pending updates) live in FleetStores; only the
    (A,)-sized in-flight bookkeeping stays device-resident."""
    store: Any              # FleetStore — (A, N) latest local model rows
    pending_store: Any      # FleetStore — (A, N) in-flight update rows
    rsu_flat: jax.Array     # (R, N) storage dtype
    rsu_mass: jax.Array     # (R,)   running absorbed cohort mass
    cloud_flat: jax.Array   # (N,)   fp32 master
    pending_w: jax.Array    # (A,)   decayed delivery weight
    pending_t: jax.Array    # (A,)   ticks until delivery (0 = none)
    conn: ConnState
    rng: jax.Array
    cloud_macc: jax.Array   # (R,)   mass since last cloud aggregation
    tick: int               # python global tick clock (cloud cadence)


def init_async_stream_state(cfg: SimConfig, spec: flatten.FlatSpec,
                            init_params: PyTree, key, *,
                            fleet_store: str = "host") -> AsyncStreamState:
    vec = spec.ravel(init_params)
    a = cfg.n_agents
    kind = resolve_fleet_store(fleet_store)
    if kind == "host":
        pending = HostFleetStore.zeros(a, spec.n, spec.storage_dtype)
    else:
        from repro.core.fleet_store import DeviceFleetStore
        pending = DeviceFleetStore(jnp.zeros((a, spec.n),
                                             spec.storage_dtype))
    return AsyncStreamState(
        store=make_fleet_store(kind, vec, a, spec.storage_dtype),
        pending_store=pending,
        rsu_flat=jnp.broadcast_to(spec.to_storage(vec),
                                  (cfg.n_rsus, spec.n)),
        rsu_mass=jnp.zeros((cfg.n_rsus,), jnp.float32),
        cloud_flat=vec,
        pending_w=jnp.zeros((a,), jnp.float32),
        pending_t=jnp.zeros((a,), jnp.int32),
        conn=init_conn_state(a),
        rng=key,
        cloud_macc=jnp.zeros((cfg.n_rsus,), jnp.float32),
        tick=0)


def make_streamed_async_round(cfg: SimConfig, hp: H2FedParams,
                              het: HeterogeneityModel, fed: FederatedData,
                              spec: flatten.FlatSpec,
                              acfg: Optional[AsyncConfig] = None,
                              loss_fn: Callable = mlp.loss_fn, *,
                              chunk_agents: int = 0, faults=None):
    """Build the streamed semi-async global round:
    AsyncStreamState -> (AsyncStreamState, metrics).

    The tick algebra is the resident engine's (fedsim/async_engine) with
    the (A, N) work chunked: the per-tick in-flight bookkeeping (busy /
    due / enqueue and their weights) runs on (A,)-sized device vectors,
    the chunk loop accumulates both arrival cohorts' numerators with
    ``ops.chunk_agg``, and the tick closes with the same
    ``buffer_absorb`` merge the fused ``agg_absorb`` tick performs.
    Row-masked store writebacks keep busy agents' rows (``where=~busy``)
    without gathering them first.  Draw/key discipline matches the
    resident engine (latency keys folded with ``_LATENCY_FOLD``), so at
    small A streamed == resident to fp32 tolerance (test-pinned).

    With ``faults``, ``global_round(state, fault_r)`` takes one
    ``FaultSchedule.round_slice``: churn folds into the connectivity
    masks (gating training, immediate uploads AND enqueues), outages
    zero both arrival cohorts' weights and mask the cloud blend, a
    recovering RSU re-anchors at its tick, and the non-finite guard
    screens both cohorts inside ``chunk_step``.
    """
    acfg = (acfg or AsyncConfig()).validate()
    A, R, N = cfg.n_agents, cfg.n_rsus, spec.n
    spe = max(int(fed.x.shape[1]) // cfg.batch, 1)
    n_steps = hp.local_epochs * spe
    plan = make_chunk_plan(A, chunk_agents)
    chunks = _data_chunks(fed, plan)
    n_per_agent = jnp.asarray(np.asarray(fed.n_per_agent), jnp.float32)
    rsu_assign = jnp.asarray(np.asarray(fed.rsu_assign), jnp.int32)
    rsu_assign_np = np.asarray(fed.rsu_assign, np.int32)
    guard = faults is not None and faults.guard_nonfinite
    decay = acfg.agent_decay(rsu_assign, R)
    keep = acfg.rsu_keep(R)
    ce = acfg.cloud_every

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    @jax.jit
    def draws_fn(conn, rng):
        rng, k_rounds = jax.random.split(rng)
        keys = round_keys(k_rounds, hp.lar)

        def draw(conn, key):
            conn, mask, act = round_draws(key, conn, het, hp, A, spe)
            d = sample_latency(jax.random.fold_in(key, _LATENCY_FOLD),
                               A, het)
            return conn, (mask.astype(jnp.float32), act, d)

        conn, outs = jax.lax.scan(draw, conn, keys)
        return (conn, rng) + outs                # masks/steps/delays (LAR, A)

    @jax.jit
    def tick_prep(pend_w, pend_t, maskf, act_steps, delays):
        """The (A,)-sized in-flight bookkeeping of one tick — identical
        order of operations to the resident tick (countdown, arrivals
        read the pre-enqueue pending weights, then enqueue overwrites)."""
        in_flight = pend_t > 0
        pend_t = jnp.maximum(pend_t - 1, 0)
        due = in_flight & (pend_t == 0)
        busy = in_flight & ~due
        free = ~busy
        act = jnp.where(busy, 0, act_steps)
        w_imm = (n_per_agent * maskf * free
                 * (delays == 0).astype(jnp.float32))
        w_due = jnp.where(due, pend_w, 0.0)
        enq = (maskf > 0) & free & (delays > 0)
        w_enq = n_per_agent * maskf * acfg.weight(delays, decay=decay)
        pend_w = jnp.where(enq, w_enq, pend_w)
        pend_t = jnp.where(enq, delays, pend_t)
        if plan.pad:
            pad = ((0, plan.pad),)
            act, w_imm, w_due = (jnp.pad(act, pad), jnp.pad(w_imm, pad),
                                 jnp.pad(w_due, pad))
        return act, w_imm, w_due, free, enq, pend_w, pend_t

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def chunk_step(num_acc, mass_acc, rsu_flat, cloud_flat, x_c, y_c,
                   assign_c, pend_rows, act_c, w_imm_c, w_due_c):
        w_start = jnp.take(rsu_flat, assign_c, axis=0)
        trained = spec.to_storage(
            train_agents(x_c, y_c, w_start, w_start, cloud_flat, act_c))
        nq = jnp.zeros((), jnp.int32)
        if guard:
            # quarantine gate over BOTH arrival cohorts: fresh trained
            # rows are scrubbed back to their RSU start; non-finite
            # pending deliveries are zero-weighted (their store rows
            # expire with the delivery)
            ok_t = jnp.all(jnp.isfinite(trained.astype(jnp.float32)),
                           axis=1)
            trained = jnp.where(ok_t[:, None], trained, w_start)
            ok_p = jnp.all(jnp.isfinite(pend_rows.astype(jnp.float32)),
                           axis=1)
            nq = (jnp.sum(((w_imm_c > 0) & ~ok_t).astype(jnp.int32))
                  + jnp.sum(((w_due_c > 0) & ~ok_p).astype(jnp.int32)))
            w_imm_c = w_imm_c * ok_t.astype(jnp.float32)
            w_due_c = w_due_c * ok_p.astype(jnp.float32)
        num_i, m_i = ops.chunk_agg(trained, w_imm_c, assign_c, R)
        num_d, m_d = ops.chunk_agg(pend_rows, w_due_c, assign_c, R)
        return num_acc + num_i + num_d, mass_acc + m_i + m_d, trained, nq

    @jax.jit
    def tick_finish(rsu_flat, rsu_mass, num_acc, mass_acc, cloud_macc):
        rsu_flat, rsu_mass = buffer_absorb(rsu_flat, rsu_mass, num_acc,
                                           mass_acc, keep=keep)
        return rsu_flat, rsu_mass, cloud_macc + mass_acc

    @jax.jit
    def cloud_update(rsu_flat, macc, cloud_flat):
        return ops.cloud_blend(rsu_flat, macc, cloud_flat)

    def put_chunk(c: int, pending_store):
        x, y, a = chunks[c]
        lo, valid = plan.bounds(c)
        pend = _pad_tail(pending_store.gather(lo, lo + valid), plan.chunk)
        return jax.device_put((x, y, a, pend))

    def global_round(state: AsyncStreamState, fault_r=None
                     ) -> Tuple[AsyncStreamState, Dict[str, np.ndarray]]:
        store, pending_store = state.store, state.pending_store
        conn, rng, masks, steps, delays = draws_fn(state.conn, state.rng)
        if faults is not None:
            # churn: hard-disconnect beyond the benign latency model —
            # gates immediate uploads and enqueues (due deliveries were
            # dispatched before the disconnect and still land)
            masks = masks * jnp.asarray(fault_r["agent_up"], jnp.float32)
        if ce:
            # decoupled cadence: buffers/mass/accumulator persist across
            # the round boundary (see async_engine for the rationale)
            rsu_flat, rsu_mass = state.rsu_flat, state.rsu_mass
            cloud_macc = state.cloud_macc
        else:
            rsu_flat = jnp.broadcast_to(spec.to_storage(state.cloud_flat),
                                        (R, N))
            rsu_mass = jnp.zeros((R,), jnp.float32)
            cloud_macc = jnp.zeros((R,), jnp.float32)
        cloud_flat = state.cloud_flat
        pend_w, pend_t, gtick = state.pending_w, state.pending_t, state.tick
        absorbed = []

        n_quar = jnp.zeros((), jnp.int32)
        for l in range(hp.lar):
            if faults is not None:
                # recovery re-anchor: an RSU coming back from an outage
                # rejoins at the current cloud master, buffer cleared
                ra = jnp.asarray(fault_r["reanchor"][l]) > 0
                rsu_flat = jnp.where(
                    ra[:, None],
                    jnp.broadcast_to(spec.to_storage(cloud_flat), (R, N)),
                    rsu_flat)
                rsu_mass = jnp.where(ra, 0.0, rsu_mass)
                cloud_macc = jnp.where(ra, 0.0, cloud_macc)
            act, w_imm, w_due, free, enq, pend_w, pend_t = tick_prep(
                pend_w, pend_t, masks[l], steps[l], delays[l])
            if faults is not None:
                # outage: uploads to a dark RSU are dropped — both fresh
                # and due arrival cohorts lose their weight BEFORE the
                # mass partial sums, so conservation holds by construction
                up_a_l = fault_r["rsu_up"][l][rsu_assign_np]
                if plan.pad:
                    up_a_l = np.pad(up_a_l, (0, plan.pad),
                                    constant_values=1.0)
                up_a_l = jnp.asarray(up_a_l, jnp.float32)
                w_imm = w_imm * up_a_l
                w_due = w_due * up_a_l
            free_h, enq_h = np.asarray(free), np.asarray(enq)
            num_acc = jnp.zeros((R, N), jnp.float32)
            mass_acc = jnp.zeros((R,), jnp.float32)
            nxt, wb = put_chunk(0, pending_store), None
            for c in range(plan.n_chunks):
                lo, valid = plan.bounds(c)
                cur = nxt
                if c + 1 < plan.n_chunks:
                    nxt = put_chunk(c + 1, pending_store)
                sl = slice(c * plan.chunk, (c + 1) * plan.chunk)
                num_acc, mass_acc, trained, nq = chunk_step(
                    num_acc, mass_acc, rsu_flat, cloud_flat, *cur,
                    act[sl], w_imm[sl], w_due[sl])
                n_quar = n_quar + nq
                if wb is not None:
                    _flush_async_wb(store, pending_store, *wb)
                rows = trained if valid == plan.chunk else trained[:valid]
                wb = (lo, rows, free_h[lo:lo + valid], enq_h[lo:lo + valid])
            if wb is not None:
                _flush_async_wb(store, pending_store, *wb)
            rsu_flat, rsu_mass, cloud_macc = tick_finish(
                rsu_flat, rsu_mass, num_acc, mass_acc, cloud_macc)
            absorbed.append(mass_acc)
            gtick += 1
            if ce and gtick % ce == 0:
                macc_fire = cloud_macc if faults is None else \
                    cloud_macc * jnp.asarray(fault_r["rsu_up"][l],
                                             jnp.float32)
                cloud_flat = cloud_update(rsu_flat, macc_fire, cloud_flat)
                cloud_macc = jnp.zeros((R,), jnp.float32)

        if not ce:
            macc_end = cloud_macc if faults is None else \
                cloud_macc * jnp.asarray(fault_r["rsu_up"][hp.lar - 1],
                                         jnp.float32)
            cloud_flat = cloud_update(rsu_flat, macc_end, cloud_flat)
            cloud_macc = jnp.zeros((R,), jnp.float32)

        out = AsyncStreamState(
            store=store, pending_store=pending_store, rsu_flat=rsu_flat,
            rsu_mass=rsu_mass, cloud_flat=cloud_flat, pending_w=pend_w,
            pending_t=pend_t, conn=conn, rng=rng, cloud_macc=cloud_macc,
            tick=gtick)
        metrics = {
            "absorbed_mass": jnp.stack(absorbed),            # (LAR, R)
            "pending_mass": jnp.sum(pend_w * (pend_t > 0)),
        }
        if faults is not None:
            metrics["quarantined"] = n_quar
        return out, metrics

    global_round.plan = plan
    global_round.chunk_step = chunk_step
    return global_round


def _flush_async_wb(store, pending_store, lo, rows, free_h, enq_h) -> None:
    """Row-masked writeback of one trained chunk: free agents' rows update
    the fleet (busy keep theirs, matching the resident ``where(busy, old,
    trained)``); enqueuing agents' rows enter the pending store."""
    store.scatter(lo, rows, where=free_h)
    pending_store.scatter(lo, rows, where=enq_h)


# --------------------------------------------------------------------------
# two-axis (agent × parameter) streamed round (DESIGN.md §12)
# --------------------------------------------------------------------------

def init_twoaxis_state(cfg: SimConfig, spec: flatten.FlatSpec,
                       init_params: PyTree, key,
                       tiles: NTilePlan) -> StreamSimState:
    """Two-axis stream state: EVERY persistent N-wide buffer is
    host-resident — agent rows in a ``HostFleetStore``, the (R, N) RSU
    buffer and the fp32 cloud master as numpy arrays, all padded to the
    N-tile grid.  The device only ever holds chunk/tile-shaped slices."""
    from repro.core.fleet_store import np_storage_dtype
    vec = np.asarray(spec.ravel(init_params), np.float32)
    if tiles.pad:
        vec = np.pad(vec, (0, tiles.pad))
    rsu_host = np.empty((cfg.n_rsus, tiles.n_padded),
                        np_storage_dtype(spec.storage_dtype))
    rsu_host[:] = vec.astype(rsu_host.dtype)
    return StreamSimState(
        store=HostFleetStore.broadcast(vec, cfg.n_agents,
                                       spec.storage_dtype),
        rsu_flat=rsu_host,
        cloud_flat=vec.copy(),
        conn=init_conn_state(cfg.n_agents),
        rng=key)


def make_streamed_twoaxis_round(cfg: SimConfig, hp: H2FedParams,
                                het: HeterogeneityModel, fed: FederatedData,
                                spec: flatten.FlatSpec,
                                loss_fn: Callable = mlp.loss_fn, *,
                                chunk_agents: int = 0,
                                chunk_params: int = 0, faults=None):
    """Build the two-axis streamed synchronous round:
    StreamSimState -> StreamSimState (host rsu/cloud buffers, see
    ``init_twoaxis_state``).

    The agent axis streams exactly like ``make_streamed_flat_round``
    (same draws, same chunk grid, same defer-by-one writeback); the
    PARAMETER axis is additionally tiled so no (R, N)-wide buffer ever
    materializes on device:

      * training is necessarily full-N per agent chunk (the gradient
        couples every parameter), so the per-chunk device working set is
        (chunk, N) rows h2d'd from the host RSU buffer;
      * aggregation is per-COLUMN independent, so the chunk's partial
        numerator is computed tile-by-tile — ``ops.chunk_agg`` on a
        (chunk, tile) slice — and d2h-accumulated into a host (R, N)
        numerator: the device aggregation working set is (R, tile);
      * the local-round ``normalize_blend`` close and the round-end
        ``cloud_blend`` run per tile on device ((R, tile) up, blended
        tile down, defer-by-one reads overlapping the next dispatch).

    Column independence of every aggregation stage makes this equivalent
    to the one-axis streamed round (itself pinned to the resident
    engine); the first ``N`` columns of the padded grid carry the model.
    Faults fold exactly like the one-axis round (churn/outage weights +
    the non-finite quarantine guard, benign schedules bitwise no-ops).
    """
    A, R, N = cfg.n_agents, cfg.n_rsus, spec.n
    spe = max(int(fed.x.shape[1]) // cfg.batch, 1)
    n_steps = hp.local_epochs * spe
    plan = make_chunk_plan(A, chunk_agents)
    tiles = make_ntile_plan(N, chunk_params)
    Np = tiles.n_padded
    chunks = _data_chunks(fed, plan)
    n_per_agent = jnp.asarray(np.asarray(fed.n_per_agent), jnp.float32)
    rsu_assign_np = np.asarray(fed.rsu_assign, np.int32)
    guard = faults is not None and faults.guard_nonfinite

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    draws_fn = _make_flat_draws_fn(cfg, hp, het, plan, n_per_agent, spe)

    @jax.jit
    def chunk_train(w_start, cloud_dev, x_c, y_c, act_c, w_c):
        """Train one agent chunk full-N from its h2d'd RSU rows; the
        non-finite quarantine gate matches the one-axis chunk_step."""
        stored = spec.to_storage(
            train_agents(x_c, y_c, w_start, w_start, cloud_dev, act_c))
        nq = jnp.zeros((), jnp.int32)
        if guard:
            ok = jnp.all(jnp.isfinite(stored.astype(jnp.float32)), axis=1)
            stored = jnp.where(ok[:, None], stored, w_start)
            nq = jnp.sum(((w_c > 0) & ~ok).astype(jnp.int32))
            w_c = w_c * ok.astype(jnp.float32)
        return stored, w_c, nq

    @jax.jit
    def tile_agg(stored_t, w_c, assign_c):
        """One (chunk, tile) slice's partial aggregation — the only
        aggregation buffer the device sees is (R, tile)."""
        return ops.chunk_agg(stored_t, w_c, assign_c, R)

    @jax.jit
    def rsu_update(num_t, mass_acc, rsu_t):
        return normalize_blend(num_t, mass_acc, rsu_t)

    @jax.jit
    def cloud_update(rsu_t, total_mass, cloud_t):
        return ops.cloud_blend(rsu_t, total_mass, cloud_t)

    def put_chunk(c: int, rsu_host):
        x, y, a = chunks[c]
        # host-side gather of the chunk's RSU start rows (padded tail
        # rows read RSU 0 at weight 0 — algebra-neutral, like jnp.take)
        return jax.device_put((x, y, a, rsu_host[a]))

    def global_round(state: StreamSimState, fault_r=None):
        store = state.store
        rsu_host, cloud_host = state.rsu_flat, state.cloud_flat
        conn, rng, weights, steps = draws_fn(state.conn, state.rng)
        if faults is not None:
            weights = weights * _fault_weight_fold(fault_r, rsu_assign_np,
                                                   plan.pad)
        # Alg. 2 line 2: host RSU rows re-anchor to the cloud master
        rsu_host = np.empty_like(rsu_host)
        rsu_host[:] = cloud_host.astype(rsu_host.dtype)
        cloud_dev = jnp.asarray(cloud_host)          # full-N, training ref
        total_mass = jnp.zeros((R,), jnp.float32)
        n_quar = jnp.zeros((), jnp.int32)
        for l in range(hp.lar):
            num_host = np.zeros((R, Np), np.float32)
            mass_acc = jnp.zeros((R,), jnp.float32)
            nxt, wb = put_chunk(0, rsu_host), None
            for c in range(plan.n_chunks):
                lo, valid = plan.bounds(c)
                cur = nxt
                if c + 1 < plan.n_chunks:
                    nxt = put_chunk(c + 1, rsu_host)
                sl = slice(c * plan.chunk, (c + 1) * plan.chunk)
                x_c, y_c, a_c, w_start = cur
                stored, w_eff, nq = chunk_train(
                    w_start, cloud_dev, x_c, y_c, steps[l, sl],
                    weights[l, sl])
                n_quar = n_quar + nq
                # tile-by-tile d2h accumulation: the (R, N) numerator
                # lives on HOST; mass is column-independent (tile 0 only)
                for t in range(tiles.n_tiles):
                    tlo, thi = tiles.bounds(t)
                    num_t, mass_t = tile_agg(stored[:, tlo:thi], w_eff,
                                             a_c)
                    if t == 0:
                        mass_acc = mass_acc + mass_t
                    num_host[:, tlo:thi] += np.asarray(num_t)
                if wb is not None:
                    store.scatter(*wb)
                wb = (lo, stored if valid == plan.chunk
                      else stored[:valid])
            if wb is not None:
                store.scatter(*wb)
            # close the local round per tile: (R, tile) up, blended down,
            # defer-by-one reads so d2h overlaps the next tile's dispatch
            pend = None
            for t in range(tiles.n_tiles):
                tlo, thi = tiles.bounds(t)
                new_t = rsu_update(jnp.asarray(num_host[:, tlo:thi]),
                                   mass_acc,
                                   jnp.asarray(rsu_host[:, tlo:thi]))
                if pend is not None:
                    plo, phi, arr = pend
                    rsu_host[:, plo:phi] = np.asarray(arr)
                pend = (tlo, thi, new_t)
            plo, phi, arr = pend
            rsu_host[:, plo:phi] = np.asarray(arr)
            total_mass = total_mass + mass_acc
        # Alg. 3 line 6: cloud blend, tile by tile
        cloud_host = cloud_host.copy()
        pend = None
        for t in range(tiles.n_tiles):
            tlo, thi = tiles.bounds(t)
            new_c = cloud_update(jnp.asarray(rsu_host[:, tlo:thi]),
                                 total_mass,
                                 jnp.asarray(cloud_host[tlo:thi]))
            if pend is not None:
                plo, phi, arr = pend
                cloud_host[plo:phi] = np.asarray(arr)
            pend = (tlo, thi, new_c)
        plo, phi, arr = pend
        cloud_host[plo:phi] = np.asarray(arr)
        out = StreamSimState(store=store, rsu_flat=rsu_host,
                             cloud_flat=cloud_host, conn=conn, rng=rng)
        if faults is not None:
            return out, {"quarantined": n_quar}
        return out

    global_round.plan = plan
    global_round.tiles = tiles
    global_round.chunk_train = chunk_train
    global_round.tile_agg = tile_agg
    return global_round


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------

def run_streamed_simulation(cfg: SimConfig, hp: H2FedParams,
                            het: HeterogeneityModel, fed: FederatedData,
                            init_params: PyTree, n_rounds: int, *,
                            engine: str = "flat",
                            acfg: Optional[AsyncConfig] = None,
                            fleet_store: str = "host",
                            chunk_agents: int = 0,
                            chunk_params: int = 0,
                            x_test=None, y_test=None,
                            loss_fn: Callable = mlp.loss_fn,
                            eval_fn: Optional[Callable] = None,
                            fleet_dtype=None, faults=None,
                            ) -> Tuple[Any, Dict[str, np.ndarray]]:
    """Cohort-streamed twin of ``run_simulation``: same rounds and history
    schema, with the (A, N) fleet in a FleetStore and the device working
    set bounded by the chunk.  ``fedsim.run_scenario`` dispatches here for
    ``fleet_store="host"`` / ``chunk_agents > 0`` specs; call directly
    when the arrays are hand-built (benchmarks/streaming_round).  Returns
    the streamed state (``.store.snapshot()`` materializes the fleet — an
    eval/test boundary for small A only)."""
    hp.validate(), het.validate()
    if engine not in ("flat", "async"):
        raise ValueError(f"engine {engine!r} does not stream "
                         f"(want 'flat'|'async'; tree/sharded are "
                         f"device-resident only)")
    if chunk_params and engine != "flat":
        raise ValueError(f"chunk_params={chunk_params} (two-axis "
                         f"streaming) is flat-engine only, got "
                         f"engine {engine!r}")
    spec = flatten.spec_of(
        init_params,
        storage_dtype=flatten.resolve_storage_dtype(fleet_dtype))
    key = jax.random.key(cfg.seed)
    if eval_fn is None and x_test is not None:
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_test, y_test))

    if engine == "flat" and chunk_params > 0:
        tiles = make_ntile_plan(spec.n, chunk_params)
        state: Any = init_twoaxis_state(cfg, spec, init_params, key, tiles)
        round_fn = make_streamed_twoaxis_round(cfg, hp, het, fed, spec,
                                               loss_fn,
                                               chunk_agents=chunk_agents,
                                               chunk_params=chunk_params,
                                               faults=faults)
    elif engine == "flat":
        state = init_stream_state(cfg, spec, init_params, key,
                                  fleet_store=fleet_store)
        round_fn = make_streamed_flat_round(cfg, hp, het, fed, spec,
                                            loss_fn,
                                            chunk_agents=chunk_agents,
                                            faults=faults)
    else:
        state = init_async_stream_state(cfg, spec, init_params, key,
                                        fleet_store=fleet_store)
        round_fn = make_streamed_async_round(cfg, hp, het, fed, spec, acfg,
                                             loss_fn,
                                             chunk_agents=chunk_agents,
                                             faults=faults)
    sched = None
    if faults is not None:
        sched = faults.validate(cfg.n_rsus).lower(cfg.n_agents, cfg.n_rsus,
                                                  n_rounds * hp.lar)

    accs, rounds, absorbed, pending, quarantined = [], [], [], [], []
    for r in range(n_rounds):
        fr = None if sched is None else sched.round_slice(r, hp.lar)
        if engine == "async":
            state, metrics = (round_fn(state) if sched is None
                              else round_fn(state, fr))
            absorbed.append(float(jnp.sum(metrics["absorbed_mass"])))
            pending.append(float(metrics["pending_mass"]))
            if sched is not None:
                quarantined.append(int(metrics["quarantined"]))
        elif sched is not None:
            state, metrics = round_fn(state, fr)
            quarantined.append(int(metrics["quarantined"]))
        else:
            state = round_fn(state)
        if eval_fn is not None and (r % cfg.eval_every == 0
                                    or r == n_rounds - 1):
            accs.append(float(eval_fn(spec.unravel(state.cloud_flat))))
            rounds.append(r + 1)
    history = {"round": np.asarray(rounds), "acc": np.asarray(accs)}
    if engine == "async":
        history["absorbed_mass"] = np.asarray(absorbed)
        history["pending_mass"] = np.asarray(pending)
    if sched is not None:
        history["quarantined"] = np.asarray(quarantined)
    return state, history


def _run_streamed(res, init_params: PyTree, *,
                  loss_fn: Callable = mlp.loss_fn,
                  eval_fn: Optional[Callable] = None):
    """``run_scenario``'s streamed dispatch target (ResolvedScenario in,
    ``run_simulation``-shaped (state, history) out)."""
    s = res.spec
    acfg = None
    if s.engine == "async":
        acfg = AsyncConfig(staleness_decay=s.staleness_decay,
                           schedule=s.schedule, buffer_keep=s.buffer_keep,
                           cloud_every=s.cloud_every)
    x_test = res.test.x if res.test is not None else None
    y_test = res.test.y if res.test is not None else None
    return run_streamed_simulation(
        res.cfg, s.hp, s.het, res.fed, init_params, s.rounds,
        engine=s.engine, acfg=acfg, fleet_store=s.fleet_store,
        chunk_agents=s.chunk_agents, chunk_params=s.chunk_params,
        x_test=x_test, y_test=y_test,
        loss_fn=loss_fn, eval_fn=eval_fn, fleet_dtype=s.fleet_dtype,
        faults=s.faults)
