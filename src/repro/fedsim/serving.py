"""Continuous-serving loop: event-driven H²-Fed ticks (DESIGN.md §9).

Every engine so far is *batch*: ``run_scenario`` executes ``rounds`` global
rounds and exits.  This module runs the SAME tick algebra as the semi-async
engine (``fedsim/async_engine``) but lets the *workload* drive time: agent
updates arrive as events from a seeded load generator (``core/load_gen``),
queue in a bounded ``EventQueue`` with an explicit overload policy, and a
tick fires on arrival pressure — queue depth (``batch:K``) or waiting time
(``deadline:W``) — instead of a round counter.  The fp32 cloud master is
snapshotted after every cloud aggregation and served to inference requests
concurrently with ingestion (``CloudModelServer``).

Event lifecycle (one arrival)::

    generator ──admit──▶ EventQueue ──drain──▶ serve tick ──▶ RSU absorb
        │ (queue full)       │ (same-agent dup)        (weight n·m·s(age))
        ├─ drop_oldest: evict oldest, dropped += 1
        ├─ backpressure: defer admission, fire a tick, deferred += 1
        └─ coalesce: newest event per agent absorbs, coalesced += rest

Tick grouping keeps the batch anchor: every ``hp.lar`` ticks form one
VIRTUAL ROUND with the exact key discipline of the async engine
(``rng, k = split(rng); keys = round_keys(k, lar)``), and with
``cloud_every=0``
the round close runs the same cloud aggregation + RSU re-anchor.  A run
whose generator delivers every agent exactly once per tick window, with
decay disabled, therefore equals ``engine="async"`` (and transitively
``engine="flat"``) to fp32 tolerance — test-pinned in
tests/test_serving.py.  Arrival latency is modeled by the QUEUE here, not
the in-flight pending buffers: an event absorbed ``k`` ticks after
admission is weighted by the same staleness schedule ``s(k)`` the async
engine applies to a ``k``-tick-late delivery.

``ServeLoopStats`` records the service-level story: sustained updates/sec,
per-tick p50/p99 latency (steady-state — the first tick carries the jit
compile and is excluded from percentiles), queue depth, drop/deferral/
coalesce counters, and two staleness-under-load signals: the sim-time each
absorbed event waited in the queue, and the age in ticks of the served
cloud snapshot.  ``benchmarks/serving_loop.py`` turns these into the
BENCH_PR7 flow.

Fault injection (DESIGN.md §11): when the spec carries a ``FaultPlan`` the
loop splits it across the host/device seam.  Host-side, per-event seeded
and stateless: clock skew perturbs admission times, duplicate admissions
re-enter the ingress queue, churned agents' events are dropped at the door
(``events_lost_churn``), and stale sequence numbers are rejected at drain
(``events_stale_rejected``).  Device-side, the lowered per-tick mask slice
rides into the jitted tick as data: corruption is applied to trained rows,
the quarantine gate scrubs and zero-weights rejected updates
(``quarantined_updates``), uploads to dark RSUs are blocked
(``blocked_mass``) and their held mass is excluded from every cloud blend,
and a recovering RSU re-anchors to the cloud master.  The benign plan is a
bitwise no-op (the zero-fault anchor in tests/test_faults.py).

Crash-resume: ``snapshot_dir``/``snapshot_every`` periodically checkpoint
the ENTIRE loop state — device state, round keys, queue/ingress contents,
stats, sim clock, and the count of events pulled from the generator —
through ``checkpoint/ckpt`` (atomic single-file commits).  Because every
source of randomness is either in the snapshotted rng state or seeded per
event, ``resume_from=`` replays the remaining trace to a bit-identical
continuation of the uninterrupted run (test-pinned).  An exception or
signal mid-loop raises :class:`ServeLoopInterrupted` carrying the final
stats, history, and a last-effort snapshot path — the loop never exits
without accounting for the events it absorbed.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import faults as faults_mod
from repro.core import flatten
from repro.core.aggregation import buffer_absorb, screen_updates
from repro.core.load_gen import (Event, PoissonLoadGen, TickTrigger,
                                 TraceLoadGen, agent_rates, parse_trigger)
from repro.fedsim.async_engine import AsyncConfig, AsyncSimState, \
    init_async_state
from repro.fedsim.simulator import _fed_arrays, _local_train_flat, \
    round_draws, round_keys
from repro.kernels import ops
from repro.models import mlp

PyTree = Any

OVERLOAD_POLICIES = ("drop_oldest", "backpressure")


# --------------------------------------------------------------------------
# event queue + overload policy
# --------------------------------------------------------------------------

class EventQueue:
    """Bounded FIFO of admitted events with explicit overload handling.

    ``capacity=0`` is unbounded.  On a full queue, ``drop_oldest`` evicts
    the head (and counts it); ``backpressure`` refuses admission — the
    caller must fire a tick to free space and retry (the generator is
    pull-based, so deferral stalls admission without touching sim time).
    Entries carry their admission tick so staleness age is
    ``current_tick - admit_tick``.
    """

    def __init__(self, capacity: int = 0, policy: str = "drop_oldest"):
        if policy not in OVERLOAD_POLICIES:
            raise ValueError(f"unknown overload policy {policy!r} "
                             f"(want one of {OVERLOAD_POLICIES})")
        if capacity < 0:
            raise ValueError(f"queue_capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self.policy = policy
        self._q: Deque[Tuple[Event, int]] = deque()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._q)

    @property
    def oldest_t(self) -> float:
        return self._q[0][0].t

    def push(self, ev: Event, tick: int) -> bool:
        """Admit one event; False = refused (backpressure, queue full)."""
        if self.capacity and len(self._q) >= self.capacity:
            if self.policy == "backpressure":
                return False
            self._q.popleft()
            self.dropped += 1
        self._q.append((ev, tick))
        return True

    def drain(self, tick: int) -> Tuple[List[Tuple[Event, int]], int]:
        """Take everything queued, coalescing same-agent duplicates to the
        NEWEST event (an agent's later update supersedes its earlier one;
        highest seq wins, so an injected duplicate of an old event can
        never shadow a genuinely newer one).
        Returns (absorbed [(event, age_ticks)], n_coalesced)."""
        newest: Dict[int, Tuple[Event, int]] = {}
        n = len(self._q)
        while self._q:
            ev, admit = self._q.popleft()
            held = newest.get(ev.agent)
            if held is None or ev.seq >= held[0].seq:
                newest[ev.agent] = (ev, tick - admit)
        batch = sorted(newest.values(), key=lambda p: p[0].seq)
        return batch, n - len(batch)

    # -- snapshot seam (crash-resume) ------------------------------------
    def entries(self) -> List[Tuple[Event, int]]:
        """The queued (event, admit_tick) pairs, head first."""
        return list(self._q)

    def load(self, entries: List[Tuple[Event, int]], dropped: int) -> None:
        """Restore queue contents + drop counter from a snapshot."""
        self._q = deque(entries)
        self.dropped = int(dropped)


# --------------------------------------------------------------------------
# observability
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ServeLoopStats:
    """Service-level counters + distributions for one serving run."""
    events_generated: int = 0
    events_absorbed: int = 0
    events_dropped: int = 0
    events_deferred: int = 0
    events_coalesced: int = 0
    # fault-injection accounting (all zero on a benign run)
    events_lost_churn: int = 0       # dropped at admission: agent churned
    events_duplicated: int = 0       # duplicate admissions injected
    events_stale_rejected: int = 0   # stale seq rejected at drain
    quarantined_updates: int = 0     # non-finite / norm-clipped updates
    blocked_mass: float = 0.0        # upload mass lost to dark RSUs
    n_ticks: int = 0
    n_rounds: int = 0
    n_cloud_aggs: int = 0
    sim_time: float = 0.0
    wall_s: float = 0.0
    tick_latency_s: List[float] = dataclasses.field(default_factory=list)
    queue_depth: List[int] = dataclasses.field(default_factory=list)
    drain_sizes: List[int] = dataclasses.field(default_factory=list)
    # staleness-under-load: sim-time each absorbed event waited queued,
    # ticks-age of absorbed events (the decay weight's argument), and the
    # served snapshot's age in ticks since the last cloud aggregation
    event_wait: List[float] = dataclasses.field(default_factory=list)
    event_age_ticks: List[int] = dataclasses.field(default_factory=list)
    model_staleness: List[int] = dataclasses.field(default_factory=list)
    serve_requests: int = 0
    serve_latency_s: List[float] = dataclasses.field(default_factory=list)

    def _steady(self) -> List[float]:
        """Tick latencies minus the compile tick (the first fire carries
        the whole jit trace; percentiles are a steady-state claim)."""
        return (self.tick_latency_s[1:] if len(self.tick_latency_s) > 1
                else self.tick_latency_s)

    def percentile(self, q: float) -> float:
        lat = self._steady()
        return float(np.percentile(lat, q)) if lat else 0.0

    @property
    def updates_per_s(self) -> float:
        """Sustained absorbed updates/sec over steady-state wall time."""
        lat = self._steady()
        absorbed = sum(self.drain_sizes[1:] if len(self.drain_sizes) > 1
                       else self.drain_sizes)
        return absorbed / max(sum(lat), 1e-12)

    def summary(self) -> Dict[str, Any]:
        return {
            "events_generated": self.events_generated,
            "events_absorbed": self.events_absorbed,
            "events_dropped": self.events_dropped,
            "events_deferred": self.events_deferred,
            "events_coalesced": self.events_coalesced,
            "events_lost_churn": self.events_lost_churn,
            "events_duplicated": self.events_duplicated,
            "events_stale_rejected": self.events_stale_rejected,
            "quarantined_updates": self.quarantined_updates,
            "blocked_mass": self.blocked_mass,
            "n_ticks": self.n_ticks,
            "n_rounds": self.n_rounds,
            "n_cloud_aggs": self.n_cloud_aggs,
            "sim_time": self.sim_time,
            "wall_s": self.wall_s,
            "updates_per_s": self.updates_per_s,
            "tick_p50_ms": self.percentile(50) * 1e3,
            "tick_p99_ms": self.percentile(99) * 1e3,
            "queue_depth_mean": (float(np.mean(self.queue_depth))
                                 if self.queue_depth else 0.0),
            "queue_depth_max": (int(np.max(self.queue_depth))
                                if self.queue_depth else 0),
            "event_wait_mean": (float(np.mean(self.event_wait))
                                if self.event_wait else 0.0),
            "event_wait_max": (float(np.max(self.event_wait))
                               if self.event_wait else 0.0),
            "event_age_ticks_mean": (float(np.mean(self.event_age_ticks))
                                     if self.event_age_ticks else 0.0),
            "model_staleness_mean": (float(np.mean(self.model_staleness))
                                     if self.model_staleness else 0.0),
            "model_staleness_max": (int(np.max(self.model_staleness))
                                    if self.model_staleness else 0),
            "serve_requests": self.serve_requests,
            "serve_p50_ms": (float(np.percentile(self.serve_latency_s, 50))
                             * 1e3 if self.serve_latency_s else 0.0),
        }


def _stats_to_tree(stats: ServeLoopStats) -> Dict[str, np.ndarray]:
    """ServeLoopStats as a flat dict of numpy arrays (snapshot leaf)."""
    out = {}
    for f in dataclasses.fields(ServeLoopStats):
        v = getattr(stats, f.name)
        out[f.name] = np.asarray(v)
    return out


def _stats_from_tree(tree: Dict[str, np.ndarray]) -> ServeLoopStats:
    stats = ServeLoopStats()
    for f in dataclasses.fields(ServeLoopStats):
        v = np.asarray(tree[f.name])
        if f.default is dataclasses.MISSING:        # list-valued field
            setattr(stats, f.name, list(v.tolist()))
        elif isinstance(f.default, int):
            setattr(stats, f.name, int(v))
        else:
            setattr(stats, f.name, float(v))
    return stats


class ServeLoopInterrupted(RuntimeError):
    """Raised when the serve loop dies mid-run (exception or signal).

    Graceful shutdown: the loop drains its accounting before re-raising —
    the exception carries the finalized ``stats``/``history``, the last
    ``state``/``server`` (which may reference donated buffers if the tick
    dispatch itself died), and the path of a last-effort snapshot (or
    ``None`` if none could be written) so a supervisor can
    ``run_serve_loop(resume_from=...)`` it."""

    def __init__(self, msg: str, *, state=None, history=None, stats=None,
                 server=None, snapshot_path=None):
        super().__init__(msg)
        self.state = state
        self.history = history
        self.stats = stats
        self.server = server
        self.snapshot_path = snapshot_path


class CloudModelServer:
    """Serve the fp32 cloud master concurrently with ingestion.

    ``publish`` snapshots the master (an explicit device copy — the tick
    jit DONATES its input state, so a held reference into the live state
    would be invalidated by the next tick); ``request`` dispatches a jitted
    prediction against the current snapshot and returns the un-blocked
    device array, so inference overlaps the in-flight tick compute and
    never blocks admission.
    """

    def __init__(self, fspec: flatten.FlatSpec,
                 predict_fn: Optional[Callable] = None):
        self.fspec = fspec
        self._predict = predict_fn or jax.jit(
            lambda v, x: jnp.argmax(mlp.forward(fspec.unravel(v), x),
                                    axis=-1))
        self._snap: Optional[jax.Array] = None
        self.published_at_tick: int = 0

    def publish(self, cloud_flat: jax.Array, tick: int) -> None:
        self._snap = cloud_flat.copy()
        self.published_at_tick = tick

    @property
    def snapshot(self) -> Optional[jax.Array]:
        return self._snap

    def params(self):
        """The served model as a pytree (the checkpoint boundary)."""
        return self.fspec.unravel(self._snap)

    def request(self, x) -> jax.Array:
        if self._snap is None:
            raise RuntimeError("no cloud snapshot published yet")
        return self._predict(self._snap, x)


# --------------------------------------------------------------------------
# the jitted serve tick (the async tick algebra, event-gated)
# --------------------------------------------------------------------------

def _make_serve_tick(cfg, hp, het, fed, spec: flatten.FlatSpec,
                     acfg: AsyncConfig, loss_fn: Callable = mlp.loss_fn, *,
                     fused: bool = True, faults=None):
    """One event-driven tick, jitted with the state donated:
    ``(state, key, arrive (A,) f32, age (A,) i32[, f]) -> (state, metrics)``.

    Identical to the async engine's tick with the in-flight machinery
    replaced by the event gate: arriving agents train from their RSU row
    and are absorbed with weight ``n_a · mask_a · arrive_a · s(age_a)``
    (``s`` the staleness schedule over the event's queue age in ticks);
    non-arriving agents keep their row and contribute nothing.  The cloud
    cadence (``cloud_every`` on the global tick clock) is unchanged.

    With ``faults`` (a validated ``FaultPlan``) the tick takes a fifth
    operand ``f`` — one :data:`core.faults.FAULT_FIELDS` tick slice — and
    runs the degraded-mode algebra: recovering RSUs re-anchor to the cloud
    master first; trained rows pass through ``apply_corruption`` and the
    ``screen_updates`` quarantine gate (rejected rows are scrubbed back to
    their dispatch model and zero-weighted — counted in
    ``metrics["quarantined"]``); uploads to dark RSUs are blocked BEFORE
    mass accounting (``metrics["blocked_mass"]``), so conservation holds
    by construction; and a dark RSU's held mass is excluded from the
    cloud-cadence blend.  Churn is enforced host-side at admission, not
    here.  The benign slice (ones/zeros) makes every fold a bitwise
    identity.
    """
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = \
        _fed_arrays(cfg, hp, fed)
    A, R, N = cfg.n_agents, cfg.n_rsus, spec.n
    decay = acfg.agent_decay(rsu_assign, R)
    keep = acfg.rsu_keep(R)
    ce = acfg.cloud_every

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    def tick(state: AsyncSimState, key, arrive, age, f=None):
        rsu_flat, rsu_mass = state.rsu_flat, state.rsu_mass
        cloud_flat, cloud_macc = state.cloud_flat, state.cloud_macc

        if faults is not None:
            # recovery re-anchor: an RSU coming back from an outage
            # rejoins at the current cloud master with an empty buffer
            ra = f["reanchor"] > 0
            rsu_flat = jnp.where(
                ra[:, None],
                jnp.broadcast_to(spec.to_storage(cloud_flat), (R, N)),
                rsu_flat)
            rsu_mass = jnp.where(ra, 0.0, rsu_mass)
            cloud_macc = jnp.where(ra, 0.0, cloud_macc)

        # stochastic realization — the flat/async engines' key discipline,
        # so the once-per-window schedule reproduces their draws exactly
        conn, mask, active_steps = round_draws(key, state.conn, het, hp,
                                               A, spe)
        maskf = mask.astype(jnp.float32)
        arrived = arrive > 0

        # training: only agents whose update-event fired this tick run
        # their drawn steps; everyone else keeps their row untouched
        act = jnp.where(arrived, active_steps, 0)
        w_start = jnp.take(rsu_flat, rsu_assign, axis=0)
        trained = spec.to_storage(
            train_agents(x_all, y_all, w_start, w_start, cloud_flat, act))

        # absorption: one cohort, weighted by data volume x connectivity
        # mask x the staleness schedule over the event's queue age
        w = n_per_agent * maskf * arrive * acfg.weight(age, decay=decay)
        if faults is not None:
            up_a = jnp.take(f["rsu_up"], rsu_assign)
            trained = faults_mod.apply_corruption(trained,
                                                  state.agent_flat, f)
            trained, okf, n_quar = screen_updates(
                trained, w_start, w * up_a,
                nonfinite=faults.guard_nonfinite,
                norm_clip=faults.norm_clip)
            blocked = jnp.sum(w * (1.0 - up_a))
            w = w * up_a * okf
        agent_flat = jnp.where(arrived[:, None], trained, state.agent_flat)
        m = jax.ops.segment_sum(w, rsu_assign, num_segments=R)
        if fused:
            rsu_flat, rsu_mass, _ = ops.agg_absorb(
                ((agent_flat, w),), rsu_assign, R, rsu_flat, rsu_mass,
                keep=keep)
        else:
            num, _ = ops.masked_scatter_accumulate(agent_flat, w,
                                                   rsu_assign, R)
            rsu_flat, rsu_mass = buffer_absorb(rsu_flat, rsu_mass, num, m,
                                               keep=keep)
        cloud_macc = cloud_macc + m

        # cloud cadence on the global tick clock (ce == 0 defers to the
        # virtual-round close outside); a dark RSU's held mass sits out
        # the blend but is NOT forgotten (it re-enters after recovery
        # unless the recovery re-anchor clears it)
        gtick = state.tick + 1
        if ce:
            macc_fire = cloud_macc if faults is None \
                else cloud_macc * f["rsu_up"]

            def _fire(args):
                rsu, maccf, cloud, macc_keep = args
                if fused:
                    cloud = ops.cloud_blend(rsu, maccf, cloud)
                else:
                    new_cloud = ops.cloud_agg(rsu, maccf)
                    cloud = jnp.where(jnp.sum(maccf) > 0,
                                      new_cloud.astype(jnp.float32), cloud)
                return cloud, jnp.zeros_like(macc_keep)

            def _hold(args):
                _, _, cloud, macc_keep = args
                return cloud, macc_keep

            cloud_flat, cloud_macc = jax.lax.cond(
                (gtick % ce) == 0, _fire, _hold,
                (rsu_flat, macc_fire, cloud_flat, cloud_macc))

        metrics = {"absorbed_mass": m,                         # (R,)
                   "absorbed_weight": jnp.sum(w)}
        if faults is not None:
            metrics["quarantined"] = n_quar
            metrics["blocked_mass"] = blocked
        out = state._replace(agent_flat=agent_flat, rsu_flat=rsu_flat,
                             rsu_mass=rsu_mass, cloud_flat=cloud_flat,
                             conn=conn, cloud_macc=cloud_macc, tick=gtick)
        return out, metrics

    return jax.jit(tick, donate_argnums=(0,))


def _make_round_close(spec: flatten.FlatSpec, n_rsus: int, *,
                      fused: bool = True, faulted: bool = False):
    """Virtual-round close for the per-round cloud cadence
    (``cloud_every=0``): aggregate the round's absorbed mass into the fp32
    master, then re-anchor the RSU buffers to it — the exact round
    boundary of the async engine's ``global_round`` (there the re-anchor
    happens at round START; the state between rounds is identical, and the
    initial ``init_async_state`` is already anchored).

    When ``faulted``, the close takes the closing tick's ``rsu_up`` mask:
    a dark RSU's held mass is excluded from the blend via the existing
    mass-guard, and the RSU keeps its (aging) buffer instead of
    re-anchoring — it cannot hear the cloud; recovery re-anchoring is the
    tick's job.  The benign mask (all ones) is a bitwise no-op."""

    def close(state: AsyncSimState, up=None) -> AsyncSimState:
        macc = state.cloud_macc if not faulted else state.cloud_macc * up
        if fused:
            cloud = ops.cloud_blend(state.rsu_flat, macc, state.cloud_flat)
        else:
            new_cloud = ops.cloud_agg(state.rsu_flat, macc)
            cloud = jnp.where(jnp.sum(macc) > 0,
                              new_cloud.astype(jnp.float32),
                              state.cloud_flat)
        anchored = jnp.broadcast_to(spec.to_storage(cloud),
                                    (n_rsus, spec.n))
        zeros = jnp.zeros((n_rsus,), jnp.float32)
        if faulted:
            upb = up > 0
            return state._replace(
                cloud_flat=cloud,
                rsu_flat=jnp.where(upb[:, None], anchored, state.rsu_flat),
                rsu_mass=jnp.where(upb, zeros, state.rsu_mass),
                cloud_macc=jnp.where(upb, zeros, state.cloud_macc))
        return state._replace(cloud_flat=cloud, rsu_flat=anchored,
                              rsu_mass=zeros, cloud_macc=zeros)

    return jax.jit(close, donate_argnums=(0,))


# --------------------------------------------------------------------------
# the loop
# --------------------------------------------------------------------------

def run_serve_loop(res, init_params: Optional[PyTree] = None, *,
                   loss_fn: Callable = mlp.loss_fn,
                   eval_fn: Optional[Callable] = None,
                   gen=None, probe_x=None,
                   snapshot_dir=None, snapshot_every: int = 0,
                   resume_from=None, resume_step: Optional[int] = None,
                   ) -> Tuple[AsyncSimState, Dict[str, np.ndarray],
                              ServeLoopStats, CloudModelServer]:
    """Drive a serve-mode scenario end-to-end; returns
    ``(state, history, stats, server)``.

    ``gen`` overrides the spec-derived load generator (any object with an
    ``events()`` iterator of ``load_gen.Event``); ``probe_x`` is a request
    batch served against the live snapshot every tick — dispatched BEFORE
    the loop blocks on the tick, so inference demonstrably overlaps
    ingestion.  History carries the per-virtual-round accuracy curve and
    absorbed mass (the async engine's schema) plus the stats summary under
    ``history["serve"]``.

    ``snapshot_dir`` + ``snapshot_every=k`` checkpoint the full loop state
    every ``k`` ticks (atomic — see ``checkpoint/ckpt``);
    ``resume_from=<dir>`` restores the latest (or ``resume_step``)
    snapshot and continues the SAME run: the generator is replayed up to
    the snapshot's event cursor and every later tick reproduces the
    uninterrupted run bit-for-bit (requires the same spec/generator; pass
    the trace, not a live Poisson stream, if the run must survive process
    death).  A mid-loop exception or signal raises
    :class:`ServeLoopInterrupted` after finalizing stats and writing a
    last-effort snapshot.
    """
    from repro.core.scenario import ScenarioSpec
    if isinstance(res, ScenarioSpec):
        res = res.resolve()
    s = res.spec.validate()
    if not s.serve_events and gen is None:
        raise ValueError("run_serve_loop needs spec.serve_events > 0 "
                         "(or an explicit gen)")
    cfg, hp, het, fed = res.cfg, s.hp, s.het, res.fed
    A, lar, ce = cfg.n_agents, hp.lar, s.cloud_every
    plan = s.faults

    if init_params is None:
        from repro.configs.mnist_mlp import CONFIG
        init_params = mlp.init_params(CONFIG, jax.random.key(s.seed))
    fspec = flatten.spec_of(
        init_params,
        storage_dtype=flatten.resolve_storage_dtype(s.fleet_dtype))
    acfg = AsyncConfig(staleness_decay=s.staleness_decay,
                       schedule=s.schedule, buffer_keep=s.buffer_keep,
                       cloud_every=s.cloud_every).validate()
    state = init_async_state(cfg, fspec, init_params,
                             jax.random.key(cfg.seed))

    trigger: TickTrigger = parse_trigger(s.tick_trigger, A)
    queue = EventQueue(capacity=s.queue_capacity,
                       policy=s.overload_policy)
    if gen is None:
        if s.serve_trace:
            gen = TraceLoadGen.from_jsonl(s.serve_trace,
                                          limit=s.serve_events,
                                          n_agents=A)
        else:
            gen = PoissonLoadGen(
                agent_rates(het, A, s.arrival_rate, seed=cfg.seed),
                seed=cfg.seed, n_events=s.serve_events)
    stream = iter(gen.events())

    # lowered fault schedule over a generous tick bound (ticks beyond it
    # clip to the last row, so an over-estimate is harmless)
    sched = None
    if plan is not None:
        n_ev = s.serve_events or (len(gen) if hasattr(gen, "__len__") else 0)
        sched = plan.lower(A, cfg.n_rsus, 2 * max(n_ev, 1) + lar + 2)

    tick_fn = _make_serve_tick(cfg, hp, het, fed, fspec, acfg, loss_fn,
                               fused=s.fused, faults=plan)
    round_close = _make_round_close(fspec, cfg.n_rsus, fused=s.fused,
                                    faulted=plan is not None)
    round_keys_fn = jax.jit(
        lambda rng: (lambda r, k: (r, round_keys(k, lar)))(
            *jax.random.split(rng)))

    if eval_fn is None and res.test is not None:
        x_t = jnp.asarray(res.test.x)
        y_t = jnp.asarray(res.test.y)
        eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_t, y_t))
    server = CloudModelServer(fspec)
    server.publish(state.cloud_flat, 0)
    probe_x = None if probe_x is None else jnp.asarray(probe_x)

    stats = ServeLoopStats()
    keys = None
    tick_in_round = 0
    last_cloud_tick = 0
    accs: List[float] = []
    rounds: List[int] = []
    round_absorbed: List[float] = []
    absorbed_acc = 0.0
    ingress: Deque[Event] = deque()     # deferred + injected-dup events
    last_seq: Dict[int, int] = {}       # per-agent last absorbed seq
    stream_pos = 0                      # events pulled from the generator
    stream_done = False
    now = 0.0
    wall_offset = 0.0

    def _key_placeholder():
        return np.zeros((lar, 2), np.uint32)

    def _loop_tree():
        """The FULL loop state as one snapshot pytree (all numpy-able)."""
        return {
            "state": state._replace(rng=jax.random.key_data(state.rng)),
            "keys": (np.asarray(jax.random.key_data(keys))
                     if keys is not None else _key_placeholder()),
            "scalars": np.asarray(
                [float(keys is not None), float(tick_in_round),
                 float(last_cloud_tick), float(stream_pos),
                 float(stream_done), float(queue.dropped)], np.float64),
            "clock": np.asarray([now, absorbed_acc, wall_offset
                                 + time.perf_counter() - t_loop],
                                np.float64),
            "queue": np.asarray(
                [[e.t, e.agent, e.seq, adm] for e, adm in queue.entries()],
                np.float64).reshape(-1, 4),
            "ingress": np.asarray([[e.t, e.agent, e.seq] for e in ingress],
                                  np.float64).reshape(-1, 3),
            "last_seq": np.asarray(sorted(last_seq.items()),
                                   np.int64).reshape(-1, 2),
            "accs": np.asarray(accs, np.float64),
            "rounds": np.asarray(rounds, np.int64),
            "round_absorbed": np.asarray(round_absorbed, np.float64),
            "stats": _stats_to_tree(stats),
        }

    t_loop = time.perf_counter()
    if resume_from is not None:
        tree = ckpt.restore(resume_from, step=resume_step,
                            like=_loop_tree())
        raw = tree["state"]
        state = jax.tree.map(jnp.asarray, raw)._replace(
            rng=jax.random.wrap_key_data(
                jnp.asarray(np.asarray(raw.rng, np.uint32))))
        sc = tree["scalars"]
        if bool(sc[0]):
            keys = jax.random.wrap_key_data(
                jnp.asarray(np.asarray(tree["keys"], np.uint32)))
        tick_in_round = int(sc[1])
        last_cloud_tick = int(sc[2])
        stream_pos = int(sc[3])
        stream_done = bool(sc[4])
        queue.load([(Event(t=float(r[0]), agent=int(r[1]), seq=int(r[2])),
                     int(r[3])) for r in tree["queue"]], dropped=int(sc[5]))
        ingress.extend(Event(t=float(r[0]), agent=int(r[1]), seq=int(r[2]))
                       for r in tree["ingress"])
        last_seq.update({int(a): int(q) for a, q in tree["last_seq"]})
        now, absorbed_acc, wall_offset = (float(v) for v in tree["clock"])
        accs = [float(v) for v in tree["accs"]]
        rounds = [int(v) for v in tree["rounds"]]
        round_absorbed = [float(v) for v in tree["round_absorbed"]]
        stats = _stats_from_tree(tree["stats"])
        # replay the generator up to the snapshot's cursor — every event
        # before it was already admitted (or deliberately dropped)
        for _ in range(stream_pos):
            next(stream, None)
        server.publish(state.cloud_flat, last_cloud_tick)

    def _eval_round(r: int):
        if eval_fn is not None:
            accs.append(float(eval_fn(fspec.unravel(state.cloud_flat))))
            rounds.append(r + 1)

    def _next_event() -> Optional[Event]:
        """Pull from the ingress queue first, then the generator —
        applying the plan's per-event-seeded clock skew and duplicate
        injection at the generator boundary (stateless: a resumed loop
        replays them identically)."""
        nonlocal stream_pos
        if ingress:
            return ingress.popleft()
        ev = next(stream, None)
        if ev is None:
            return None
        stream_pos += 1
        if plan is not None:
            if plan.clock_skew > 0.0:
                ev = Event(t=faults_mod.skewed_time(plan, cfg.seed, ev.seq,
                                                    ev.t),
                           agent=ev.agent, seq=ev.seq)
            for _ in range(faults_mod.duplicate_count(plan, cfg.seed,
                                                      ev.seq)):
                ingress.append(Event(t=ev.t, agent=ev.agent, seq=ev.seq))
                stats.events_duplicated += 1
        return ev

    def _rsu_up_at(t: int):
        return jnp.asarray(sched.tick_slice(t)["rsu_up"])

    try:
        while True:
            # ---- admit events until a trigger fires (or stream ends) ----
            while not (stream_done and not ingress):
                if trigger.batch and len(queue) >= trigger.batch:
                    break
                ev = _next_event()
                if ev is None:
                    stream_done = True
                    break
                if not 0 <= ev.agent < A:
                    raise ValueError(
                        f"event agent {ev.agent} outside the fleet "
                        f"(n_agents={A}) — trace from a different "
                        f"scenario?")
                if (sched is not None and sched.agent_up[
                        min(stats.n_ticks, sched.n_ticks - 1),
                        ev.agent] == 0.0):
                    # churned agent: the event never reaches the queue
                    stats.events_generated += 1
                    stats.events_lost_churn += 1
                    now = max(now, ev.t)
                    continue
                if (trigger.deadline and len(queue)
                        and ev.t - queue.oldest_t >= trigger.deadline):
                    ingress.appendleft(ev)     # fire first, admit after
                    break
                if queue.push(ev, stats.n_ticks):
                    stats.events_generated += 1
                    now = max(now, ev.t)
                else:                          # backpressure: defer + fire
                    ingress.appendleft(ev)
                    stats.events_deferred += 1
                    break
            if not len(queue):
                break                          # stream drained, queue empty

            # ---- drain + fire one tick ------------------------------------
            if tick_in_round == 0:
                new_rng, keys = round_keys_fn(state.rng)
                state = state._replace(rng=new_rng)
            depth = len(queue)
            batch, coalesced = queue.drain(stats.n_ticks)
            stats.events_coalesced += coalesced
            if plan is not None:
                kept = []
                for e, a_ticks in batch:
                    if e.seq <= last_seq.get(e.agent, -1):
                        stats.events_stale_rejected += 1   # replayed dup
                    else:
                        kept.append((e, a_ticks))
                        last_seq[e.agent] = e.seq
                batch = kept
            arrive = np.zeros((A,), np.float32)
            age = np.zeros((A,), np.int32)
            for e, a_ticks in batch:
                arrive[e.agent] = 1.0
                age[e.agent] = a_ticks
                stats.event_wait.append(now - e.t)
                stats.event_age_ticks.append(a_ticks)

            t0 = time.perf_counter()
            tick_args = (state, keys[tick_in_round],
                         jnp.asarray(arrive), jnp.asarray(age))
            if sched is not None:
                fslice = {k: jnp.asarray(v) for k, v in
                          sched.tick_slice(stats.n_ticks).items()}
                state, tm = tick_fn(*tick_args, fslice)
            else:
                state, tm = tick_fn(*tick_args)
            if probe_x is not None:
                t_req = time.perf_counter()
                preds = server.request(probe_x)  # overlaps tick compute
            jax.block_until_ready(state.rsu_mass)
            lat = time.perf_counter() - t0
            if probe_x is not None:
                jax.block_until_ready(preds)
                stats.serve_latency_s.append(time.perf_counter() - t_req)
                stats.serve_requests += 1

            absorbed_acc += float(tm["absorbed_weight"])
            if plan is not None:
                stats.quarantined_updates += int(tm["quarantined"])
                stats.blocked_mass += float(tm["blocked_mass"])
            stats.tick_latency_s.append(lat)
            stats.queue_depth.append(depth)
            stats.drain_sizes.append(len(batch))
            stats.events_absorbed += len(batch)
            stats.n_ticks += 1
            tick_in_round += 1
            if ce and stats.n_ticks % ce == 0:
                last_cloud_tick = stats.n_ticks
                stats.n_cloud_aggs += 1
                server.publish(state.cloud_flat, stats.n_ticks)
            stats.model_staleness.append(stats.n_ticks - last_cloud_tick)

            # ---- virtual-round boundary -----------------------------------
            if tick_in_round == lar:
                if not ce:
                    state = round_close(state) if sched is None else \
                        round_close(state, _rsu_up_at(stats.n_ticks - 1))
                    last_cloud_tick = stats.n_ticks
                    stats.n_cloud_aggs += 1
                    server.publish(state.cloud_flat, stats.n_ticks)
                r = stats.n_rounds
                stats.n_rounds += 1
                round_absorbed.append(absorbed_acc)
                absorbed_acc = 0.0
                if r % cfg.eval_every == 0:
                    _eval_round(r)
                tick_in_round = 0

            if (snapshot_dir is not None and snapshot_every
                    and stats.n_ticks % snapshot_every == 0):
                ckpt.save(snapshot_dir, stats.n_ticks, _loop_tree())

    except BaseException as exc:
        if isinstance(exc, ValueError):
            raise   # input/config validation, not an operational failure
        # graceful shutdown: finalize the accounting, write a last-effort
        # snapshot, and hand everything to the caller on the exception
        stats.events_dropped = queue.dropped
        stats.sim_time = now
        stats.wall_s = wall_offset + time.perf_counter() - t_loop
        history = {"round": np.asarray(rounds), "acc": np.asarray(accs),
                   "absorbed_mass": np.asarray(round_absorbed),
                   "serve": stats.summary()}
        snap_path = None
        if snapshot_dir is not None:
            try:
                # may fail if the tick dispatch itself died (the donated
                # state buffers are then invalid) — a stale-but-complete
                # earlier snapshot is still on disk
                snap_path = ckpt.save(snapshot_dir, stats.n_ticks,
                                      _loop_tree())
            except Exception:
                snap_path = None
        raise ServeLoopInterrupted(
            f"serve loop interrupted at tick {stats.n_ticks} "
            f"({stats.events_absorbed} events absorbed): {exc!r}",
            state=state, history=history, stats=stats, server=server,
            snapshot_path=snap_path) from exc

    # partial final round: close it so trailing absorbed mass reaches the
    # cloud master (then eval once more if the last round wasn't)
    if tick_in_round:
        if not ce:
            state = round_close(state) if sched is None else \
                round_close(state, _rsu_up_at(stats.n_ticks - 1))
            last_cloud_tick = stats.n_ticks
            stats.n_cloud_aggs += 1
        server.publish(state.cloud_flat, stats.n_ticks)
        r = stats.n_rounds
        stats.n_rounds += 1
        round_absorbed.append(absorbed_acc)
        _eval_round(r)
    elif stats.n_rounds and (rounds == [] or rounds[-1] != stats.n_rounds):
        _eval_round(stats.n_rounds - 1)

    stats.events_dropped = queue.dropped
    stats.sim_time = now
    stats.wall_s = wall_offset + time.perf_counter() - t_loop
    history = {"round": np.asarray(rounds), "acc": np.asarray(accs),
               "absorbed_mass": np.asarray(round_absorbed),
               "serve": stats.summary()}
    if snapshot_dir is not None and snapshot_every:
        ckpt.save(snapshot_dir, stats.n_ticks, _loop_tree())
    return state, history, stats, server


def _run_serve(res, init_params: Optional[PyTree] = None, *,
               loss_fn: Callable = mlp.loss_fn,
               eval_fn: Optional[Callable] = None,
               ) -> Tuple[AsyncSimState, Dict[str, np.ndarray]]:
    """``run_scenario``'s serve-mode dispatch target (spec.serve_events >
    0): same ``(state, history)`` contract as every other engine, with the
    service-level summary under ``history["serve"]``."""
    state, history, _, _ = run_serve_loop(res, init_params,
                                          loss_fn=loss_fn, eval_fn=eval_fn)
    return state, history
