"""Agent-sharded flat-buffer simulation: shard_map over the mesh agent axes.

The flat engine (fedsim/simulator, DESIGN.md §3) already holds the fleet as
an ``(A, N)`` buffer; this module partitions that agent axis over the
``pod``/``data`` mesh axes from launch/mesh.py (DESIGN.md §2) so each device
trains and aggregates only its ``A / n_shards`` agents:

  * per-shard training is the same vmap'd flat dual-proximal scan,
  * the RSU layer becomes a *partial* ``(R, A_local) @ (A_local, N)``
    aggregation matmul per shard (the Pallas kernel via kernels/ops)
    followed by ONE ``psum`` of the (R, N) partial sums + masses — the
    weight-matrix formulation makes cross-shard cohorts exact,
  * RSU and cloud buffers stay replicated, so the cloud layer (Alg. 3) is
    collective-free replicated math.

Stochastic draws (CSR/SCD/FSR) happen once per round on the replicated
(A,)-sized state — identical key discipline to the single-device engines, so
``run_sharded_simulation`` is numerically equivalent to ``run_simulation``
(engine="flat") to fp32 tolerance on any device count that divides A
(tests/test_sharded.py asserts this; CI's multi-device smoke runs it on 8
forced host devices the way launch/dryrun.py does).
"""
from __future__ import annotations

from math import prod
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import flatten
from repro.core.aggregation import (normalized_weights,
                                    unnormalized_weight_matrix)
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.data.partition import FederatedData
from repro.kernels import ops
from repro.launch.mesh import agent_axes, make_mesh, shard_map
from repro.models import mlp
from repro.fedsim.simulator import (FlatSimState, SimConfig,
                                    _fed_arrays, _local_train_flat,
                                    init_flat_state, round_draws)

PyTree = Any


def make_fleet_mesh(n_devices: Optional[int] = None):
    """Lay the fleet out over the available devices.

    >= 4 devices: a ('pod', 'data') mesh (2 x n/2) exercising both agent
    axes of the production topology; fewer: a 1-D ('data',) mesh.  The
    `model` axis is intentionally absent — fleet models are vmapped per
    agent, not tensor-parallel (launch/h2fed_round handles that regime).
    """
    n = n_devices or len(jax.devices())
    if n >= 4 and n % 2 == 0:
        return make_mesh((2, n // 2), ("pod", "data"))
    return make_mesh((n,), ("data",))


def n_shards(mesh) -> int:
    return prod(mesh.shape[a] for a in agent_axes(mesh))


def make_sharded_global_round(cfg: SimConfig, hp: H2FedParams,
                              het: HeterogeneityModel, fed: FederatedData,
                              spec: flatten.FlatSpec, mesh,
                              loss_fn: Callable = mlp.loss_fn):
    """Build the jitted agent-sharded FlatSimState -> FlatSimState round."""
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = \
        _fed_arrays(cfg, hp, fed)
    axes = agent_axes(mesh)
    shards = n_shards(mesh)
    if cfg.n_agents % shards:
        raise ValueError(
            f"n_agents={cfg.n_agents} must divide over {shards} shards "
            f"(mesh {dict(mesh.shape)})")
    R, N = cfg.n_rsus, spec.n
    ax = axes if len(axes) > 1 else axes[0]

    train_agents = jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))

    def round_fn(cloud_flat, agent_flat, x, y, n_data, assign, masks, steps):
        """Shard-local view: leading agent axes are A_local-sized; cloud and
        RSU state replicated.  masks/steps: (LAR, A_local)."""
        rsu_flat = jnp.broadcast_to(cloud_flat, (R, N))   # Alg. 2 l.2

        def local_round(carry, inp):
            rsu_flat, agent_flat = carry
            mask_l, act_l = inp
            w_start = jnp.take(rsu_flat, assign, axis=0)  # (A_local, N)
            agent_flat = train_agents(x, y, w_start, w_start,
                                      cloud_flat, act_l)

            # Alg. 2 l.8: per-shard partial aggregation matmul, ONE psum
            W_part = unnormalized_weight_matrix(
                n_data, mask_l, assign, R)                # (R, A_local)
            num = ops.weighted_agg_matmul(W_part, agent_flat)     # (R, N)
            num = jax.lax.psum(num, ax)
            mass = jax.lax.psum(jnp.sum(W_part, axis=1), ax)      # (R,)
            new_rsu = num / jnp.where(mass > 0, mass, 1.0)[:, None]
            rsu_flat = jnp.where((mass > 0)[:, None], new_rsu, rsu_flat)
            return (rsu_flat, agent_flat), mass

        (rsu_flat, agent_flat), masses = jax.lax.scan(
            local_round, (rsu_flat, agent_flat), (masks, steps))

        # Alg. 3 l.6: replicated cloud math — no collective needed
        total = jnp.sum(masses, axis=0)                   # (R,)
        wn, tsum = normalized_weights(total)
        new_cloud = wn @ rsu_flat
        cloud_flat = jnp.where(tsum > 0, new_cloud, cloud_flat)
        return cloud_flat, rsu_flat, agent_flat

    smapped = shard_map(
        round_fn, mesh,
        in_specs=(P(), P(ax), P(ax), P(ax), P(ax), P(ax),
                  P(None, ax), P(None, ax)),
        out_specs=(P(), P(), P(ax)),
        axis_names=set(axes))

    def global_round(state: FlatSimState) -> FlatSimState:
        rng, k_rounds = jax.random.split(state.rng)
        keys = jax.random.split(k_rounds, hp.lar)

        # stochastic realization on the replicated (A,) state — same key
        # discipline as the single-device engines
        def draw(conn, key):
            conn, mask, act = round_draws(key, conn, het, hp,
                                          cfg.n_agents, spe)
            return conn, (mask.astype(jnp.float32), act)

        conn, (masks, steps) = jax.lax.scan(draw, state.conn, keys)
        cloud_flat, rsu_flat, agent_flat = smapped(
            state.cloud_flat, state.agent_flat, x_all, y_all,
            n_per_agent, rsu_assign, masks, steps)
        return FlatSimState(agent_flat=agent_flat, rsu_flat=rsu_flat,
                            cloud_flat=cloud_flat, conn=conn, rng=rng)

    # donate the state buffers so the sharded (A, N) update is in-place on
    # every device (callers rebind: state = round_fn(state))
    return jax.jit(global_round, donate_argnums=(0,))


def run_sharded_simulation(cfg: SimConfig, hp: H2FedParams,
                           het: HeterogeneityModel, fed: FederatedData,
                           init_params: PyTree, n_rounds: int, *,
                           mesh=None, x_test=None, y_test=None,
                           loss_fn: Callable = mlp.loss_fn,
                           ) -> Tuple[FlatSimState, Dict[str, np.ndarray]]:
    """Sharded twin of ``run_simulation``: same rounds, agents partitioned
    over the mesh; unravel happens only at the eval boundary."""
    hp.validate(), het.validate()
    mesh = mesh if mesh is not None else make_fleet_mesh()
    spec = flatten.spec_of(init_params)
    state = init_flat_state(cfg, spec, init_params, jax.random.key(cfg.seed))
    round_fn = make_sharded_global_round(cfg, hp, het, fed, spec, mesh,
                                         loss_fn)
    eval_fn = None
    if x_test is not None:
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        eval_fn = jax.jit(lambda v: mlp.accuracy(spec.unravel(v),
                                                 x_test, y_test))

    accs, rounds = [], []
    with mesh:
        for r in range(n_rounds):
            state = round_fn(state)
            if eval_fn is not None and (r % cfg.eval_every == 0
                                        or r == n_rounds - 1):
                accs.append(float(eval_fn(state.cloud_flat)))
                rounds.append(r + 1)
    history = {"round": np.asarray(rounds), "acc": np.asarray(accs)}
    return state, history
