"""Agent-sharded flat-buffer simulation: shard_map over the mesh agent axes.

The flat engine (fedsim/simulator, DESIGN.md §3) already holds the fleet as
an ``(A, N)`` buffer; this module partitions that agent axis over the
``pod``/``data`` mesh axes according to a ``core.topology.HierarchyTopology``
(DESIGN.md §4), which owns all mesh/shard math.  Two modes:

  replicated (default, the small-R fast path / equivalence anchor):
  * per-shard training is the same vmap'd flat dual-proximal scan,
  * the RSU layer becomes a *partial* ``(R, A_local) @ (A_local, N)``
    aggregation matmul per shard (the Pallas kernel via kernels/ops)
    followed by ONE ``psum`` over all agent axes of the (R, N) partial sums
    + masses — the weight-matrix formulation makes cross-shard cohorts
    exact,
  * RSU and cloud buffers stay replicated, so the cloud layer (Alg. 3) is
    collective-free replicated math.

  rsu_sharded (``rsu_sharded=True``, large R): the topology co-locates every
  agent with its RSU's pod (``HierarchyTopology.agent_perm``), making the
  weight matrix block-diagonal over pods — so
  * the RSU layer is one BLOCK-LOCAL ``(R_local, A_local) @ (A_local, N)``
    matmul per shard (``kernels/ops.block_local_agg``) psum'd over the
    within-pod ``data`` axis ONLY: the ``(R, N)`` buffer lives sharded over
    the pod axis and never crosses pods,
  * only the cloud layer pays ONE cross-pod collective per global round —
    the paper's communication-avoidance insight made literal in the device
    topology (``launch/hlo_analysis.collective_schedule`` pins: zero
    cross-pod collectives inside the LAR scan).

Stochastic draws (CSR/SCD/FSR) happen once per round on the replicated
(A,)-sized state in the ORIGINAL agent order — identical key discipline to
the single-device engines, so both modes of ``run_sharded_simulation`` are
numerically equivalent to ``run_simulation`` (engine="flat") to fp32
tolerance on any admissible mesh (tests/test_sharded.py asserts this for
pod counts 1/2/4 dividing R; CI's multi-device smoke runs it on forced host
devices the way launch/dryrun.py does).
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flatten
from repro.core.aggregation import normalize_blend
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.core.topology import HierarchyTopology, make_fleet_mesh  # noqa: F401 — re-export
from repro.data.partition import FederatedData
from repro.kernels import ops
from repro.launch.mesh import agent_axes, shard_map
from repro.models import mlp
from repro.fedsim.simulator import (FlatSimState, SimConfig,
                                    _fed_arrays, _local_train_flat,
                                    init_flat_state, round_draws,
                                    round_keys)

PyTree = Any


def n_shards(mesh) -> int:
    from math import prod
    return prod(mesh.shape[a] for a in agent_axes(mesh))


def resolve_topology(cfg: SimConfig, fed: FederatedData, mesh, *,
                     rsu_sharded: bool = False) -> HierarchyTopology:
    """Bind the federated workload to a mesh; pass a ``HierarchyTopology``
    through unchanged (single source of the mesh/shard math)."""
    if isinstance(mesh, HierarchyTopology):
        return mesh
    return HierarchyTopology(cfg.n_agents, cfg.n_rsus, mesh,
                             rsu_assign=np.asarray(fed.rsu_assign),
                             rsu_sharded=rsu_sharded)


def _make_train_agents(cfg: SimConfig, hp: H2FedParams, spec, n_steps,
                       loss_fn):
    return jax.vmap(
        lambda x, y, w0, wr, wc, act: _local_train_flat(
            loss_fn, spec, x, y, w0, wr, wc, hp, n_steps, act, cfg.batch),
        in_axes=(0, 0, 0, 0, None, 0))


def _make_psum_num(storage, ax):
    """Cross-shard psum of an (R, N) numerator, reduced in the fleet
    storage dtype (DESIGN.md §3: bf16 halves the collective bytes of the
    RSU layer; the fp32 default is the exact reduction, a no-op cast)."""
    exact = storage == jnp.dtype(jnp.float32)

    def psum_num(v):
        if exact:
            return jax.lax.psum(v, ax)
        return jax.lax.psum(v.astype(storage), ax).astype(jnp.float32)

    return psum_num


def _make_round_draws_scan(cfg: SimConfig, hp: H2FedParams,
                           het: HeterogeneityModel, spe: int):
    """One global round's stochastic realization on the replicated (A,)
    state — same key discipline as the single-device engines (draws always
    run in the ORIGINAL agent order; RSU-sharded callers permute after)."""

    def draw(conn, key):
        conn, mask, act = round_draws(key, conn, het, hp, cfg.n_agents, spe)
        return conn, (mask.astype(jnp.float32), act)

    return draw


def make_sharded_global_round(cfg: SimConfig, hp: H2FedParams,
                              het: HeterogeneityModel, fed: FederatedData,
                              spec: flatten.FlatSpec, mesh,
                              loss_fn: Callable = mlp.loss_fn, *,
                              rsu_sharded: bool = False):
    """Build the jitted agent-sharded FlatSimState -> FlatSimState round.

    ``mesh`` may be a mesh or a prebuilt ``HierarchyTopology``;
    ``rsu_sharded=True`` selects the pod-sharded RSU buffer (DESIGN.md §4).
    NOTE (rsu_sharded): the round consumes/produces ``agent_flat`` in the
    topology's pod-block agent order — ``run_sharded_simulation`` converts
    at the boundary.
    """
    topo = resolve_topology(cfg, fed, mesh, rsu_sharded=rsu_sharded)
    if topo.model_shards > 1:
        return _make_nsharded_round(cfg, hp, het, fed, spec, topo, loss_fn)
    if topo.rsu_sharded:
        return _make_rsu_sharded_round(cfg, hp, het, fed, spec, topo,
                                       loss_fn)
    return _make_replicated_round(cfg, hp, het, fed, spec, topo, loss_fn)


def _make_replicated_round(cfg: SimConfig, hp: H2FedParams,
                           het: HeterogeneityModel, fed: FederatedData,
                           spec: flatten.FlatSpec, topo: HierarchyTopology,
                           loss_fn: Callable):
    """Replicated-RSU mode: partial weight-matrix matmul + ONE psum over
    all agent axes (DESIGN.md §4, the small-R fast path)."""
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = \
        _fed_arrays(cfg, hp, fed)
    R, N = cfg.n_rsus, spec.n
    ax = topo.shard_axes
    storage = spec.storage_dtype
    psum_num = _make_psum_num(storage, ax)

    train_agents = _make_train_agents(cfg, hp, spec, n_steps, loss_fn)

    def round_fn(cloud_flat, agent_flat, x, y, n_data, assign, masks, steps):
        """Shard-local view: leading agent axes are A_local-sized; cloud and
        RSU state replicated.  masks/steps: (LAR, A_local)."""
        rsu_flat = jnp.broadcast_to(cloud_flat.astype(storage),
                                    (R, N))               # Alg. 2 l.2

        def local_round(carry, inp):
            rsu_flat, agent_flat = carry
            mask_l, act_l = inp
            w_start = jnp.take(rsu_flat, assign, axis=0)  # (A_local, N)
            agent_flat = train_agents(x, y, w_start, w_start,
                                      cloud_flat, act_l).astype(storage)

            # Alg. 2 l.8: per-shard partial aggregation matmul, ONE psum,
            # then the shared normalize-and-blend algebra (the post-psum
            # half of the fused single-device kernels, DESIGN.md §3)
            num, mass = ops.block_local_agg(
                agent_flat, n_data * mask_l, assign, R)   # (R, N), (R,)
            num = psum_num(num)
            mass = jax.lax.psum(mass, ax)
            rsu_flat = normalize_blend(num, mass, rsu_flat)
            return (rsu_flat, agent_flat), mass

        (rsu_flat, agent_flat), masses = jax.lax.scan(
            local_round, (rsu_flat, agent_flat), (masks, steps))

        # Alg. 3 l.6: replicated cloud math — no collective needed
        total = jnp.sum(masses, axis=0)                   # (R,)
        num_c = total @ rsu_flat.astype(jnp.float32)      # (N,)
        mass_c = jnp.sum(total)
        new_cloud = num_c / jnp.where(mass_c > 0, mass_c, 1.0)
        cloud_flat = jnp.where(mass_c > 0, new_cloud, cloud_flat)
        return cloud_flat, rsu_flat, agent_flat

    smapped = shard_map(
        round_fn, topo.mesh,
        in_specs=(topo.cloud_spec, topo.agent_spec, topo.agent_spec,
                  topo.agent_spec, topo.agent_spec, topo.agent_spec,
                  topo.stacked_spec(), topo.stacked_spec()),
        out_specs=(topo.cloud_spec, topo.rsu_spec, topo.agent_spec),
        axis_names=set(topo.agent_axes))

    draw = _make_round_draws_scan(cfg, hp, het, spe)

    def global_round(state: FlatSimState) -> FlatSimState:
        rng, k_rounds = jax.random.split(state.rng)
        keys = round_keys(k_rounds, hp.lar)
        conn, (masks, steps) = jax.lax.scan(draw, state.conn, keys)
        cloud_flat, rsu_flat, agent_flat = smapped(
            state.cloud_flat, state.agent_flat, x_all, y_all,
            n_per_agent, rsu_assign, masks, steps)
        return FlatSimState(agent_flat=agent_flat, rsu_flat=rsu_flat,
                            cloud_flat=cloud_flat, conn=conn, rng=rng)

    # donate the state buffers so the sharded (A, N) update is in-place on
    # every device (callers rebind: state = round_fn(state))
    return jax.jit(global_round, donate_argnums=(0,))


def _make_rsu_sharded_round(cfg: SimConfig, hp: H2FedParams,
                            het: HeterogeneityModel, fed: FederatedData,
                            spec: flatten.FlatSpec, topo: HierarchyTopology,
                            loss_fn: Callable):
    """RSU-sharded mode: the (R, N) buffer lives sharded over the pod axis,
    agents are permuted onto their RSU's pod, the RSU layer is block-local
    (within-pod psum only) and the cloud layer pays the round's ONE
    cross-pod collective (DESIGN.md §4)."""
    x_all, y_all, n_per_agent, _, spe, n_steps = _fed_arrays(cfg, hp, fed)
    perm = jnp.asarray(topo.agent_perm)
    x_all = jnp.take(x_all, perm, axis=0)
    y_all = jnp.take(y_all, perm, axis=0)
    n_per_agent = jnp.take(n_per_agent, perm, axis=0)
    local_assign = jnp.asarray(topo.local_assign)
    R_loc, N = topo.rsu_per_pod, spec.n
    data_ax = topo.data_shard_axes
    storage = spec.storage_dtype
    cloud_reduce = None if storage == jnp.dtype(jnp.float32) else storage
    psum_num = (None if data_ax is None
                else _make_psum_num(storage, data_ax))

    train_agents = _make_train_agents(cfg, hp, spec, n_steps, loss_fn)

    def round_fn(cloud_flat, agent_flat, x, y, n_data, assign, masks, steps):
        """Shard-local view: this shard's agents all belong to this pod's
        RSU block; ``rsu_flat`` is the pod's (R_local, N) slice of the
        global buffer and ``assign`` holds pod-local RSU ids."""
        rsu_flat = jnp.broadcast_to(cloud_flat.astype(storage),
                                    (R_loc, N))           # Alg. 2 l.2

        def local_round(carry, inp):
            rsu_flat, agent_flat = carry
            mask_l, act_l = inp
            w_start = jnp.take(rsu_flat, assign, axis=0)  # (A_local, N)
            agent_flat = train_agents(x, y, w_start, w_start,
                                      cloud_flat, act_l).astype(storage)

            # Alg. 2 l.8: block-local matmul; psum over the WITHIN-POD data
            # axis only — no cross-pod traffic in the RSU layer
            num, mass = ops.block_local_agg(
                agent_flat, n_data * mask_l, assign, R_loc)
            if data_ax is not None:
                num = psum_num(num)
                mass = jax.lax.psum(mass, data_ax)
            rsu_flat = normalize_blend(num, mass, rsu_flat)
            return (rsu_flat, agent_flat), mass

        (rsu_flat, agent_flat), masses = jax.lax.scan(
            local_round, (rsu_flat, agent_flat), (masks, steps))

        # Alg. 3 l.6: the cloud layer is the ONE cross-pod collective —
        # mass-weighted partial sums reduced over the pod axis
        total = jnp.sum(masses, axis=0)                   # (R_local,)
        cloud_flat = topo.cloud_psum_mean(total, rsu_flat, cloud_flat,
                                          reduce_dtype=cloud_reduce)
        return cloud_flat, rsu_flat, agent_flat

    smapped = shard_map(
        round_fn, topo.mesh,
        in_specs=(topo.cloud_spec, topo.agent_spec, topo.agent_spec,
                  topo.agent_spec, topo.agent_spec, topo.agent_spec,
                  topo.stacked_spec(), topo.stacked_spec()),
        out_specs=(topo.cloud_spec, topo.rsu_spec, topo.agent_spec),
        axis_names=set(topo.agent_axes))

    draw = _make_round_draws_scan(cfg, hp, het, spe)

    def global_round(state: FlatSimState) -> FlatSimState:
        rng, k_rounds = jax.random.split(state.rng)
        keys = round_keys(k_rounds, hp.lar)
        # draws in the ORIGINAL agent order (the flat-engine key
        # discipline), then permuted onto the pod-block layout
        conn, (masks, steps) = jax.lax.scan(draw, state.conn, keys)
        masks = jnp.take(masks, perm, axis=1)
        steps = jnp.take(steps, perm, axis=1)
        cloud_flat, rsu_flat, agent_flat = smapped(
            state.cloud_flat, state.agent_flat, x_all, y_all,
            n_per_agent, local_assign, masks, steps)
        return FlatSimState(agent_flat=agent_flat, rsu_flat=rsu_flat,
                            cloud_flat=cloud_flat, conn=conn, rng=rng)

    return jax.jit(global_round, donate_argnums=(0,))


def _make_nsharded_round(cfg: SimConfig, hp: H2FedParams,
                         het: HeterogeneityModel, fed: FederatedData,
                         spec: flatten.FlatSpec, topo: HierarchyTopology,
                         loss_fn: Callable):
    """N-sharded mode (DESIGN.md §12): the persistent (R, N) staleness
    buffers and the fp32 cloud master live 1/model_shards per device
    (ZeRO-style parameter sharding).  Each round opens with the ONE wide
    collective — a storage-dtype all-gather of the blended (N/S,) cloud
    slices into the full-N reference — then training and the LAR scan run
    full-N exactly like the replicated engine (H²-Fed's row-weighted
    aggregation is N-separable, so no extra RSU-layer collectives
    appear), and the scan's (R, N) result is sliced back to this device's
    N-shard before the cloud blend: psum-then-slice is a reduce-scatter
    of the round's updates along N in byte-and-state terms — only the
    slice persists.  Composes with rsu_sharded: the cloud layer's
    cross-pod psum then moves (N/S,) partials instead of (N,).

    The parameter axis is padded to ``topo.model_pad(spec.n)`` (lane-
    aligned equal slices); zero tails are invariant through training
    (zero grads, zero proximal pull) and ``spec.unravel`` ignores them.
    """
    x_all, y_all, n_per_agent, rsu_assign, spe, n_steps = \
        _fed_arrays(cfg, hp, fed)
    storage = spec.storage_dtype
    model_ax = topo.model_axis
    N_pad = topo.model_pad(spec.n)
    Nt = N_pad // topo.model_shards
    if topo.rsu_sharded:
        perm = jnp.asarray(topo.agent_perm)
        x_all = jnp.take(x_all, perm, axis=0)
        y_all = jnp.take(y_all, perm, axis=0)
        n_per_agent = jnp.take(n_per_agent, perm, axis=0)
        assign_arr = jnp.asarray(topo.local_assign)
        R_loc = topo.rsu_per_pod
        agg_ax = topo.data_shard_axes         # within-pod psum only
    else:
        perm = None
        assign_arr = rsu_assign
        R_loc = cfg.n_rsus
        agg_ax = topo.shard_axes
    psum_num = None if agg_ax is None else _make_psum_num(storage, agg_ax)
    cloud_reduce = None if storage == jnp.dtype(jnp.float32) else storage

    train_agents = _make_train_agents(cfg, hp, spec, n_steps, loss_fn)

    def round_fn(cloud_loc, agent_flat, x, y, n_data, assign, masks, steps):
        """Shard-local view: ``cloud_loc`` is this device's (N/S,) slice
        of the fp32 master; the (A_local, N) training working set and the
        in-scan (R, N) blend stay full-(padded-)N."""
        ref = jax.lax.all_gather(cloud_loc.astype(storage), model_ax,
                                 tiled=True)              # (N_pad,) storage
        ref32 = ref.astype(jnp.float32)
        rsu_full = jnp.broadcast_to(ref, (R_loc, N_pad))  # Alg. 2 l.2

        def local_round(carry, inp):
            rsu_full, agent_flat = carry
            mask_l, act_l = inp
            w_start = jnp.take(rsu_full, assign, axis=0)  # (A_local, N_pad)
            agent_flat = train_agents(x, y, w_start, w_start,
                                      ref32, act_l).astype(storage)
            num, mass = ops.block_local_agg(
                agent_flat, n_data * mask_l, assign, R_loc)
            if psum_num is not None:
                num = psum_num(num)
                mass = jax.lax.psum(mass, agg_ax)
            rsu_full = normalize_blend(num, mass, rsu_full)
            return (rsu_full, agent_flat), mass

        (rsu_full, agent_flat), masses = jax.lax.scan(
            local_round, (rsu_full, agent_flat), (masks, steps))

        midx = jax.lax.axis_index(model_ax)
        rsu_loc = jax.lax.dynamic_slice_in_dim(
            rsu_full, midx * Nt, Nt, axis=1)              # (R_loc, Nt)

        total = jnp.sum(masses, axis=0)                   # (R_loc,)
        if topo.rsu_sharded:
            # Alg. 3 l.6: the cross-pod psum moves this device's (Nt,)
            # partial — 1/model_shards of the replicated DCI bytes
            cloud_loc = topo.cloud_psum_mean(total, rsu_loc, cloud_loc,
                                             reduce_dtype=cloud_reduce)
        else:
            # Alg. 3 l.6 on the slice: collective-free replicated math
            num_c = total @ rsu_loc.astype(jnp.float32)   # (Nt,)
            mass_c = jnp.sum(total)
            new_cloud = num_c / jnp.where(mass_c > 0, mass_c, 1.0)
            cloud_loc = jnp.where(mass_c > 0, new_cloud, cloud_loc)
        return cloud_loc, rsu_loc, agent_flat

    smapped = shard_map(
        round_fn, topo.mesh,
        in_specs=(topo.nshard_cloud_spec, topo.agent_spec, topo.agent_spec,
                  topo.agent_spec, topo.agent_spec, topo.agent_spec,
                  topo.stacked_spec(), topo.stacked_spec()),
        out_specs=(topo.nshard_cloud_spec, topo.nshard_rsu_spec,
                   topo.agent_spec),
        axis_names=set(topo.agent_axes) | {model_ax})

    draw = _make_round_draws_scan(cfg, hp, het, spe)

    def global_round(state: FlatSimState) -> FlatSimState:
        rng, k_rounds = jax.random.split(state.rng)
        keys = round_keys(k_rounds, hp.lar)
        # draws in the ORIGINAL agent order (the flat-engine key
        # discipline), permuted onto the pod-block layout if RSU-sharded
        conn, (masks, steps) = jax.lax.scan(draw, state.conn, keys)
        if perm is not None:
            masks = jnp.take(masks, perm, axis=1)
            steps = jnp.take(steps, perm, axis=1)
        cloud_flat, rsu_flat, agent_flat = smapped(
            state.cloud_flat, state.agent_flat, x_all, y_all,
            n_per_agent, assign_arr, masks, steps)
        return FlatSimState(agent_flat=agent_flat, rsu_flat=rsu_flat,
                            cloud_flat=cloud_flat, conn=conn, rng=rng)

    return jax.jit(global_round, donate_argnums=(0,))


def pad_model_axis(state: FlatSimState, topo: HierarchyTopology,
                   n: int) -> FlatSimState:
    """Zero-pad the parameter axis of a fresh FlatSimState to
    ``topo.model_pad(n)`` (no-op at model_shards == 1); the first ``n``
    columns carry the model, tails stay zero through every round."""
    n_pad = topo.model_pad(n)
    if n_pad == n:
        return state
    pad = n_pad - n
    return state._replace(
        agent_flat=jnp.pad(state.agent_flat, ((0, 0), (0, pad))),
        rsu_flat=jnp.pad(state.rsu_flat, ((0, 0), (0, pad))),
        cloud_flat=jnp.pad(state.cloud_flat, ((0, pad),)))


def run_sharded_simulation(cfg: SimConfig, hp: H2FedParams,
                           het: HeterogeneityModel, fed: FederatedData,
                           init_params: PyTree, n_rounds: int, *,
                           mesh=None, rsu_sharded: bool = False,
                           x_test=None, y_test=None,
                           loss_fn: Callable = mlp.loss_fn,
                           fleet_dtype=None,
                           ) -> Tuple[FlatSimState, Dict[str, np.ndarray]]:
    """DEPRECATED: use ``fedsim.run_scenario`` with an
    ``engine="sharded"`` ``ScenarioSpec`` (``rsu_sharded`` is a spec
    field; pass a custom ``mesh`` via ``run_scenario(..., mesh=)``).

    This wrapper builds an ad-hoc scenario around the pre-built arrays and
    delegates; numerics are unchanged (DESIGN.md §8)."""
    warnings.warn(
        "run_sharded_simulation is deprecated; use fedsim.run_scenario "
        "with an engine='sharded' ScenarioSpec",
        DeprecationWarning, stacklevel=2)
    from repro.fedsim import sweep
    res = sweep.adhoc_scenario(
        cfg, hp, het, fed, n_rounds=n_rounds, engine="sharded",
        fleet_dtype=fleet_dtype, rsu_sharded=rsu_sharded,
        x_test=x_test, y_test=y_test)
    return sweep.run_scenario(res, init_params, loss_fn=loss_fn, mesh=mesh)


def _run_sharded(res, init_params: PyTree, *,
                 loss_fn: Callable = mlp.loss_fn, mesh=None,
                 ) -> Tuple[FlatSimState, Dict[str, np.ndarray]]:
    """``run_scenario``'s sharded dispatch target: same rounds as the flat
    engine, agents partitioned over the mesh; unravel happens only at the
    eval boundary.  The returned state is in the ORIGINAL agent order in
    both modes (the RSU-sharded rounds run pod-block-permuted internally).
    ``fleet_dtype`` sets the fleet-buffer storage dtype — bf16 also halves
    the psum'd numerator / cross-pod cloud collective bytes (§3)."""
    s = res.spec
    cfg, hp, het, fed = res.cfg, s.hp, s.het, res.fed
    n_rounds, rsu_sharded, fleet_dtype = s.rounds, s.rsu_sharded, \
        s.fleet_dtype
    x_test = res.test.x if res.test is not None else None
    y_test = res.test.y if res.test is not None else None
    hp.validate(), het.validate()
    if mesh is None:
        mesh = make_fleet_mesh(n_model_shards=s.model_shards)
    topo = resolve_topology(cfg, fed, mesh, rsu_sharded=rsu_sharded)
    spec = flatten.spec_of(
        init_params, storage_dtype=flatten.resolve_storage_dtype(fleet_dtype))
    state = init_flat_state(cfg, spec, init_params, jax.random.key(cfg.seed))
    state = pad_model_axis(state, topo, spec.n)
    round_fn = make_sharded_global_round(cfg, hp, het, fed, spec, topo,
                                         loss_fn)
    eval_fn = None
    if x_test is not None:
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        eval_fn = jax.jit(lambda v: mlp.accuracy(spec.unravel(v),
                                                 x_test, y_test))

    accs, rounds = [], []
    with topo.mesh:
        if topo.rsu_sharded:
            state = state._replace(
                agent_flat=topo.permute_agents(state.agent_flat))
        for r in range(n_rounds):
            state = round_fn(state)
            if eval_fn is not None and (r % cfg.eval_every == 0
                                        or r == n_rounds - 1):
                accs.append(float(eval_fn(state.cloud_flat)))
                rounds.append(r + 1)
        if topo.rsu_sharded:
            state = state._replace(
                agent_flat=topo.unpermute_agents(state.agent_flat))
    history = {"round": np.asarray(rounds), "acc": np.asarray(accs)}
    return state, history
