"""Vmapped multi-scenario sweep engine (DESIGN.md §7).

The paper's figures are grids — CSR ∈ {0.1..1.0}, μ1/μ2 sweeps,
seed-averaged curves.  Running each cell as its own Python-loop simulation
pays S compiles and S× dispatch overhead for programs that differ only in
a handful of scalars.  This module makes the GRID the compiled unit:

  * S resolved scenarios (``core.scenario.ResolvedScenario``) with equal
    ``static_key`` (same shapes / scan lengths / engine flavor) are stacked
    along a new leading sweep axis — (S, A, N) fleet, (S, R, N) RSU
    buffers, (S,) PRNG keys — and the per-scenario scalars that differ
    (csr / fsr / scd / delay_p, μ1 / μ2 / lr) become (S,)-batched inputs;
  * the flat global round (or the semi-async tick loop) is ``vmap``-ed over
    the sweep axis and jitted ONCE with the state donated, so an entire CSR
    grid or seed-average runs as one compiled scan program instead of S
    sequential simulations — and matches them to fp32 tolerance, because
    the vmapped body IS ``fedsim.simulator._make_flat_round_body`` /
    ``fedsim.async_engine._make_async_round_body`` (tests/test_sweep.py);
  * scenarios that share a dataset / partition (same ``partition_key`` —
    e.g. a μ sweep over one realization) pass the (A, n, D) data block
    UNBATCHED (``in_axes=None``): no S× data copy;
  * fault plans (``core.faults.FaultPlan``) lower to per-round mask DATA
    stacked along the sweep axis — a grid of different fault schedules
    (one guard config, enforced by ``static_key``'s fingerprint) compiles
    to ONE program, trace-count-pinned in tests/test_faults.py;
  * when several host devices are visible and S divides them, the sweep
    axis is laid over a 1-D ('sweep',) mesh — pure data parallelism, zero
    collectives (``sweep_mesh``).  Composed with a
    ``core.topology.HierarchyTopology``: sweeps fill the spare pod axis
    when S ≥ pods, and fold into per-device vmap otherwise (the
    device-mapping table in DESIGN.md §7).

``run_scenarios`` is the one entry point the experiment layer needs: it
resolves specs, groups them by ``static_key``, sweeps each group — the
cadence knobs (``lar`` / ``local_epochs`` / ``cloud_every``) batch as
(S,) data under masked static upper bounds, so mixed-cadence cells share
ONE program — falling back to sequential execution only for the
tree/sharded/streamed/serve engines, and returns per-scenario histories
in input order.  Built programs are memoized in the
``core/program_cache`` registry (and, with ``REPRO_CACHE_DIR`` set, in
JAX's persistent compilation cache), so re-runs skip tracing/compiling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import flatten, program_cache
from repro.core import faults as faults_mod
from repro.core.heterogeneity import ConnState
from repro.core.scenario import ResolvedScenario, ScenarioSpec
from repro.data.partition import FederatedData
from repro.fedsim import async_engine, simulator
from repro.models import mlp

PyTree = Any

# the per-scenario scalars a sweep may batch along the sweep axis; every
# other field is static program structure and must be EQUAL across the
# group (enforced by grouping on ResolvedScenario.static_key)
DYN_HP = ("mu1", "mu2", "lr")
DYN_HET = ("csr", "fsr", "scd", "delay_p")
# cadence knobs batched as (S,) int32 data under masked static upper
# bounds (DESIGN.md §7 "cadence as data"): the scans pad to the group
# maxima and live masks neutralize the tail, so mixed-cadence cells
# share ONE program instead of one trace per cadence
DYN_CADENCE = ("lar", "local_epochs")        # hp.* int fields
DYN_SPEC = ("cloud_every",)                  # spec.* int fields (async)

# engines whose round body vmaps over the sweep axis
SWEEPABLE = ("flat", "async")


def async_config(spec: ScenarioSpec) -> async_engine.AsyncConfig:
    """The semi-async engine's config from a spec's async knobs."""
    return async_engine.AsyncConfig(
        staleness_decay=spec.staleness_decay, schedule=spec.schedule,
        buffer_keep=spec.buffer_keep, cloud_every=spec.cloud_every)


def run_scenario(res, init_params: Optional[PyTree] = None, *,
                 loss_fn: Callable = mlp.loss_fn,
                 eval_fn: Optional[Callable] = None,
                 mesh=None, topo=None):
    """THE engine entry point (DESIGN.md §8): run ONE scenario through its
    declared engine; returns ``(final state, history)``.

    Every knob is a ``ScenarioSpec`` field — ``engine`` (flat | tree |
    sharded | async), ``fleet_dtype``, ``fused``, ``rsu_sharded``, the
    semi-async schedule, and the cohort-streaming pair ``fleet_store`` /
    ``chunk_agents`` (either one non-default dispatches the streamed
    engines in ``fedsim/streaming``).  The legacy ``run_simulation`` /
    ``run_async_simulation`` / ``run_sharded_simulation`` signatures are
    deprecated wrappers over this function (via ``adhoc_scenario``).

    ``init_params`` defaults to the paper's MLP initialized from the
    spec's data seed; pass a pytree (e.g. the OEM-pretrained model) to
    override.  ``mesh`` (sharded) and ``topo`` (async) pass through to
    those engines; ``eval_fn`` overrides the test-set accuracy eval.
    """
    if isinstance(res, ScenarioSpec):
        res = res.resolve()
    s = res.spec.validate()
    if s.program_cache:
        program_cache.enable_persistent_cache()
    if init_params is None:
        from repro.configs.mnist_mlp import CONFIG
        cfg_model = (CONFIG if not s.hidden_dims else
                     dataclasses.replace(
                         CONFIG, hidden_dims=tuple(s.hidden_dims)))
        init_params = mlp.init_params(cfg_model, jax.random.key(s.seed))
    if s.serve_events:
        from repro.fedsim import serving
        return serving._run_serve(res, init_params, loss_fn=loss_fn,
                                  eval_fn=eval_fn)
    if s.engine == "sharded":
        from repro.fedsim import sharded
        return sharded._run_sharded(res, init_params, loss_fn=loss_fn,
                                    mesh=mesh)
    if s.fleet_store != "device" or s.chunk_agents:
        from repro.fedsim import streaming
        return streaming._run_streamed(res, init_params, loss_fn=loss_fn,
                                       eval_fn=eval_fn)
    if s.engine == "async":
        return async_engine._run_async(res, init_params, loss_fn=loss_fn,
                                       eval_fn=eval_fn, topo=topo)
    return simulator._run_sync(res, init_params, loss_fn=loss_fn,
                               eval_fn=eval_fn)


def adhoc_scenario(cfg, hp, het, fed, *, n_rounds: int,
                   engine: str = "flat", fleet_dtype=None,
                   fused: bool = True, rsu_sharded: bool = False,
                   model_shards: int = 1, async_cfg=None,
                   fleet_store: str = "device", chunk_agents: int = 0,
                   chunk_params: int = 0, hidden_dims=(), x_test=None,
                   y_test=None) -> ResolvedScenario:
    """Wrap pre-built arrays (SimConfig + FederatedData + optional test
    set) in the scenario contract so ``run_scenario`` can drive them —
    the deprecated ``run_*_simulation`` wrappers' bridge.  Only ``fed``
    and ``test`` are populated (train/pretrain pools stay ``None``); the
    seed mapping ``seed=0, sim_seed=cfg.seed`` makes ``spec.sim_config()``
    reproduce ``cfg`` exactly, so wrapper numerics are unchanged."""
    dt = flatten.resolve_storage_dtype(fleet_dtype)
    dtype_name = ("bfloat16" if jnp.dtype(dt) == jnp.dtype(jnp.bfloat16)
                  else "float32")
    async_kw = {}
    if async_cfg is not None:
        async_kw = dict(staleness_decay=async_cfg.staleness_decay,
                        schedule=async_cfg.schedule,
                        buffer_keep=async_cfg.buffer_keep,
                        cloud_every=async_cfg.cloud_every)
    spec = ScenarioSpec(
        n_agents=cfg.n_agents, n_rsus=cfg.n_rsus, batch=cfg.batch,
        hp=hp, het=het, engine=engine, fleet_dtype=dtype_name, fused=fused,
        rsu_sharded=rsu_sharded, model_shards=model_shards,
        fleet_store=fleet_store, chunk_agents=chunk_agents,
        chunk_params=chunk_params, hidden_dims=tuple(hidden_dims),
        rounds=n_rounds, eval_every=cfg.eval_every, seed=0,
        sim_seed=cfg.seed, **async_kw)
    test = None
    if x_test is not None:
        from repro.data.synthetic import Dataset
        x_np, y_np = np.asarray(x_test), np.asarray(y_test)
        test = Dataset(x=x_np, y=y_np, n_classes=int(y_np.max()) + 1)
    return ResolvedScenario(spec=spec, train=None, test=test,
                            pretrain_pool=None, fed_pool=None, fed=fed)


# --------------------------------------------------------------------------
# grouping
# --------------------------------------------------------------------------

def group_indices(resolved: Sequence[ResolvedScenario]) -> List[List[int]]:
    """Partition scenario indices into sweep-compatible groups (equal
    ``static_key``), preserving first-seen order."""
    groups: Dict[tuple, List[int]] = {}
    for i, r in enumerate(resolved):
        groups.setdefault(r.static_key, []).append(i)
    return list(groups.values())


def _stack_or_share(arrays):
    """(stacked (S, ...) array, 0) when members differ; (shared array,
    None in_axes) when every scenario references the same object — the
    no-copy path for grids over one dataset realization."""
    first = arrays[0]
    if all(a is first for a in arrays):
        return jnp.asarray(first), None
    return jnp.stack([jnp.asarray(a) for a in arrays]), 0


def _dyn_scalars(specs: Sequence[ScenarioSpec],
                 force: Sequence[str] = ()) -> Dict[str, jax.Array]:
    """(S,)-batched hp/het/cadence scalars — the fields that actually
    differ across the group (equal fields stay baked into the template, so
    a pure seed-average compiles the identical body the single run does).

    ``force`` names fields to batch even when equal within ``specs`` —
    ``run_scenarios`` passes the whole group's varying set so every
    ``max_sweep`` chunk of one group (including a constant tail chunk)
    traces the identical program."""
    force = set(force)
    dyn: Dict[str, jax.Array] = {}

    def _add(key, vals, dtype):
        if key in force or any(v != vals[0] for v in vals[1:]):
            dyn[key] = jnp.asarray(vals, dtype)

    for name in DYN_HP:
        _add(f"hp.{name}", [getattr(s.hp, name) for s in specs],
             jnp.float32)
    for name in DYN_CADENCE:
        _add(f"hp.{name}", [getattr(s.hp, name) for s in specs], jnp.int32)
    for name in DYN_HET:
        _add(f"het.{name}", [getattr(s.het, name) for s in specs],
             jnp.int32 if name == "scd" else jnp.float32)
    for name in DYN_SPEC:
        _add(f"spec.{name}", [getattr(s, name) for s in specs], jnp.int32)
    return dyn


def _stack_fault_rounds(group: Sequence[ResolvedScenario],
                        lar_bound: int) -> Dict[str, np.ndarray]:
    """Per-scenario lowered fault schedules stacked over the sweep axis:
    dict of (S, rounds, lar_bound, A|R) float32 host arrays — the fault
    masks ride the vmapped round as ORDINARY DATA, so a grid of different
    :class:`~repro.core.faults.FaultPlan` schedules (same guard
    fingerprint, enforced by ``static_key`` grouping) still compiles to
    one sweep program.

    Each scenario's plan lowers over its OWN tick clock (``rounds × its
    lar``).  When the group batches cadence, rows are padded to the
    group-wide scan bound by clipping to the round's last live tick —
    those scan iterations are masked dead, so the clipped values never
    land (the same neutrality argument as the cadence live masks)."""
    out: Dict[str, list] = {k: [] for k in faults_mod.FAULT_FIELDS}
    for r in group:
        s = r.spec
        lar = s.hp.lar
        sched = s.faults.validate(s.n_rsus).lower(
            s.n_agents, s.n_rsus, s.rounds * lar)
        pad = np.minimum(np.arange(lar_bound), lar - 1)          # (L,)
        idx = np.minimum(np.arange(s.rounds)[:, None] * lar + pad[None, :],
                         sched.n_ticks - 1)                      # (rounds, L)
        for k in faults_mod.FAULT_FIELDS:
            out[k].append(getattr(sched, k)[idx])
    return {k: np.stack(v) for k, v in out.items()}


def _cadence_bounds(specs: Sequence[ScenarioSpec],
                    dyn_names: Sequence[str]
                    ) -> Optional[simulator.Cadence]:
    """Group-wide static scan bounds when any cadence knob is batched;
    None keeps the fully static (ungated) round body."""
    if not any(f"hp.{n}" in dyn_names for n in DYN_CADENCE):
        return None
    return simulator.Cadence(
        lar=max(s.hp.lar for s in specs),
        local_epochs=max(s.hp.local_epochs for s in specs))


# --------------------------------------------------------------------------
# the batched program
# --------------------------------------------------------------------------

class SweepProgram(NamedTuple):
    """One compiled sweep: ``state = round_fn(state, data, dyn)`` advances
    every scenario one global round (async: returns (state, metrics)).
    Faulted sweeps take a 4th operand — the round's (S, lar, ·) fault
    mask slice — and always return (state, metrics)."""
    round_fn: Callable        # jitted, state donated
    state: Any                # (S,)-batched FlatSimState / AsyncSimState
    data: Dict[str, jax.Array]
    dyn: Dict[str, jax.Array]
    eval_fn: Optional[Callable]   # (cloud (S, N)) -> (S,) accuracies
    engine: str
    fspec: flatten.FlatSpec
    n_scenarios: int
    # (S, rounds, lar_bound, A|R) lowered fault masks (host numpy; None
    # for fault-free groups) — run_sweep slices round r and vmaps it in
    fault_rounds: Optional[Dict[str, np.ndarray]] = None


def sweep_mesh(n_scenarios: int):
    """1-D ('sweep',) mesh over the visible devices when the sweep axis can
    map onto them (S divisible by the device count); None otherwise — the
    sweep then runs vmapped within one device.  With a hierarchy mesh in
    scope the same rule applies per pod: S ≥ pods sweeps across pods,
    smaller sweeps fold into per-device vmap (DESIGN.md §7)."""
    from repro.launch.mesh import make_mesh
    n = len(jax.devices())
    if n <= 1 or n_scenarios % n:
        return None
    return make_mesh((n,), ("sweep",))


def _shard_sweep(tree, mesh):
    """Lay every (S, ...) leaf over the sweep mesh axis (leading dim)."""
    def put(a):
        spec = P(*(("sweep",) + (None,) * (jnp.ndim(a) - 1)))
        return jax.device_put(a, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree)


def _baked_scalars(s0: ScenarioSpec, dyn_names) -> tuple:
    """The hp/het/cadence values a trace bakes in as constants — every
    sweepable scalar NOT batched in ``dyn``.  Part of the program-cache
    key: two groups may share one registry entry exactly when their baked
    constants (and everything else in the key) agree."""
    baked = []
    for name in DYN_HP + DYN_CADENCE:
        if f"hp.{name}" not in dyn_names:
            baked.append((f"hp.{name}", getattr(s0.hp, name)))
    for name in DYN_HET:
        if f"het.{name}" not in dyn_names:
            baked.append((f"het.{name}", getattr(s0.het, name)))
    for name in DYN_SPEC:
        if f"spec.{name}" not in dyn_names:
            baked.append((f"spec.{name}", getattr(s0, name)))
    return tuple(baked)


def build_sweep(group: Sequence[ResolvedScenario], init_params,
                *, loss_fn: Callable = mlp.loss_fn,
                shard: bool = True,
                force_dyn: Sequence[str] = (),
                cadence: Optional[simulator.Cadence] = None
                ) -> SweepProgram:
    """Stack a static-compatible scenario group into one vmapped, jitted
    round program (the ONE jit trace a grid pays).

    ``init_params``: a single parameter pytree shared by every scenario or
    a per-scenario list; sweep state is built from its ravel.

    ``force_dyn`` / ``cadence`` let ``run_scenarios`` pin the batched-field
    set and the scan bounds group-wide, so every ``max_sweep`` chunk of one
    group reuses the identical program (core/program_cache registry hit).
    When the spec opts in (``program_cache=True``, the default) the built
    round/eval programs are memoized under a :class:`ProgramKey` — a
    repeated grid, a later chunk, or a singleton re-run skips tracing.
    """
    specs = [r.spec for r in group]
    s0, cfg = specs[0], group[0].cfg
    S, A, R = len(group), s0.n_agents, s0.n_rsus
    engine = s0.engine
    if engine not in SWEEPABLE:
        raise ValueError(f"engine {engine!r} is not sweepable "
                         f"(want one of {SWEEPABLE})")
    if s0.serve_events:
        raise ValueError("serve-mode scenarios (serve_events > 0) are "
                         "event-driven and cannot be vmapped into a sweep; "
                         "run them through run_scenario")

    params_list = (list(init_params) if isinstance(init_params, (list, tuple))
                   else [init_params] * S)
    if len(params_list) != S:
        raise ValueError(f"init_params list must have one entry per "
                         f"scenario ({S}), got {len(params_list)}")
    fspec = flatten.spec_of(
        params_list[0],
        storage_dtype=flatten.resolve_storage_dtype(s0.fleet_dtype))
    if all(p is params_list[0] for p in params_list):
        vecs = jnp.broadcast_to(fspec.ravel(params_list[0]), (S, fspec.n))
    else:
        vecs = jnp.stack([fspec.ravel(p) for p in params_list])

    # per-scenario draw keys — the exact ``jax.random.key(cfg.seed)`` the
    # sequential engines build, stacked
    seeds = jnp.asarray([r.cfg.seed for r in group], jnp.uint32)
    keys = jax.vmap(jax.random.key)(seeds)

    # data blocks: unbatched (in_axes=None) when the group shares one
    # FederatedData realization, stacked otherwise
    feds = [r.fed for r in group]
    data, data_axes = {}, {}
    for name in ("x", "y", "n_per_agent", "rsu_assign"):
        data[name], data_axes[name] = _stack_or_share(
            [getattr(f, name) for f in feds])
    dyn = _dyn_scalars(specs, force=force_dyn)
    if cadence is None:
        cadence = _cadence_bounds(specs, dyn)

    # fault plans: guard structure (fingerprint) is in static_key, so the
    # group is all-faulted or all-clean with ONE guard config; the
    # schedules themselves become a per-round vmapped data operand
    plan0 = s0.faults
    fault_rounds = None
    if plan0 is not None:
        fault_rounds = _stack_fault_rounds(
            group, cadence.lar if cadence is not None else s0.hp.lar)

    hp0, het0 = s0.hp, s0.het

    def _materialize(dyn_i):
        hp_kw = {k.split(".", 1)[1]: v for k, v in dyn_i.items()
                 if k.startswith("hp.")}
        het_kw = {k.split(".", 1)[1]: v for k, v in dyn_i.items()
                  if k.startswith("het.")}
        hp = dataclasses.replace(hp0, **hp_kw) if hp_kw else hp0
        het = dataclasses.replace(het0, **het_kw) if het_kw else het0
        return hp, het

    # eval axes enter the program key too (shared vs stacked test set is
    # a different eval trace)
    x_t, ax_x = _stack_or_share([r.test.x for r in group])
    y_t, ax_y = _stack_or_share([r.test.y for r in group])
    mesh = sweep_mesh(S) if shard else None

    if engine == "flat":
        def one_round(state, data_i, dyn_i, fault_i=None):
            program_cache.note_trace("sweep_round")
            hp, het = _materialize(dyn_i)
            fed = FederatedData(**data_i)
            body = simulator._make_flat_round_body(
                cfg, hp, het, fed, fspec, loss_fn, fused=s0.fused,
                cadence=cadence, faults=plan0)
            return body(state) if plan0 is None else body(state, fault_i)

        sv = fspec.to_storage(vecs)
        state: Any = simulator.FlatSimState(
            agent_flat=jnp.broadcast_to(sv[:, None, :], (S, A, fspec.n)),
            rsu_flat=jnp.broadcast_to(sv[:, None, :], (S, R, fspec.n)),
            cloud_flat=vecs.astype(jnp.float32),
            conn=ConnState(jnp.zeros((S, A), jnp.int32)),
            rng=keys)
    else:
        acfg = async_config(s0).validate()

        def one_round(state, data_i, dyn_i, fault_i=None):
            program_cache.note_trace("sweep_round")
            hp, het = _materialize(dyn_i)
            a = acfg
            if "spec.cloud_every" in dyn_i:
                a = dataclasses.replace(
                    acfg, cloud_every=dyn_i["spec.cloud_every"])
            fed = FederatedData(**data_i)
            body = async_engine._make_async_round_body(
                cfg, hp, het, fed, fspec, a, loss_fn, fused=s0.fused,
                cadence=cadence, faults=plan0)
            return body(state) if plan0 is None else body(state, fault_i)

        sv = fspec.to_storage(vecs)
        state = async_engine.AsyncSimState(
            agent_flat=jnp.broadcast_to(sv[:, None, :], (S, A, fspec.n)),
            rsu_flat=jnp.broadcast_to(sv[:, None, :], (S, R, fspec.n)),
            rsu_mass=jnp.zeros((S, R), jnp.float32),
            cloud_flat=vecs.astype(jnp.float32),
            pending_x=jnp.zeros((S, A, fspec.n), fspec.storage_dtype),
            pending_w=jnp.zeros((S, A), jnp.float32),
            pending_t=jnp.zeros((S, A), jnp.int32),
            conn=ConnState(jnp.zeros((S, A), jnp.int32)),
            rng=keys,
            cloud_macc=jnp.zeros((S, R), jnp.float32),
            tick=jnp.zeros((S,), jnp.int32))

    def _build_programs():
        axes = ((0, data_axes, 0) if plan0 is None
                else (0, data_axes, 0, 0))
        round_fn = jax.jit(jax.vmap(one_round, in_axes=axes),
                           donate_argnums=(0,))
        # batched eval on the (S, N) cloud master — shared test set when
        # every scenario references the same arrays
        eval_fn = jax.jit(jax.vmap(
            lambda v, x, y: mlp.accuracy(fspec.unravel(v), x, y),
            in_axes=(0, ax_x, ax_y)))
        return round_fn, eval_fn

    if s0.program_cache:
        program_cache.enable_persistent_cache()
    prog_key = program_cache.ProgramKey(
        kind="sweep",
        static_key=group[0].static_key,
        n_scenarios=S,
        dyn_names=tuple(sorted(dyn)),
        baked=(_baked_scalars(s0, dyn), loss_fn),
        cadence=cadence,
        data_axes=(tuple(sorted(data_axes.items(),
                                key=lambda kv: kv[0])), ax_x, ax_y),
        donation=(0,),
        devices=program_cache.device_fingerprint(),
        mesh=program_cache.mesh_fingerprint(mesh),
        flags=program_cache.ops_flags(s0.fused))
    round_fn, eval_fn = program_cache.get_or_build(
        prog_key, _build_programs, enabled=s0.program_cache)
    eval_closed = lambda cloud: eval_fn(cloud, x_t, y_t)    # noqa: E731

    if mesh is not None:
        state = _shard_sweep(state, mesh)
        dyn = _shard_sweep(dyn, mesh)
        # stacked (S, ...) data blocks live sweep-sharded too; shared
        # (in_axes=None) blocks stay replicated
        data = {k: (_shard_sweep(v, mesh) if data_axes[k] == 0 else v)
                for k, v in data.items()}

    return SweepProgram(round_fn=round_fn, state=state, data=data, dyn=dyn,
                        eval_fn=eval_closed, engine=engine, fspec=fspec,
                        n_scenarios=S, fault_rounds=fault_rounds)


def run_sweep(group: Sequence[ResolvedScenario], init_params, *,
              loss_fn: Callable = mlp.loss_fn, shard: bool = True,
              force_dyn: Sequence[str] = (),
              cadence: Optional[simulator.Cadence] = None,
              ) -> List[Dict[str, np.ndarray]]:
    """Run one static-compatible group as a single compiled sweep; returns
    per-scenario histories (same schema as ``run_simulation``'s; async
    scenarios additionally record absorbed/pending mass, faulted ones the
    per-round quarantine counts)."""
    prog = build_sweep(group, init_params, loss_fn=loss_fn, shard=shard,
                       force_dyn=force_dyn, cadence=cadence)
    s0 = group[0].spec
    state = prog.state
    faulted = prog.fault_rounds is not None
    accs, rounds = [], []
    absorbed, pending = [], []
    quar, blocked = [], []
    for r in range(s0.rounds):
        args = (state, prog.data, prog.dyn)
        if faulted:
            # round r's (S, lar, ·) mask slice rides in as vmapped data
            args += ({k: jnp.asarray(v[:, r])
                      for k, v in prog.fault_rounds.items()},)
        if prog.engine == "async":
            state, metrics = prog.round_fn(*args)
            absorbed.append(np.asarray(
                jnp.sum(metrics["absorbed_mass"], axis=(1, 2))))   # (S,)
            pending.append(np.asarray(metrics["pending_mass"]))    # (S,)
            if faulted:
                quar.append(np.asarray(
                    jnp.sum(metrics["quarantined"], axis=1)))      # (S,)
                blocked.append(np.asarray(
                    jnp.sum(metrics["blocked_mass"], axis=1)))
        elif faulted:
            state, metrics = prog.round_fn(*args)
            quar.append(np.asarray(metrics["quarantined"]))        # (S,)
        else:
            state = prog.round_fn(*args)
        if r % s0.eval_every == 0 or r == s0.rounds - 1:
            accs.append(np.asarray(prog.eval_fn(state.cloud_flat)))
            rounds.append(r + 1)
    acc_mat = np.stack(accs, axis=1)                        # (S, T)
    out = []
    for i in range(prog.n_scenarios):
        h = {"round": np.asarray(rounds), "acc": acc_mat[i]}
        if prog.engine == "async":
            h["absorbed_mass"] = np.asarray([a[i] for a in absorbed])
            h["pending_mass"] = np.asarray([p[i] for p in pending])
        if faulted:
            h["quarantined"] = np.asarray([q[i] for q in quar])
            if prog.engine == "async":
                h["blocked_mass"] = np.asarray([b[i] for b in blocked])
        out.append(h)
    return out


def run_scenarios(specs_or_resolved: Sequence, init_params, *,
                  loss_fn: Callable = mlp.loss_fn, shard: bool = True,
                  max_sweep: int = 0) -> List[Dict[str, np.ndarray]]:
    """Run a whole grid: group by ``static_key``, sweep every compatible
    group as one compiled program, fall back to sequential execution for
    non-sweepable engines.  Returns histories in input order.

    Sweepable singleton groups run through the (cached) one-cell sweep
    program rather than the sequential engines, so a lone spec re-run is a
    warm program-cache hit (DESIGN.md §10).

    ``init_params``: one shared pytree, a per-scenario list, or a callable
    ``spec -> pytree`` (e.g. the per-dataset pretrained model).
    ``max_sweep`` > 0 chunks oversized groups (memory bound: the sweep
    state is S× the single-scenario fleet).  A short tail chunk is padded
    to ``max_sweep`` with duplicates of its last cell (results sliced
    off), and the batched-field set + cadence bounds are pinned group-wide,
    so every chunk of a group runs the SAME compiled program.
    """
    resolved = [s.resolve() if isinstance(s, ScenarioSpec) else s
                for s in specs_or_resolved]
    if callable(init_params):
        params_list = [init_params(r.spec) for r in resolved]
    elif isinstance(init_params, (list, tuple)):
        params_list = list(init_params)
    else:
        params_list = [init_params] * len(resolved)
    if len(params_list) != len(resolved):
        raise ValueError("need one init_params per scenario")

    out: List[Optional[Dict[str, np.ndarray]]] = [None] * len(resolved)
    for idx in group_indices(resolved):
        s0 = resolved[idx[0]].spec
        if (s0.engine not in SWEEPABLE or s0.fleet_store != "device"
                or s0.chunk_agents or s0.serve_events):
            for i in idx:
                _, hist = run_scenario(resolved[i], params_list[i],
                                       loss_fn=loss_fn)
                out[i] = hist
            continue
        # pin the batched fields + cadence bounds across the WHOLE group
        # so every max_sweep chunk traces (or registry-hits) one program
        group_specs = [resolved[i].spec for i in idx]
        force_dyn = tuple(sorted(_dyn_scalars(group_specs)))
        cadence = _cadence_bounds(group_specs, force_dyn)
        chunks = ([idx] if not max_sweep else
                  [idx[i:i + max_sweep]
                   for i in range(0, len(idx), max_sweep)])
        for chunk in chunks:
            # pad a short tail chunk to max_sweep with duplicates of its
            # last cell — same program as the full chunks; the duplicate
            # lanes are algebra-neutral (vmap lanes are independent) and
            # their histories are sliced off below
            pad = (max_sweep - len(chunk)
                   if max_sweep and len(idx) > max_sweep else 0)
            cidx = list(chunk) + [chunk[-1]] * pad
            hists = run_sweep([resolved[i] for i in cidx],
                              [params_list[i] for i in cidx],
                              loss_fn=loss_fn, shard=shard,
                              force_dyn=force_dyn, cadence=cadence)
            for i, h in zip(chunk, hists):
                out[i] = h
    return out
