"""C-ITS topology: agents ↔ RSUs ↔ cloud (paper Fig. 1).

The simulator uses a static assignment (agent a → RSU a mod R, matching the
partitioner); ``unbalanced_assignment`` models diverse average traffic flows
(paper Sec. III: "unbalanced agent number at RSUs").
"""
from __future__ import annotations

import numpy as np


def balanced_assignment(n_agents: int, n_rsus: int) -> np.ndarray:
    return (np.arange(n_agents) % n_rsus).astype(np.int32)


def unbalanced_assignment(n_agents: int, n_rsus: int, *, alpha: float = 1.0,
                          seed: int = 0) -> np.ndarray:
    """Dirichlet(alpha) cohort sizes; every RSU keeps >= 1 agent."""
    rng = np.random.default_rng(seed)
    props = rng.dirichlet([alpha] * n_rsus)
    counts = np.maximum(np.round(props * n_agents).astype(int), 1)
    while counts.sum() > n_agents:
        counts[np.argmax(counts)] -= 1
    while counts.sum() < n_agents:
        counts[np.argmin(counts)] += 1
    return np.repeat(np.arange(n_rsus), counts).astype(np.int32)


def cohort_sizes(assign: np.ndarray, n_rsus: int) -> np.ndarray:
    return np.bincount(assign, minlength=n_rsus).astype(np.int32)
