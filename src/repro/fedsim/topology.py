"""Compat shim — the C-ITS topology grew into ``core/topology``
(DESIGN.md §4): ``HierarchyTopology`` now owns the agent→RSU assignment,
the pod ↔ RSU-group block structure, and the engines' PartitionSpecs.
The original assignment helpers keep their import path here.
"""
from repro.core.topology import (HierarchyTopology,  # noqa: F401
                                 balanced_assignment, cohort_sizes,
                                 make_fleet_mesh, unbalanced_assignment)
