"""Centralized (pre-)training — the OEM phase (paper Sec. V) and the
centralized-reference curve used by Fig. 3's MSE metric."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import classification_batches
from repro.data.synthetic import Dataset
from repro.models import mlp


def train_centralized(params, ds: Dataset, *, lr: float = 0.05,
                      batch: int = 32, epochs: int = 1, seed: int = 0,
                      x_test=None, y_test=None,
                      eval_every: int = 50) -> Tuple[dict, Dict[str, np.ndarray]]:
    """Plain SGD over the pooled dataset; returns (params, history)."""

    @jax.jit
    def step(p, xb, yb):
        g = jax.grad(mlp.loss_fn)(p, xb, yb)
        return jax.tree.map(lambda w, gg: w - lr * gg, p, g)

    eval_fn = None
    if x_test is not None:
        x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
        eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_test, y_test))

    accs, steps = [], []
    i = 0
    for xb, yb in classification_batches(ds, batch, seed=seed, epochs=epochs):
        params = step(params, jnp.asarray(xb), jnp.asarray(yb))
        if eval_fn is not None and i % eval_every == 0:
            accs.append(float(eval_fn(params)))
            steps.append(i)
        i += 1
    return params, {"step": np.asarray(steps), "acc": np.asarray(accs)}


def pretrain_to_target(params, pre_ds: Dataset, x_test, y_test,
                       *, target_acc: float = 0.68, lr: float = 0.05,
                       batch: int = 32, max_epochs: int = 30,
                       seed: int = 0) -> Tuple[dict, float]:
    """Train on the label-excluded OEM pool until test acc reaches the
    paper's pre-trained level (~68%) — stops at the first epoch boundary
    past the target so the bias is reproducible."""
    x_test, y_test = jnp.asarray(x_test), jnp.asarray(y_test)
    eval_fn = jax.jit(lambda p: mlp.accuracy(p, x_test, y_test))

    @jax.jit
    def step(p, xb, yb):
        g = jax.grad(mlp.loss_fn)(p, xb, yb)
        return jax.tree.map(lambda w, gg: w - lr * gg, p, g)

    acc = float(eval_fn(params))
    for e in range(max_epochs):
        for xb, yb in classification_batches(pre_ds, batch, seed=seed + e):
            params = step(params, jnp.asarray(xb), jnp.asarray(yb))
        acc = float(eval_fn(params))
        if acc >= target_acc:
            break
    return params, acc
