"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    arch_type="dense",
    source="arXiv:2403.04652",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    mlp_type="swiglu",
    attn_impl="gqa",
    rope_theta=5_000_000.0,
)
