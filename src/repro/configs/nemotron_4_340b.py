"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    source="arXiv:2402.16819",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    mlp_type="squared_relu",
    attn_impl="gqa",
    rope_theta=10_000.0,
)
