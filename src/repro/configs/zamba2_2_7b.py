"""zamba2-2.7b [hybrid] — Mamba2 blocks + weight-shared attention blocks
[arXiv:2411.15242].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000 ssm_state=64.
Layout: 9 super-blocks of (shared attention+MLP block, then 6 Mamba2 blocks);
the attention block weights are shared across all 9 applications (Zamba2's
parameter-sharing design; per-invocation LoRA deltas omitted — noted in
DESIGN.md).
"""
from repro.models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    source="arXiv:2411.15242",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    mlp_type="swiglu",
    attn_impl="gqa",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_dim=4,
                  chunk_size=64),
    layout=(("zamba_super", 9),),
    shared_every=6,
)
