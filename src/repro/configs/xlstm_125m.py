"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H d_ff=0 (blocks carry their own projections) vocab=50304.
Layout: 3 mLSTM blocks then 1 sLSTM block, repeated (9 mLSTM : 3 sLSTM).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    arch_type="ssm",
    source="arXiv:2405.04517",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    attn_impl="none",
    pos_embed="none",
    tie_embeddings=True,
    layout=(("mlstm", 3), ("slstm", 1),
            ("mlstm", 3), ("slstm", 1),
            ("mlstm", 3), ("slstm", 1)),
    # chunkwise-parallel mLSTM (§Perf hillclimb A — exact vs the per-step
    # scan oracle, tests/test_xlstm_chunkwise.py); the reduced smoke config
    # keeps the oracle form via get_reduced_config.
    mlstm_chunk=128,
)
