"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8, head_dim=128) expert d_ff=2048 vocab=163840,
MoE 384 routed experts top-8 + 1 shared expert.
"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    source="arXiv:2501.kimi2",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    attn_impl="gqa",
    moe=MoEConfig(n_experts=384, top_k=8, n_shared=1, expert_d_ff=2048,
                  capacity_factor=1.25, group_size=2048),
)
