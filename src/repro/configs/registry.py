"""Architecture registry: ``--arch <id>`` -> ArchConfig."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ArchConfig, reduced

_MODULES = {
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "xlstm-125m": "xlstm_125m",
    "zamba2-2.7b": "zamba2_2_7b",
    "command-r-35b": "command_r_35b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "yi-34b": "yi_34b",
    "whisper-tiny": "whisper_tiny",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen3-0.6b": "qwen3_0_6b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_reduced_config(arch_id: str, **kw) -> ArchConfig:
    """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
    return reduced(get_config(arch_id), **kw)


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
