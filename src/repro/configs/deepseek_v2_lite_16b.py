"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE [arXiv:2405.04434].

27L d_model=2048 16H, MLA kv_lora=512 (+64 decoupled-RoPE dims), MoE with 64
routed experts top-6 + 2 shared experts, per-expert d_ff=1408, vocab=102400.
"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    source="arXiv:2405.04434",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    attn_impl="mla",
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, q_head_dim=128,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, expert_d_ff=1408,
                  capacity_factor=1.25, group_size=2048),
)
