"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    arch_type="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    mlp_type="swiglu",
    attn_impl="gqa",
    rope_theta=8_000_000.0,
)
