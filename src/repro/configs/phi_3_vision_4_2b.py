"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP vision stub.

[hf:microsoft/Phi-3-vision-128k-instruct]: 32L d_model=3072 32H (GQA kv=32)
d_ff=8192 vocab=32064.  Vision frontend (CLIP ViT-L/14 + projector input) is
a stub per spec: input_specs() provides 576 patch embeddings; the projector
linear and the full language backbone are implemented.
"""
from repro.models.config import ArchConfig, EncoderStub

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    mlp_type="swiglu",
    attn_impl="gqa",
    rope_theta=10_000.0,
    encoder=EncoderStub(kind="vision", n_positions=576, d_embed=1024),
)
