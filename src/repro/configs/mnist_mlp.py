"""The paper's own DNN: ~130 kB MLP trained on an MNIST-class task.

H²-Fed (Sec. VI) federates "a DNN model with a size of 130kB" on MNIST
(10 labels, treated as road-traffic scenario classes).  A 784-40-10 MLP is
31.8k fp32 params = 127 kB — matching the stated size.  Used by fedsim /
examples / paper-figure benchmarks.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class MLPTaskConfig:
    name: str = "mnist-mlp"
    source: str = "H2-Fed Sec. VI (130 kB DNN on MNIST)"
    input_dim: int = 784
    hidden_dims: Tuple[int, ...] = (40,)
    n_classes: int = 10


CONFIG = MLPTaskConfig()
