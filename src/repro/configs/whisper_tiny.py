"""whisper-tiny [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

4L d_model=384 6H d_ff=1536 vocab=51865.  The mel-spectrogram + conv encoder
frontend is a stub per spec: input_specs() provides 1500 encoder frame
embeddings as the cross-attention memory.  The decoder backbone (self-attn +
cross-attn + GELU MLP, learned positions, layernorm, biases) is implemented.
max_seq_len is extended beyond Whisper's 448-token decoder context so that
the assigned decode_32k shape lowers; long_500k is skipped (see DESIGN.md).
"""
from repro.models.config import ArchConfig, EncoderStub

CONFIG = ArchConfig(
    name="whisper-tiny",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    mlp_type="gelu",
    mlp_bias=True,
    attn_impl="gqa",
    attn_bias=True,
    pos_embed="learned",
    norm_type="layernorm",
    tie_embeddings=True,
    max_seq_len=32768,
    layout=(("encdec", 4),),
    encoder=EncoderStub(kind="audio", n_positions=1500, d_embed=384),
)
