"""Production training launcher: H²-Fed hierarchical rounds on a device mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        [--devices 8 --mesh 2,4,1] [--reduced] [--rounds 8] \
        [--lar 4] [--epochs 1] [--csr 0.8] [--quantize-cloud] \
        [--adaptive-mu] [--ckpt-dir results/ckpt] [--seq 128 --batch 4]

Runs the paper's Algorithms 1–3 as one compiled SPMD program per global
round (launch/h2fed_round.py) over synthetic Non-IID LM shards, with
checkpointing and optional adaptive-mu orchestration (core/orchestrator).
On CPU pass --devices to materialize host devices; on a real TPU slice the
flag is unnecessary and --mesh should match the topology.

``--scenario-json spec.json`` instead runs a declarative experiment
scenario (core/scenario.ScenarioSpec, DESIGN.md §7) through the fedsim
engines — any paper-figure cell, engine / partition / heterogeneity chosen
by the spec.
"""
import argparse
import os


def _decay_arg(s: str):
    """float, or comma list -> tuple of per-pod/RSU decay rates."""
    vals = tuple(float(x) for x in s.split(","))
    return vals[0] if len(vals) == 1 else vals


def _parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced config (full configs need a real pod)")
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    ap.add_argument("--devices", type=int, default=8,
                    help="host device count (CPU dry runs)")
    ap.add_argument("--mesh", default="2,4,1",
                    help="pod,data,model mesh shape")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--lar", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=1)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--mu1", type=float, default=0.001)
    ap.add_argument("--mu2", type=float, default=0.005)
    ap.add_argument("--csr", type=float, default=0.8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--quantize-cloud", action="store_true")
    ap.add_argument("--flat-agg", action="store_true",
                    help="flat-buffer aggregation: one fused collective per "
                         "hierarchy layer instead of per-leaf reductions")
    ap.add_argument("--async-rounds", type=int, default=0, metavar="D",
                    help="semi-async rounds with a staleness-bounded "
                         "in-flight buffer: agents deliver up to D local "
                         "ticks late with staleness-decayed weight "
                         "(implies --flat-agg; 0 = synchronous)")
    ap.add_argument("--staleness-decay", type=_decay_arg, default=0.5,
                    metavar="D[,D...]",
                    help="per-tick exponential decay of late deliveries; a "
                         "comma list gives one rate per pod/RSU (per-RSU "
                         "adaptive staleness, DESIGN.md §6)")
    ap.add_argument("--buffer-keep", type=float, default=0.0,
                    help="RSU cohort mass retained across ticks [0, 1]")
    ap.add_argument("--fleet-dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="fleet-buffer / aggregation-reduction dtype "
                         "(DESIGN.md §3 dtype policy): bfloat16 halves "
                         "ICI/DCI collective bytes (requires --flat-agg)")
    ap.add_argument("--adaptive-mu", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario-json", default="", metavar="PATH",
                    help="run a declarative ScenarioSpec (core/scenario, "
                         "DESIGN.md §7) through the fedsim engines instead "
                         "of the LM arch path — any paper-figure cell from "
                         "the CLI")
    ap.add_argument("--scenario-pretrain", action="store_true",
                    help="with --scenario-json: run the spec's OEM "
                         "pretrain stage first (the biased '68%' model) "
                         "instead of a fresh init")
    ap.add_argument("--fleet-store", default="", choices=("", "device",
                                                          "host"),
                    help="with --scenario-json: override the spec's fleet "
                         "row storage (DESIGN.md §8) — 'host' streams the "
                         "(A, N) fleet from host memory in cohort chunks")
    ap.add_argument("--chunk-agents", type=int, default=-1, metavar="C",
                    help="with --scenario-json: override the spec's "
                         "streamed chunk size (agents per device chunk; "
                         "0 = auto)")
    return ap.parse_args()


def _run_scenario_json(args):
    """Run one declarative scenario end to end (engine chosen by the spec:
    flat / tree / sharded / async; sharded uses the visible devices)."""
    from pathlib import Path

    import jax

    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core.scenario import ScenarioSpec
    from repro.fedsim.sweep import run_scenario
    from repro.models import mlp

    spec = ScenarioSpec.from_json(Path(args.scenario_json).read_text())
    if args.fleet_store:
        spec = spec.replace(fleet_store=args.fleet_store)
    if args.chunk_agents >= 0:
        spec = spec.replace(chunk_agents=args.chunk_agents)
    spec.validate()
    res = spec.resolve()
    print(f"[scenario] {args.scenario_json}  cache_key={spec.cache_key}")
    print(f"[scenario] engine={spec.engine} partition={spec.partition} "
          f"A={spec.n_agents} R={spec.n_rsus} rounds={spec.rounds} "
          f"fleet_store={spec.fleet_store} chunk_agents={spec.chunk_agents}")
    params = mlp.init_params(MLP_CFG, jax.random.key(spec.seed))
    if args.scenario_pretrain:
        from repro.fedsim.pretrain import pretrain_to_target
        params, pre_acc = pretrain_to_target(
            params, res.pretrain_pool, res.test.x, res.test.y,
            target_acc=spec.pretrain_target, seed=spec.seed)
        print(f"[pretrain] biased OEM model: test acc {pre_acc:.3f}")
    _, hist = run_scenario(res, params)
    for r, a in zip(hist["round"], hist["acc"]):
        print(f"[round {r:3d}] acc {a:.4f}")
    print("[done]")


def main():
    args = _parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    if args.scenario_json:
        return _run_scenario_json(args)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding

    from repro.checkpoint import ckpt
    from repro.configs.registry import get_config, get_reduced_config
    from repro.core import orchestrator as orch
    from repro.core.h2fed import H2FedParams
    from repro.core.topology import HierarchyTopology
    from repro.data.synthetic import lm_token_task
    from repro.launch import sharding as shard
    from repro.launch.h2fed_round import comm_model, make_h2fed_round
    from repro.models import model as M

    from repro.launch.mesh import make_mesh

    mesh_shape = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_mesh(mesh_shape, ("pod", "data", "model"))
    topo = HierarchyTopology.from_mesh(mesh)
    A = topo.n_agents
    if args.async_rounds and not args.flat_agg:
        print("[async] --async-rounds implies --flat-agg (raveled pending "
              "buffer); enabling it")
        args.flat_agg = True
    if args.fleet_dtype != "float32" and not args.flat_agg:
        print("[dtype] --fleet-dtype implies --flat-agg (storage-dtype "
              "reduction on the raveled buffer); enabling it")
        args.flat_agg = True
    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    if cfg.encoder.kind != "none":
        raise SystemExit("text-only archs for the LM training launcher")

    base_hp = H2FedParams(mu1=args.mu1, mu2=args.mu2, lar=args.lar,
                          local_epochs=args.epochs, lr=args.lr)
    params = M.init_params(cfg, jax.random.key(args.seed))
    n_par = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    cm = comm_model(cfg, base_hp, mesh, quantize_cloud=args.quantize_cloud)
    print(f"[mesh] {dict(mesh.shape)}  agents={A}")
    print(f"[model] {args.arch}{' (reduced)' if args.reduced else ''}: "
          f"{n_par/1e6:.1f}M params")
    print(f"[comm] ici={cm['ici_s']*1e3:.1f}ms dci={cm['dci_s']*1e3:.1f}ms "
          f"per-round (analytical)")

    # Non-IID agent shards: per-agent Markov streams
    streams = [lm_token_task(vocab=min(cfg.vocab_size, 512),
                             n_tokens=args.lar * args.batch * (args.seq + 1)
                             * 4, seed=100 + a) for a in range(A)]
    rng = np.random.default_rng(args.seed)

    mu_state, mu_cfg = orch.init_state(), orch.AdaptiveMuConfig()
    hp = base_hp
    round_fns = {}

    with mesh:
        cloud = jax.device_put(
            params, jax.tree.map(lambda _: shard.replicated(mesh), params))
        ev = {"tokens": jnp.asarray(streams[0][:args.batch * args.seq]
                                    .reshape(args.batch, args.seq)),
              "labels": jnp.asarray(streams[0][1:args.batch * args.seq + 1]
                                    .reshape(args.batch, args.seq))}
        print(f"[init] eval loss {float(M.loss_fn(cfg, cloud, ev)[0]):.4f}")

        for r in range(args.rounds):
            if args.adaptive_mu:
                hp, badness = orch.schedule(mu_state, mu_cfg, base_hp)
            key = (hp.mu1, hp.mu2)
            if key not in round_fns:
                fn = make_h2fed_round(cfg, hp, mesh,
                                      quantize_cloud=args.quantize_cloud,
                                      flat_agg=args.flat_agg,
                                      async_rounds=args.async_rounds,
                                      staleness_decay=args.staleness_decay,
                                      buffer_keep=args.buffer_keep,
                                      fleet_dtype=args.fleet_dtype)
                mask_sh = NamedSharding(mesh, topo.stacked_spec())
                in_sh = (
                    shard.param_shardings_model_only(
                        jax.eval_shape(lambda: params), mesh),
                    {"tokens": NamedSharding(mesh, topo.stacked_spec()),
                     "labels": NamedSharding(mesh, topo.stacked_spec())},
                    mask_sh,
                    NamedSharding(mesh, topo.agent_spec))
                if args.async_rounds:
                    in_sh = in_sh + (mask_sh,)
                round_fns[key] = jax.jit(fn, in_shardings=in_sh)

            n = args.batch * (args.seq + 1)
            toks = np.zeros((args.lar, A, args.batch, args.seq), np.int32)
            labs = np.zeros_like(toks)
            for a in range(A):
                off = (r * args.lar * n) % max(len(streams[a])
                                               - n * args.lar, 1)
                for l in range(args.lar):
                    seg = np.resize(streams[a][off + l * n:
                                               off + (l + 1) * n], n)
                    seg = seg.reshape(args.batch, args.seq + 1)
                    toks[l, a], labs[l, a] = seg[:, :-1], seg[:, 1:]
            mask = (rng.random((args.lar, A)) < args.csr).astype(np.float32)
            n_data = np.full((A,), float(args.batch * args.seq), np.float32)

            round_args = [cloud, {"tokens": jnp.asarray(toks),
                                  "labels": jnp.asarray(labs)},
                          jnp.asarray(mask), jnp.asarray(n_data)]
            if args.async_rounds:
                delays = rng.integers(0, args.async_rounds + 1,
                                      (args.lar, A)).astype(np.int32)
                round_args.append(jnp.asarray(delays))
            cloud, metrics = round_fns[key](*round_args)
            observed = float(mask.mean())
            mu_state = orch.observe_csr(mu_state, mu_cfg, observed, 1.0)
            loss = float(M.loss_fn(cfg, cloud, ev)[0])
            print(f"[round {r+1:3d}] loss {loss:.4f} csr_obs {observed:.2f} "
                  f"mu=({hp.mu1:.4f},{hp.mu2:.4f}) "
                  f"mass {float(metrics['surviving_mass']):.0f}")
            if args.ckpt_dir and (r + 1) % args.ckpt_every == 0:
                path = ckpt.save(args.ckpt_dir, r + 1, cloud)
                print(f"[ckpt] {path}")

    print("[done]")


if __name__ == "__main__":
    main()
