"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input shape) cell on the production
mesh (single-pod 16×16 = 256 chips; multi-pod 2×16×16 = 512 chips), prints
memory/cost analysis, parses collective bytes from the HLO, and persists one
JSON record per cell under ``results/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
      --shape train_4k [--multi-pod] [--step h2fed_round] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full matrix
"""
# The VERY FIRST lines — before ANY other import — so the 512 placeholder
# host devices exist before jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs.registry import ARCH_IDS, get_config        # noqa: E402
from repro.launch import hlo_analysis                          # noqa: E402
from repro.launch import steps as steps_mod                    # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402

# v5e hardware constants for the roofline terms (per chip)
PEAK_FLOPS = 197e12       # bf16 FLOP/s
HBM_BW = 819e9            # bytes/s
LINK_BW = 50e9            # bytes/s per ICI link


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             step_kind: str = "default", overrides: dict | None = None):
    """Lower + compile one cell; returns the record dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = len(jax.devices()) if multi_pod else 256
    cfg = get_config(arch)
    overrides = dict(overrides or {})
    # step-level (non-ArchConfig) knobs for the h2fed_round variants
    qc = bool(overrides.pop("quantize_cloud", False))
    lar = int(overrides.pop("lar", 4))
    if overrides:
        import dataclasses as _dc
        flat = {k: v for k, v in overrides.items() if "." not in k}
        nested: dict = {}
        for k, v in overrides.items():
            if "." in k:
                outer, inner = k.split(".", 1)
                nested.setdefault(outer, {})[inner] = v
        for outer, kv in nested.items():
            flat[outer] = _dc.replace(getattr(cfg, outer), **kv)
        cfg = cfg.replace(**flat)
    t0 = time.time()

    if step_kind == "h2fed_round":
        from repro.core.h2fed import H2FedParams
        from repro.launch.h2fed_round import round_input_specs
        spec = round_input_specs(cfg, shape_name, mesh,
                                 hp=H2FedParams(local_epochs=1, lar=lar),
                                 quantize_cloud=qc)
    else:
        spec = steps_mod.input_specs(cfg, shape_name, mesh)

    with mesh:
        jitted = jax.jit(spec["fn"], in_shardings=spec["in_shardings"])
        lowered = jitted.lower(*spec["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # old-jax: one dict per device
        cost = cost[0]
    hlo = compiled.as_text()
    # trip-count-aware per-device analysis (XLA counts scan bodies once)
    an = hlo_analysis.analyze(hlo)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": step_kind,
        "desc": spec["desc"],
        "n_chips": 512 if multi_pod else 256,
        "adapted_window": spec["cfg"].attn_window,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "xla_cost_raw": {"flops_per_device": float(cost.get("flops", 0.0)),
                         "bytes_per_device":
                             float(cost.get("bytes accessed", 0.0))},
        "cost": {"flops_per_device": an["flops"],
                 "hbm_bytes_per_device": an["bytes"]},
        "collectives_per_device_bytes": an["collectives"],
        "roofline": {
            # per-device work / per-chip rate == global / (chips × rate)
            "compute_s": an["flops"] / PEAK_FLOPS,
            "memory_s": an["bytes"] / HBM_BW,
            "collective_s": an["collective_bytes"] / LINK_BW,
        },
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: rec["roofline"][k])
    rec["roofline"]["dominant"] = dom
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(steps_mod.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--step", default="default",
                    choices=("default", "h2fed_round"))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--override", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="ArchConfig override for §Perf variants, e.g. "
                         "--override mlstm_chunk=128 (repeatable)")
    ap.add_argument("--tag", default="",
                    help="suffix for the result file (perf variants)")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in steps_mod.SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}" \
              + ("" if args.step == "default" else f"__{args.step}") \
              + (f"__{args.tag}" if args.tag else "")
        path = out_dir / f"{tag}.json"
        if path.exists():
            print(f"[skip-cached] {tag}")
            continue
        if (arch, shape) in steps_mod.SKIPS:
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "skipped": steps_mod.SKIPS[(arch, shape)]}
            path.write_text(json.dumps(rec, indent=1))
            print(f"[SKIP] {tag}: {rec['skipped']}")
            continue
        try:
            rec = run_cell(arch, shape, mp, args.step, overrides)
            if overrides:
                rec["overrides"] = overrides
            path.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(f"[ok] {tag}: compile={rec['compile_s']}s "
                  f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
                  f"collective={r['collective_s']:.2e}s dom={r['dominant']} "
                  f"peakMB={(rec['memory']['peak_bytes'] or 0)/1e6:.0f}")
        except Exception as e:  # noqa: BLE001 — record and continue
            failures += 1
            (out_dir / f"{tag}.FAIL.txt").write_text(traceback.format_exc())
            print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:300]}")
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
