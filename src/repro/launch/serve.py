"""Serving launcher: continuous H²-Fed serving loop / KV-cache decode.

Two modes:

  --serve-loop — the continuous-serving subsystem (DESIGN.md §9): run an
      event-driven H²-Fed round loop from a serve-mode ``ScenarioSpec``
      (``--scenario-json``, or a built-in default), with updates arriving
      from the seeded Poisson generator (or a ``serve_trace`` JSONL
      replay) and the fp32 cloud master served to inference probes
      concurrently with ingestion.  Prints the ``ServeLoopStats``
      service-level summary; ``--dump-trace`` writes the realized event
      schedule for bit-exact replay.

        PYTHONPATH=src python -m repro.launch.serve --serve-loop \
            [--scenario-json spec.json] [--events 480] [--dump-trace t.jsonl] \
            [--snapshot-dir d --snapshot-every 64] [--resume d]

      ``--snapshot-dir``/``--snapshot-every`` periodically checkpoint the
      FULL loop state (fleet + queue + stats + rng) through the atomic
      ``checkpoint.ckpt`` store; ``--resume`` restarts from the latest
      snapshot and replays the rest of the trace bit-identically.

  (default) — batched KV-cache decode of a (possibly federated) global
      model checkpoint: prefill into the per-arch cache (GQA ring buffer /
      MLA compressed / SSM state) and greedy-decode a batch of requests —
      the same `serve_step` the decode_32k / long_500k dry-run shapes
      lower.

        PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
            [--ckpt-dir results/ckpt] [--batch 8] [--prompt-len 32] \
            [--gen 32] [--window 0]
"""
import argparse


def _serve_loop(args) -> None:
    import json

    from repro.core.load_gen import (PoissonLoadGen, agent_rates,
                                     write_trace)
    from repro.core.scenario import ScenarioSpec
    from repro.fedsim.serving import run_serve_loop

    if args.scenario_json:
        spec = ScenarioSpec.from_json(
            open(args.scenario_json).read())
        if not spec.serve_events:
            spec = spec.replace(engine="async",
                                serve_events=args.events).validate()
    else:
        spec = ScenarioSpec(
            n_agents=24, n_rsus=4, batch=16, n_train=2400, n_test=400,
            engine="async", staleness_decay=1.0, rounds=2,
            serve_events=args.events, queue_capacity=96).validate()
    res = spec.resolve()

    if args.dump_trace:
        rates = agent_rates(spec.het, spec.n_agents, spec.arrival_rate,
                            seed=res.cfg.seed)
        write_trace(PoissonLoadGen(rates, seed=res.cfg.seed,
                                   n_events=spec.serve_events).events(),
                    args.dump_trace)
        print(f"[trace] {spec.serve_events} events -> {args.dump_trace}")

    state, hist, stats, server = run_serve_loop(
        res, probe_x=res.test.x[:64],
        snapshot_dir=args.snapshot_dir or None,
        snapshot_every=args.snapshot_every,
        resume_from=args.resume or None)
    s = stats.summary()
    print(f"[serve-loop] {spec.n_agents} agents / {spec.n_rsus} RSUs, "
          f"trigger={spec.tick_trigger!r} "
          f"capacity={spec.queue_capacity or 'inf'} "
          f"policy={spec.overload_policy}")
    print(f"[events] generated={s['events_generated']} "
          f"absorbed={s['events_absorbed']} "
          f"coalesced={s['events_coalesced']} "
          f"dropped={s['events_dropped']} "
          f"deferred={s['events_deferred']}")
    print(f"[ticks] {s['n_ticks']} ticks / {s['n_rounds']} rounds | "
          f"{s['updates_per_s']:.0f} upd/s "
          f"p50={s['tick_p50_ms']:.1f}ms p99={s['tick_p99_ms']:.1f}ms | "
          f"queue depth mean={s['queue_depth_mean']:.1f} "
          f"max={s['queue_depth_max']}")
    print(f"[staleness] event wait mean={s['event_wait_mean']:.2f} "
          f"(sim), model staleness mean={s['model_staleness_mean']:.1f} "
          f"ticks | probes={s['serve_requests']} "
          f"p50={s['serve_p50_ms']:.2f}ms")
    if len(hist["acc"]):
        print(f"[acc] cloud accuracy {hist['acc'][0]:.3f} -> "
              f"{hist['acc'][-1]:.3f} over {s['n_rounds']} virtual rounds")
    if args.stats_json:
        with open(args.stats_json, "w") as f:
            json.dump(s, f, indent=1)
        print(f"[json] {args.stats_json}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--serve-loop", action="store_true",
                    help="run the continuous event-driven serving loop "
                         "(DESIGN.md §9) instead of KV-cache decode")
    ap.add_argument("--scenario-json", default="",
                    help="serve-mode ScenarioSpec JSON (serve_events > 0)")
    ap.add_argument("--events", type=int, default=480,
                    help="serve-loop event count when the spec has none")
    ap.add_argument("--dump-trace", default="",
                    help="write the realized Poisson schedule as JSONL "
                         "(replayable via the spec's serve_trace)")
    ap.add_argument("--stats-json", default="",
                    help="write the ServeLoopStats summary JSON here")
    ap.add_argument("--snapshot-dir", default="",
                    help="serve-loop crash-resume snapshot directory "
                         "(atomic ckpt of the full loop state)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot the serve loop every N ticks "
                         "(0 = only the final/interrupt snapshot)")
    ap.add_argument("--resume", default="",
                    help="resume a serve loop from this snapshot dir "
                         "(bit-identical continuation of the trace)")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window attention (0 = full causal)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.serve_loop:
        _serve_loop(args)
        return

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import ckpt
    from repro.configs.registry import get_config, get_reduced_config
    from repro.models import model as M

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    if args.window:
        cfg = cfg.replace(attn_window=args.window)
    if cfg.encoder.kind == "vision":
        raise SystemExit("text decode launcher; VLM needs the image path")

    params = M.init_params(cfg, jax.random.key(args.seed))
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        params = ckpt.restore(args.ckpt_dir, like=params)
        print(f"[ckpt] restored step {ckpt.latest_step(args.ckpt_dir)}")

    rng = np.random.default_rng(args.seed)
    B, Sp = args.batch, args.prompt_len
    max_len = Sp + args.gen
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Sp)), jnp.int32)
    memory = None
    if cfg.encoder.kind == "audio":
        memory = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder.n_positions, cfg.encoder.d_embed)), jnp.float32)

    cache = M.init_cache(cfg, B, max_len)
    decode = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos,
                                                        memory=memory))
    t0 = time.perf_counter()
    logits = None
    for t in range(Sp):
        logits, cache = decode(params, cache, prompts[:, t:t + 1],
                               jnp.full((B,), t, jnp.int32))
    t_pre = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = []
    t0 = time.perf_counter()
    for t in range(Sp, max_len):
        outs.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, cache, tok,
                               jnp.full((B,), t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_dec = time.perf_counter() - t0
    gen = np.stack(outs, axis=1)

    print(f"[arch] {args.arch}{' (reduced)' if args.reduced else ''} "
          f"batch={B} cache={max_len}"
          + (f" window={args.window}" if args.window else ""))
    print(f"[prefill] {Sp} tok in {t_pre:.2f}s | "
          f"[decode] {args.gen} tok in {t_dec:.2f}s "
          f"({B * args.gen / max(t_dec, 1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  req {b}: {np.asarray(prompts[b])[:6]}... -> "
              f"{gen[b][:10]}...")
    assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
