"""Serving launcher: batched KV-cache decode of the federated global model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        [--ckpt-dir results/ckpt] [--batch 8] [--prompt-len 32] [--gen 32] \
        [--window 0]

Loads the latest H²-Fed cloud checkpoint if given (else fresh init),
prefills the prompts into the per-arch cache (GQA ring buffer / MLA
compressed / SSM state) and greedy-decodes a batch of requests — the same
`serve_step` the decode_32k / long_500k dry-run shapes lower.
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full-config", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=0,
                    help="sliding-window attention (0 = full causal)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import ckpt
    from repro.configs.registry import get_config, get_reduced_config
    from repro.models import model as M

    cfg = (get_reduced_config if args.reduced else get_config)(args.arch)
    if args.window:
        cfg = cfg.replace(attn_window=args.window)
    if cfg.encoder.kind == "vision":
        raise SystemExit("text decode launcher; VLM needs the image path")

    params = M.init_params(cfg, jax.random.key(args.seed))
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        params = ckpt.restore(args.ckpt_dir, like=params)
        print(f"[ckpt] restored step {ckpt.latest_step(args.ckpt_dir)}")

    rng = np.random.default_rng(args.seed)
    B, Sp = args.batch, args.prompt_len
    max_len = Sp + args.gen
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Sp)), jnp.int32)
    memory = None
    if cfg.encoder.kind == "audio":
        memory = jnp.asarray(rng.standard_normal(
            (B, cfg.encoder.n_positions, cfg.encoder.d_embed)), jnp.float32)

    cache = M.init_cache(cfg, B, max_len)
    decode = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos,
                                                        memory=memory))
    t0 = time.perf_counter()
    logits = None
    for t in range(Sp):
        logits, cache = decode(params, cache, prompts[:, t:t + 1],
                               jnp.full((B,), t, jnp.int32))
    t_pre = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = []
    t0 = time.perf_counter()
    for t in range(Sp, max_len):
        outs.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, cache, tok,
                               jnp.full((B,), t, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_dec = time.perf_counter() - t0
    gen = np.stack(outs, axis=1)

    print(f"[arch] {args.arch}{' (reduced)' if args.reduced else ''} "
          f"batch={B} cache={max_len}"
          + (f" window={args.window}" if args.window else ""))
    print(f"[prefill] {Sp} tok in {t_pre:.2f}s | "
          f"[decode] {args.gen} tok in {t_dec:.2f}s "
          f"({B * args.gen / max(t_dec, 1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  req {b}: {np.asarray(prompts[b])[:6]}... -> "
              f"{gen[b][:10]}...")
    assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
