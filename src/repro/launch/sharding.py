"""Sharding rules: param / batch / cache PartitionSpecs for the production
mesh.

Params use a generic 2D (FSDP x TP) rule over the trailing matrix dims, with
an explicit expert-parallel rule for MoE expert tensors (E -> `model`).
Params are NOT sharded over `pod`: each pod (RSU in the DESIGN.md mapping)
holds a full sharded replica, and cross-pod reduction is the cloud layer.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

PyTree = Any

_EXPERT_NAMES = ("w_gate", "w_up", "w_down")


def _divisible_dims(shape, size, taken):
    return [i for i, d in enumerate(shape)
            if i not in taken and d % size == 0 and d >= size]


def param_spec(path: str, shape, mesh) -> P:
    """Generic FSDP('data') x TP('model') spec for one parameter leaf."""
    ndim = len(shape)
    data, model = mesh.shape.get("data", 1), mesh.shape.get("model", 1)
    spec = [None] * ndim

    # MoE routed-expert tensors: (..., E, a, b) -> E over `model` (expert
    # parallelism), larger of (a, b) over `data`.
    if (any(n in path for n in _EXPERT_NAMES) and "shared" not in path
            and ndim >= 3 and "router" not in path):
        e_dim = ndim - 3
        if shape[e_dim] % model == 0:
            spec[e_dim] = "model"
            a, b = ndim - 2, ndim - 1
            pick = a if shape[a] >= shape[b] else b
            other = b if pick == a else a
            if shape[pick] % data == 0:
                spec[pick] = "data"
            elif shape[other] % data == 0:
                spec[other] = "data"
            return P(*spec)
        # fall through to generic rule if E not divisible (reduced configs)

    if ndim == 0:
        return P()
    # generic: consider only the trailing two dims (the matrix); leading dims
    # are layer stacks / expert axes handled above.
    cand = [ndim - 1] if ndim == 1 else [ndim - 2, ndim - 1]
    cand = sorted(cand, key=lambda i: -shape[i])
    taken: set = set()
    # largest divisible dim -> model
    for i in cand:
        if shape[i] % model == 0 and shape[i] >= model:
            spec[i] = "model"
            taken.add(i)
            break
    for i in cand:
        if i not in taken and shape[i] % data == 0 and shape[i] >= data:
            spec[i] = "data"
            taken.add(i)
            break
    return P(*spec)


def param_shardings(params_shapes: PyTree, mesh,
                    strategy: str = "fsdp_tp") -> PyTree:
    """NamedSharding pytree matching a params (shape) pytree."""
    if strategy == "dp":
        return jax.tree.map(lambda _: NamedSharding(mesh, P()), params_shapes)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(p) for p in path)
        out.append(NamedSharding(mesh, param_spec(pstr, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def act_spec_dp(shape, mesh) -> P:
    """Pure-DP activation spec: leading agent dim over (pod, data), second
    (per-agent batch) dim over `model` — every chip holds distinct data."""
    ba = batch_axes(mesh)
    from math import prod
    bsz = prod(mesh.shape[a] for a in ba)
    model = mesh.shape.get("model", 1)
    spec = [None] * len(shape)
    if shape and shape[0] % bsz == 0 and shape[0] >= bsz:
        spec[0] = ba if len(ba) > 1 else ba[0]
    if len(shape) > 1 and shape[1] % model == 0 and shape[1] >= model:
        spec[1] = "model"
    return P(*spec)


def param_spec_model_only(path: str, shape, mesh) -> P:
    """TP('model')-only spec — used by the h2fed_round shard_map program
    where (pod, data) are manual agent axes and each agent materializes its
    own replica as a loop temporary."""
    ndim = len(shape)
    model = mesh.shape.get("model", 1)
    spec = [None] * ndim
    if ndim == 0:
        return P()
    if (any(n in path for n in _EXPERT_NAMES) and "shared" not in path
            and ndim >= 3 and "router" not in path
            and shape[ndim - 3] % model == 0):
        spec[ndim - 3] = "model"                    # expert-parallel
        return P(*spec)
    cand = [ndim - 1] if ndim == 1 else [ndim - 2, ndim - 1]
    for i in sorted(cand, key=lambda i: -shape[i]):
        if shape[i] % model == 0 and shape[i] >= model:
            spec[i] = "model"
            break
    return P(*spec)


def param_shardings_model_only(params_shapes: PyTree, mesh) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in flat:
        pstr = "/".join(str(p) for p in path)
        out.append(NamedSharding(mesh,
                                 param_spec_model_only(pstr, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(ndim: int, mesh) -> P:
    """Leading dim = agents/batch over (pod, data); rest replicated."""
    return P(batch_axes(mesh), *([None] * (ndim - 1)))


def act_spec(shape, mesh) -> P:
    """Batch-sharded activation spec; replicates when dim0 isn't divisible
    (e.g. the batch=1 long-context decode)."""
    from math import prod
    ba = batch_axes(mesh)
    bsz = prod(mesh.shape[a] for a in ba)
    if shape and shape[0] % bsz == 0 and shape[0] >= bsz:
        return P(ba if len(ba) > 1 else ba[0], *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def cache_spec(shape, mesh) -> P:
    """Decode-cache leaf: batch dim over (pod,data) when divisible; then the
    largest remaining dim over `model`; for batch=1 (long-context) also place
    `data` on the longest remaining dim."""
    ndim = len(shape)
    spec = [None] * ndim
    if ndim == 0:
        return P()
    ba = batch_axes(mesh)
    from math import prod
    bsz = prod(mesh.shape[a] for a in ba)
    used_data = False
    if shape[0] % bsz == 0 and shape[0] >= bsz:
        spec[0] = ba if len(ba) > 1 else ba[0]
        used_data = True
    model = mesh.shape.get("model", 1)
    rest = sorted(range(1, ndim), key=lambda i: -shape[i])
    for i in rest:
        if shape[i] % model == 0 and shape[i] >= model:
            spec[i] = "model"
            rest = [j for j in rest if j != i]
            break
    if not used_data:
        data = mesh.shape.get("data", 1)
        for i in rest:
            if spec[i] is None and shape[i] % data == 0 and shape[i] >= data:
                spec[i] = "data"
                break
    return P(*spec)


def cache_shardings(cache_shapes: PyTree, mesh) -> PyTree:
    return jax.tree.map(
        lambda l: NamedSharding(mesh, cache_spec(l.shape, mesh)), cache_shapes)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
