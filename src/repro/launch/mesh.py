"""Production mesh definitions.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.  The dry-run driver sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
smoke tests and benches see the real single CPU device.

Topology mapping (DESIGN.md §2): `pod` = RSU/cloud layer (cross-pod DCI),
`data` = traffic agents within an RSU (ICI), `model` = tensor parallel.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    to cover prod(shape) devices)."""
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def agent_axes(mesh) -> tuple:
    """Mesh axes along which federated agents are laid out."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_agents(mesh) -> int:
    from math import prod
    return prod(mesh.shape[a] for a in agent_axes(mesh))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
