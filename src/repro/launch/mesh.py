"""Production mesh definitions.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state.  The dry-run driver sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
smoke tests and benches see the real single CPU device.

Topology mapping (DESIGN.md §2): `pod` = RSU/cloud layer (cross-pod DCI),
`data` = traffic agents within an RSU (ICI), `model` = tensor parallel.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    ``axis_types`` (explicit Auto axes) only exists on newer jax; on the
    0.4.x line every mesh axis is Auto already, so omitting it is exact.
    """
    shape, axes = tuple(shape), tuple(axes)
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map(f, mesh, in_specs, out_specs, axis_names, check: bool = False):
    """``jax.shard_map`` across jax versions.

    Newer jax takes ``axis_names`` (manual axes) + ``check_vma``; the 0.4.x
    ``jax.experimental.shard_map`` expresses the same contract as
    ``auto`` (the complement set) + ``check_rep``.
    """
    axis_names = frozenset(axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - axis_names
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    to cover prod(shape) devices)."""
    return make_mesh(shape, axes)


def agent_axes(mesh) -> tuple:
    """Mesh axes along which federated agents are laid out."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_agents(mesh) -> int:
    from math import prod
    return prod(mesh.shape[a] for a in agent_axes(mesh))


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
