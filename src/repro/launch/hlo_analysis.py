"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE — but our
layer stacks are ``lax.scan``-ed, so a 96-layer model would be undercounted
96x (verified empirically; see tests/test_hlo_analysis.py).  This module
re-derives FLOPs / HBM bytes / collective bytes from the post-optimization
HLO text with while-loop trip counts multiplied through the call graph:

  cost(computation) = Σ own-op costs
                    + Σ_while  trip · (cost(body) + cost(cond))
                    + Σ_fusion cost(called fused computation)   [flops only]
                    + Σ_call   cost(callee)

Shapes in SPMD HLO are per-partition, so all results are per-device.
FLOPs: dot ops (2·prod(out)·K from lhs contracting dims).  Bytes: operand +
output bytes of every materializing op (the CPU/TPU HLO is already fused, so
elementwise chains are inside fusions and counted once at the fusion
boundary).  Collectives: output bytes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, split per kind.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(r"^((?:\([^)]*\))|(?:[a-z][a-z0-9]*\[[0-9,]*\]\S*))\s+"
                    r"([\w\-]+)\(")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# ops that don't materialize traffic (pure bookkeeping / aliasing)
_FREE_OPS = {"bitcast", "get-tuple-element", "tuple", "parameter", "constant",
             "after-all", "partition-id", "replica-id", "iota", "bitcast-convert"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class OpLine:
    name: str
    out_type: str
    op: str
    rest: str          # full rhs after the op name's open paren
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpLine]
    shapes: Dict[str, str]        # value name -> type string
    root: Optional[str] = None    # ROOT value name


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry = cur.name
                # record parameter shapes from the signature
                for pm in re.finditer(r"%?([\w\.\-]+):\s*([a-z][a-z0-9]*\["
                                      r"[0-9,]*\][^,)]*|\([^)]*\))",
                                      m.group(2)):
                    cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(s)
        if not dm:
            continue
        name, rhs = dm.groups()
        if s.startswith("ROOT"):
            cur.root = name
        om = _OP_RE.match(rhs)
        if om:
            out_type, op = om.groups()
            paren = rhs[om.end():]
            operands = re.findall(r"%([\w\.\-]+)", paren.split(")")[0])
            cur.shapes[name] = out_type
            cur.ops.append(OpLine(name, out_type, op, rhs, operands))
        else:
            # e.g. `%x = s32[] parameter(0)` handled above; constants w/o parens
            parts = rhs.split(" ", 2)
            if len(parts) >= 2:
                cur.shapes[name] = parts[0]
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """lax.scan conditions compare the counter to the trip bound; take the
    largest integer constant in the (tiny) condition computation."""
    best = 1
    for op in cond.ops:
        for c in _CONST_RE.findall(op.rest):
            best = max(best, int(c))
    # also catch constants recorded in shapes-only lines
    return best


def _dot_flops(op: OpLine, shapes: Dict[str, str]) -> float:
    out = _shape_dims(op.out_type)
    if out is None:
        return 0.0
    _, out_dims = out
    k = 1
    cm = _CONTRACT_RE.search(op.rest)
    if cm and op.operands:
        lhs_type = shapes.get(op.operands[0], "")
        lhs = _shape_dims(lhs_type)
        if lhs:
            _, lhs_dims = lhs
            for d in cm.group(1).split(","):
                if d and int(d) < len(lhs_dims):
                    k *= lhs_dims[int(d)]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * k


_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _root_write_bytes(callee: Computation) -> float:
    """Bytes WRITTEN by a fusion: normally the root output, but a
    dynamic-update-slice root is in-place on TPU — only the update slice is
    written (the rest of the buffer is aliased, not copied).  Tuple roots
    resolve element-wise."""
    by_name = {ol.name: ol for ol in callee.ops}

    def resolve(name: str, depth: int = 0) -> float:
        ol = by_name.get(name)
        if ol is None or depth > 8:
            return float(_shape_bytes(callee.shapes.get(name, "")))
        if ol.op == "dynamic-update-slice" and len(ol.operands) > 1:
            upd = callee.shapes.get(ol.operands[1], "")
            return float(_shape_bytes(upd))
        if ol.op == "tuple":
            return sum(resolve(o, depth + 1) for o in ol.operands)
        if ol.op in ("bitcast", "get-tuple-element", "copy"):
            if ol.operands:
                return resolve(ol.operands[0], depth + 1)
        return float(_shape_bytes(ol.out_type))

    if callee.root is not None:
        return resolve(callee.root)
    return float(_shape_bytes(callee.ops[-1].out_type)) if callee.ops else 0.0


def _fusion_bytes(callee: Optional[Computation], caller: Computation,
                  op: OpLine) -> float:
    """HBM traffic of one fusion: write the root output (in-place DUS roots
    write only the update slice); read each parameter in full UNLESS it is
    only consumed by slice/gather ops inside (then read just the slices —
    exactly how a scan body reads its stacked weights) or is the aliased
    buffer of a root dynamic-update-slice (no read at all)."""
    if callee is None:
        total = float(_shape_bytes(op.out_type))
        for o in op.operands:
            total += _shape_bytes(caller.shapes.get(o, ""))
        return total
    total = _root_write_bytes(callee)
    # map parameter index -> consumers
    param_names = {}
    for ol in callee.ops:
        if ol.op == "parameter":
            m = re.match(r"\s*(\d+)", ol.rest.split("parameter(")[-1])
            if m:
                param_names[ol.name] = int(m.group(1))
    consumers: Dict[str, List[OpLine]] = {n: [] for n in param_names}
    for ol in callee.ops:
        for o in ol.operands:
            if o in consumers:
                consumers[o].append(ol)
    for pname, idx in param_names.items():
        cons = consumers.get(pname, [])
        if cons and all(c.op in _SLICE_OPS for c in cons):
            total += sum(_shape_bytes(c.out_type) for c in cons)
        elif cons and all(c.op == "dynamic-update-slice"
                          and c.operands and c.operands[0] == pname
                          for c in cons):
            # aliased in-place buffer: not read, only (slice-)written
            continue
        else:
            if idx < len(op.operands):
                total += _shape_bytes(caller.shapes.get(op.operands[idx], ""))
            else:
                total += _shape_bytes(callee.shapes.get(pname, ""))
    return total


def analyze(text: str) -> Dict[str, float]:
    """Per-device totals with while-loop trip counts applied."""
    comps, entry = parse_module(text)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].ops)) if comps else None
        if entry is None:
            return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                    "collectives": {}}

    memo: Dict[str, Dict[str, float]] = {}

    def cost(cname: str) -> Dict[str, float]:
        if cname in memo:
            return memo[cname]
        comp = comps.get(cname)
        res = {"flops": 0.0, "bytes": 0.0}
        res.update({f"coll_{k}": 0.0 for k in COLLECTIVE_KINDS})
        if comp is None:
            memo[cname] = res
            return res
        memo[cname] = res  # guard cycles
        for op in comp.ops:
            base = op.op.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVE_KINDS:
                if op.op.endswith("-done"):
                    continue  # counted at -start
                res[f"coll_{base}"] += _shape_bytes(op.out_type)
                res["bytes"] += _shape_bytes(op.out_type)
                continue
            if op.op == "while":
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                trips = 1
                if cond and cond.group(1) in comps:
                    trips = _trip_count(comps[cond.group(1)])
                if body:
                    sub = cost(body.group(1))
                    for k, v in sub.items():
                        res[k] += trips * v
                continue
            if op.op in ("call", "conditional", "async-start"):
                for cm in _CALLS_RE.finditer(op.rest):
                    sub = cost(cm.group(1))
                    for k, v in sub.items():
                        res[k] += v
                # conditional: true/false computations
                for cm in re.finditer(r"(?:true|false|branch\w*)_computation="
                                      r"%?([\w\.\-]+)", op.rest):
                    sub = cost(cm.group(1))
                    for k, v in sub.items():
                        res[k] += v
            if op.op == "fusion":
                fm = _CALLS_RE.search(op.rest)
                if fm:
                    sub = cost(fm.group(1))
                    res["flops"] += sub["flops"]
                    res["bytes"] += _fusion_bytes(comps.get(fm.group(1)),
                                                  comp, op)
                else:
                    res["bytes"] += _shape_bytes(op.out_type)
                continue
            if op.op == "dot":
                res["flops"] += _dot_flops(op, comp.shapes)
            if op.op == "convolution":
                # rough: 2 * out elements * (filter elements / out channels)
                res["flops"] += 2.0 * _shape_bytes(op.out_type)
            if op.op in ("while", "call", "conditional"):
                continue  # traffic counted inside the callee
            if op.op in ("dynamic-slice", "gather", "slice"):
                # HBM read is the slice, not the full operand
                res["bytes"] += 2 * _shape_bytes(op.out_type)
                continue
            if op.op == "dynamic-update-slice":
                upd = (comp.shapes.get(op.operands[1], "")
                       if len(op.operands) > 1 else "")
                res["bytes"] += 2 * _shape_bytes(upd)
                continue
            if op.op not in _FREE_OPS:
                nbytes = _shape_bytes(op.out_type)
                for o in op.operands:
                    t = comp.shapes.get(o)
                    if t:
                        nbytes += _shape_bytes(t)
                res["bytes"] += nbytes
        memo[cname] = res
        return res

    total = cost(entry)
    colls = {k: total[f"coll_{k}"] for k in COLLECTIVE_KINDS
             if total[f"coll_{k}"] > 0}
    return {"flops": total["flops"], "bytes": total["bytes"],
            "collective_bytes": sum(colls.values()), "collectives": colls}


def round_cost(fn, *args, latency_s: Optional[float] = None
               ) -> Dict[str, float]:
    """Per-round bytes-moved estimate of one compiled round program.

    Lowers + compiles ``fn(*args)`` (``fn`` may already be jitted; args are
    only traced, never executed — donation-safe) and runs ``analyze`` on
    the post-optimization HLO, so while-loop trip counts (the LAR scan,
    the training step scan) are multiplied through and fusion boundaries
    are respected: the returned ``bytes`` is the program's per-device HBM
    traffic for ONE round.  Keys: ``flops``, ``bytes``,
    ``collective_bytes``, ``collectives``, plus — when ``latency_s`` is
    given — ``hbm_gbps``, the achieved HBM bandwidth the benchmarks record
    next to round latency (benchmarks/topology_round, async_round).
    """
    import jax
    jfn = fn if hasattr(fn, "lower") else jax.jit(fn)
    res = analyze(jfn.lower(*args).compile().as_text())
    if latency_s is not None:
        res["hbm_gbps"] = res["bytes"] / max(latency_s, 1e-12) / 1e9
    return res


def memory_footprint(fn, *args) -> Dict[str, float]:
    """Compiled device-memory footprint of ``fn(*args)`` — the allocation
    check behind the streamed engines' bounded-working-set claim
    (DESIGN.md §8): the peak live bytes of ONE chunk step must be
    O(chunk·N + R·N), independent of the fleet size A.

    Lowers + compiles (args traced, never executed — donation-safe) and
    reads the compiler's ``memory_analysis()``.  Keys (0.0 where a backend
    doesn't report a statistic): ``argument_bytes``, ``output_bytes``,
    ``temp_bytes``, ``alias_bytes``, ``generated_code_bytes`` and
    ``total_bytes`` — arguments + outputs + temporaries − aliased
    (donated) pairs, the peak resident set the program needs beyond code.
    """
    import jax
    jfn = fn if hasattr(fn, "lower") else jax.jit(fn)
    mem = jfn.lower(*args).compile().memory_analysis()
    stats = {
        "argument_bytes": "argument_size_in_bytes",
        "output_bytes": "output_size_in_bytes",
        "temp_bytes": "temp_size_in_bytes",
        "alias_bytes": "alias_size_in_bytes",
        "generated_code_bytes": "generated_code_size_in_bytes",
    }
    out = {k: float(getattr(mem, attr, 0) or 0)
           for k, attr in stats.items()}
    out["total_bytes"] = (out["argument_bytes"] + out["output_bytes"]
                          + out["temp_bytes"] - out["alias_bytes"])
    return out


def stream_round_cost(chunk_fn, *args, n_chunks: int, lar: int = 1,
                      h2d_bytes_per_chunk: float = 0.0,
                      d2h_bytes_per_chunk: float = 0.0,
                      latency_s: Optional[float] = None) -> Dict[str, float]:
    """Per-round cost model of a cohort-streamed round (DESIGN.md §8):
    ``round_cost`` of ONE compiled chunk step scaled by the
    ``n_chunks × lar`` executions a global round dispatches, plus the
    host↔device transfer bytes the chunk pipeline moves (which ``analyze``
    cannot see — they happen outside the compiled program).  Also reports
    the chunk step's ``memory_footprint`` under ``peak_*`` keys: the
    device working set the streamed round is bounded by.
    """
    per_chunk = round_cost(chunk_fn, *args)
    n_exec = float(n_chunks * lar)
    res = {
        "flops": per_chunk["flops"] * n_exec,
        "bytes": per_chunk["bytes"] * n_exec,
        "collective_bytes": per_chunk["collective_bytes"] * n_exec,
        "collectives": per_chunk["collectives"],
        "transfer_bytes": (h2d_bytes_per_chunk + d2h_bytes_per_chunk)
        * n_exec,
        "n_chunks": float(n_chunks),
    }
    for k, v in memory_footprint(chunk_fn, *args).items():
        res[f"peak_{k}"] = v
    if latency_s is not None:
        res["hbm_gbps"] = res["bytes"] / max(latency_s, 1e-12) / 1e9
    return res


_RG_LIST_RE = re.compile(r"replica_groups=\{((?:\{[0-9,\s]*\},?\s*)*)\}")
_RG_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")


def _parse_replica_groups(rest: str) -> Optional[List[List[int]]]:
    """Replica groups of one collective op line.

    Handles both HLO spellings: the explicit list form
    ``replica_groups={{0,1},{2,3}}`` and the iota form
    ``replica_groups=[G,S]<=[dims](T(perm))`` (flattened transposed iota
    reshaped to (G, S)).  Returns None when no groups are spelled out
    (= one group of all devices).
    """
    m = _RG_IOTA_RE.search(rest)
    if m:
        import numpy as np
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        v = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            v = np.transpose(v, [int(d) for d in m.group(4).split(",")])
        return v.reshape(g, s).tolist()
    m = _RG_LIST_RE.search(rest)
    if m:
        groups = [[int(x) for x in grp.split(",") if x.strip()]
                  for grp in re.findall(r"\{([0-9,\s]*)\}", m.group(1))]
        return [g for g in groups if g] or None
    return None


def collective_schedule(text: str) -> List[Dict]:
    """Every collective reachable from the entry computation:
    ``[{kind, bytes, groups, in_loop}]``.

    ``in_loop`` marks collectives reached through a while body/cond — i.e.
    executed inside a compiled ``lax.scan`` (for the hierarchical rounds:
    the LAR loop, the RSU aggregation step).  Paired with
    ``groups_within`` this pins the topology-first communication contract
    (DESIGN.md §4): an RSU-sharded round must show NO cross-pod groups
    in-loop — only the cloud layer's out-of-loop reduction crosses pods.
    """
    comps, entry = parse_module(text)
    out: List[Dict] = []
    seen = set()

    def walk(cname: str, in_loop: bool):
        if cname not in comps or (cname, in_loop) in seen:
            return
        seen.add((cname, in_loop))
        for op in comps[cname].ops:
            base = op.op.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVE_KINDS and not op.op.endswith("-done"):
                out.append({"kind": base,
                            "bytes": float(_shape_bytes(op.out_type)),
                            "groups": _parse_replica_groups(op.rest),
                            "in_loop": in_loop})
            if op.op == "while":
                for m in (_BODY_RE.search(op.rest),
                          _COND_RE.search(op.rest)):
                    if m:
                        walk(m.group(1), True)
                continue
            for cm in _CALLS_RE.finditer(op.rest):
                walk(cm.group(1), in_loop)
            # cadence-gated collectives live inside conditional branches
            for cm in re.finditer(r"(?:true|false|branch\w*)_computation="
                                  r"%?([\w\.\-]+)", op.rest):
                walk(cm.group(1), in_loop)

    if entry is not None:
        walk(entry, False)
    return out


def groups_within(groups: Optional[List[List[int]]],
                  partition: List[List[int]]) -> bool:
    """True iff every replica group stays inside ONE cell of ``partition``
    (e.g. partition = the per-pod device-id sets: a within-pod collective).
    ``groups=None`` means one group of all devices — within only if the
    partition has a single cell.
    """
    cells = [set(c) for c in partition]
    if groups is None:
        return len(cells) <= 1
    for g in groups:
        owners = {i for i, c in enumerate(cells) if c & set(g)}
        if len(owners) > 1:
            return False
    return True


def collective_axes(groups: Optional[List[List[int]]],
                    mesh_axes: List[Tuple[str, int]]) -> List[str]:
    """Which mesh axes a collective's replica groups SPAN.

    ``mesh_axes`` is the ordered (name, size) mesh spec — callers pass
    ``list(zip(mesh.axis_names, mesh.devices.shape))``; device ids in SPMD
    replica groups are row-major over that shape (how our meshes are
    built: launch/mesh make_mesh / mesh_utils in device order).  An axis
    is spanned when some group holds two devices with different
    coordinates on it — the devices the collective moves bytes BETWEEN
    differ along that axis.  ``groups=None`` (one group of all devices)
    spans every non-trivial axis.
    """
    import numpy as np
    sizes = [s for _, s in mesh_axes]
    if groups is None:
        return [name for name, s in mesh_axes if s > 1]
    coords = {}
    for g in groups:
        for d in g:
            if d not in coords:
                coords[d] = np.unravel_index(d, sizes)
    spanned = []
    for i, (name, _) in enumerate(mesh_axes):
        if any(len({coords[d][i] for d in g}) > 1 for g in groups):
            spanned.append(name)
    return spanned


def collective_axis_bytes(text: str, mesh_axes: List[Tuple[str, int]]
                          ) -> Dict:
    """``collective_schedule`` with every entry attributed to the mesh
    axes it spans, plus a per-axis bytes rollup — the DCI-vs-ICI split of
    a round program on a (pod, data, model) mesh: bytes spanning ``pod``
    travel the cross-pod DCI links, ``data``/``model`` bytes stay on
    intra-pod ICI (ROADMAP TPU-validation item; DESIGN.md §12).

    Returns ``{"entries": [...schedule + "axes" key...],
    "per_axis": {axis: bytes}}``.  A collective spanning several axes is
    charged to EACH (it rides every link class it crosses), so per-axis
    numbers are link-class loads, not a partition of total bytes.
    """
    entries = []
    per_axis = {name: 0.0 for name, _ in mesh_axes}
    for e in collective_schedule(text):
        axes = collective_axes(e["groups"], mesh_axes)
        for a in axes:
            per_axis[a] += e["bytes"]
        entries.append({**e, "axes": axes})
    return {"entries": entries, "per_axis": per_axis}


_ALIAS_PAIR_RE = re.compile(r"\{([0-9 ,]*)\}:\s*\((\d+)")


def donated_params(text: str) -> List[int]:
    """Entry-parameter numbers aliased to outputs in post-optimization HLO.

    Buffer donation (``jit(..., donate_argnums=...)``) that XLA actually
    honored shows up as the module-level ``input_output_alias`` table —
    ``{out_index}: (param_number, {param_index}, ...)`` pairs.  Returns the
    sorted set of donated parameter numbers (empty: nothing aliased, i.e.
    the update is NOT in-place).  Used by the dry-run flow and
    tests/test_async.py to verify the FlatSimState donation is a no-copy
    round.
    """
    start = text.find("input_output_alias=")
    if start < 0:
        return []
    # brace-match the alias table (it contains nested {out_index} groups)
    i = text.find("{", start)
    depth, j = 0, i
    while j < len(text):
        if text[j] == "{":
            depth += 1
        elif text[j] == "}":
            depth -= 1
            if depth == 0:
                break
        j += 1
    table = text[i + 1:j]
    return sorted({int(m.group(2))
                   for m in _ALIAS_PAIR_RE.finditer(table)})


def param_shapes(text: str) -> Dict[int, str]:
    """Entry-computation parameter number -> type string (donation checks
    pair this with ``donated_params`` to name which buffers went in-place).
    """
    comps, entry = parse_module(text)
    out: Dict[int, str] = {}
    if entry is None:
        return out
    for op in comps[entry].ops:
        if op.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.rest)
            if m:
                out[int(m.group(1))] = op.out_type
    return out


def breakdown(text: str, top: int = 20) -> List[Tuple[str, float, float]]:
    """Per-top-level-op attribution of (bytes, flops) in the entry
    computation, trip counts applied — the §Perf 'profile'.  Returns
    [(label, bytes, flops)] sorted by bytes."""
    comps, entry = parse_module(text)
    if entry is None:
        return []
    an_memo: Dict[str, Dict[str, float]] = {}

    def comp_cost(cname: str) -> Dict[str, float]:
        if cname not in an_memo:
            sub_text_rows = []
            an_memo[cname] = _cost_of(comps, cname, an_memo)
        return an_memo[cname]

    rows = []
    ent = comps[entry]
    for op in ent.ops:
        b = f = 0.0
        label = f"{op.op} {op.name} {op.out_type[:40]}"
        if op.op == "while":
            bm = _BODY_RE.search(op.rest)
            cm = _COND_RE.search(op.rest)
            trips = (_trip_count(comps[cm.group(1)])
                     if cm and cm.group(1) in comps else 1)
            if bm:
                sub = comp_cost(bm.group(1))
                b, f = trips * sub["bytes"], trips * sub["flops"]
            label = f"while×{trips} {op.name} body={bm.group(1) if bm else '?'}"
        elif op.op == "fusion":
            fm = _CALLS_RE.search(op.rest)
            callee = comps.get(fm.group(1)) if fm else None
            b = _fusion_bytes(callee, ent, op)
            f = comp_cost(fm.group(1))["flops"] if fm else 0.0
        elif op.op == "dot":
            f = _dot_flops(op, ent.shapes)
            b = _shape_bytes(op.out_type)
        elif op.op.removesuffix("-start") in COLLECTIVE_KINDS:
            b = _shape_bytes(op.out_type)
            label = f"COLL {label}"
        elif op.op not in _FREE_OPS and op.op not in (
                "call", "conditional"):
            b = _shape_bytes(op.out_type)
            for o in op.operands:
                t = ent.shapes.get(o)
                if t:
                    b += _shape_bytes(t)
        rows.append((label, b, f))
    rows.sort(key=lambda r: -r[1])
    return rows[:top]


def _cost_of(comps, cname, memo):
    """Recursive (bytes, flops) of one computation — shared with analyze()'s
    inner cost(); kept separate to avoid closure plumbing."""
    if cname in memo:
        return memo[cname]
    comp = comps.get(cname)
    res = {"flops": 0.0, "bytes": 0.0}
    if comp is None:
        memo[cname] = res
        return res
    memo[cname] = res
    for op in comp.ops:
        base = op.op.removesuffix("-start").removesuffix("-done")
        if base in COLLECTIVE_KINDS:
            if not op.op.endswith("-done"):
                res["bytes"] += _shape_bytes(op.out_type)
            continue
        if op.op == "while":
            bm = _BODY_RE.search(op.rest)
            cm = _COND_RE.search(op.rest)
            trips = (_trip_count(comps[cm.group(1)])
                     if cm and cm.group(1) in comps else 1)
            if bm:
                sub = _cost_of(comps, bm.group(1), memo)
                res["bytes"] += trips * sub["bytes"]
                res["flops"] += trips * sub["flops"]
            continue
        if op.op in ("call", "conditional", "async-start"):
            for cm2 in _CALLS_RE.finditer(op.rest):
                sub = _cost_of(comps, cm2.group(1), memo)
                res["bytes"] += sub["bytes"]
                res["flops"] += sub["flops"]
            continue
        if op.op == "fusion":
            fm = _CALLS_RE.search(op.rest)
            if fm:
                sub = _cost_of(comps, fm.group(1), memo)
                res["flops"] += sub["flops"]
                res["bytes"] += _fusion_bytes(comps.get(fm.group(1)),
                                              comp, op)
            else:
                res["bytes"] += _shape_bytes(op.out_type)
            continue
        if op.op == "dot":
            res["flops"] += _dot_flops(op, comp.shapes)
        if op.op in ("dynamic-slice", "gather", "slice"):
            res["bytes"] += 2 * _shape_bytes(op.out_type)
            continue
        if op.op == "dynamic-update-slice":
            upd = (comp.shapes.get(op.operands[1], "")
                   if len(op.operands) > 1 else "")
            res["bytes"] += 2 * _shape_bytes(upd)
            continue
        if op.op not in _FREE_OPS:
            nbytes = _shape_bytes(op.out_type)
            for o in op.operands:
                t = comp.shapes.get(o)
                if t:
                    nbytes += _shape_bytes(t)
            res["bytes"] += nbytes
    return res
