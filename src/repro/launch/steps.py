"""Distributed step builders + input specs for every (arch × input shape).

`fsdp_fed` train step (pjit / GSPMD): params, momentum and both H²-Fed
proximal anchors sharded (FSDP×TP); the batch carries a leading agent axis
laid out over the (pod, data) mesh axes; the loss is the CSR-masked,
weighted per-agent objective with the dual proximal pull applied in the
fused optimizer update (closed form — no autodiff through the penalty).

`serve_step`: single-token decode against a KV/state cache.

All inputs are produced as ShapeDtypeStructs by ``input_specs`` — the
dry-run lowers and compiles without allocating anything.
"""
from __future__ import annotations

import functools
from math import prod
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.h2fed import H2FedParams
from repro.launch import sharding as shard
from repro.launch.mesh import agent_axes, n_agents
from repro.models import model as M
from repro.models.config import ArchConfig

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# sliding window applied to full-attention archs for the long-context shape
LONG_CONTEXT_WINDOW = 8192

# whisper-tiny long_500k: documented skip (DESIGN.md §Shape-coverage)
SKIPS = {("whisper-tiny", "long_500k"): "enc-dec ASR with 448-token decoder "
         "context; 524k-token decode is not a meaningful configuration"}


def shape_adapted_config(cfg: ArchConfig, shape_name: str) -> ArchConfig:
    """Adapt the arch to the input shape: long_500k forces sub-quadratic
    attention (sliding window) on archs with full attention."""
    if shape_name == "long_500k" and cfg.attn_impl != "none" \
            and cfg.attn_window == 0:
        cfg = cfg.replace(attn_window=LONG_CONTEXT_WINDOW)
    return cfg


# --------------------------------------------------------------------------
# train step (fsdp_fed)
# --------------------------------------------------------------------------

class TrainState(NamedTuple):
    params: Any
    momentum: Any
    anchor_rsu: Any      # w_k  (layer-1 proximal anchor)
    anchor_cloud: Any    # w    (layer-2 proximal anchor)


def init_train_state(cfg: ArchConfig, key) -> TrainState:
    params = M.init_params(cfg, key)
    zeros = jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32), params)
    return TrainState(params=params, momentum=zeros,
                      anchor_rsu=params, anchor_cloud=params)


def make_train_step(cfg: ArchConfig, hp: H2FedParams, beta: float = 0.9):
    aux_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0

    def train_step(state: TrainState, batch: Dict[str, Any], mask):
        """batch leaves: (A, b, ...); mask: (A,) float connectivity."""
        A, b = batch["tokens"].shape[:2]

        def task_loss(p):
            flat = {k: v.reshape((-1,) + v.shape[2:]) for k, v in batch.items()}
            nll, aux = M.per_example_loss(cfg, p, flat)      # (A*b,)
            per_agent = nll.reshape(A, b).mean(axis=1)       # (A,)
            mf = mask.astype(jnp.float32)
            loss = jnp.sum(per_agent * mf) / jnp.maximum(jnp.sum(mf), 1.0)
            return loss + aux_w * aux, (loss, aux)

        grads, (loss, aux) = jax.grad(task_loss, has_aux=True)(state.params)

        def upd(w, m, g, a1, a2):
            wf = w.astype(jnp.float32)
            gf = (g.astype(jnp.float32)
                  + hp.mu1 * (wf - a1.astype(jnp.float32))
                  + hp.mu2 * (wf - a2.astype(jnp.float32)))
            m_new = beta * m + gf
            w_new = (wf - hp.lr * m_new).astype(w.dtype)
            return w_new, m_new

        flat_p, treedef = jax.tree_util.tree_flatten(state.params)
        flat_m = treedef.flatten_up_to(state.momentum)
        flat_g = treedef.flatten_up_to(grads)
        flat_a1 = treedef.flatten_up_to(state.anchor_rsu)
        flat_a2 = treedef.flatten_up_to(state.anchor_cloud)
        new_p, new_m = zip(*[upd(*t) for t in
                             zip(flat_p, flat_m, flat_g, flat_a1, flat_a2)])
        new_state = TrainState(
            params=jax.tree_util.tree_unflatten(treedef, new_p),
            momentum=jax.tree_util.tree_unflatten(treedef, new_m),
            anchor_rsu=state.anchor_rsu, anchor_cloud=state.anchor_cloud)
        return new_state, {"loss": loss, "aux": aux}

    return train_step


# --------------------------------------------------------------------------
# prefill / serve steps
# --------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        logits, _ = M.forward(cfg, params, batch)
        # inference-prefill emits the last-position logits (next-token)
        return logits[:, -1, :]
    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, tokens, cur_pos, memory=None):
        logits, new_cache = M.decode_step(cfg, params, cache, tokens, cur_pos,
                                          memory=memory)
        return logits[:, -1, :], new_cache
    return serve_step


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStructs + shardings)
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _extra_model_inputs(cfg: ArchConfig, lead: Tuple[int, ...]):
    """VLM patch embeddings / audio encoder memory, with leading dims."""
    extras, f32 = {}, jnp.float32
    if cfg.encoder.kind == "vision":
        extras["patch_embeds"] = _sds(
            lead + (cfg.encoder.n_positions, cfg.encoder.d_embed), f32)
    if cfg.encoder.kind == "audio":
        extras["memory"] = _sds(
            lead + (cfg.encoder.n_positions, cfg.encoder.d_embed), f32)
    return extras


def input_specs(cfg: ArchConfig, shape_name: str, mesh,
                hp: Optional[H2FedParams] = None):
    """Build (fn, args, in_shardings) for one (arch × shape × mesh) cell.

    Returns a dict: {fn, args (tuple of SDS pytrees), in_shardings,
    static description}.  ``fn`` is un-jitted; the dry-run driver wraps it
    with jax.jit(fn, in_shardings=...) and lowers with the SDS args.
    """
    cfg = shape_adapted_config(cfg, shape_name)
    info = SHAPES[shape_name]
    seq, batch = info["seq"], info["batch"]
    hp = hp or H2FedParams()
    i32 = jnp.int32

    params_shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.key(0)))
    p_shard = shard.param_shardings(params_shapes, mesh,
                                    strategy=cfg.shard_strategy)
    repl = shard.replicated(mesh)
    _act_spec = (shard.act_spec_dp if cfg.shard_strategy == "dp"
                 else shard.act_spec)

    if info["kind"] == "train":
        A = n_agents(mesh)
        b = batch // A
        assert b >= 1, f"{shape_name}: global batch {batch} < {A} agents"
        state = TrainState(
            params=params_shapes,
            momentum=jax.tree.map(lambda l: _sds(l.shape, jnp.float32),
                                  params_shapes),
            anchor_rsu=params_shapes, anchor_cloud=params_shapes)
        state_shard = TrainState(
            params=p_shard,
            momentum=p_shard, anchor_rsu=p_shard, anchor_cloud=p_shard)
        batch_tree = {"tokens": _sds((A, b, seq), i32),
                      "labels": _sds((A, b, seq), i32)}
        batch_tree.update(_extra_model_inputs(cfg, (A, b)))
        bspec = {k: NamedSharding(mesh, _act_spec(v.shape, mesh))
                 for k, v in batch_tree.items()}
        mask = _sds((A,), jnp.float32)
        return dict(fn=make_train_step(cfg, hp),
                    args=(state, batch_tree, mask),
                    in_shardings=(state_shard, bspec, repl),
                    cfg=cfg, desc=f"train A={A} b={b} S={seq}")

    if info["kind"] == "prefill":
        batch_tree = {"tokens": _sds((batch, seq), i32)}
        batch_tree.update(_extra_model_inputs(cfg, (batch,)))
        bspec = {k: NamedSharding(mesh, shard.act_spec(v.shape, mesh))
                 for k, v in batch_tree.items()}
        return dict(fn=make_prefill_step(cfg),
                    args=(params_shapes, batch_tree),
                    in_shardings=(p_shard, bspec),
                    cfg=cfg, desc=f"prefill B={batch} S={seq}")

    # decode
    cache_len = seq
    cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, batch, cache_len))
    c_shard = shard.cache_shardings(cache_shapes, mesh)
    tokens = _sds((batch, 1), i32)
    cur_pos = _sds((batch,), i32)
    extras = _extra_model_inputs(cfg, (batch,))
    memory = extras.get("memory")
    mem_shard = (NamedSharding(mesh, shard.act_spec(memory.shape, mesh))
                 if memory is not None else None)
    tok_shard = NamedSharding(mesh, shard.act_spec(tokens.shape, mesh))
    pos_shard = NamedSharding(mesh, shard.act_spec(cur_pos.shape, mesh))
    # VLM decode: image context lives in the prefilled KV cache; no patch
    # embeddings are consumed at decode time.
    return dict(fn=make_serve_step(cfg),
                args=(params_shapes, cache_shapes, tokens, cur_pos, memory),
                in_shardings=(p_shard, c_shard, tok_shard, pos_shard,
                              mem_shard),
                cfg=cfg, desc=f"decode B={batch} T={cache_len}"
                              + (f" win={cfg.attn_window}" if cfg.attn_window
                                 else ""))
