"""The paper-faithful H²-Fed hierarchical round as ONE compiled SPMD program.

Topology mapping (DESIGN.md §2): mesh axis `data` = traffic agents within an
RSU, `pod` = RSUs under the traffic cloud, `model` = tensor parallel (auto /
GSPMD).  ``jax.shard_map`` is manual over ('pod', 'data') and auto over
'model', so every (pod, data) position is one *agent* running Algorithm 1,
``psum`` over 'data' is the RSU aggregation (Algorithm 2, fast ICI) and
``psum`` over 'pod' is the cloud aggregation (Algorithm 3, slow DCI).

Program structure (per global round):

    w_k := w                                   # Alg.2 l.2 (anchor refresh)
    for r in range(LAR):                       # lax.scan, Alg.2 l.1
        w_ik := w_k                            # Alg.1 l.1
        for e in range(E):                     # lax.scan, Alg.1 l.3
            w_ik -= lr(∇F_ik(w_ik) + mu1(w_ik − w_k) + mu2(w_ik − w))
        w_k := Σ_data m·n·w_ik / Σ_data m·n    # psum('data'),  Alg.2 l.8
    w := Σ_pod mass_k·w_k / Σ_pod mass_k       # psum('pod'),   Alg.3 l.6

Communication profile: LAR within-pod reductions (cheap) per ONE cross-pod
reduction (expensive) — the paper's communication-avoidance insight, visible
directly in the dry-run's collective schedule.

The cross-pod reduction supports optional int8 quantization with per-leaf
scales (beyond-paper §Perf lever): the cloud average is a convex combination,
so quantizing the *delta from the round-start anchor* keeps the error bounded
and zero-mean; EXPERIMENTS.md §Perf quantifies the collective-term win.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import flatten
from repro.core.aggregation import staleness_weights
from repro.core.h2fed import H2FedParams
from repro.core.topology import HierarchyTopology
from repro.launch import sharding as shard
from repro.launch.mesh import shard_map
from repro.models import model as M
from repro.models.config import ArchConfig

PyTree = Any


def _wmean_over(axis: str, tree: PyTree, weight, old: PyTree) -> PyTree:
    """Masked weighted mean over a manual mesh axis; keeps ``old`` where the
    surviving mass is zero.  weight: scalar per shard."""
    mass = jax.lax.psum(weight, axis)
    safe = jnp.where(mass > 0, mass, 1.0)

    def agg(leaf, o):
        s = jax.lax.psum(leaf.astype(jnp.float32) * weight, axis)
        return jnp.where(mass > 0, s / safe, o.astype(jnp.float32)) \
            .astype(leaf.dtype)

    return jax.tree.map(agg, tree, old), mass


def _wmean_over_flat(axis: str, tree: PyTree, weight, old: PyTree, *,
                     storage=jnp.float32) -> PyTree:
    """``_wmean_over`` on the raveled (N,) buffer (DESIGN.md §3): ONE psum
    of one contiguous vector per aggregation layer instead of an
    O(leaves) collective schedule.  Semantics identical to the per-leaf
    path under the fp32 default.

    ``storage`` is the fleet dtype (``--fleet-dtype``): the weighted
    contribution is cast to it before the psum — bf16 halves the ICI/DCI
    bytes of both hierarchy reductions at a documented looser tolerance;
    normalization happens in fp32 after the reduction either way.

    Model-axis-replicated fleets only: raveling a tensor-parallel-sharded
    tree would force an all-gather over `model` before the psum, inflating
    the collective volume by the TP degree — ``make_h2fed_round`` rejects
    that combination up front."""
    spec = flatten.spec_of(tree)
    vec = spec.ravel(tree)
    mass = jax.lax.psum(weight, axis)
    safe = jnp.where(mass > 0, mass, 1.0)
    s = jax.lax.psum((vec * weight).astype(storage),
                     axis).astype(jnp.float32)
    out = jnp.where(mass > 0, s / safe, spec.ravel(old))
    return spec.unravel(out), mass


def _quantized_pod_mean(tree: PyTree, anchor: PyTree, weight, old: PyTree,
                        mass_ok) -> PyTree:
    """int8-quantized cross-pod weighted mean of (tree − anchor) + anchor.

    Each leaf's delta is scaled to int8 range by its per-pod absmax; the
    absmax and the weighted delta are reduced together.  Bytes on the `pod`
    axis drop ~4x (fp32 path) / ~2x (bf16) at <0.4% relative error.
    """
    w_norm = weight / jnp.where(mass_ok > 0, mass_ok, 1.0)

    def agg(leaf, a, o):
        delta = leaf.astype(jnp.float32) - a.astype(jnp.float32)
        absmax = jnp.max(jnp.abs(delta))
        absmax = jax.lax.pmax(absmax, "pod")
        scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        q = jnp.clip(jnp.round(delta / scale), -127, 127).astype(jnp.int8)
        # weighted sum of int8 deltas in int32 is exact for <=2^15 pods;
        # weights are folded in fp32 after the integer reduction.
        deq = q.astype(jnp.float32) * (scale * w_norm)
        s = jax.lax.psum(deq, "pod")
        out = a.astype(jnp.float32) + s
        return jnp.where(mass_ok > 0, out, o.astype(jnp.float32)) \
            .astype(leaf.dtype)

    return jax.tree.map(agg, tree, anchor, old)


def make_h2fed_round(cfg: ArchConfig, hp: H2FedParams, mesh,
                     *, quantize_cloud: bool = False,
                     flat_agg: bool = False,
                     microbatch: int = 0,
                     async_rounds: int = 0,
                     staleness_decay: float = 0.5,
                     buffer_keep: float = 0.0,
                     fleet_dtype: str = "float32"):
    """Build the hierarchical round function (to be jit'd by the caller).

    flat_agg=True runs both aggregation layers on the raveled parameter
    buffer (one fused collective each — the flat-buffer engine's formulation
    threaded into the SPMD program); incompatible with quantize_cloud,
    which keeps its own per-leaf scale handling.

    fleet_dtype ("float32" | "bfloat16", ``--fleet-dtype``) is the
    DESIGN.md §3 dtype-policy knob for the SPMD path: the raveled
    aggregation contributions are reduced in this dtype (halving ICI/DCI
    collective bytes at bf16; fp32 accumulation of the normalization stays
    exact).  Requires flat_agg when not fp32.

    async_rounds=D > 0 runs the semi-async tick model (DESIGN.md §6) inside
    the SPMD program: each agent keeps a staleness-bounded (one-slot, delay
    <= D) in-flight buffer of its raveled update, deliveries are
    staleness-decayed (``core.aggregation.staleness_weights``) and the RSU
    psum absorbs them with running cohort-mass accounting (buffer_keep).
    Requires flat_agg (the pending buffer is the raveled (N,) vector) and
    takes one extra input, ``delays`` — with all delays zero and
    buffer_keep=0 the program is the synchronous flat_agg round exactly.

    ``staleness_decay`` may be a per-pod sequence (one RSU per pod in the
    SPMD mapping — the per-RSU adaptive schedule of DESIGN.md §6); a scalar
    keeps the uniform decay.

    The mesh's agent-axis bookkeeping (pod axis, batch specs) comes from
    ``core.topology.HierarchyTopology.from_mesh`` — the same object the
    fedsim engines shard with (DESIGN.md §4).

    Inputs (global view):
      cloud_params — model-sharded, replicated over (pod, data)
      batch        — leaves (LAR, A, b, ...) with A over ('pod','data')
      mask         — (LAR, A) float connectivity (CSR/SCD/FSR realization)
      n_data       — (A,) float per-agent data volume n_{i,k}
      delays       — (LAR, A) int arrival latency (async_rounds > 0 only)
    Output: (new cloud_params, metrics)
    """
    topo = HierarchyTopology.from_mesh(mesh)
    pod = topo.pod_axis
    if isinstance(staleness_decay, (tuple, list)):
        if len(staleness_decay) != topo.n_pods:
            raise ValueError(
                f"per-RSU staleness_decay needs one entry per pod "
                f"({topo.n_pods}), got {len(staleness_decay)}")
        decay_vec = jnp.asarray(staleness_decay, jnp.float32)
    else:
        decay_vec = None
    if flat_agg and quantize_cloud:
        raise ValueError(
            "flat_agg composes with the exact cloud reduction only")
    if flat_agg and mesh.shape.get("model", 1) > 1:
        raise ValueError(
            "flat_agg requires model-axis size 1: raveling tensor-parallel-"
            "sharded params would all-gather over `model` before the psum "
            "(use the per-leaf path on TP meshes)")
    if async_rounds and not flat_agg:
        raise ValueError(
            "async_rounds requires flat_agg: the staleness-bounded in-flight "
            "buffer lives on the raveled (N,) vector")
    storage = flatten.resolve_storage_dtype(fleet_dtype)
    if storage != jnp.dtype(jnp.float32) and not flat_agg:
        raise ValueError(
            "fleet_dtype != float32 requires flat_agg: the storage-dtype "
            "reduction runs on the raveled buffer")
    wmean = (functools.partial(_wmean_over_flat, storage=storage)
             if flat_agg else _wmean_over)
    aux_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0

    def agent_loss(w, local_batch):
        loss, _ = M.loss_fn(cfg, w, local_batch)
        return loss

    grad_fn = jax.grad(lambda w, b: agent_loss(w, b))

    def local_epochs(w_k, w_cloud, local_batch):
        """Alg. 1: E proximal-SGD epochs from w_k on this agent's batch."""

        def epoch(w_ik, _):
            g = grad_fn(w_ik, local_batch)

            def upd(wl, gl, a1, a2):
                wf = wl.astype(jnp.float32)
                step = (gl.astype(jnp.float32)
                        + hp.mu1 * (wf - a1.astype(jnp.float32))
                        + hp.mu2 * (wf - a2.astype(jnp.float32)))
                return (wf - hp.lr * step).astype(wl.dtype)

            return jax.tree.map(upd, w_ik, g, w_k, w_cloud), None

        w_ik, _ = jax.lax.scan(epoch, w_k, None, length=hp.local_epochs)
        return w_ik

    def round_fn(cloud_params, batch, mask, n_data):
        # shard-local views: leading agent axis is size 1 on each shard
        local_batch_all = jax.tree.map(
            lambda l: l.reshape((l.shape[0],) + l.shape[2:]), batch)
        my_n = n_data.reshape(())                      # scalar n_{i,k}
        my_mask = mask.reshape((mask.shape[0],))       # (LAR,)

        def lar_round(carry, inp):
            w_k, mass_acc = carry
            local_batch, m = inp
            w_ik = local_epochs(w_k, cloud_params, local_batch)
            weight = my_n * m                          # CSR-masked volume
            w_k, mass = wmean("data", w_ik, weight, w_k)
            return (w_k, mass_acc + mass), mass

        (w_k, mass_total), masses = jax.lax.scan(
            lar_round, (cloud_params, jnp.zeros((), jnp.float32)),
            (local_batch_all, my_mask))

        # Alg. 3: cloud aggregation over the pod (RSU) axis
        if pod is None:
            new_cloud, _ = (w_k, None)                 # single-pod: RSU==cloud
            pod_mass = mass_total
        else:
            pod_mass = jax.lax.psum(mass_total, pod)
            if quantize_cloud:
                new_cloud = _quantized_pod_mean(
                    w_k, cloud_params, mass_total, cloud_params, pod_mass)
            else:
                new_cloud, _ = wmean(pod, w_k, mass_total, cloud_params)

        metrics = {"surviving_mass": pod_mass,
                   "lar_masses": masses}
        return new_cloud, metrics

    def async_round_fn(cloud_params, batch, mask, n_data, delays):
        """Semi-async tick body (DESIGN.md §6) — per shard = one agent.

        The agent keeps a one-slot staleness-bounded in-flight buffer of its
        raveled update (pend_x/pend_w/pend_t); while it is in flight the
        agent is busy and contributes nothing new.  Each tick the RSU psum
        absorbs the zero-latency cohort plus due stragglers (decayed at
        enqueue) with running cohort-mass accounting — the same algebra the
        fedsim async engine runs on (A, N) buffers.
        """
        spec = flatten.spec_of(cloud_params)
        local_batch_all = jax.tree.map(
            lambda l: l.reshape((l.shape[0],) + l.shape[2:]), batch)
        my_n = n_data.reshape(())
        my_mask = mask.reshape((mask.shape[0],))
        my_delay = jnp.clip(delays.reshape((delays.shape[0],)),
                            0, async_rounds)
        cloud_vec = spec.ravel(cloud_params)
        # per-RSU (== per-pod here) adaptive decay: this shard's rate
        my_decay = (decay_vec[jax.lax.axis_index(pod)]
                    if decay_vec is not None and pod is not None
                    else (decay_vec[0] if decay_vec is not None
                          else staleness_decay))

        def tick(carry, inp):
            w_k_vec, rsu_mass, pend_x, pend_w, pend_t, mass_acc = carry
            local_batch, m, d = inp
            in_flight = pend_t > 0
            pend_t = jnp.maximum(pend_t - 1, 0)
            due = in_flight & (pend_t == 0)
            free = ~(in_flight & ~due)                 # not still busy

            w_ik = local_epochs(spec.unravel(w_k_vec), cloud_params,
                                local_batch)
            x_new = spec.ravel(w_ik)

            freef = free.astype(jnp.float32)
            w_imm = my_n * m * freef * (d == 0).astype(jnp.float32)
            w_due = jnp.where(due, pend_w, 0.0)
            # fleet-dtype reduction (bf16 halves the per-tick ICI bytes;
            # fp32 default is the exact psum, a no-op cast)
            num = jax.lax.psum(
                (w_imm * x_new + w_due * pend_x).astype(storage),
                "data").astype(jnp.float32)
            m_new = jax.lax.psum(w_imm + w_due, "data")

            retained = buffer_keep * rsu_mass
            total = retained + m_new
            safe = jnp.where(total > 0, total, 1.0)
            w_k_vec = jnp.where(total > 0,
                                (retained * w_k_vec + num) / safe,
                                w_k_vec)
            # per-tick leaf-dtype round-trip: the sync flat path unravels
            # w_k after every aggregation (bf16 leaves quantize there), so
            # the zero-delay limit must too to stay bit-identical
            w_k_vec = spec.ravel(spec.unravel(w_k_vec))

            enq = (m > 0) & free & (d > 0)
            pend_x = jnp.where(enq, x_new, pend_x)
            pend_w = jnp.where(
                enq, my_n * m * staleness_weights(d, decay=my_decay),
                pend_w)
            pend_t = jnp.where(enq, d, pend_t)
            return (w_k_vec, total, pend_x, pend_w, pend_t,
                    mass_acc + m_new), m_new

        zf = jnp.zeros((), jnp.float32)
        init = (cloud_vec, zf, jnp.zeros_like(cloud_vec), zf,
                jnp.zeros((), jnp.int32), zf)
        (w_k_vec, _, _, _, _, mass_total), masses = jax.lax.scan(
            tick, init, (local_batch_all, my_mask, my_delay))

        # cloud layer on the raveled buffer, weighted by absorbed mass
        if pod is None:
            new_vec, pod_mass = w_k_vec, mass_total
        else:
            pod_mass = jax.lax.psum(mass_total, pod)
            safe = jnp.where(pod_mass > 0, pod_mass, 1.0)
            s = jax.lax.psum(w_k_vec * mass_total, pod)
            new_vec = jnp.where(pod_mass > 0, s / safe, cloud_vec)
        metrics = {"surviving_mass": pod_mass, "lar_masses": masses}
        return spec.unravel(new_vec), metrics

    axis_names = set(topo.agent_axes)

    # manual-axes specs: params replicated over (pod,data); batch split on A
    p_rep = P()                                        # model axis stays auto
    batch_spec = topo.stacked_spec()
    mask_spec = topo.stacked_spec()
    n_spec = topo.agent_spec
    out_mass = P()

    if async_rounds:
        return shard_map(
            async_round_fn, mesh,
            in_specs=(p_rep, batch_spec, mask_spec, n_spec, mask_spec),
            out_specs=(p_rep, {"surviving_mass": out_mass,
                               "lar_masses": P(None)}),
            axis_names=axis_names)
    smapped = shard_map(
        round_fn, mesh,
        in_specs=(p_rep, batch_spec, mask_spec, n_spec),
        out_specs=(p_rep, {"surviving_mass": out_mass,
                           "lar_masses": P(None)}),
        axis_names=axis_names)
    return smapped


def comm_model(cfg: ArchConfig, hp: H2FedParams, mesh,
               *, quantize_cloud: bool = False,
               ici_bw: float = 50e9, dci_bw: float = 6.25e9) -> Dict[str, float]:
    """Analytical ICI/DCI communication model for one hierarchical round.

    The flat 50 GB/s roofline hides the paper's insight: within-pod (RSU)
    aggregation rides ICI, the cross-pod (cloud) reduction rides the much
    slower inter-pod DCI (~1/8 ICI per chip).  This model is exact for the
    round's program structure:

      ICI bytes/device = LAR · 2(A−1)/A · P_dev      (ring all-reduce, Alg.2)
      DCI bytes/device = 2(K−1)/K · P_dev · q        (cloud psum, Alg.3)

    with P_dev the per-device parameter bytes (fp32 aggregation),
    A agents/pod (data axis), K pods, q = 0.25 for int8 quantization.
    """
    from math import prod
    n_par = cfg.n_params()
    model_ways = mesh.shape.get("model", 1)
    p_dev = n_par * 4 / model_ways                  # fp32 aggregation
    A = mesh.shape.get("data", 1)
    K = mesh.shape.get("pod", 1)
    ici = hp.lar * 2 * (A - 1) / A * p_dev
    q = 0.25 if quantize_cloud else 1.0
    dci = (2 * (K - 1) / K * p_dev * q) if K > 1 else 0.0
    return {
        "ici_bytes_per_dev": ici,
        "dci_bytes_per_dev": dci,
        "ici_s": ici / ici_bw,
        "dci_s": dci / dci_bw,
        "per_local_round_s": (ici / ici_bw + dci / dci_bw) / hp.lar,
    }


# --------------------------------------------------------------------------
# dry-run input specs
# --------------------------------------------------------------------------

def round_input_specs(cfg: ArchConfig, shape_name: str, mesh,
                      hp: Optional[H2FedParams] = None,
                      quantize_cloud: bool = False,
                      flat_agg: bool = False) -> Dict[str, Any]:
    """(fn, SDS args, in_shardings) for the dry-run driver."""
    from repro.launch.steps import SHAPES, shape_adapted_config

    info = SHAPES[shape_name]
    assert info["kind"] == "train", "h2fed_round lowers training shapes only"
    cfg = shape_adapted_config(cfg, shape_name)
    hp = hp or H2FedParams(local_epochs=1, lar=4)

    topo = HierarchyTopology.from_mesh(mesh)
    A = topo.n_agents
    b = max(info["batch"] // A, 1)
    seq = info["seq"]
    i32, f32 = jnp.int32, jnp.float32

    params_shapes = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.key(0)))
    p_shard = shard.param_shardings_model_only(params_shapes, mesh)

    batch_tree = {"tokens": jax.ShapeDtypeStruct((hp.lar, A, b, seq), i32),
                  "labels": jax.ShapeDtypeStruct((hp.lar, A, b, seq), i32)}
    if cfg.encoder.kind == "vision":
        batch_tree["patch_embeds"] = jax.ShapeDtypeStruct(
            (hp.lar, A, b, cfg.encoder.n_positions, cfg.encoder.d_embed), f32)
    if cfg.encoder.kind == "audio":
        batch_tree["memory"] = jax.ShapeDtypeStruct(
            (hp.lar, A, b, cfg.encoder.n_positions, cfg.encoder.d_embed), f32)

    bspec = {k: NamedSharding(mesh, topo.stacked_spec())
             for k in batch_tree}
    mask = jax.ShapeDtypeStruct((hp.lar, A), f32)
    n_data = jax.ShapeDtypeStruct((A,), f32)

    fn = make_h2fed_round(cfg, hp, mesh, quantize_cloud=quantize_cloud,
                          flat_agg=flat_agg)
    return dict(
        fn=fn,
        args=(params_shapes, batch_tree, mask, n_data),
        in_shardings=(p_shard, bspec,
                      NamedSharding(mesh, topo.stacked_spec()),
                      NamedSharding(mesh, topo.agent_spec)),
        cfg=cfg,
        desc=f"h2fed_round LAR={hp.lar} E={hp.local_epochs} A={A} b={b} "
             f"S={seq}" + (" q8" if quantize_cloud else ""))
