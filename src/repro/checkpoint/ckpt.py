"""Dependency-free pytree checkpoint store (single npz payload per step).

Layout per step:  <dir>/step_<n>.npz holding the leaf arrays plus an
embedded ``__manifest__`` JSON blob recording the treedef (as a string and,
when possible, a serialized proto) and leaf dtypes so restore round-trips
exactly.

Crash safety: the npz is written to a temp file in the same directory and
committed with ``os.replace`` (atomic on POSIX), so a reader either sees the
complete previous checkpoint or the complete new one — never a torn file.
A legacy directory layout (``step_<n>/manifest.json`` + ``arrays.npz``) is
still readable for checkpoints written by older versions.
"""
from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_MANIFEST_KEY = "__manifest__"


def _to_storable(a: np.ndarray) -> np.ndarray:
    """npz cannot store ml_dtypes (bfloat16/fp8) natively — widen to f32.
    Widening bf16->f32 is exact, so restore's astype() round-trips."""
    if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
        return a.astype(np.float32)
    return a


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _build_manifest(step: int, tree: PyTree, leaves) -> dict:
    try:
        structure = jax.tree_util.tree_structure(
            tree).serialize_using_proto().hex()
    except ValueError:
        # user-defined pytree nodes (e.g. ConnState) cannot be
        # proto-serialized — restore then needs ``like=``
        structure = None
    return {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(jax.tree_util.tree_structure(tree)),
        "structure": structure,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }


def save(directory: str | Path, step: int, tree: PyTree) -> Path:
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}.npz"
    leaves, _ = _flatten(tree)
    arrays = {f"leaf_{i}": _to_storable(np.asarray(l))
              for i, l in enumerate(leaves)}
    manifest = _build_manifest(step, tree, leaves)
    arrays[_MANIFEST_KEY] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8).copy()
    # Write-then-replace: a crash mid-write leaves only an orphan temp file;
    # the committed checkpoint is always complete.
    fd, tmp_name = tempfile.mkstemp(
        prefix=f".tmp_step_{step:08d}_", suffix=".npz", dir=directory)
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp_name, final)
    except BaseException:
        try:
            os.unlink(tmp_name)
        except OSError:
            pass
        raise
    return final


def _step_of(p: Path) -> Optional[int]:
    stem = p.name[:-len(".npz")] if p.name.endswith(".npz") else p.name
    if stem.startswith("."):
        return None
    try:
        return int(stem.split("_")[1])
    except (IndexError, ValueError):
        return None


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [s for p in directory.glob("step_*")
             if (s := _step_of(p)) is not None]
    return max(steps) if steps else None


def _load_payload(directory: Path, step: int):
    """Return (manifest, leaves) for a step, reading either layout."""
    file_path = directory / f"step_{step:08d}.npz"
    legacy_dir = directory / f"step_{step:08d}"
    if file_path.exists():
        with np.load(file_path) as z:
            manifest = json.loads(bytes(z[_MANIFEST_KEY]).decode())
            leaves = [z[f"leaf_{i}"].astype(_resolve_dtype(dt))
                      for i, dt in enumerate(manifest["dtypes"])]
        return manifest, leaves
    if legacy_dir.is_dir():
        manifest = json.loads((legacy_dir / "manifest.json").read_text())
        with np.load(legacy_dir / "arrays.npz") as z:
            leaves = [z[f"leaf_{i}"].astype(_resolve_dtype(dt))
                      for i, dt in enumerate(manifest["dtypes"])]
        return manifest, leaves
    raise FileNotFoundError(f"no checkpoint for step {step} under {directory}")


def restore(directory: str | Path, step: Optional[int] = None,
            like: Optional[PyTree] = None) -> PyTree:
    """Restore a checkpoint. ``like`` provides the treedef; without it the
    serialized treedef proto is used."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    manifest, leaves = _load_payload(directory, step)
    if like is not None:
        treedef = jax.tree_util.tree_structure(like)
    elif manifest.get("structure"):
        treedef = jax.tree_util.PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry, bytes.fromhex(manifest["structure"]))
    else:
        raise ValueError(
            f"checkpoint step {step} under {directory} holds user-defined "
            f"pytree nodes; pass ``like=`` with a matching template to restore")
    return jax.tree_util.tree_unflatten(treedef, leaves)
