"""Dependency-free pytree checkpoint store (npz payload + json manifest).

Layout per step:  <dir>/step_<n>/manifest.json + arrays.npz
The manifest records the treedef (as a nested structure of Nones) and leaf
dtypes so restore round-trips exactly.  Atomic via tmp-dir rename.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any


def _to_storable(a: np.ndarray) -> np.ndarray:
    """npz cannot store ml_dtypes (bfloat16/fp8) natively — widen to f32.
    Widening bf16->f32 is exact, so restore's astype() round-trips."""
    if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
        return a.astype(np.float32)
    return a


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str | Path, step: int, tree: PyTree) -> Path:
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": _to_storable(np.asarray(l))
              for i, l in enumerate(leaves)}
    np.savez(tmp / "arrays.npz", **arrays)
    try:
        structure = jax.tree_util.tree_structure(
            tree).serialize_using_proto().hex()
    except ValueError:
        # user-defined pytree nodes (e.g. ConnState) cannot be
        # proto-serialized — restore then needs ``like=``
        structure = None
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "structure": structure,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")]
    return max(steps) if steps else None


def restore(directory: str | Path, step: Optional[int] = None,
            like: Optional[PyTree] = None) -> PyTree:
    """Restore a checkpoint. ``like`` provides the treedef; without it the
    serialized treedef proto is used."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = directory / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        leaves = [z[f"leaf_{i}"].astype(_resolve_dtype(dt))
                  for i, dt in enumerate(manifest["dtypes"])]
    if like is not None:
        treedef = jax.tree_util.tree_structure(like)
    elif manifest.get("structure"):
        treedef = jax.tree_util.PyTreeDef.deserialize_using_proto(
            jax.tree_util.default_registry, bytes.fromhex(manifest["structure"]))
    else:
        raise ValueError(
            f"checkpoint {path} holds user-defined pytree nodes; pass "
            f"``like=`` with a matching template to restore")
    return jax.tree_util.tree_unflatten(treedef, leaves)
