"""Mamba-2 block via the chunked SSD (state-space duality) formulation.

TPU adaptation: the recurrence is evaluated chunk-parallel — intra-chunk
terms are dense (MXU-friendly) masked matmuls, inter-chunk state carry is a
`lax.scan` over n_chunks.  State update per head: h_t = exp(dt·A)·h_{t-1}
+ dt·B_t ⊗ x_t ;  y_t = C_t·h_t + D·x_t   (scalar A per head, n_groups=1).

Decode keeps an O(1) cache: (conv window, SSM state) — this is what makes
`long_500k` trivial for SSM/hybrid architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init


class MambaCache(NamedTuple):
    conv: jax.Array       # (B, conv_dim-1, conv_channels) rolling input window
    state: jax.Array      # (B, H, N, P) SSM state
    pos: jax.Array        # (B,) step count


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim            # x, B, C all convolved
    return d_inner, n_heads, conv_ch


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner, H, conv_ch = _dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, s.conv_dim - 1, conv_ch), dtype),
        state=jnp.zeros((batch, H, s.state_dim, s.head_dim), jnp.float32),
        pos=jnp.zeros((batch,), jnp.int32),
    )


def mamba_init(cfg: ArchConfig, key):
    s, d = cfg.ssm, cfg.d_model
    d_inner, H, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 5)
    wd = cfg.weight_dtype
    return {
        # projections: [z (gate), x, B, C, dt]
        "w_in": dense_init(ks[0], (d, 2 * d_inner + 2 * s.state_dim + H), wd),
        "conv_w": dense_init(ks[1], (s.conv_dim, conv_ch), wd, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), wd),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm_scale": jnp.ones((d_inner,), wd),
        "w_out": dense_init(ks[3], (d_inner, d), wd),
    }


def _split_proj(cfg: ArchConfig, p, x):
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * s.state_dim]
    dt_raw = zxbcdt[..., -H:]
    return z, xbc, dt_raw


def _causal_conv(p, xbc, conv_dim: int):
    """Depthwise causal conv over (B, S, C) with window `conv_dim`."""
    pad = jnp.pad(xbc, ((0, 0), (conv_dim - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * p["conv_w"][i]
              for i in range(conv_dim))
    return jax.nn.silu((out + p["conv_b"]).astype(jnp.float32)).astype(xbc.dtype)


def _gated_out(cfg, p, y, z, B, S):
    d_inner, _, _ = _dims(cfg)
    y = y.reshape(B, S, d_inner)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(ms + 1e-6)).astype(y.dtype) * p["norm_scale"]
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return y @ p["w_out"]


def mamba_prefill(cfg: ArchConfig, p, x):
    """x: (B, S, d_model) -> (B, S, d_model). Chunked SSD scan."""
    s = cfg.ssm
    d_inner, H, _ = _dims(cfg)
    B, S, _ = x.shape
    N, P, L = s.state_dim, s.head_dim, s.chunk_size

    z, xbc, dt_raw = _split_proj(cfg, p, x)
    xbc = _causal_conv(p, xbc, s.conv_dim)
    xs = xbc[..., :d_inner].reshape(B, S, H, P)
    Bm = xbc[..., d_inner:d_inner + N]                    # (B,S,N) shared heads
    Cm = xbc[..., d_inner + N:]                           # (B,S,N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                              # (H,) negative
    dA = dt * A                                           # (B,S,H) log-decay

    # pad to chunk multiple
    nc = -(-S // L)
    pad = nc * L - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
    rs = lambda t, *tail: t.reshape(B, nc, L, *tail)
    xs, Bm, Cm = rs(xs, H, P), rs(Bm, N), rs(Cm, N)
    dt, dA = rs(dt, H), rs(dA, H)

    cum = jnp.cumsum(dA, axis=2)                          # (B,nc,L,H)
    # intra-chunk: decay matrix M[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)        # (B,nc,L,L)
    y_diag = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                        scores.astype(jnp.float32), M, dt, xs.astype(jnp.float32))

    # chunk-final states: sum_j exp(cum_L - cum_j) * dt_j * B_j ⊗ x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (B,nc,L,H)
    chunk_states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                              (decay_to_end * dt), Bm.astype(jnp.float32),
                              xs.astype(jnp.float32))     # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,H) total decay

    def carry_fn(h, inp):
        st, dec = inp                                     # (B,H,N,P), (B,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                   # emit state *before* chunk

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_prev = jax.lax.scan(
        carry_fn, h0,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)              # (B,nc,H,N,P)

    # inter-chunk contribution: C_i · (decay from chunk start) · h_prev
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         Cm.astype(jnp.float32), jnp.exp(cum), h_prev)
    y = (y_diag + y_inter) + p["D"][None, None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(B, nc * L, H, P)[:, :S]
    return _gated_out(cfg, p, y.astype(x.dtype), z, B, S)


def mamba_decode(cfg: ArchConfig, p, x, cache: MambaCache):
    """x: (B, 1, d_model); O(1) state update."""
    s = cfg.ssm
    d_inner, H, conv_ch = _dims(cfg)
    B = x.shape[0]
    N, P = s.state_dim, s.head_dim

    z, xbc_new, dt_raw = _split_proj(cfg, p, x)           # (B,1,·)
    # rolling conv window
    win = jnp.concatenate([cache.conv, xbc_new], axis=1)  # (B, conv_dim, C)
    conv_out = jnp.einsum("bwc,wc->bc", win, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)  # (B,C)

    xs = xbc[:, :d_inner].reshape(B, H, P)
    Bm = xbc[:, d_inner:d_inner + N]
    Cm = xbc[:, d_inner + N:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A)                                 # (B,H)

    state = cache.state * dec[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, Bm.astype(jnp.float32), xs.astype(jnp.float32))
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), state) \
        + p["D"][None, :, None] * xs.astype(jnp.float32)
    out = _gated_out(cfg, p, y.astype(x.dtype)[:, None], z, B, 1)
    new_cache = MambaCache(conv=win[:, 1:], state=state, pos=cache.pos + 1)
    return out, new_cache
