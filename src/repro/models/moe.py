"""Mixture-of-Experts: GShard/Switch-style capacity-based dispatch.

TPU-native lineage (GShard, Switch, GLaM, ST-MoE all shipped on this einsum
formulation): tokens are grouped, routed top-k, and dispatched to per-expert
capacity slots with one-hot einsums.  Expert weights carry a leading E dim
that shards over the `model` mesh axis (expert parallelism); the dispatch
einsum is where GSPMD inserts the all-to-all.

An alternative sort-based `ragged` dispatch (jax.lax.ragged_dot) is provided
for the §Perf hillclimb — it removes the O(S·E·C) dispatch-tensor FLOPs that
dominate the einsum formulation at large E.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init


def moe_init(cfg: ArchConfig, key):
    m, d = cfg.moe, cfg.d_model
    ks = jax.random.split(key, 5)
    wd = cfg.weight_dtype
    E, F = m.n_experts, m.expert_d_ff
    p = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, F), wd),
        "w_up": dense_init(ks[2], (E, d, F), wd),
        "w_down": dense_init(ks[3], (E, F, d), wd),
    }
    if m.n_shared:
        S = m.n_shared
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, (d, S * F), wd),
            "w_up": dense_init(k2, (d, S * F), wd),
            "w_down": dense_init(k3, (S * F, d), wd),
        }
    return p


def _expert_ffn(p, x):
    """x: (E, G, C, d) -> (E, G, C, d) via per-expert SwiGLU."""
    g = jnp.einsum("egcd,edf->egcf", x, p["w_gate"])
    u = jnp.einsum("egcd,edf->egcf", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("egcf,efd->egcd", h, p["w_down"])


def _route(cfg: ArchConfig, p, xg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Router: returns (gates (G,S,E) float32, topk mask (G,S,E), aux loss)."""
    m = cfg.moe
    logits = (xg.astype(jnp.float32) @ p["router"])        # (G,S,E)
    gates = jax.nn.softmax(logits, axis=-1)
    _, top_idx = jax.lax.top_k(gates, m.top_k)             # (G,S,k)
    mask = jnp.sum(jax.nn.one_hot(top_idx, m.n_experts, dtype=jnp.float32),
                   axis=-2)                                # (G,S,E) in {0,1}
    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    f = jnp.mean(mask, axis=1)                             # (G,E) token fraction
    pr = jnp.mean(gates, axis=1)                           # (G,E) mean router prob
    aux = m.n_experts * jnp.mean(jnp.sum(f * pr, axis=-1))
    return gates, mask, aux


def _dispatch_einsum(cfg: ArchConfig, p, xg, gates, mask):
    """GShard capacity dispatch. xg: (G,S,d)."""
    m = cfg.moe
    G, S, d = xg.shape
    E = m.n_experts
    C = max(1, int(m.top_k * S * m.capacity_factor / E))
    pos = jnp.cumsum(mask, axis=1) * mask - 1.0            # (G,S,E) slot index
    in_cap = (pos >= 0) & (pos < C)
    disp = jax.nn.one_hot(pos, C, dtype=xg.dtype) \
        * in_cap[..., None].astype(xg.dtype)               # (G,S,E,C)
    combine = disp.astype(jnp.float32) * (gates * mask)[..., None]
    expert_in = jnp.einsum("gsec,gsd->egcd", disp, xg)     # all-to-all here
    expert_out = _expert_ffn(p, expert_in)
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(xg.dtype), expert_out)
    return out


def _dispatch_ragged(cfg: ArchConfig, p, xg, gates, mask):
    """Sort-based dispatch using jax.lax.ragged_dot — O(k·S) token movement
    instead of the O(S·E·C) one-hot dispatch tensor (which at E = 384 is
    terabytes per layer).  One GLOBAL argsort over all (token, expert)
    assignments; ragged_dot cannot be vmapped, so groups are flattened."""
    m = cfg.moe
    G, S, d = xg.shape
    E, K = m.n_experts, m.top_k
    N = G * S
    x = xg.reshape(N, d)
    gk, top_idx = jax.lax.top_k(gates.reshape(N, E), K)    # (N,K)

    eid = top_idx.reshape(-1)                              # (N*K,)
    tok = jnp.repeat(jnp.arange(N), K)
    order = jnp.argsort(eid)
    eid_s, tok_s = eid[order], tok[order]
    xs = x[tok_s]                                          # (N*K, d) gathered
    sizes = jnp.bincount(eid_s, length=E)
    h_g = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes=sizes)
    h_u = jax.lax.ragged_dot(xs, p["w_up"], group_sizes=sizes)
    h = jax.nn.silu(h_g.astype(jnp.float32)).astype(x.dtype) * h_u
    ys = jax.lax.ragged_dot(h, p["w_down"], group_sizes=sizes)
    w = gk.reshape(-1)[order].astype(jnp.float32)
    out = jnp.zeros((N, d), jnp.float32).at[tok_s].add(
        ys.astype(jnp.float32) * w[:, None])
    return out.astype(x.dtype).reshape(G, S, d)


def moe_apply(cfg: ArchConfig, p, x):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    tokens = B * S
    gs = min(m.group_size, tokens)
    # pad token count to a multiple of the group size
    n_groups = -(-tokens // gs)
    pad = n_groups * gs - tokens
    xf = x.reshape(tokens, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    xg = xf.reshape(n_groups, gs, d)

    gates, mask, aux = _route(cfg, p, xg)
    if m.dispatch_impl == "ragged":
        out = _dispatch_ragged(cfg, p, xg, gates, mask)
    else:
        out = _dispatch_einsum(cfg, p, xg, gates, mask)
    out = out.reshape(n_groups * gs, d)[:tokens].reshape(B, S, d)

    if m.n_shared:
        sp = p["shared"]
        g = x @ sp["w_gate"]
        u = x @ sp["w_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        out = out + h @ sp["w_down"]
    return out, aux
