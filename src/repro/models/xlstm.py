"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory) and sLSTM (scalar
memory with recurrent gate connections).

Both are implemented as exact stabilized recurrences via `lax.scan` over time
(compiles to a single while-loop — tiny HLO, O(seq) work, and the decode step
is literally one scan iteration, giving O(1)-state `long_500k` decode).
The chunkwise-parallel mLSTM (GLA-style) is a §Perf candidate, not a
correctness requirement; the scan form is the oracle.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import dense_init, rms_normalize, soft_cap

GATE_CAP = 15.0   # xLSTM-7B-style soft cap on i/f gate pre-activations


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: jax.Array      # (B, H, P, P) matrix memory
    n: jax.Array      # (B, H, P) normalizer
    m: jax.Array      # (B, H) stabilizer


def _mlstm_dims(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model           # projection factor 2
    H = cfg.n_heads
    P = d_inner // H
    return d_inner, H, P


def init_mlstm_state(cfg: ArchConfig, batch: int):
    _, H, P = _mlstm_dims(cfg)
    return MLSTMState(C=jnp.zeros((batch, H, P, P), jnp.float32),
                      n=jnp.zeros((batch, H, P), jnp.float32),
                      m=jnp.full((batch, H), -1e30, jnp.float32))


def mlstm_init(cfg: ArchConfig, key):
    d = cfg.d_model
    d_inner, H, P = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    wd = cfg.weight_dtype
    return {
        "w_up": dense_init(ks[0], (d, 2 * d_inner), wd),   # x branch + z gate
        "wq": dense_init(ks[1], (d_inner, d_inner), wd),
        "wk": dense_init(ks[2], (d_inner, d_inner), wd),
        "wv": dense_init(ks[3], (d_inner, d_inner), wd),
        "w_if": dense_init(ks[4], (d_inner, 2 * H), wd, scale=0.01),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]
                                ).astype(jnp.float32),
        "norm_scale": jnp.ones((d_inner,), wd),
        "w_down": dense_init(ks[5], (d_inner, d), wd),
    }


def _mlstm_step(state: MLSTMState, qkvif):
    q, k, v, i_t, f_t = qkvif        # (B,H,P) ×3, (B,H) ×2
    P = q.shape[-1]
    scale = P ** -0.5
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + state.m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(logf + state.m - m_new)
    C = state.C * f_p[..., None, None] \
        + i_p[..., None, None] * (v[..., :, None] * k[..., None, :])
    n = state.n * f_p[..., None] + i_p[..., None] * k
    h_num = jnp.einsum("bhpq,bhq->bhp", C, q * scale)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q * scale)),
                        jnp.exp(-m_new))
    h = h_num / h_den[..., None]
    return MLSTMState(C, n, m_new), h


def _mlstm_qkvif(cfg, p, xu):
    """xu: (B, S, d_inner) -> per-head q,k,v (B,S,H,P) and gates (B,S,H)."""
    _, H, P = _mlstm_dims(cfg)
    B, S, _ = xu.shape
    q = (xu @ p["wq"]).reshape(B, S, H, P)
    k = (xu @ p["wk"]).reshape(B, S, H, P)
    v = (xu @ p["wv"]).reshape(B, S, H, P)
    gates = soft_cap((xu @ p["w_if"]).astype(jnp.float32) + p["b_if"], GATE_CAP)
    i_t, f_t = gates[..., :H], gates[..., H:]
    # qk-norm: bounds the dot-products feeding the matrix memory so the
    # normalizer n·q cannot cancel catastrophically under large weights.
    return rms_normalize(q), rms_normalize(k), v, i_t, f_t


def _mlstm_chunk_step(state: MLSTMState, qkvif, *, scale: float):
    """One chunk of the chunkwise-parallel mLSTM (exact, stabilized).

    The stabilized sequential recurrence admits a closed per-chunk form:
    with b_j = Σ_{s<=j} log σ(f_s) and u_k = i_k − b_k, the true running
    stabilizer is m_j = b_j + max(m_0, cummax_k<=j u_k), and

        Ĉ_j = c_j·Ĉ_0 + Σ_{k<=j} A_{jk} v_k k_kᵀ,  c_j = e^{b_j + m_0 − m_j},
        A_{jk} = e^{(b_j − m_j) + u_k}   (0 for k > j),

    so one chunk needs two (T,T) einsums + one state update instead of T
    sequential state materializations.  All exponents are ≤ 0 by
    construction of m_j, hence no overflow.
    """
    C0, n0, m0 = state                     # (B,H,P,P), (B,H,P), (B,H)
    q, k, v, i_t, f_t = qkvif              # (B,T,H,P) ×3, (B,T,H) ×2
    logf = jax.nn.log_sigmoid(f_t)
    b = jnp.cumsum(logf, axis=1)           # (B,T,H)
    u = i_t - b
    g = jax.lax.cummax(u, axis=1)
    m = b + jnp.maximum(m0[:, None], g)    # (B,T,H)
    c = jnp.exp(b + m0[:, None] - m)       # inter-chunk coefficient
    # A[j,k] = exp(b_j - m_j + u_k), masked to k<=j
    expo = (b - m)[:, :, None, :] + u[:, None, :, :]      # (B,Tq,Tk,H)
    T = q.shape[1]
    mask = jnp.tril(jnp.ones((T, T), bool))
    A = jnp.where(mask[None, :, :, None], jnp.exp(expo), 0.0)

    qs = q * scale
    inter_num = jnp.einsum("bthq,bhpq->bthp", qs, C0) * c[..., None]
    S_ = jnp.einsum("bthp,bshp->btsh", qs, k) * A         # (B,Tq,Tk,H)
    h_num = inter_num + jnp.einsum("btsh,bshp->bthp", S_, v)
    n = c[..., None] * n0[:, None] + jnp.einsum("btsh,bshp->bthp", A, k)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bthp,bthp->bth", n, qs)),
                        jnp.exp(-m))
    h = h_num / h_den[..., None]

    # end-of-chunk carry (row j = T-1)
    AT = A[:, -1]                                         # (B,Tk,H)
    C_T = C0 * c[:, -1, :, None, None] \
        + jnp.einsum("bsh,bshp,bshq->bhpq", AT, v, k)
    n_T = n[:, -1]
    m_T = m[:, -1]
    return MLSTMState(C_T, n_T, m_T), h


def _mlstm_prefill_chunkwise(cfg: ArchConfig, q, k, v, i_t, f_t, B, S):
    """Chunkwise-parallel scan over S/T chunks; exact w.r.t. the oracle."""
    T = cfg.mlstm_chunk
    P = q.shape[-1]
    pad = (-S) % T
    if pad:
        padf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = padf(q), padf(k), padf(v)
        i_t = jnp.pad(i_t, ((0, 0), (0, pad), (0, 0)),
                      constant_values=-1e30)     # pad inputs: no contribution
        f_t = jnp.pad(f_t, ((0, 0), (0, pad), (0, 0)),
                      constant_values=30.0)      # pad forget: no state decay
    nC = (S + pad) // T
    chunked = jax.tree.map(
        lambda t: jnp.swapaxes(t.reshape((B, nC, T) + t.shape[2:]), 0, 1)
        .astype(jnp.float32), (q, k, v, i_t, f_t))
    state = init_mlstm_state(cfg, B)
    step = functools.partial(_mlstm_chunk_step, scale=P ** -0.5)
    _, hs = jax.lax.scan(step, state, chunked)   # (nC, B, T, H, P)
    h = jnp.swapaxes(hs, 0, 1).reshape(B, nC * T, -1)
    return h[:, :S]


def mlstm_prefill(cfg: ArchConfig, p, x):
    """x: (B, S, d) -> (B, S, d)."""
    d_inner, H, P = _mlstm_dims(cfg)
    B, S, _ = x.shape
    up = x @ p["w_up"]
    xu, z = up[..., :d_inner], up[..., d_inner:]
    q, k, v, i_t, f_t = _mlstm_qkvif(cfg, p, xu)
    if cfg.mlstm_chunk and S > 1:
        h = _mlstm_prefill_chunkwise(cfg, q, k, v, i_t, f_t, B, S)
    else:
        xs = jax.tree.map(lambda t: jnp.swapaxes(t, 0, 1).astype(jnp.float32),
                          (q, k, v, i_t, f_t))
        state = init_mlstm_state(cfg, B)
        _, hs = jax.lax.scan(_mlstm_step, state, xs)      # (S, B, H, P)
        h = jnp.swapaxes(hs, 0, 1)
    h = h.reshape(B, S, d_inner)
    h = rms_normalize(h) * p["norm_scale"].astype(jnp.float32)
    out = h.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return out @ p["w_down"]


def mlstm_decode(cfg: ArchConfig, p, x, state: MLSTMState):
    d_inner, H, P = _mlstm_dims(cfg)
    B = x.shape[0]
    up = x @ p["w_up"]
    xu, z = up[..., :d_inner], up[..., d_inner:]
    q, k, v, i_t, f_t = _mlstm_qkvif(cfg, p, xu)
    args = jax.tree.map(lambda t: t[:, 0].astype(jnp.float32),
                        (q, k, v, i_t, f_t))
    state, h = _mlstm_step(state, args)
    h = h.reshape(B, 1, d_inner)
    h = rms_normalize(h) * p["norm_scale"].astype(jnp.float32)
    out = h.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return out @ p["w_down"], state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array      # (B, D) cell
    n: jax.Array      # (B, D) normalizer
    h: jax.Array      # (B, D) hidden (recurrent input)
    m: jax.Array      # (B, D) stabilizer


def init_slstm_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    return SLSTMState(c=jnp.zeros((batch, d), jnp.float32),
                      n=jnp.zeros((batch, d), jnp.float32),
                      h=jnp.zeros((batch, d), jnp.float32),
                      m=jnp.full((batch, d), -1e30, jnp.float32))


def slstm_init(cfg: ArchConfig, key):
    d, H = cfg.d_model, cfg.n_heads
    P = d // H
    ks = jax.random.split(key, 4)
    wd = cfg.weight_dtype
    ff = int(4 / 3 * d)
    return {
        "w_gates": dense_init(ks[0], (d, 4 * d), wd),     # i, f, z, o from x
        # block-diagonal recurrent weights: (H, P, 4*P)
        "r_gates": dense_init(ks[1], (H, P, 4 * P), wd, scale=P ** -0.5),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d,)), 3.0 * jnp.ones((d,)), jnp.zeros((2 * d,))]
        ).astype(jnp.float32),
        "w_ff_gate": dense_init(ks[2], (d, ff), wd),
        "w_ff_up": dense_init(ks[3], (d, ff), wd),
        "w_ff_down": dense_init(jax.random.fold_in(key, 9), (ff, d), wd),
    }


def _slstm_step(cfg: ArchConfig, p, state: SLSTMState, wx):
    """wx: (B, 4d) pre-computed input contribution for this step."""
    d, H = cfg.d_model, cfg.n_heads
    P = d // H
    B = wx.shape[0]
    hr = state.h.reshape(B, H, P).astype(p["r_gates"].dtype)
    rec = jnp.einsum("bhp,hpq->bhq", hr, p["r_gates"]).reshape(B, 4 * d)
    g = (wx + rec).astype(jnp.float32) + p["b_gates"]
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    gi, gf = soft_cap(gi, GATE_CAP), soft_cap(gf, GATE_CAP)
    logf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(logf + state.m, gi)
    i_p = jnp.exp(gi - m_new)
    f_p = jnp.exp(logf + state.m - m_new)
    c = f_p * state.c + i_p * jnp.tanh(gz)
    n = f_p * state.n + i_p
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, h=h, m=m_new), h


def slstm_prefill(cfg: ArchConfig, p, x):
    B, S, d = x.shape
    wx = (x @ p["w_gates"]).astype(jnp.float32)           # (B,S,4d)
    state = init_slstm_state(cfg, B)
    step = lambda st, w: _slstm_step(cfg, p, st, w)
    _, hs = jax.lax.scan(step, state, jnp.swapaxes(wx, 0, 1))
    h = jnp.swapaxes(hs, 0, 1).astype(x.dtype)
    h = rms_normalize(h)
    # GeGLU post-FFN (xLSTM sLSTM block projection)
    g = h @ p["w_ff_gate"]
    u = h @ p["w_ff_up"]
    y = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u
    return y @ p["w_ff_down"]


def slstm_decode(cfg: ArchConfig, p, x, state: SLSTMState):
    B = x.shape[0]
    wx = (x[:, 0] @ p["w_gates"]).astype(jnp.float32)
    state, h = _slstm_step(cfg, p, state, wx)
    h = rms_normalize(h[:, None].astype(x.dtype))
    g = h @ p["w_ff_gate"]
    u = h @ p["w_ff_up"]
    y = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u
    return y @ p["w_ff_down"], state
