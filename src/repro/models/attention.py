"""Attention: GQA (RoPE, qk-norm, sliding window), MLA, cross-attention.

Prefill uses a chunked online-softmax scan over KV blocks (flash-style,
memory-bounded — the Pallas kernel in repro.kernels.flash_attention implements
the same blocking for TPU VMEM; this file is the pure-jnp/XLA path).
Decode uses either a linear KV cache (full causal) or a ring buffer
(sliding window), so a 524k-token context costs O(window) memory.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, dense_init, rms_normalize

NEG_INF = -1e30


def maybe_constrain(x, *spec):
    """with_sharding_constraint IF a physical mesh with the named axes is
    active and the dims divide; a no-op on un-meshed CPU tests.

    Needed because GSPMD occasionally picks a catastrophic layout for scan
    carries (observed: the KV-chunk carry sharded over (KV, head_dim) on the
    data axis, forcing a partial-score all-reduce of (S × chunk) slabs every
    chunk step × every layer — §Perf hillclimb B)."""
    from jax._src.mesh import thread_resources
    pm = thread_resources.env.physical_mesh
    if pm.empty:
        return x
    # inside shard_map some axes are Manual — the constraint may only name
    # Auto axes (the abstract mesh carries the per-trace axis types).  Older
    # jax has no abstract mesh and its axis env can't tell Manual from Auto,
    # so there the hint is skipped whenever any named axis is in scope (the
    # constraint is an optimization, never a semantics change).
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    auto = set(pm.axis_names)
    if get_am is not None:
        am = get_am()
        if am is not None and not am.empty:
            auto = {a for a in am.axis_names
                    if am._name_to_type[a] == jax.sharding.AxisType.Auto}
    else:
        from jax._src import core as _core
        if getattr(_core.get_axis_env(), "axis_sizes", None):
            return x
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if (ax is None or ax not in pm.axis_names or ax not in auto
                or dim % pm.shape[ax]):
            fixed.append(None)
        else:
            fixed.append(ax)
    if all(a is None for a in fixed):
        return x
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*fixed))
    except ValueError:   # exotic axis-type contexts: the hint is optional
        return x


# --------------------------------------------------------------------------
# chunked online-softmax attention (shared by GQA & MLA prefill)
# --------------------------------------------------------------------------

def chunked_attention(q, k, v, q_pos, kv_pos, *, window: int = 0,
                      chunk: int = 1024, causal: bool = True):
    """Memory-bounded attention via online softmax over KV chunks.

    q: (B, S, H, D); k/v: (B, T, KV, D) with H % KV == 0.
    q_pos: (S,), kv_pos: (T,) absolute positions for masking.
    Returns (B, S, H, D).
    """
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = D ** -0.5

    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-(1 << 30))
    kc = k.reshape(B, n_chunks, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, D).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, chunk)
    # pin layouts: batch over data, q-heads over model (see maybe_constrain)
    q = maybe_constrain(q, "data", None, "model", None)
    kc = maybe_constrain(kc, None, "data", None, None, None)
    vc = maybe_constrain(vc, None, "data", None, None, None)

    def step(carry, inp):
        m, l, acc = carry                       # (B,S,H), (B,S,H), (B,S,H,D)
        k_i, v_i, p_i = inp                     # (B,c,KV,D), (B,c,KV,D), (c,)
        # flat-H score layout (§Perf hillclimb B): repeating the KV chunk to
        # all H q-heads keeps the einsum sharded purely on H (H % model == 0
        # for every assigned arch), whereas the grouped (KV, G) layout makes
        # GSPMD split the head_dim contraction when KV < model-axis size and
        # all-reduce full (S × T) score slabs.
        kh = jnp.repeat(k_i, G, axis=2)         # (B,c,H,D)
        vh = jnp.repeat(v_i, G, axis=2)
        s = jnp.einsum("bshd,bchd->bshc", q, kh,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= p_i[None, :] <= q_pos[:, None]
        if window:
            mask &= p_i[None, :] > q_pos[:, None] - window
        mask &= p_i[None, :] >= 0
        s = jnp.where(mask[None, :, None, :], s, NEG_INF)
        m_i = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_i)
        p = jnp.exp(s - m_i[..., None])
        l_i = l * alpha + jnp.sum(p, axis=-1)
        acc_i = acc * alpha[..., None] + jnp.einsum(
            "bshc,bchd->bshd", p.astype(vh.dtype), vh,
            preferred_element_type=jnp.float32)
        return (m_i, l_i, acc_i), None

    m0 = jnp.full((B, S, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, H), jnp.float32)
    a0 = jnp.zeros((B, S, H, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_pos, cur_pos, *, window: int = 0):
    """Single-token attention over a (possibly ring-buffered) KV cache.

    q: (B, 1, H, D); k/v_cache: (B, T, KV, D); kv_pos: (B, T) absolute
    positions (-1 for unwritten slots); cur_pos: (B,) current position.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = (kv_pos >= 0) & (kv_pos <= cur_pos[:, None])
    if window:
        valid &= kv_pos > (cur_pos[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# KV cache containers
# --------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, T, KV, D)
    v: jax.Array          # (B, T, KV, D)
    pos: jax.Array        # (B, T) int32 absolute positions, -1 = empty
    idx: jax.Array        # (B,) int32 next write slot (ring index)


def init_kv_cache(batch: int, length: int, n_kv: int, head_dim: int, dtype):
    return KVCache(
        k=jnp.zeros((batch, length, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, length, n_kv, head_dim), dtype),
        pos=jnp.full((batch, length), -1, jnp.int32),
        idx=jnp.zeros((batch,), jnp.int32),
    )


def cache_append(cache: KVCache, k_new, v_new, positions) -> KVCache:
    """Write one token's k/v at the ring slot. k_new: (B, 1, KV, D)."""
    T = cache.k.shape[1]
    slot = cache.idx % T

    def write(buf, new):
        return jax.vmap(
            lambda b, n, s: jax.lax.dynamic_update_slice(b, n, (s, 0, 0))
        )(buf, new, slot)

    pos = jax.vmap(
        lambda p, s, val: jax.lax.dynamic_update_slice(p, val[None], (s,))
    )(cache.pos, slot, positions.astype(jnp.int32))
    return KVCache(k=write(cache.k, k_new), v=write(cache.v, v_new),
                   pos=pos, idx=cache.idx + 1)


# --------------------------------------------------------------------------
# GQA self-attention module
# --------------------------------------------------------------------------

def gqa_init(cfg: ArchConfig, key):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    wd = cfg.weight_dtype
    p = {"wq": dense_init(ks[0], (d, H * hd), wd),
         "wk": dense_init(ks[1], (d, KV * hd), wd),
         "wv": dense_init(ks[2], (d, KV * hd), wd),
         "wo": dense_init(ks[3], (H * hd, d), wd)}
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * hd,), wd)
        p["bk"] = jnp.zeros((KV * hd,), wd)
        p["bv"] = jnp.zeros((KV * hd,), wd)
    return p


def _gqa_qkv(cfg: ArchConfig, p, x, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q, k = rms_normalize(q), rms_normalize(k)
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_prefill(cfg: ArchConfig, p, x, positions, *, causal: bool = True):
    """positions: (S,) — shared across batch during prefill."""
    q, k, v = _gqa_qkv(cfg, p, x, positions[None, :])
    out = chunked_attention(q, k, v, positions, positions,
                            window=cfg.attn_window, chunk=cfg.attn_chunk,
                            causal=causal)
    B, S = x.shape[:2]
    return out.reshape(B, S, -1) @ p["wo"]


def gqa_decode(cfg: ArchConfig, p, x, cache: KVCache, cur_pos):
    """x: (B, 1, d); cur_pos: (B,) absolute position of the new token."""
    q, k, v = _gqa_qkv(cfg, p, x, cur_pos[:, None])
    cache = cache_append(cache, k, v, cur_pos)
    out = decode_attention(q, cache.k, cache.v, cache.pos, cur_pos,
                           window=cfg.attn_window)
    B = x.shape[0]
    return out.reshape(B, 1, -1) @ p["wo"], cache


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed KV cache + decoupled RoPE
# --------------------------------------------------------------------------

class MLACache(NamedTuple):
    ckv: jax.Array        # (B, T, kv_lora)
    krope: jax.Array      # (B, T, rope_hd)
    pos: jax.Array        # (B, T)
    idx: jax.Array        # (B,)


def init_mla_cache(batch: int, length: int, cfg: ArchConfig, dtype):
    m = cfg.mla
    return MLACache(
        ckv=jnp.zeros((batch, length, m.kv_lora_rank), dtype),
        krope=jnp.zeros((batch, length, m.rope_head_dim), dtype),
        pos=jnp.full((batch, length), -1, jnp.int32),
        idx=jnp.zeros((batch,), jnp.int32),
    )


def mla_init(cfg: ArchConfig, key):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    wd = cfg.weight_dtype
    return {
        # queries: nope + rope parts
        "wq": dense_init(ks[0], (d, H * (m.q_head_dim + m.rope_head_dim)), wd),
        # compressed kv + shared k-rope
        "wdkv": dense_init(ks[1], (d, m.kv_lora_rank + m.rope_head_dim), wd),
        "wuk": dense_init(ks[2], (m.kv_lora_rank, H * m.q_head_dim), wd),
        "wuv": dense_init(ks[3], (m.kv_lora_rank, H * m.v_head_dim), wd),
        "wo": dense_init(ks[4], (H * m.v_head_dim, d), wd),
    }


def _mla_q(cfg: ArchConfig, p, x, positions):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, H, m.q_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., :m.q_head_dim], q[..., m.q_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv(cfg: ArchConfig, p, x, positions):
    m = cfg.mla
    dkv = x @ p["wdkv"]
    ckv, krope = dkv[..., :m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    krope = apply_rope(krope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return ckv, krope


def _mla_expand(cfg: ArchConfig, p, ckv):
    """Up-project compressed cache to per-head k_nope / v."""
    m, H = cfg.mla, cfg.n_heads
    B, T, _ = ckv.shape
    k_nope = (ckv @ p["wuk"]).reshape(B, T, H, m.q_head_dim)
    v = (ckv @ p["wuv"]).reshape(B, T, H, m.v_head_dim)
    return k_nope, v


def mla_prefill(cfg: ArchConfig, p, x, positions):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(cfg, p, x, positions[None, :])
    ckv, krope = _mla_kv(cfg, p, x, positions[None, :])
    k_nope, v = _mla_expand(cfg, p, ckv)
    # fold rope part in as extra head dims (shared krope broadcast per head)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  (B, S, H, m.rope_head_dim))], axis=-1)
    # pad v to match head_dim for the shared kernel, then slice back
    out = chunked_attention(q, k,
                            jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                        (0, q.shape[-1] - m.v_head_dim))),
                            positions, positions, window=cfg.attn_window,
                            chunk=cfg.attn_chunk)
    out = out[..., :m.v_head_dim].reshape(B, S, -1)
    return out @ p["wo"]


def mla_decode(cfg: ArchConfig, p, x, cache: MLACache, cur_pos):
    """Weight-absorbed MLA decode (DeepSeek-V2): scores are computed in the
    compressed kv_lora space — q_nope is absorbed through w_uk and the
    context is read in compressed space then expanded through w_uv, so the
    per-step cost is O(T · kv_lora) instead of O(T · H · head_dim)."""
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    q_nope, q_rope = _mla_q(cfg, p, x, cur_pos[:, None])   # (B,1,H,·)
    ckv_new, krope_new = _mla_kv(cfg, p, x, cur_pos[:, None])
    T = cache.ckv.shape[1]
    slot = cache.idx % T
    wr = jax.vmap(lambda b, n, s: jax.lax.dynamic_update_slice(b, n, (s, 0)))
    cache = MLACache(
        ckv=wr(cache.ckv, ckv_new, slot),
        krope=wr(cache.krope, krope_new, slot),
        pos=jax.vmap(lambda pbuf, s, val:
                     jax.lax.dynamic_update_slice(pbuf, val[None], (s,)))(
                         cache.pos, slot, cur_pos.astype(jnp.int32)),
        idx=cache.idx + 1)
    # absorb w_uk into q: q_c (B,H,lora)
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.q_head_dim)
    q_c = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], wuk)
    scale = (m.q_head_dim + m.rope_head_dim) ** -0.5
    s_nope = jnp.einsum("bhl,btl->bht", q_c.astype(jnp.float32),
                        cache.ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bhr,btr->bht", q_rope[:, 0].astype(jnp.float32),
                        cache.krope.astype(jnp.float32))
    s = (s_nope + s_rope) * scale
    valid = (cache.pos >= 0) & (cache.pos <= cur_pos[:, None])
    if cfg.attn_window:
        valid &= cache.pos > (cur_pos[:, None] - cfg.attn_window)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bht,btl->bhl", w,
                       cache.ckv.astype(jnp.float32))      # compressed ctx
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhl,lhd->bhd", ctx_c,
                     wuv.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(B, 1, H * m.v_head_dim)
    return out @ p["wo"], cache


# --------------------------------------------------------------------------
# cross-attention (whisper decoder -> encoder memory)
# --------------------------------------------------------------------------

def xattn_init(cfg: ArchConfig, key):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    wd = cfg.weight_dtype
    return {"wq": dense_init(ks[0], (d, H * hd), wd),
            "wk": dense_init(ks[1], (cfg.encoder.d_embed or d, H * hd), wd),
            "wv": dense_init(ks[2], (cfg.encoder.d_embed or d, H * hd), wd),
            "wo": dense_init(ks[3], (H * hd, d), wd)}


def xattn_apply(cfg: ArchConfig, p, x, memory):
    """x: (B, S, d); memory: (B, M, d_embed). Non-causal full attention."""
    B, S, _ = x.shape
    M = memory.shape[1]
    H, hd = cfg.n_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (memory @ p["wk"]).reshape(B, M, H, hd)
    v = (memory @ p["wv"]).reshape(B, M, H, hd)
    pos_q = jnp.arange(S)
    pos_kv = jnp.arange(M)
    out = chunked_attention(q, k, v, pos_q, pos_kv, chunk=min(cfg.attn_chunk, M),
                            causal=False)
    return out.reshape(B, S, -1) @ p["wo"]
