"""Architecture configuration for the repro model zoo.

One dataclass drives every architecture family (dense / MoE / SSM / hybrid /
VLM / audio).  Block composition is expressed by ``block_pattern`` entries,
each of which names a residual block type:

  "attn"    — self-attention (GQA / MLA / qk-norm / sliding-window variants)
  "mlp"     — feed-forward (swiglu / squared_relu / gelu)
  "moe"     — mixture-of-experts feed-forward
  "mamba2"  — Mamba-2 chunked-SSD block
  "mlstm"   — xLSTM matrix-memory block (chunkwise parallel)
  "slstm"   — xLSTM scalar-memory block (recurrent scan)
  "xattn"   — cross-attention to an encoder memory (whisper decoder)

A transformer "layer" is a list of such entries; ``layer_patterns`` maps a
pattern name to the list, and ``layout`` is the per-layer sequence of pattern
names.  Homogeneous runs of the same pattern are stacked and ``lax.scan``-ed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    top_k: int = 0
    n_shared: int = 0               # shared (always-on) experts
    expert_d_ff: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25   # GShard capacity factor
    group_size: int = 2048          # dispatch group size (tokens)
    router_aux_weight: float = 0.01  # load-balance aux loss weight
    dispatch_impl: str = "einsum"   # "einsum" (GShard) | "ragged" (sort+ragged_dot)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64             # N: per-head state size
    head_dim: int = 64              # P: channels per head
    expand: int = 2                 # d_inner = expand * d_model
    conv_dim: int = 4               # depthwise causal conv width
    chunk_size: int = 64            # SSD chunk length


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512         # compressed KV dim (cached)
    rope_head_dim: int = 64         # decoupled-RoPE dims (cached)
    q_head_dim: int = 128           # non-rope q/k head dims
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class EncoderStub:
    """Modality frontend stub: input_specs() provides these embeddings."""
    kind: str = "none"              # "vision" | "audio" | "none"
    n_positions: int = 0            # patches (vision) / frames (audio)
    d_embed: int = 0                # embedding dim fed to the backbone


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    source: str                     # citation for the config numbers

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab_size: int = 0

    # attention details
    attn_impl: str = "gqa"          # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    attn_window: int = 0            # 0 = full causal; >0 = sliding window
    attn_bias: bool = False
    attn_chunk: int = 1024          # online-softmax KV chunk for prefill
    pos_embed: str = "rope"         # rope | learned | none

    mlp_type: str = "swiglu"        # swiglu | squared_relu | gelu
    mlp_bias: bool = False
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    encoder: EncoderStub = EncoderStub()

    # layer layout: list of (pattern_name, repeat) tuples; pattern defs below.
    # default dense layout is [("decoder", n_layers)].
    layout: Tuple[Tuple[str, int], ...] = ()
    # hybrid: shared attention block applied every `shared_every` core blocks
    shared_every: int = 0

    # xLSTM mLSTM execution: 0 = exact per-step scan (oracle); T > 0 =
    # chunkwise-parallel form with chunk length T (§Perf hillclimb A — the
    # state is materialized once per chunk instead of once per step).
    mlstm_chunk: int = 0

    # distribution strategy for the launch path (§Perf lever):
    #   fsdp_tp — params sharded FSDP('data') x TP('model')  [default]
    #   dp      — params replicated, batch over ('data','model'): pure
    #             256-way data parallelism (wins for small models where TP
    #             collectives dominate the tiny per-shard matmuls)
    shard_strategy: str = "fsdp_tp"

    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    max_seq_len: int = 1 << 20

    # ---- derived ----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def layout_(self) -> Tuple[Tuple[str, int], ...]:
        if self.layout:
            return self.layout
        return (("decoder", self.n_layers),)

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks); for roofline."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def n_active_params(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ArchConfig, *, n_layers: int = 2, d_model: int = 256,
            n_heads: int = 4, n_kv_heads: int = 2, d_ff: int = 512,
            vocab_size: int = 512, n_experts: int = 4, top_k: int = 2,
            seq_len_cap: int = 128) -> ArchConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    kw = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=min(n_kv_heads, cfg.n_kv_heads or n_kv_heads) or n_kv_heads,
        d_ff=d_ff if cfg.d_ff else 0, vocab_size=vocab_size, head_dim=0,
        max_seq_len=seq_len_cap,
        mlstm_chunk=0,   # smoke tests run the per-step oracle form
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(n_experts, cfg.moe.n_experts),
            top_k=min(top_k, cfg.moe.top_k), expert_d_ff=d_ff // 2,
            group_size=32, n_shared=min(cfg.moe.n_shared, 1))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=32,
                                        chunk_size=16)
    if cfg.mla is not None:
        kw["mla"] = dataclasses.replace(cfg.mla, kv_lora_rank=64, rope_head_dim=16,
                                        q_head_dim=32, v_head_dim=32)
    if cfg.encoder.kind != "none":
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_positions=16,
                                            d_embed=d_model)
    if cfg.layout:
        # shrink layout preserving structure: keep pattern kinds, cap repeats
        seen, new_layout = set(), []
        for pat, rep in cfg.layout:
            r = 1 if pat in seen else min(rep, 2)
            seen.add(pat)
            new_layout.append((pat, r))
        kw["layout"] = tuple(new_layout)
    if cfg.attn_window:
        kw["attn_window"] = 32
    kw["attn_chunk"] = 32
    return cfg.replace(**kw)
