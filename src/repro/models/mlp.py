"""The paper's ~130 kB classification MLP (784-40-10) as pure functions.

Kept deliberately tiny and flat (a dict of arrays) so the federated
simulator can vmap over a stacked per-agent copy of it cheaply.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.mnist_mlp import MLPTaskConfig

Params = Dict[str, Any]


def init_params(cfg: MLPTaskConfig, key) -> Params:
    dims = (cfg.input_dim,) + tuple(cfg.hidden_dims) + (cfg.n_classes,)
    params = {}
    for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        key, k = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k, (d_in, d_out), jnp.float32) \
            * jnp.sqrt(2.0 / d_in)
        params[f"b{i}"] = jnp.zeros((d_out,), jnp.float32)
    return params


def n_layers(params: Params) -> int:
    return sum(1 for k in params if k.startswith("w"))


def forward(params: Params, x: jax.Array) -> jax.Array:
    """x: (..., input_dim) -> logits (..., n_classes)."""
    L = n_layers(params)
    h = x
    for i in range(L):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < L - 1:
            h = jax.nn.relu(h)
    return h


def loss_fn(params: Params, x: jax.Array, y: jax.Array) -> jax.Array:
    """Mean cross-entropy."""
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def accuracy(params: Params, x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.mean(jnp.argmax(forward(params, x), axis=-1) == y)


def param_bytes(params: Params) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(params))
