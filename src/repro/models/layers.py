"""Core layers: norms, MLPs, embeddings, RoPE. Pure-functional (init/apply)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (maps to Lecun-normal for 2D)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_init(cfg: ArchConfig, d: int):
    p = {"scale": jnp.ones((d,), cfg.weight_dtype)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), cfg.weight_dtype)
    return p


def norm_apply(cfg: ArchConfig, p, x):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def soft_cap(x, cap: float):
    """Bounded pre-activation (xLSTM-7B / Gemma-2 style): cap * tanh(x/cap).

    Keeps recurrent gate pre-activations in [-cap, cap] so the exp-based
    stabilized recurrences cannot overflow, and damps the gradient of
    already-saturated gates (sech^2 factor) — the standard robustness fix
    for exp-gated recurrent cells under aggressive learning rates.
    """
    return cap * jnp.tanh(x / cap)


def rms_normalize(x, eps: float = 1e-6):
    """Parameter-free RMS normalization (qk-norm building block)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype)


# --------------------------------------------------------------------------
# MLP variants
# --------------------------------------------------------------------------

def mlp_init(cfg: ArchConfig, key, d_model: int | None = None,
             d_ff: int | None = None):
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    wd = cfg.weight_dtype
    if cfg.mlp_type == "swiglu":
        p = {"w_gate": dense_init(k1, (d, f), wd),
             "w_up": dense_init(k2, (d, f), wd),
             "w_down": dense_init(k3, (f, d), wd)}
    else:  # squared_relu | gelu: single up projection
        p = {"w_up": dense_init(k1, (d, f), wd),
             "w_down": dense_init(k2, (f, d), wd)}
        if cfg.mlp_bias:
            p["b_up"] = jnp.zeros((f,), wd)
            p["b_down"] = jnp.zeros((d,), wd)
    return p


def mlp_apply(cfg: ArchConfig, p, x):
    if cfg.mlp_type == "swiglu":
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = x @ p["w_up"]
        if "b_up" in p:
            h = h + p["b_up"]
        if cfg.mlp_type == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:  # gelu
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    angles = angles[..., None, :]                       # (..., s, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# embeddings / head
# --------------------------------------------------------------------------

def embedding_init(cfg: ArchConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"tok": embed_init(k1, (cfg.vocab_size, cfg.d_model), cfg.weight_dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), cfg.weight_dtype)
    if cfg.pos_embed == "learned":
        p["pos"] = embed_init(k3, (cfg.max_seq_len, cfg.d_model), cfg.weight_dtype)
    return p


def embed_tokens(cfg: ArchConfig, p, tokens, positions=None):
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.activation_dtype)
    if cfg.pos_embed == "learned":
        pos = positions if positions is not None else jnp.arange(tokens.shape[-1])
        x = x + jnp.take(p["pos"], pos, axis=0).astype(x.dtype)
    return x


def lm_logits(cfg: ArchConfig, p, x):
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return (x @ w.astype(x.dtype)).astype(jnp.float32)
