"""Top-level model: embeddings + block stack + LM head; train loss & decode.

Handles the modality stubs per spec: VLM patch embeddings are projected and
prepended to the token stream; audio (whisper) encoder frames are the
cross-attention memory.  Everything else is the real backbone.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.config import ArchConfig
from repro.models.layers import (dense_init, embed_tokens, embedding_init,
                                 lm_logits, norm_apply, norm_init)


def init_params(cfg: ArchConfig, key):
    k_emb, k_stack, k_out, k_proj = jax.random.split(key, 4)
    params = {
        "embed": embedding_init(cfg, k_emb),
        "stack": tf.stack_init(cfg, k_stack),
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    if cfg.encoder.kind == "vision":
        params["patch_proj"] = dense_init(
            k_proj, (cfg.encoder.d_embed, cfg.d_model), cfg.weight_dtype)
    return params


def _merge_inputs(cfg: ArchConfig, params, batch: Dict[str, Any]):
    """Embed tokens; prepend projected patch embeddings for VLM."""
    tokens = batch["tokens"]
    S = tokens.shape[-1]
    if cfg.encoder.kind == "vision":
        patches = batch["patch_embeds"].astype(cfg.activation_dtype)
        pe = patches @ params["patch_proj"]
        n_p = pe.shape[1]
        positions = jnp.arange(n_p + S)
        x_tok = embed_tokens(cfg, params["embed"], tokens,
                             positions[n_p:][None, :].repeat(tokens.shape[0], 0)
                             if cfg.pos_embed == "learned" else None)
        x = jnp.concatenate([pe, x_tok], axis=1)
        return x, positions, n_p
    x = embed_tokens(cfg, params["embed"], tokens)
    return x, jnp.arange(S), 0


def forward(cfg: ArchConfig, params, batch: Dict[str, Any]):
    """Full-sequence forward (training / prefill). Returns (logits, aux)."""
    x, positions, n_prefix = _merge_inputs(cfg, params, batch)
    memory = batch.get("memory")
    if memory is not None:
        memory = memory.astype(cfg.activation_dtype)
    x, aux = tf.stack_prefill(cfg, params["stack"], x, positions, memory)
    x = norm_apply(cfg, params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = lm_logits(cfg, params["embed"], x)
    return logits, aux


def loss_fn(cfg: ArchConfig, params, batch: Dict[str, Any]):
    """Mean next-token cross-entropy over valid labels (+ MoE aux)."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    valid = (labels >= 0)
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    task = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    aux_w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0
    return task + aux_w * aux, {"task_loss": task, "aux_loss": aux}


def per_example_loss(cfg: ArchConfig, params, batch: Dict[str, Any]):
    """Per-example mean NLL (B,) + MoE aux — the CSR-masked aggregation in
    the federated train step weights these per agent."""
    logits, aux = forward(cfg, params, batch)
    labels = batch["labels"]
    valid = (labels >= 0)
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    per_ex = jnp.sum(nll * valid, axis=-1) / jnp.maximum(
        jnp.sum(valid, axis=-1), 1)
    return per_ex, aux


def init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    return tf.stack_init_cache(cfg, batch, cache_len)


def decode_step(cfg: ArchConfig, params, cache, tokens, cur_pos,
                memory=None, patch_embeds=None):
    """One decode step. tokens: (B, 1); cur_pos: (B,). Returns (logits, cache)."""
    x = embed_tokens(cfg, params["embed"], tokens,
                     cur_pos[:, None] if cfg.pos_embed == "learned" else None)
    if memory is not None:
        memory = memory.astype(cfg.activation_dtype)
    x, new_cache = tf.stack_decode(cfg, params["stack"], cache, x, cur_pos,
                                   memory)
    x = norm_apply(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params["embed"], x)
    return logits, new_cache


# --------------------------------------------------------------------------
# analytic parameter counts (from eval_shape — exact, no allocation)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _param_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def count_params_analytic(cfg: ArchConfig, active_only: bool = False) -> int:
    shapes = _param_shapes(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        if active_only and cfg.moe is not None:
            keys = "/".join(str(p) for p in path)
            if any(w in keys for w in ("w_gate", "w_up", "w_down")) \
                    and "shared" not in keys \
                    and cfg.moe.n_experts in leaf.shape:
                # routed expert tensor (..., E, ., .) — possibly layer-stacked
                # (L, E, d, d_ff): scale to active experts
                n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total
