"""Block assembly: composable residual blocks -> scanned layer stacks.

A layer *pattern* is a tuple of sub-block kinds; ``cfg.layout_`` is a list of
(pattern_name, repeat) segments.  Each segment stacks its per-layer params
with vmap and applies them with ``lax.scan`` (one compiled body per segment —
small HLO, fast compile, TPU-friendly).

Supported kinds: attn (GQA/MLA), ffn (mlp/moe), xattn, mamba, mlstm, slstm.
The ``zamba_super`` pattern implements Zamba2's weight-shared attention block
applied before every run of `shared_every` Mamba blocks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ArchConfig
from repro.models.layers import mlp_apply, mlp_init, norm_apply, norm_init

PATTERNS = {
    "decoder": ("attn", "ffn"),
    "encdec": ("attn", "xattn", "ffn"),
    "mamba": ("mamba",),
    "mlstm": ("mlstm",),
    "slstm": ("slstm",),
}


# --------------------------------------------------------------------------
# sub-block init / apply
# --------------------------------------------------------------------------

def sub_init(cfg: ArchConfig, kind: str, key):
    kn, kb = jax.random.split(key)
    if kind == "attn":
        inner = (attn_mod.mla_init if cfg.attn_impl == "mla"
                 else attn_mod.gqa_init)(cfg, kb)
        return {"norm": norm_init(cfg, cfg.d_model), "inner": inner}
    if kind == "ffn":
        if cfg.moe is not None:
            return {"norm": norm_init(cfg, cfg.d_model),
                    "inner": moe_mod.moe_init(cfg, kb)}
        return {"norm": norm_init(cfg, cfg.d_model),
                "inner": mlp_init(cfg, kb)}
    if kind == "xattn":
        return {"norm": norm_init(cfg, cfg.d_model),
                "inner": attn_mod.xattn_init(cfg, kb)}
    if kind == "mamba":
        return {"norm": norm_init(cfg, cfg.d_model),
                "inner": ssm_mod.mamba_init(cfg, kb)}
    if kind == "mlstm":
        return {"norm": norm_init(cfg, cfg.d_model),
                "inner": xlstm_mod.mlstm_init(cfg, kb)}
    if kind == "slstm":
        return {"norm": norm_init(cfg, cfg.d_model),
                "inner": xlstm_mod.slstm_init(cfg, kb)}
    raise ValueError(kind)


def sub_prefill(cfg: ArchConfig, kind: str, p, x, positions, memory):
    """Returns (residual delta, aux_loss)."""
    xn = norm_apply(cfg, p["norm"], x)
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        if cfg.attn_impl == "mla":
            return attn_mod.mla_prefill(cfg, p["inner"], xn, positions), aux
        return attn_mod.gqa_prefill(cfg, p["inner"], xn, positions), aux
    if kind == "ffn":
        if cfg.moe is not None:
            out, aux = moe_mod.moe_apply(cfg, p["inner"], xn)
            return out, aux
        return mlp_apply(cfg, p["inner"], xn), aux
    if kind == "xattn":
        return attn_mod.xattn_apply(cfg, p["inner"], xn, memory), aux
    if kind == "mamba":
        return ssm_mod.mamba_prefill(cfg, p["inner"], xn), aux
    if kind == "mlstm":
        return xlstm_mod.mlstm_prefill(cfg, p["inner"], xn), aux
    if kind == "slstm":
        return xlstm_mod.slstm_prefill(cfg, p["inner"], xn), aux
    raise ValueError(kind)


def sub_init_cache(cfg: ArchConfig, kind: str, batch: int, cache_len: int):
    dt = cfg.activation_dtype
    if kind == "attn":
        length = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        if cfg.attn_impl == "mla":
            return attn_mod.init_mla_cache(batch, length, cfg, dt)
        return attn_mod.init_kv_cache(batch, length, cfg.n_kv_heads,
                                      cfg.head_dim_, dt)
    if kind == "mamba":
        return ssm_mod.init_mamba_cache(cfg, batch, dt)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch)
    return None


def sub_decode(cfg: ArchConfig, kind: str, p, x, cache, cur_pos, memory):
    """Returns (residual delta, new cache)."""
    xn = norm_apply(cfg, p["norm"], x)
    if kind == "attn":
        if cfg.attn_impl == "mla":
            return attn_mod.mla_decode(cfg, p["inner"], xn, cache, cur_pos)
        return attn_mod.gqa_decode(cfg, p["inner"], xn, cache, cur_pos)
    if kind == "ffn":
        if cfg.moe is not None:
            out, _ = moe_mod.moe_apply(cfg, p["inner"], xn)
            return out, None
        return mlp_apply(cfg, p["inner"], xn), None
    if kind == "xattn":
        return attn_mod.xattn_apply(cfg, p["inner"], xn, memory), None
    if kind == "mamba":
        return ssm_mod.mamba_decode(cfg, p["inner"], xn, cache)
    if kind == "mlstm":
        return xlstm_mod.mlstm_decode(cfg, p["inner"], xn, cache)
    if kind == "slstm":
        return xlstm_mod.slstm_decode(cfg, p["inner"], xn, cache)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# layer (pattern) level
# --------------------------------------------------------------------------

def layer_init(cfg: ArchConfig, pattern: str, key):
    kinds = PATTERNS[pattern]
    keys = jax.random.split(key, len(kinds))
    return {k: sub_init(cfg, k, kk) for k, kk in zip(kinds, keys)}


def layer_prefill(cfg, pattern, p, x, positions, memory):
    aux = jnp.zeros((), jnp.float32)
    for kind in PATTERNS[pattern]:
        delta, a = sub_prefill(cfg, kind, p[kind], x, positions, memory)
        x = x + delta
        aux = aux + a
    return x, aux


def layer_init_cache(cfg, pattern, batch, cache_len):
    return {k: sub_init_cache(cfg, k, batch, cache_len)
            for k in PATTERNS[pattern]
            if sub_init_cache(cfg, k, batch, cache_len) is not None}


def layer_decode(cfg, pattern, p, x, cache, cur_pos, memory):
    new_cache = {}
    for kind in PATTERNS[pattern]:
        delta, nc = sub_decode(cfg, kind, p[kind], x,
                               cache.get(kind) if cache else None,
                               cur_pos, memory)
        x = x + delta
        if nc is not None:
            new_cache[kind] = nc
    return x, new_cache


# --------------------------------------------------------------------------
# stack level: segments of scanned layers (+ zamba hybrid special case)
# --------------------------------------------------------------------------

def stack_init(cfg: ArchConfig, key):
    params: Dict[str, Any] = {"segments": []}
    segs = list(cfg.layout_)
    keys = jax.random.split(key, len(segs) + 1)
    for (pattern, repeat), k in zip(segs, keys[:-1]):
        if pattern == "zamba_super":
            n_super = repeat
            km, ks = jax.random.split(k)
            mamba_keys = jax.random.split(km, n_super * cfg.shared_every) \
                .reshape(n_super, cfg.shared_every)
            stacked = jax.vmap(jax.vmap(
                lambda kk: layer_init(cfg, "mamba", kk)))(mamba_keys)
            params["segments"].append(stacked)
            params["shared_attn"] = layer_init(cfg, "decoder", ks)
        else:
            lkeys = jax.random.split(k, repeat)
            params["segments"].append(
                jax.vmap(lambda kk: layer_init(cfg, pattern, kk))(lkeys))
    return params


def stack_prefill(cfg: ArchConfig, params, x, positions, memory=None,
                  remat: bool = True):
    """Forward through all segments.  Each layer application is wrapped in
    jax.checkpoint (recompute-on-backward) so scanned 32k-sequence training
    keeps O(layers · B · S · d) residual memory instead of saving every
    attention/SSM intermediate."""
    aux_total = jnp.zeros((), jnp.float32)

    def lp(pattern):
        f = lambda p, h, pos, mem: layer_prefill(cfg, pattern, p, h, pos, mem)
        return jax.checkpoint(f) if remat else f

    for seg_params, (pattern, repeat) in zip(params["segments"], cfg.layout_):
        if pattern == "zamba_super":
            shared = params["shared_attn"]
            attn_f, mamba_f = lp("decoder"), lp("mamba")

            def super_body(carry, layer_p):
                h, aux = carry
                h, a0 = attn_f(shared, h, positions, memory)

                def inner(c, lpm):
                    hh, au = c
                    hh, a = mamba_f(lpm, hh, positions, memory)
                    return (hh, au + a), None

                (h, aux), _ = jax.lax.scan(inner, (h, aux + a0), layer_p)
                return (h, aux), None

            (x, aux_total), _ = jax.lax.scan(
                super_body, (x, aux_total), seg_params)
        else:
            layer_f = lp(pattern)

            def body(carry, layer_p, _f=layer_f):
                h, aux = carry
                h, a = _f(layer_p, h, positions, memory)
                return (h, aux + a), None

            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), seg_params)
    return x, aux_total


def stack_init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    caches = []
    for pattern, repeat in cfg.layout_:
        if pattern == "zamba_super":
            attn_c = layer_init_cache(cfg, "decoder", batch, cache_len)
            mamba_c = layer_init_cache(cfg, "mamba", batch, cache_len)
            stack = lambda c, n: jax.tree.map(
                lambda t: jnp.broadcast_to(t, (n,) + t.shape).copy(), c)
            caches.append({"shared": stack(attn_c, repeat),
                           "mamba": jax.tree.map(
                               lambda t: jnp.broadcast_to(
                                   t, (repeat, cfg.shared_every) + t.shape
                               ).copy(), mamba_c)})
        else:
            c = layer_init_cache(cfg, pattern, batch, cache_len)
            caches.append(jax.tree.map(
                lambda t: jnp.broadcast_to(t, (repeat,) + t.shape).copy(), c))
    return caches


def stack_decode(cfg: ArchConfig, params, caches, x, cur_pos, memory=None):
    new_caches = []
    for seg_params, seg_cache, (pattern, repeat) in zip(
            params["segments"], caches, cfg.layout_):
        if pattern == "zamba_super":
            shared = params["shared_attn"]

            def super_body(h, scan_in):
                layer_p, c_attn, c_mamba = scan_in
                h, nc_attn = layer_decode(cfg, "decoder", shared, h, c_attn,
                                          cur_pos, memory)

                def inner(hh, lp_c):
                    lp, cc = lp_c
                    hh, nc = layer_decode(cfg, "mamba", lp, hh, cc, cur_pos,
                                          memory)
                    return hh, nc

                h, nc_mamba = jax.lax.scan(inner, h, (layer_p, c_mamba))
                return h, (nc_attn, nc_mamba)

            x, (nc_a, nc_m) = jax.lax.scan(
                super_body, x, (seg_params, seg_cache["shared"],
                                seg_cache["mamba"]))
            new_caches.append({"shared": nc_a, "mamba": nc_m})
        else:
            def body(h, scan_in, _pattern=pattern):
                layer_p, cc = scan_in
                h, nc = layer_decode(cfg, _pattern, layer_p, h, cc, cur_pos,
                                     memory)
                return h, nc

            x, nc = jax.lax.scan(body, x, (seg_params, seg_cache))
            new_caches.append(nc)
    return x, new_caches
