"""Spec-keyed compiled-program caching (DESIGN.md §10).

Two layers kill redundant compilation:

  1. **Persistent XLA compilation cache** (cross-process): when
     ``REPRO_CACHE_DIR`` is set (or a dir is passed explicitly),
     ``enable_persistent_cache`` points JAX's persistent compilation cache
     at it with the thresholds dropped to zero, so every jitted program —
     sweep rounds, figure grids, benchmarks, CI re-runs — compiles once
     per machine and loads from disk afterwards.  The XLA cache keys on
     the serialized HLO + compile options + backend, so it is safe across
     unrelated programs by construction.

  2. **In-process program registry** (cross-call): ``get_or_build`` memoizes
     built program bundles (the jitted round fn + eval core of a sweep
     group) under an explicit :class:`ProgramKey`.  The key carries
     everything that changes the traced program but is NOT visible in the
     jit signature: the widened ``ResolvedScenario.static_key``, the sweep
     width S and which scalars are batched, the baked (non-batched)
     hp/het/cadence values, the donation signature, the device + mesh
     fingerprint, and the ``kernels.ops`` interpret/fused flags — the last
     three MUST enter the key or a backend/mesh/interpret flip would serve
     a stale program.  A registry hit skips Python tracing entirely; the
     persistent cache below it skips XLA compilation.

Trace accounting: round bodies call :func:`note_trace` from inside their
Python trace, so ``trace_count(label)`` counts actual (re)traces — the
number benchmarks/CI pin to 1 for a mixed-cadence group (BENCH_PR9.json).
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax

ENV_CACHE_DIR = "REPRO_CACHE_DIR"

_persistent_dir: Optional[str] = None
_REGISTRY: Dict[Any, Any] = {}
_TRACES: Dict[str, int] = {}
_stats = {"hits": 0, "misses": 0}


# --------------------------------------------------------------------------
# layer 1: the persistent XLA compilation cache
# --------------------------------------------------------------------------

def enable_persistent_cache(path: Optional[str] = None) -> Optional[str]:
    """Wire JAX's persistent compilation cache to ``path`` (default: the
    ``REPRO_CACHE_DIR`` env var).  Idempotent; returns the active cache dir
    or None when disabled (env unset and no path given).

    Thresholds are dropped to zero so even the small CI/test programs
    persist — the default min-compile-time gate would skip exactly the
    programs our warm-start asserts measure.
    """
    global _persistent_dir
    target = path if path is not None else os.environ.get(ENV_CACHE_DIR)
    if not target:
        return _persistent_dir
    target = os.path.abspath(target)
    if _persistent_dir == target:
        return _persistent_dir
    os.makedirs(target, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", target)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except AttributeError:       # older jax: size gate doesn't exist
        pass
    # jax materializes its cache object once, at the first compile — if
    # anything compiled before this call (data gen, init_params), the dir
    # update alone is silently ignored for the rest of the process.
    try:
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:            # noqa: BLE001 — private API moved
        pass
    _persistent_dir = target
    return _persistent_dir


def persistent_cache_dir() -> Optional[str]:
    """The active persistent-cache dir (None = disabled)."""
    return _persistent_dir


# --------------------------------------------------------------------------
# layer 2: the in-process program registry
# --------------------------------------------------------------------------

def device_fingerprint(devices=None) -> Tuple:
    """Hashable identity of the devices a program was built against."""
    devices = jax.devices() if devices is None else list(devices)
    return tuple((d.platform, d.device_kind, d.id) for d in devices)


def mesh_fingerprint(mesh) -> Optional[Tuple]:
    """Hashable identity of a jax.sharding.Mesh (None passes through):
    axis names/sizes plus the flat device list."""
    if mesh is None:
        return None
    return (tuple(mesh.shape.items()),
            device_fingerprint(mesh.devices.flat))


def ops_flags(fused: bool) -> Tuple:
    """The kernels.ops lowering flags a traced program bakes in."""
    from repro.kernels import ops
    return ("interpret", ops.interpret_mode(), "fused", bool(fused))


class ProgramKey(NamedTuple):
    """The full identity of a built program bundle (DESIGN.md §10)."""
    kind: str                    # e.g. "sweep"
    static_key: Tuple            # widened ResolvedScenario.static_key
    n_scenarios: int             # sweep width S (a shape)
    dyn_names: Tuple[str, ...]   # which scalars are batched (S,) data
    baked: Tuple                 # non-batched hp/het/cadence scalar values
    cadence: Any                 # simulator.Cadence bounds or None
    data_axes: Tuple             # vmap in_axes of the stacked fed arrays
    donation: Tuple[int, ...]    # donate_argnums signature
    devices: Tuple               # device_fingerprint()
    mesh: Optional[Tuple]        # mesh_fingerprint()
    flags: Tuple                 # ops_flags(): interpret + fused


def get_or_build(key, builder: Callable[[], Any], *, enabled: bool = True):
    """Return the program bundle registered under ``key``, building (and
    registering) it on first use.  ``enabled=False`` (the ScenarioSpec
    ``program_cache=False`` opt-out) always builds fresh and never touches
    the registry."""
    if not enabled:
        return builder()
    try:
        bundle = _REGISTRY[key]
    except KeyError:
        _stats["misses"] += 1
        bundle = _REGISTRY[key] = builder()
        return bundle
    _stats["hits"] += 1
    return bundle


def note_trace(label: str) -> None:
    """Called from inside a round body's Python trace: one call == one
    actual (re)trace of that program family."""
    _TRACES[label] = _TRACES.get(label, 0) + 1


def trace_count(label: str) -> int:
    return _TRACES.get(label, 0)


def stats() -> Dict[str, int]:
    return dict(_stats, entries=len(_REGISTRY), **{
        f"traces/{k}": v for k, v in _TRACES.items()})


def reset_stats() -> None:
    """Zero the hit/miss/trace counters (the registry itself survives)."""
    _stats["hits"] = _stats["misses"] = 0
    _TRACES.clear()


def clear() -> None:
    """Drop the registry + counters (tests; frees held jitted callables)."""
    _REGISTRY.clear()
    reset_stats()
