"""Deterministic fault-injection subsystem (DESIGN.md §11).

A :class:`FaultPlan` is a declarative, seeded, fully reproducible fault
schedule for one scenario: per-agent churn windows (hard disconnects
beyond the benign latency model), whole-RSU outage intervals,
corrupted-update injection (NaN/Inf payloads, scaled/byzantine payloads,
replayed stale rows) and event-queue perturbations for the serve loop
(duplicate admissions, clock skew).  Plans hash into
``ScenarioSpec.cache_key`` and are **lowered to mask data, not program
structure**: :meth:`FaultPlan.lower` produces a :class:`FaultSchedule`
of per-tick numpy arrays that ride into the jitted round/tick programs
as ordinary operands, so a grid of different fault plans still compiles
to ONE sweep program (only :meth:`FaultPlan.static_fingerprint` — the
guard *structure* — is part of ``static_key``).

The benign lowering is a bitwise no-op by construction: every fold the
engines apply is of the form ``w * 1.0`` (exact in every IEEE format),
``mask & True`` or ``where(False, x, y) == y``, so an empty/disabled
plan leaves each engine bit-identical to the fault-free program — the
zero-fault anchor in ``tests/test_faults.py`` pins exactly this.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax.numpy as jnp

__all__ = [
    "ChurnWindow", "RsuOutage", "CorruptSpec", "FaultPlan",
    "FaultSchedule", "FAULT_FIELDS", "apply_corruption",
    "skewed_time", "duplicate_count",
]

_CORRUPT_KINDS = ("nan", "inf", "scale", "stale")


@dataclasses.dataclass(frozen=True)
class ChurnWindow:
    """A seeded fraction of the fleet is hard-disconnected for ticks
    ``[start, stop)`` (``stop <= 0`` = never reconnects).  Which agents
    go dark is a seeded without-replacement draw — reproducible and
    independent of evaluation order."""
    frac: float
    start: int = 0
    stop: int = 0
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class RsuOutage:
    """RSU ``rsu`` is unreachable for ticks ``[start, stop)`` — uploads
    to it are dropped, its buffer ages under ``buffer_keep``, and it is
    excluded from cloud aggregation via the existing mass-guard.  On the
    recovery tick it re-anchors to the cloud master (``stop <= 0`` =
    dark forever, no re-anchor)."""
    rsu: int
    start: int = 0
    stop: int = 0


@dataclasses.dataclass(frozen=True)
class CorruptSpec:
    """Per-tick seeded corruption of submitted updates during ticks
    ``[start, stop)``: each tick an independent ``frac`` of agents is
    drawn (``default_rng([plan.seed, seed, i, tick])``) and their
    trained payload is replaced/perturbed before aggregation.

    kinds: ``nan`` / ``inf`` — payload filled with the non-finite value
    (screened by ``guard_nonfinite``); ``scale`` — payload multiplied by
    ``scale`` (a byzantine blow-up, screened by ``norm_clip``);
    ``stale`` — the agent replays its previous round's row."""
    kind: str
    frac: float
    start: int = 0
    stop: int = 0
    scale: float = 10.0
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative fault schedule + guard configuration for one scenario.

    ``churn`` / ``outages`` / ``corrupt`` are tick-indexed schedules
    lowered to data masks; ``dup_frac`` / ``clock_skew`` perturb the
    serve loop's event queue host-side (per-event seeded, stateless — so
    crash-resume replays them identically).  ``guard_nonfinite`` and
    ``norm_clip`` configure the quarantine gate (the only *structural*
    part of the plan — see :meth:`static_fingerprint`)."""
    churn: Tuple[ChurnWindow, ...] = ()
    outages: Tuple[RsuOutage, ...] = ()
    corrupt: Tuple[CorruptSpec, ...] = ()
    dup_frac: float = 0.0
    clock_skew: float = 0.0
    guard_nonfinite: bool = True
    norm_clip: float = 0.0
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "churn", tuple(
            c if isinstance(c, ChurnWindow) else ChurnWindow(**dict(c))
            for c in self.churn))
        object.__setattr__(self, "outages", tuple(
            o if isinstance(o, RsuOutage) else RsuOutage(**dict(o))
            for o in self.outages))
        object.__setattr__(self, "corrupt", tuple(
            c if isinstance(c, CorruptSpec) else CorruptSpec(**dict(c))
            for c in self.corrupt))

    # -- validation ------------------------------------------------------
    def validate(self, n_rsus: Optional[int] = None) -> "FaultPlan":
        for w in self.churn:
            assert 0.0 <= w.frac <= 1.0, f"churn frac {w.frac} not in [0,1]"
            assert w.start >= 0, "churn start must be >= 0"
        for o in self.outages:
            assert o.rsu >= 0, "outage rsu must be >= 0"
            if n_rsus is not None:
                assert o.rsu < n_rsus, \
                    f"outage rsu {o.rsu} outside fleet of {n_rsus} RSUs"
            assert o.start >= 0, "outage start must be >= 0"
        for c in self.corrupt:
            assert c.kind in _CORRUPT_KINDS, \
                f"corrupt kind {c.kind!r} not in {_CORRUPT_KINDS}"
            assert 0.0 <= c.frac <= 1.0, f"corrupt frac {c.frac} not in [0,1]"
        assert 0.0 <= self.dup_frac < 1.0, "dup_frac must be in [0, 1)"
        assert self.clock_skew >= 0.0, "clock_skew must be >= 0"
        assert self.norm_clip >= 0.0, "norm_clip must be >= 0"
        return self

    # -- program-structure fingerprint ----------------------------------
    @property
    def static_fingerprint(self) -> tuple:
        """The part of the plan that is baked into the traced program:
        the guard algebra flag and the exact clip threshold (a compiled
        constant inside ``screen_updates``).  Schedules (churn / outages /
        corruption) are pure data and deliberately absent, so a fault
        GRID — many plans, one guard config — shares one compiled
        program (trace-count-pinned in tests/test_faults.py)."""
        return (bool(self.guard_nonfinite), float(self.norm_clip))

    @property
    def injects(self) -> bool:
        return bool(self.churn or self.outages or self.corrupt)

    @property
    def corrupts(self) -> bool:
        return bool(self.corrupt)

    # -- (de)serialization ----------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        d = dict(d)
        d["churn"] = tuple(ChurnWindow(**dict(c)) for c in d.get("churn", ()))
        d["outages"] = tuple(RsuOutage(**dict(o))
                             for o in d.get("outages", ()))
        d["corrupt"] = tuple(CorruptSpec(**dict(c))
                             for c in d.get("corrupt", ()))
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown FaultPlan fields: {sorted(unknown)}")
        return cls(**d)

    # -- lowering --------------------------------------------------------
    def lower(self, n_agents: int, n_rsus: int,
              n_ticks: int) -> "FaultSchedule":
        """Lower the declarative schedule to per-tick mask arrays over a
        global tick clock of ``n_ticks`` ticks (rounds × lar for the
        round engines, an event-count bound for serving).  Ticks beyond
        ``n_ticks`` clip to the last row (schedules are frozen there)."""
        A, R, T = int(n_agents), int(n_rsus), max(1, int(n_ticks))
        agent_up = np.ones((T, A), np.float32)
        rsu_up = np.ones((T, R), np.float32)
        reanchor = np.zeros((T, R), np.float32)
        poison_mask = np.zeros((T, A), np.float32)
        poison_val = np.zeros((T, A), np.float32)
        scale = np.ones((T, A), np.float32)
        stale = np.zeros((T, A), np.float32)
        for wi, w in enumerate(self.churn):
            k = int(round(w.frac * A))
            rng = np.random.default_rng([self.seed, w.seed, wi, 0xC4])
            idx = rng.choice(A, size=min(k, A), replace=False)
            stop = w.stop if w.stop > 0 else T
            agent_up[w.start:stop, idx] = 0.0
        for o in self.outages:
            if o.rsu >= R:
                continue
            stop = o.stop if o.stop > 0 else T
            rsu_up[o.start:stop, o.rsu] = 0.0
            if o.start < stop < T:
                reanchor[stop, o.rsu] = 1.0
        for ci, c in enumerate(self.corrupt):
            stop = c.stop if c.stop > 0 else T
            fill = np.float32("nan") if c.kind == "nan" \
                else np.float32("inf")
            for t in range(max(0, c.start), min(stop, T)):
                rng = np.random.default_rng([self.seed, c.seed, ci, t])
                hit = rng.random(A) < c.frac
                if c.kind in ("nan", "inf"):
                    poison_mask[t, hit] = 1.0
                    poison_val[t, hit] = fill
                elif c.kind == "scale":
                    scale[t, hit] = np.float32(c.scale)
                else:  # stale replay
                    stale[t, hit] = 1.0
        return FaultSchedule(agent_up, rsu_up, reanchor, poison_mask,
                             poison_val, scale, stale)


# field order matters: it is the canonical key order everywhere the
# schedule crosses a jit boundary (scan xs, vmapped sweep operands).
FAULT_FIELDS = ("agent_up", "rsu_up", "reanchor", "poison_mask",
                "poison_val", "scale", "stale")


class FaultSchedule(NamedTuple):
    """Lowered per-tick fault masks.  (T, A) float32 agent-side arrays,
    (T, R) float32 RSU-side arrays.  The benign schedule is all-ones
    up/scale and all-zeros reanchor/poison/stale — every engine fold of
    these values is a bitwise identity."""
    agent_up: np.ndarray     # (T, A)  1 = connected
    rsu_up: np.ndarray       # (T, R)  1 = reachable
    reanchor: np.ndarray     # (T, R)  1 = re-anchor to cloud this tick
    poison_mask: np.ndarray  # (T, A)  1 = payload replaced by poison_val
    poison_val: np.ndarray   # (T, A)  NaN/Inf fill value
    scale: np.ndarray        # (T, A)  payload multiplier (1 = benign)
    stale: np.ndarray        # (T, A)  1 = replay previous round's row

    @classmethod
    def benign(cls, n_agents: int, n_rsus: int,
               n_ticks: int) -> "FaultSchedule":
        return FaultPlan().lower(n_agents, n_rsus, n_ticks)

    @property
    def n_ticks(self) -> int:
        return self.agent_up.shape[0]

    def tick_slice(self, t: int) -> dict:
        """Per-tick (A,)/(R,) mask vectors; ticks past the end clip."""
        t = min(int(t), self.n_ticks - 1)
        return {k: getattr(self, k)[t] for k in FAULT_FIELDS}

    def round_slice(self, r: int, lar: int) -> dict:
        """Per-round (lar, A)/(lar, R) stacks for the scan-based round
        engines; rows past the end clip to the last tick."""
        idx = np.minimum(np.arange(r * lar, (r + 1) * lar),
                         self.n_ticks - 1)
        return {k: getattr(self, k)[idx] for k in FAULT_FIELDS}

    def stacked_rounds(self, rounds: int, lar: int) -> dict:
        """All rounds at once: dict of (rounds, lar, ·) arrays — the
        sweep engine's per-scenario fault operand."""
        return {k: np.stack([self.round_slice(r, lar)[k]
                             for r in range(rounds)])
                for k in FAULT_FIELDS}


def apply_corruption(trained, prev_rows, f):
    """Apply the lowered per-tick corruption masks to freshly trained
    agent rows (device-side, inside the round/tick program).  ``f`` is a
    tick slice of :data:`FAULT_FIELDS` arrays; ``prev_rows`` is the
    agent buffer before this tick's update (the stale-replay payload).
    Benign masks (scale=1, poison=0, stale=0) are a bitwise no-op."""
    dt = trained.dtype
    out = trained * f["scale"][:, None].astype(dt)
    out = jnp.where(f["poison_mask"][:, None] > 0,
                    f["poison_val"][:, None].astype(dt), out)
    return jnp.where(f["stale"][:, None] > 0, prev_rows.astype(dt), out)


# -- serve-loop queue perturbations (host-side, per-event seeded) --------

def skewed_time(plan: FaultPlan, loop_seed: int, seq: int,
                t: float) -> float:
    """Clock-skewed admission time for event ``seq``.  Seeded per event
    (stateless), so a resumed serve loop replays the identical skew."""
    if plan.clock_skew <= 0.0:
        return t
    rng = np.random.default_rng([plan.seed, loop_seed, int(seq), 0x5E])
    return float(t + rng.normal(0.0, plan.clock_skew))


def duplicate_count(plan: FaultPlan, loop_seed: int, seq: int) -> int:
    """Number of duplicate admissions for event ``seq`` (0 or 1), seeded
    per event so replay/resume see the same duplicates."""
    if plan.dup_frac <= 0.0:
        return 0
    rng = np.random.default_rng([plan.seed, loop_seed, int(seq), 0xD0])
    return int(rng.random() < plan.dup_frac)
