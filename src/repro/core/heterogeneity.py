"""Heterogeneity model (paper Sec. III, Tab. I): CSR, SCD, FSR, LAR —
plus the arrival-latency extension for the semi-async engine.

Connectivity is a per-round process: an agent that (re)connects stays
connected for SCD rounds (Stable Connection Duration), then re-draws with
probability CSR.  FSR draws how many of the requested E local epochs each
agent completes (< 1 epoch == disconnected, per the paper).  All draws are
functional (keyed) so experiments are reproducible.

Arrival latency (DESIGN.md §6, cf. arXiv:2110.09073): each agent's finished
update reaches its RSU ``d`` sub-round ticks after it was computed, with
``d`` drawn from a censored geometric on ``[0, max_delay]`` (tail mass
clips to the bound).  With ``max_delay=0`` every arrival is immediate and
the semi-async engine degenerates to the synchronous ones.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HeterogeneityModel:
    csr: float = 1.0       # Connection Success Ratio  in [0, 1]
    scd: int = 1           # Stable Connection Duration (rounds)
    fsr: float = 1.0       # Full-task Success Ratio   in [0, 1]
    lar: int = 1           # Local Aggregation Rounds (per RSU, paper <= 50)
    max_delay: int = 0     # arrival-latency bound D (sub-round ticks)
    delay_p: float = 0.0   # geometric tail of the latency draw in [0, 1]

    def validate(self):
        assert 0.0 <= self.csr <= 1.0 and 0.0 <= self.fsr <= 1.0
        assert self.scd >= 1 and self.lar >= 1
        assert self.max_delay >= 0 and 0.0 <= self.delay_p <= 1.0
        return self


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ConnState:
    """Per-agent connection countdown: >0 connected, 0 disconnected."""
    remaining: jax.Array    # (A,) int32

    def tree_flatten(self):
        return (self.remaining,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_conn_state(n_agents: int) -> ConnState:
    return ConnState(remaining=jnp.zeros((n_agents,), jnp.int32))


def step_connectivity(key, state: ConnState,
                      het: HeterogeneityModel) -> Tuple[ConnState, jax.Array]:
    """Advance one round. Returns (new state, connected mask (A,) bool)."""
    rem = jnp.maximum(state.remaining - 1, 0)
    need_draw = rem == 0
    draw = jax.random.bernoulli(key, het.csr, rem.shape)
    rem = jnp.where(need_draw & draw, het.scd, rem)
    connected = rem > 0
    return ConnState(remaining=rem), connected


def sample_epochs(key, n_agents: int, het: HeterogeneityModel,
                  requested_e: int) -> jax.Array:
    """FSR draw: epochs completed per agent (0 == counts as disconnected)."""
    full = jax.random.bernoulli(key, het.fsr, (n_agents,))
    partial = jax.random.randint(jax.random.fold_in(key, 1), (n_agents,),
                                 0, max(requested_e, 1))
    return jnp.where(full, requested_e, partial).astype(jnp.int32)


def sample_latency(key, n_agents: int, het: HeterogeneityModel) -> jax.Array:
    """Arrival latency per agent in sub-round ticks: CENSORED geometric —
    ``P(d) = (1-p)·p^d`` for ``d < max_delay`` with the remaining tail mass
    ``p^max_delay`` piled on ``max_delay`` (inverse-CDF then clip, NOT a
    renormalized truncation), so ``P(d=0) == 1 - delay_p`` exactly — the
    identity the async benchmark's timely-participation calibration uses.

    ``delay_p=0`` (or ``max_delay=0``) is the synchronous limit (all zeros);
    ``delay_p=1`` pins every agent at the full ``max_delay`` — the
    all-arrivals-stale regime the property tests exercise.

    ``max_delay`` is STATIC (it bounds the in-flight countdown), but
    ``delay_p`` may be a traced scalar — scenario sweeps
    (``fedsim/sweep``) batch it along the sweep axis, so the limit
    branches become ``jnp.where`` guards under tracing (identical values
    to the concrete branches for any fixed p).
    """
    if het.max_delay == 0:
        return jnp.zeros((n_agents,), jnp.int32)
    p = het.delay_p
    concrete = isinstance(p, (int, float))
    if concrete and p <= 0.0:
        return jnp.zeros((n_agents,), jnp.int32)
    if concrete and p >= 1.0:
        return jnp.full((n_agents,), het.max_delay, jnp.int32)
    u = jax.random.uniform(key, (n_agents,), minval=1e-7, maxval=1.0)
    if concrete:
        d = jnp.floor(jnp.log(u) / jnp.log(p))
    else:
        pc = jnp.clip(jnp.asarray(p, jnp.float32), 1e-7, 1.0 - 1e-7)
        d = jnp.floor(jnp.log(u) / jnp.log(pc))
        d = jnp.where(p <= 0.0, 0,
                      jnp.where(p >= 1.0, het.max_delay, d))
    return jnp.clip(d, 0, het.max_delay).astype(jnp.int32)


def connectivity_trace(key, n_agents: int, n_rounds: int,
                       het: HeterogeneityModel) -> jax.Array:
    """Pre-sample the full (n_rounds, A) connectivity mask via scan."""
    keys = jax.random.split(key, n_rounds)

    def body(state, k):
        state, mask = step_connectivity(k, state, het)
        return state, mask

    _, masks = jax.lax.scan(body, init_conn_state(n_agents), keys)
    return masks
