"""Baselines as H²-Fed parameterizations (paper Sec. V):

  (i)   mu_{k,l}=0, L=1  -> FedAvg   [McMahan et al. 2017]
  (ii)  mu_{k,l}>0, L=1  -> FedProx  [Li et al. 2020]
  (iii) mu_{k,l}=0, L>1  -> HierFAVG [Liu et al. 2020]

The property tests assert these equivalences numerically against the
framework's general path.
"""
from __future__ import annotations

from repro.core.h2fed import H2FedParams


def fedavg(lr: float = 0.05, local_epochs: int = 1) -> H2FedParams:
    """FedAvg: no proximal terms, single aggregation layer (LAR=1 makes the
    RSU layer a pass-through so aggregation is effectively flat)."""
    return H2FedParams(mu1=0.0, mu2=0.0, lar=1, local_epochs=local_epochs,
                       lr=lr, n_layers=1).validate()


def fedprox(mu: float = 0.01, lr: float = 0.05,
            local_epochs: int = 1) -> H2FedParams:
    """FedProx: single proximal term toward the (single-layer) global model."""
    return H2FedParams(mu1=mu, mu2=0.0, lar=1, local_epochs=local_epochs,
                       lr=lr, n_layers=1).validate()


def hierfavg(lar: int = 5, lr: float = 0.05,
             local_epochs: int = 1) -> H2FedParams:
    """HierFAVG: hierarchical aggregation, no proximal stabilization."""
    return H2FedParams(mu1=0.0, mu2=0.0, lar=lar, local_epochs=local_epochs,
                       lr=lr, n_layers=2).validate()


def h2fed(mu1: float = 0.01, mu2: float = 0.005, lar: int = 5,
          lr: float = 0.05, local_epochs: int = 1) -> H2FedParams:
    """The paper's framework with both proximal layers active."""
    return H2FedParams(mu1=mu1, mu2=mu2, lar=lar, local_epochs=local_epochs,
                       lr=lr, n_layers=2).validate()


BASELINES = {"fedavg": fedavg, "fedprox": fedprox, "hierfavg": hierfavg,
             "h2fed": h2fed}
