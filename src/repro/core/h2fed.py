"""H²-Fed objective (paper Eq. 4/6): dual proximal terms, one per
aggregation layer.

    min_w  F(w) + (mu1/2)·||w − w_rsu||² + (mu2/2)·||w − w_cloud||²

The proximal penalty is generic over parameter pytrees.  ``H2FedParams``
carries the full tunable surface of the framework; ``baselines.py`` shows
that FedAvg / FedProx / HierFAVG are parameterizations of it (paper Sec. V).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class H2FedParams:
    """Framework parameter set  M_k = {mu_{k,l}} plus cadence knobs."""
    mu1: float = 0.01      # agent->RSU proximal weight (layer l=1)
    mu2: float = 0.005     # agent->cloud proximal weight (layer l=2)
    lar: int = 5           # Local Aggregation Rounds per global round
    local_epochs: int = 1  # E: local training epochs per agent per LAR
    lr: float = 0.05       # agent SGD learning rate
    n_layers: int = 2      # L: aggregation layers (2 = RSU + cloud)

    def validate(self):
        assert self.mu1 >= 0 and self.mu2 >= 0
        assert self.lar >= 1 and self.local_epochs >= 1
        assert self.n_layers in (1, 2)
        return self


def sq_norm(tree: PyTree) -> jax.Array:
    """Sum of squared L2 norms over all leaves (float32 accumulation)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(lambda x, y: x.astype(jnp.float32)
                        - y.astype(jnp.float32), a, b)


def dual_proximal_penalty(w: PyTree, w_rsu: PyTree, w_cloud: PyTree,
                          mu1: float, mu2: float) -> jax.Array:
    """(mu1/2)||w − w_rsu||² + (mu2/2)||w − w_cloud||²  (Eq. 6)."""
    pen = jnp.zeros((), jnp.float32)
    if mu1:
        pen = pen + 0.5 * mu1 * sq_norm(tree_sub(w, w_rsu))
    if mu2:
        pen = pen + 0.5 * mu2 * sq_norm(tree_sub(w, w_cloud))
    return pen


def h2fed_objective(task_loss_fn: Callable[[PyTree], jax.Array],
                    hp: H2FedParams) -> Callable:
    """Wrap a task loss F(w) into the H²-Fed objective h_k(·)."""

    def objective(w: PyTree, w_rsu: PyTree, w_cloud: PyTree) -> jax.Array:
        return task_loss_fn(w) + dual_proximal_penalty(
            w, w_rsu, w_cloud, hp.mu1, hp.mu2)

    return objective


def proximal_grad_terms(w: PyTree, w_rsu: PyTree, w_cloud: PyTree,
                        mu1: float, mu2: float) -> PyTree:
    """Closed-form gradient of the penalty: mu1(w−w_rsu) + mu2(w−w_cloud).

    Used by the fused update path (kernels/dual_proximal_sgd) so the penalty
    never needs autodiff — the anchors enter the optimizer step directly.
    """
    return jax.tree.map(
        lambda x, a1, a2: (mu1 * (x.astype(jnp.float32) - a1.astype(jnp.float32))
                           + mu2 * (x.astype(jnp.float32) - a2.astype(jnp.float32))),
        w, w_rsu, w_cloud)


def proximal_sgd_step(w: PyTree, grads: PyTree, w_rsu: PyTree, w_cloud: PyTree,
                      hp: H2FedParams) -> PyTree:
    """w ← w − lr·(∇F(w) + mu1(w−w_rsu) + mu2(w−w_cloud))  (Alg. 1 line 4)."""
    def upd(x, g, a1, a2):
        xf = x.astype(jnp.float32)
        step = g.astype(jnp.float32) \
            + hp.mu1 * (xf - a1.astype(jnp.float32)) \
            + hp.mu2 * (xf - a2.astype(jnp.float32))
        return (xf - hp.lr * step).astype(x.dtype)
    return jax.tree.map(upd, w, grads, w_rsu, w_cloud)
