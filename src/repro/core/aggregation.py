"""Hierarchical CSR-masked weighted aggregation (paper Alg. 2 line 8,
Alg. 3 line 6).

All functions operate on *stacked* parameter pytrees: every leaf carries a
leading agent (or RSU) axis.  Weights are data-volume weights n_i/n masked by
connectivity; aggregation renormalizes over the surviving mass so that a
partial cohort still produces a convex combination (FedAvg semantics under
partial participation).

This module is the REFERENCE implementation of the weighting algebra:
``build_weight_matrix`` / ``cohort_mass`` / ``normalized_weights`` are the
single source of truth shared by the tree-map path here, the Pallas matmul
kernel (kernels/masked_hier_agg re-exports them), and the sharded engine
(fedsim/sharded) — tests pin the kernel paths against these.

It also owns the STALENESS algebra of the semi-async engine (DESIGN.md §6):
``staleness_weights`` (the decay schedule applied to late arrivals),
``scatter_accumulate`` (the unnormalized segment-sum late-merge the Pallas
route in kernels/ops is pinned against), and ``buffer_absorb`` (the running
cohort-mass RSU-buffer merge that keeps weights normalized as stragglers
trickle in).  ``fedsim/async_engine`` and ``launch/h2fed_round
--async-rounds`` both consume exactly these functions.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def normalized_weights(weights: jax.Array,
                       mask: Optional[jax.Array] = None,
                       ) -> Tuple[jax.Array, jax.Array]:
    """Masked weights normalized to sum 1; uniform fallback on zero mass.

    Returns (wn (A,), mass scalar).  The uniform fallback keeps downstream
    math total — callers that must keep the previous model on a dead cohort
    guard on the returned mass.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    mass = jnp.sum(w)
    safe = jnp.where(mass > 0, mass, 1.0)
    wn = jnp.where(mass > 0, w / safe, jnp.ones_like(w) / w.shape[0])
    return wn, mass


def cohort_mass(weights: jax.Array, mask: jax.Array,
                rsu_assign: jax.Array, n_rsus: int) -> jax.Array:
    """Surviving data mass per RSU: Σ_{a∈cohort(r)} m_a·w_a  ->  (R,)."""
    w = weights.astype(jnp.float32) * mask.astype(jnp.float32)
    return jax.ops.segment_sum(w, rsu_assign, num_segments=n_rsus)


def unnormalized_weight_matrix(weights: jax.Array, mask: jax.Array,
                               rsu_assign: jax.Array,
                               n_rsus: int) -> jax.Array:
    """Cohort-masked (R, A) weight matrix before row normalization: zero
    outside each RSU's cohort, m_a·w_a inside.  Shard-local slices of this
    matrix are what the sharded engine psums (partial aggregation)."""
    w = weights.astype(jnp.float32) * mask.astype(jnp.float32)   # (A,)
    onehot = (rsu_assign[None, :] == jnp.arange(n_rsus)[:, None])
    return onehot.astype(jnp.float32) * w[None, :]               # (R, A)


def build_weight_matrix(weights: jax.Array, mask: jax.Array,
                        rsu_assign: jax.Array, n_rsus: int) -> jax.Array:
    """Row-normalized (R, A) masked weight matrix.

    ``out[r] = W[r] @ stacked`` is the per-RSU weighted mean; rows with zero
    surviving mass become all-zero — the caller blends those RSUs with their
    previous model (``blend_on_mass`` semantics).  This is the one matrix
    both the tree-map reference and the Pallas matmul kernel consume.
    """
    wm = unnormalized_weight_matrix(weights, mask, rsu_assign, n_rsus)
    mass = jnp.sum(wm, axis=1, keepdims=True)
    return wm / jnp.where(mass > 0, mass, 1.0)


def staleness_weights(staleness: jax.Array, *, decay=0.5,
                      schedule: str = "exp") -> jax.Array:
    """Staleness-decay multiplier s(τ) for updates arriving τ ticks late.

    schedule="exp":  s(τ) = decay^τ    (decay in [0, 1]; 1.0 disables decay)
    schedule="poly": s(τ) = (1+τ)^-decay  (decay >= 0; 0.0 disables decay)

    ``decay`` may be a scalar (today's uniform schedule) or an array
    broadcastable against ``staleness`` — per-RSU adaptive schedules pass
    ``decay_vec[rsu_assign]`` so each agent decays with its own RSU's rate
    (DESIGN.md §6; scalar broadcast keeps the uniform behavior exactly).

    Both schedules are monotone non-increasing in τ with s(0) = 1, so fresh
    arrivals are never down-weighted and the synchronous limit is exact
    (property-tested in tests/test_async.py).
    """
    tau = jnp.asarray(staleness, jnp.float32)
    dec = jnp.asarray(decay, jnp.float32)
    if schedule == "exp":
        return jnp.power(dec, tau)
    if schedule == "poly":
        return jnp.power(1.0 + tau, -dec)
    raise ValueError(f"unknown schedule {schedule!r} (want 'exp'|'poly')")


def scatter_accumulate(stacked: jax.Array, weights: jax.Array,
                       rsu_assign: jax.Array,
                       n_rsus: int) -> Tuple[jax.Array, jax.Array]:
    """Unnormalized masked scatter-accumulate (the batched late-merge):

        num[r]  = Σ_{a: assign(a)=r} w_a · x_a      -> (R, N)
        mass[r] = Σ_{a: assign(a)=r} w_a            -> (R,)

    This segment-sum formulation is the reference; ``kernels/ops
    .masked_scatter_accumulate`` routes to the Pallas MXU matmul on TPU and
    back here off-TPU.  Weights already carry mask x data-volume x staleness
    decay — zero-weight rows contribute nothing.
    """
    w = weights.astype(jnp.float32)
    mass = jax.ops.segment_sum(w, rsu_assign, num_segments=n_rsus)
    num = jax.ops.segment_sum(stacked.astype(jnp.float32) * w[:, None],
                              rsu_assign, num_segments=n_rsus)
    return num, mass


def normalize_blend(num: jax.Array, mass: jax.Array,
                    prev: jax.Array) -> jax.Array:
    """Post-reduction half of the fused blend: normalize accumulated
    numerators by their mass and keep ``prev`` rows where the mass is zero
    (out dtype follows ``prev``).  The sharded engines run this AFTER the
    cross-shard psum — the shared algebra the one-pass kernels
    (``kernels/ops.agg_blend``) fold into their grid on a single device.

        out[r] = where(mass[r] > 0, num[r] / mass[r], prev[r])
    """
    safe = jnp.where(mass > 0, mass, 1.0)[:, None]
    out = jnp.where((mass > 0)[:, None],
                    num.astype(jnp.float32) / safe,
                    prev.astype(jnp.float32))
    return out.astype(prev.dtype)


def buffer_absorb(buf: jax.Array, buf_mass: jax.Array, num: jax.Array,
                  new_mass: jax.Array, *, keep=0.0,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Merge one tick's accumulated arrivals into a staleness buffer.

    buf: (R, N) current buffer model; buf_mass: (R,) its running absorbed
    cohort mass M; num/new_mass: this tick's ``scatter_accumulate`` output.

        retained = keep · M
        buf'     = (retained · buf + num) / (retained + new_mass)
        M'       = retained + new_mass

    so ``buf'`` stays the exactly-normalized weighted mean of everything
    absorbed (running cohort-mass accounting), rows with zero total mass
    keep the old model, and ``keep=0`` is replace-on-arrivals — the
    synchronous RSU semantics (blend_on_mass) the sync-limit anchor pins.

    ``keep`` may be a scalar or an (R,) vector — per-RSU adaptive retention
    (DESIGN.md §6); scalar broadcast keeps today's uniform behavior.
    """
    retained = jnp.asarray(keep, jnp.float32) * buf_mass.astype(jnp.float32)
    total = retained + new_mass.astype(jnp.float32)
    safe = jnp.where(total > 0, total, 1.0)[:, None]
    merged = (retained[:, None] * buf.astype(jnp.float32) + num) / safe
    out = jnp.where((total > 0)[:, None], merged, buf.astype(jnp.float32))
    return out.astype(buf.dtype), total


def screen_updates(payload: jax.Array, ref: jax.Array, weights: jax.Array,
                   *, nonfinite: bool = True, norm_clip: float = 0.0,
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quarantine gate for submitted updates (DESIGN.md §11).

    payload: (A, N) trained rows about to enter aggregation; ref: (A, N)
    the rows each agent trained *from* (its RSU model at dispatch);
    weights: (A,) the unguarded aggregation weights (used only to count
    quarantines — a zero-weight corrupt row is not a quarantine event).

    Screens: ``nonfinite`` rejects rows with any NaN/Inf entry;
    ``norm_clip > 0`` additionally rejects rows whose update norm
    ``||payload - ref||₂`` exceeds the clip (byzantine blow-ups; a
    non-finite delta compares False, so it is rejected here too).

    Returns ``(clean, okf, n_quarantined)``: quarantined rows are
    *scrubbed* back to ``ref`` (0·NaN = NaN would otherwise poison the
    aggregation matmul even at zero weight), ``okf`` is the (A,) float32
    survival mask the caller folds into its weight-matrix mask — mass
    accounting stays conserved because the mass IS the sum of guarded
    weights — and ``n_quarantined`` counts rejected rows that carried
    weight.  With every row surviving, ``clean`` is bitwise ``payload``
    and ``okf`` all-ones (the zero-fault anchor relies on this).
    """
    p32 = payload.astype(jnp.float32)
    ok = jnp.ones((payload.shape[0],), bool)
    if nonfinite:
        ok = ok & jnp.all(jnp.isfinite(p32), axis=1)
    if norm_clip > 0.0:
        delta = p32 - ref.astype(jnp.float32)
        nrm = jnp.sqrt(jnp.sum(delta * delta, axis=1))
        ok = ok & (nrm <= jnp.float32(norm_clip))
    clean = jnp.where(ok[:, None], payload, ref.astype(payload.dtype))
    n_quarantined = jnp.sum(
        ((weights.astype(jnp.float32) > 0) & ~ok).astype(jnp.int32))
    return clean, ok.astype(jnp.float32), n_quarantined


def masked_weighted_mean(stacked: PyTree, weights: jax.Array,
                         mask: Optional[jax.Array] = None) -> PyTree:
    """Σ_a m_a·w_a·x_a / Σ_a m_a·w_a over the leading axis.

    stacked: pytree with leaves (A, ...); weights/mask: (A,).
    If the surviving mass is zero the unweighted mean is returned instead
    (an RSU with no connected agents keeps its old model upstream — callers
    guard on the mass; this keeps the function total).
    """
    wn, _ = normalized_weights(weights, mask)

    def agg(leaf):
        wb = wn.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.sum(leaf.astype(jnp.float32) * wb, axis=0).astype(leaf.dtype)

    return jax.tree.map(agg, stacked)


def rsu_aggregate(agent_params: PyTree, weights: jax.Array,
                  mask: jax.Array, rsu_assign: jax.Array,
                  n_rsus: int) -> Tuple[PyTree, jax.Array]:
    """Per-RSU masked aggregation (Alg. 2 line 8) via the weight matrix.

    agent_params: leaves (A, ...); rsu_assign: (A,) int RSU id per agent.
    Returns (rsu_params with leaves (R, ...), rsu_mass (R,)).
    RSUs whose cohort mass is zero get zeros — the caller must blend with the
    previous RSU model using the returned mass (see ``blend_on_mass``).
    """
    W = build_weight_matrix(weights, mask, rsu_assign, n_rsus)   # (R, A)
    mass = cohort_mass(weights, mask, rsu_assign, n_rsus)

    def agg(leaf):
        return jnp.tensordot(W, leaf.astype(jnp.float32),
                             axes=1).astype(leaf.dtype)

    return jax.tree.map(agg, agent_params), mass


def blend_on_mass(new: PyTree, old: PyTree, mass: jax.Array) -> PyTree:
    """Keep `old` rows where `mass` is zero (RSU with no connected agents)."""
    keep = (mass > 0)

    def blend(n, o):
        kb = keep.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(kb, n, o)

    return jax.tree.map(blend, new, old)


def cloud_aggregate(rsu_params: PyTree, rsu_weights: jax.Array) -> PyTree:
    """Global aggregation over the RSU axis (Alg. 3 line 6)."""
    return masked_weighted_mean(rsu_params, rsu_weights)


def broadcast_to_agents(params: PyTree, n_agents: int) -> PyTree:
    """Duplicate a single model to a stacked per-agent pytree (model
    dissemination, Alg. 2 line 5)."""
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l, (n_agents,) + l.shape), params)


def gather_rsu_for_agents(rsu_params: PyTree, rsu_assign: jax.Array) -> PyTree:
    """Give each agent its own RSU's model: leaves (R, ...) -> (A, ...)."""
    return jax.tree.map(lambda l: jnp.take(l, rsu_assign, axis=0), rsu_params)
