"""Fleet parameter storage: where the (A, N) agent rows live (DESIGN.md §8).

The resident engines hold the whole fleet as one device ``(A, N)`` buffer,
so fleet size is HBM-bound — the opposite of the paper's participation
model, where a CSR-sized cohort of a huge connected fleet is active per
round.  A ``FleetStore`` abstracts the storage choice:

  * ``DeviceFleetStore`` — today's resident buffer, the unchanged fast
    path: gather/scatter are O(chunk) slices of a device array.
  * ``HostFleetStore`` — the fleet lives in host (numpy) memory in the
    ``FlatSpec`` STORAGE dtype (fp32 | bf16, DESIGN.md §3; bf16 rows use
    ``ml_dtypes.bfloat16``, numpy's bridge dtype for jax bf16 arrays).
    Only the round's cohort chunks are gathered to device by the
    cohort-streamed engines (fedsim/streaming), so the device working set
    is O(chunk · N) — independent of A.  This is what makes A=1e6 fleets
    runnable on fixed HBM.

Stores are plain Python objects and never cross a jit boundary: engines
``gather`` a chunk, ``jax.device_put`` it, run the jitted chunk program,
and ``scatter`` results back — the store is the host side of the
double-buffered transfer pipeline.  ``scatter(..., where=)`` supports the
semi-async engines' row-masked writes (busy agents keep their rows) without
a read-modify-write gather of the old rows.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

FLEET_STORES = ("device", "host")


def resolve_fleet_store(name: Optional[str]) -> str:
    """Canonical fleet-store spelling from a CLI/spec value."""
    if name is None:
        return "device"
    if name not in FLEET_STORES:
        raise ValueError(f"unknown fleet store {name!r} "
                         f"(want one of {FLEET_STORES})")
    return name


def np_storage_dtype(storage_dtype) -> np.dtype:
    """The numpy dtype holding host-side fleet rows: bf16 storage maps to
    ``ml_dtypes.bfloat16`` (a jax dependency — numpy itself has no native
    bfloat16), everything else passes through."""
    dt = jnp.dtype(storage_dtype)
    if dt == jnp.dtype(jnp.bfloat16):
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(dt)


class DeviceFleetStore:
    """The resident (A, N) device buffer behind the FleetStore interface.

    ``gather`` returns device rows directly (``device_put`` on them is a
    no-op), ``scatter`` is a functional dynamic-update-slice — the store
    rebinds its buffer, matching the donated-buffer discipline of the
    resident engines."""

    kind = "device"

    def __init__(self, buffer: jax.Array):
        self._buf = buffer

    @classmethod
    def broadcast(cls, vec: jax.Array, n_agents: int,
                  storage_dtype) -> "DeviceFleetStore":
        row = jnp.asarray(vec).astype(storage_dtype)
        # materialized (not a broadcast view) so scatter can donate rows
        return cls(jnp.tile(row, (n_agents, 1)))

    @property
    def n_agents(self) -> int:
        return int(self._buf.shape[0])

    @property
    def n(self) -> int:
        return int(self._buf.shape[1])

    @property
    def dtype(self):
        return self._buf.dtype

    @property
    def nbytes(self) -> int:
        return int(self._buf.size * self._buf.dtype.itemsize)

    def gather(self, lo: int, hi: int, col_lo: int = 0,
               col_hi: Optional[int] = None):
        """Rows [lo, hi); optionally only columns [col_lo, col_hi) — the
        two-axis streamed engine's N-tile reads (DESIGN.md §12)."""
        rows = jax.lax.dynamic_slice_in_dim(self._buf, lo, hi - lo, axis=0)
        if col_lo or (col_hi is not None and col_hi != self.n):
            hi_c = self.n if col_hi is None else col_hi
            rows = jax.lax.dynamic_slice_in_dim(
                rows, col_lo, hi_c - col_lo, axis=1)
        return rows

    def scatter(self, lo: int, rows: jax.Array, where=None,
                col_lo: int = 0) -> None:
        rows = rows.astype(self._buf.dtype)
        if where is not None:
            cur = self.gather(lo, lo + rows.shape[0],
                              col_lo, col_lo + rows.shape[1])
            rows = jnp.where(jnp.asarray(where)[:, None], rows, cur)
        self._buf = jax.lax.dynamic_update_slice(
            self._buf, rows, (lo, col_lo))

    def snapshot(self) -> jax.Array:
        return self._buf


class HostFleetStore:
    """The fleet as one host numpy (A, N) array in the storage dtype.

    ``gather`` returns a host view (the caller ``device_put``s it as part
    of the streamed round's double-buffered pipeline); ``scatter`` copies
    device rows back with an optional row mask.  Host RAM bounds the fleet;
    the device never sees more than a chunk."""

    kind = "host"

    def __init__(self, buffer: np.ndarray):
        self._buf = buffer

    @classmethod
    def broadcast(cls, vec, n_agents: int, storage_dtype) -> "HostFleetStore":
        row = np.asarray(vec).astype(np_storage_dtype(storage_dtype))
        buf = np.empty((n_agents, row.shape[-1]), dtype=row.dtype)
        buf[:] = row
        return cls(buf)

    @classmethod
    def zeros(cls, n_agents: int, n: int, storage_dtype) -> "HostFleetStore":
        return cls(np.zeros((n_agents, n), dtype=np_storage_dtype(
            storage_dtype)))

    @property
    def n_agents(self) -> int:
        return int(self._buf.shape[0])

    @property
    def n(self) -> int:
        return int(self._buf.shape[1])

    @property
    def dtype(self):
        return jnp.dtype(self._buf.dtype)

    @property
    def nbytes(self) -> int:
        return int(self._buf.nbytes)

    def gather(self, lo: int, hi: int, col_lo: int = 0,
               col_hi: Optional[int] = None) -> np.ndarray:
        """Rows [lo, hi) as a host view; the optional column range keeps
        two-axis streamed h2d transfers tile-sized (DESIGN.md §12)."""
        if col_lo == 0 and col_hi is None:
            return self._buf[lo:hi]
        return self._buf[lo:hi, col_lo:col_hi]

    def scatter(self, lo: int, rows, where=None, col_lo: int = 0) -> None:
        rows = np.asarray(rows)          # blocks until the rows are ready
        dst = self._buf[lo:lo + rows.shape[0],
                        col_lo:col_lo + rows.shape[1]]
        if where is None:
            np.copyto(dst, rows.astype(dst.dtype))
        else:
            np.copyto(dst, rows.astype(dst.dtype),
                      where=np.asarray(where)[:, None])

    def snapshot(self) -> jax.Array:
        """The whole fleet as ONE device array — an eval/test boundary for
        small fleets; at streaming scale callers must stay chunked."""
        return jnp.asarray(self._buf)


def make_fleet_store(kind: str, vec, n_agents: int, storage_dtype):
    """Build the fleet rows store with every row initialized to ``vec``."""
    kind = resolve_fleet_store(kind)
    if kind == "host":
        return HostFleetStore.broadcast(vec, n_agents, storage_dtype)
    return DeviceFleetStore.broadcast(vec, n_agents, storage_dtype)
