"""Flat-buffer parameter representation (DESIGN.md §3).

The simulator's hot path treats the fleet as matrices, not pytrees: every
agent's parameters are raveled into one contiguous fp32 row of an ``(A, N)``
buffer (RSUs: ``(R, N)``; cloud: ``(N,)``), so hierarchical aggregation is a
single ``(R, A) @ (A, N)`` Pallas matmul (kernels/masked_hier_agg) instead of
O(leaves) tree-mapped reductions, and the dual-proximal SGD update is one
fused vector expression.  Structure round-trips losslessly: ravel/unravel are
pure reshape+concatenate/slice, bit-exact for matching dtypes, and
differentiable — ``jax.grad`` of a loss composed with ``unravel`` yields the
raveled gradient directly.

A ``FlatSpec`` is static metadata (treedef + leaf shapes/dtypes/offsets)
derived once per simulation from the parameter template; it never crosses a
jit boundary as a traced value.

Dtype policy (DESIGN.md §3): the spec carries a ``storage_dtype`` knob for
the FLEET buffers — ``bfloat16`` storage halves the HBM bytes (and any
collective bytes) of the dominant (A, N)/(R, N) traffic and doubles the
agent count that fits a device.  ``ravel``/``unravel`` stay fp32 masters
(the cloud buffer and all eval/checkpoint boundaries), kernels accumulate
fp32 regardless of storage, and ``to_storage`` is the single cast point
engines use when writing into fleet buffers.  The default keeps everything
fp32 — bit-compatible with the pre-knob behavior.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

BUFFER_DTYPE = jnp.float32

# accepted --fleet-dtype spellings -> storage dtype
STORAGE_DTYPES = {
    "float32": jnp.float32, "f32": jnp.float32, "fp32": jnp.float32,
    "bfloat16": jnp.bfloat16, "bf16": jnp.bfloat16,
}


def resolve_storage_dtype(name) -> Any:
    """Fleet-buffer storage dtype from a CLI/config spelling (or a dtype).

    Only the dtypes the policy covers (fp32, bf16) are admitted — dtype
    OBJECTS are held to the same allowlist as strings, so an fp16 fleet
    (whose ±65k range can overflow weighted numerators) fails at
    configuration time rather than producing inf buffers mid-run."""
    if name is None:
        return jnp.dtype(BUFFER_DTYPE)
    if isinstance(name, str):
        if name not in STORAGE_DTYPES:
            raise ValueError(f"unknown fleet dtype {name!r} "
                             f"(want one of {sorted(STORAGE_DTYPES)})")
        return jnp.dtype(STORAGE_DTYPES[name])
    dt = jnp.dtype(name)
    if dt not in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)):
        raise ValueError(f"unsupported fleet dtype {dt} "
                         f"(the dtype policy covers float32 | bfloat16)")
    return dt


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static ravel plan for one parameter pytree (no leading fleet axis)."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    n: int                       # total flat length Σ sizes
    storage_dtype: Any = BUFFER_DTYPE   # fleet-buffer dtype (DESIGN.md §3)

    def to_storage(self, x: jax.Array) -> jax.Array:
        """Cast into the fleet-buffer storage dtype (the ONE cast point for
        writes into (A, N)/(R, N) buffers; no-op under the fp32 default)."""
        return x.astype(self.storage_dtype)

    # -- single model: (N,) ------------------------------------------------
    def ravel(self, tree: PyTree) -> jax.Array:
        leaves = self.treedef.flatten_up_to(tree)
        return jnp.concatenate(
            [l.astype(BUFFER_DTYPE).reshape(-1) for l in leaves])

    def unravel(self, vec: jax.Array) -> PyTree:
        leaves = [
            vec[off:off + size].reshape(shape).astype(dtype)
            for off, size, shape, dtype in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- stacked fleet: (A, N) ---------------------------------------------
    def ravel_stacked(self, stacked: PyTree) -> jax.Array:
        leaves = self.treedef.flatten_up_to(stacked)
        a = leaves[0].shape[0]
        return jnp.concatenate(
            [l.astype(BUFFER_DTYPE).reshape(a, -1) for l in leaves], axis=1)

    def unravel_stacked(self, mat: jax.Array) -> PyTree:
        a = mat.shape[0]
        leaves = [
            mat[:, off:off + size].reshape((a,) + shape).astype(dtype)
            for off, size, shape, dtype in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


def spec_of(tree: PyTree, *, storage_dtype=BUFFER_DTYPE) -> FlatSpec:
    """Build the ravel plan from a parameter template (arrays or tracers —
    only static shape/dtype metadata is read)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    return FlatSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=offsets, sizes=sizes, n=int(sum(sizes)),
                    storage_dtype=resolve_storage_dtype(storage_dtype))


def spec_of_stacked(stacked: PyTree, *,
                    storage_dtype=BUFFER_DTYPE) -> FlatSpec:
    """Ravel plan from a fleet-stacked template (leading axis dropped)."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    return FlatSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=offsets, sizes=sizes, n=int(sum(sizes)),
                    storage_dtype=resolve_storage_dtype(storage_dtype))
