"""Flat-buffer parameter representation (DESIGN.md §3).

The simulator's hot path treats the fleet as matrices, not pytrees: every
agent's parameters are raveled into one contiguous fp32 row of an ``(A, N)``
buffer (RSUs: ``(R, N)``; cloud: ``(N,)``), so hierarchical aggregation is a
single ``(R, A) @ (A, N)`` Pallas matmul (kernels/masked_hier_agg) instead of
O(leaves) tree-mapped reductions, and the dual-proximal SGD update is one
fused vector expression.  Structure round-trips losslessly: ravel/unravel are
pure reshape+concatenate/slice, bit-exact for matching dtypes, and
differentiable — ``jax.grad`` of a loss composed with ``unravel`` yields the
raveled gradient directly.

A ``FlatSpec`` is static metadata (treedef + leaf shapes/dtypes/offsets)
derived once per simulation from the parameter template; it never crosses a
jit boundary as a traced value.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

BUFFER_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Static ravel plan for one parameter pytree (no leading fleet axis)."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    n: int                       # total flat length Σ sizes

    # -- single model: (N,) ------------------------------------------------
    def ravel(self, tree: PyTree) -> jax.Array:
        leaves = self.treedef.flatten_up_to(tree)
        return jnp.concatenate(
            [l.astype(BUFFER_DTYPE).reshape(-1) for l in leaves])

    def unravel(self, vec: jax.Array) -> PyTree:
        leaves = [
            vec[off:off + size].reshape(shape).astype(dtype)
            for off, size, shape, dtype in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- stacked fleet: (A, N) ---------------------------------------------
    def ravel_stacked(self, stacked: PyTree) -> jax.Array:
        leaves = self.treedef.flatten_up_to(stacked)
        a = leaves[0].shape[0]
        return jnp.concatenate(
            [l.astype(BUFFER_DTYPE).reshape(a, -1) for l in leaves], axis=1)

    def unravel_stacked(self, mat: jax.Array) -> PyTree:
        a = mat.shape[0]
        leaves = [
            mat[:, off:off + size].reshape((a,) + shape).astype(dtype)
            for off, size, shape, dtype in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes)]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


def spec_of(tree: PyTree) -> FlatSpec:
    """Build the ravel plan from a parameter template (arrays or tracers —
    only static shape/dtype metadata is read)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    return FlatSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=offsets, sizes=sizes, n=int(sum(sizes)))


def spec_of_stacked(stacked: PyTree) -> FlatSpec:
    """Ravel plan from a fleet-stacked template (leading axis dropped)."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked)
    shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    return FlatSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    offsets=offsets, sizes=sizes, n=int(sum(sizes)))
