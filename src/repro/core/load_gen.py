"""Seeded event-stream load generators for the serving loop (DESIGN.md §9).

The continuous-serving subsystem (``fedsim/serving``) is driven by *events*
— "agent ``a``'s update is ready at sim-time ``t``" — instead of a round
counter.  This module owns the event side:

  * ``Event``: one arrival — ``(t, agent, seq)`` with ``t`` on a MONOTONIC
    float64 simulation clock.  No wall-clock ever enters the schedule, so a
    seeded run is a pure function of ``(rates, seed, n_events)`` and a trace
    replay reproduces it bit-for-bit (the determinism seam, test-pinned in
    tests/test_serving.py).
  * ``agent_rates``: per-agent Poisson rates derived from the
    ``HeterogeneityModel`` — the latency model that the semi-async engine
    spends on its in-flight buffers moves INTO the workload here: an
    agent's censored-geometric latency class ``d`` (the same draw shape as
    ``heterogeneity.sample_latency``) becomes a persistent speed factor
    ``1 / (1 + d)`` on its arrival rate, and CSR × FSR scale the rate of
    *useful* updates.
  * ``PoissonLoadGen``: merges per-agent exponential inter-arrival streams
    into one time-ordered event stream.  Each agent draws from its OWN
    ``numpy`` Generator (seeded ``[seed, agent]``), so an agent's arrival
    times are independent of how the merge interleaves them.
  * ``TraceLoadGen`` + ``write_trace`` / ``read_trace``: replayable JSONL
    traces.  Python's ``json`` serializes float64 via ``repr`` round-trip,
    so a dumped Poisson schedule reloads with every timestamp bit-equal.
  * ``parse_trigger``: the tick-trigger grammar of the serving loop —
    ``"batch:K"`` (fire on queue depth), ``"deadline:W"`` (fire before an
    event would leave the oldest queued entry waiting longer than ``W``
    sim-time units), ``"batch:K,deadline:W"`` (either), or ``"auto"``
    (``batch:n_agents`` — one tick per fleet's worth of arrivals, the
    batch↔serving anchor cadence).

Everything here is numpy-only (no jax): the generator runs on the host
thread interleaved with device ticks and must never touch device state.
"""
from __future__ import annotations

import heapq
import json
from typing import Iterable, Iterator, List, NamedTuple, Optional, Sequence

import numpy as np

from repro.core.heterogeneity import HeterogeneityModel


class Event(NamedTuple):
    """One arrival on the simulated clock."""
    t: float        # monotonic float64 sim-time of the arrival
    agent: int      # which agent's update is ready
    seq: int        # global emission index (identity + tie-break)


class TickTrigger(NamedTuple):
    """Parsed tick-trigger: fire when EITHER bound is hit (0 = disabled)."""
    batch: int       # queue depth >= batch  (0 = no depth trigger)
    deadline: float  # oldest queued event would wait > deadline sim-time

    def validate(self) -> "TickTrigger":
        if self.batch < 0 or self.deadline < 0:
            raise ValueError(f"negative trigger bound: {self}")
        if not self.batch and not self.deadline:
            raise ValueError("tick trigger needs batch>0 or deadline>0 "
                             "(else ticks never fire)")
        return self


def parse_trigger(s: str, n_agents: int) -> TickTrigger:
    """``"auto" | "batch:K" | "deadline:W" | "batch:K,deadline:W"``."""
    if s == "auto":
        return TickTrigger(batch=int(n_agents), deadline=0.0).validate()
    batch, deadline = 0, 0.0
    for part in s.split(","):
        kind, _, val = part.partition(":")
        try:
            if kind == "batch":
                batch = int(val)
            elif kind == "deadline":
                deadline = float(val)
            else:
                raise ValueError
        except ValueError:
            raise ValueError(
                f"bad tick_trigger {s!r} (want 'auto', 'batch:K', "
                f"'deadline:W' or 'batch:K,deadline:W')") from None
    return TickTrigger(batch=batch, deadline=deadline).validate()


def agent_rates(het: HeterogeneityModel, n_agents: int,
                base_rate: float = 1.0, seed: int = 0) -> np.ndarray:
    """Per-agent mean arrival rates (events per sim-time unit ==
    per tick window), derived from the heterogeneity model.

    ``rate_a = base · csr · fsr · 1/(1 + d_a)`` with ``d_a`` a per-agent
    censored-geometric latency-class draw (same distribution shape as
    ``sample_latency``, but drawn ONCE per agent: a persistent speed
    class, not a per-tick delay).  Rates are floored at 5% of ``base`` so
    every agent eventually reports even at csr→0 (the generator must stay
    live; a zero-rate agent would stall its stream forever).
    """
    het.validate()
    if base_rate <= 0:
        raise ValueError(f"base_rate must be > 0, got {base_rate}")
    rng = np.random.default_rng([int(seed), 0x10AD])
    if het.max_delay and het.delay_p > 0:
        if het.delay_p >= 1.0:
            d = np.full(n_agents, het.max_delay, np.float64)
        else:
            u = rng.uniform(1e-7, 1.0, n_agents)
            d = np.clip(np.floor(np.log(u) / np.log(het.delay_p)),
                        0, het.max_delay)
    else:
        d = np.zeros(n_agents, np.float64)
    rate = base_rate * het.csr * het.fsr / (1.0 + d)
    return np.maximum(rate, 0.05 * base_rate)


class PoissonLoadGen:
    """Merged per-agent Poisson arrival streams, time-ordered, seeded.

    Each agent owns an independent ``default_rng([seed, agent])`` stream of
    exponential inter-arrival gaps, merged through a heap — so the merged
    order can never perturb any agent's own draw sequence, and the whole
    schedule is a pure function of ``(rates, seed, n_events)``.
    """

    def __init__(self, rates: Sequence[float], seed: int = 0,
                 n_events: Optional[int] = None):
        self.rates = np.asarray(rates, np.float64)
        if (self.rates <= 0).any():
            raise ValueError("all arrival rates must be > 0 "
                             "(see agent_rates' floor)")
        self.seed = int(seed)
        self.n_events = n_events

    def events(self) -> Iterator[Event]:
        rngs = [np.random.default_rng([self.seed, a])
                for a in range(len(self.rates))]
        heap = [(rngs[a].exponential(1.0 / self.rates[a]), a)
                for a in range(len(self.rates))]
        heapq.heapify(heap)
        seq = 0
        while self.n_events is None or seq < self.n_events:
            t, a = heapq.heappop(heap)
            yield Event(t=float(t), agent=a, seq=seq)
            seq += 1
            heapq.heappush(
                heap, (t + rngs[a].exponential(1.0 / self.rates[a]), a))

    def take(self, n: int) -> List[Event]:
        out = []
        for ev in self.events():
            out.append(ev)
            if len(out) >= n:
                break
        return out


class TraceLoadGen:
    """Replay a fixed event schedule (a list or a JSONL trace file)."""

    def __init__(self, events: Iterable[Event]):
        self._events = [Event(float(t), int(a), i)
                        for i, (t, a, *_) in enumerate(events)]
        ts = [e.t for e in self._events]
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise ValueError("trace timestamps must be non-decreasing "
                             "(the monotonic event clock)")

    @classmethod
    def from_jsonl(cls, path, limit: int = 0,
                   n_agents: Optional[int] = None) -> "TraceLoadGen":
        return cls(read_trace(path, limit=limit, n_agents=n_agents))

    def events(self) -> Iterator[Event]:
        return iter(self._events)

    def take(self, n: int) -> List[Event]:
        return self._events[:n]

    def __len__(self) -> int:
        return len(self._events)


def every_agent_once_trace(n_agents: int, n_windows: int) -> TraceLoadGen:
    """The batch↔serving anchor schedule: every agent arrives exactly once
    per unit tick window, in agent order — ``t = w + (a + 0.5) / A``.  With
    trigger ``batch:A`` this fires exactly one full-fleet tick per window,
    every absorption at age 0 (tests/test_serving.py pins the equivalence
    to ``engine="async"``)."""
    return TraceLoadGen([
        Event(t=w + (a + 0.5) / n_agents, agent=a, seq=w * n_agents + a)
        for w in range(n_windows) for a in range(n_agents)])


def write_trace(events: Iterable[Event], path) -> None:
    """JSONL, one ``{"t": ..., "agent": ...}`` per line.  ``json`` emits
    float64 via ``repr`` — re-reading yields bit-equal timestamps, the
    replay-determinism seam."""
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps({"t": ev.t, "agent": ev.agent}) + "\n")


def read_trace(path, limit: int = 0,
               n_agents: Optional[int] = None) -> List[Event]:
    """Read a JSONL trace, validating every record as it is parsed.

    A trace is external input (often hand-edited or produced by another
    tool), so malformed records fail loudly HERE with the 1-based line
    number — not ticks later as a NaN sim-clock or a device-side scatter
    out of bounds.  Rejected: unparseable JSON, missing ``t``/``agent``
    keys, non-finite timestamps, negative agent ids, and (when
    ``n_agents`` is given) agents outside the fleet.
    """
    out: List[Event] = []
    with open(path) as f:
        for i, line in enumerate(f):
            if not line.strip():
                continue
            where = f"{path}:{i + 1}"
            try:
                d = json.loads(line)
                t, agent = float(d["t"]), int(d["agent"])
            except (ValueError, KeyError, TypeError) as e:
                raise ValueError(f"bad trace record at {where}: {e}") from None
            if not np.isfinite(t):
                raise ValueError(
                    f"non-finite timestamp {t!r} at {where} — the event "
                    f"clock must stay finite and monotonic")
            if agent < 0 or (n_agents is not None and agent >= n_agents):
                bound = f"[0, {n_agents})" if n_agents is not None else ">= 0"
                raise ValueError(
                    f"agent id {agent} at {where} outside the fleet "
                    f"(want {bound}) — trace from a different scenario?")
            out.append(Event(t=t, agent=agent, seq=i))
            if limit and len(out) >= limit:
                break
    return out
