"""Dynamic parameter orchestration — the paper's stated future work.

The conclusion of the paper: *"we believe that in our framework the
robustness of federated learning can be further improved by dynamic
parameter settings, which will be validated in future simulations."*

This module implements and validates that idea (beyond-paper):
``AdaptiveMuController`` re-tunes the proximal weights each global round
from the *observed* connectivity (the surviving data mass the cloud
aggregation actually saw), instead of requiring the operator to know the
network's CSR in advance:

  * low observed CSR  -> raise mu2 (stability matters: few, noisy cohorts)
  * high observed CSR -> decay mu2 toward mu2_min (don't slow convergence)
  * mu1 follows the same signal at a smaller gain (agent-level anchor).

The controller is a pure function of (state, observation) so it stays
jit-/scan-friendly and reproducible.  ``benchmarks/ablation_adaptive.py``
validates it in the fedsim simulator against fixed-mu baselines under a
time-varying CSR schedule.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

from repro.core.h2fed import H2FedParams


@dataclasses.dataclass(frozen=True)
class AdaptiveMuConfig:
    mu1_min: float = 0.0
    mu1_max: float = 0.004
    mu2_min: float = 0.0
    mu2_max: float = 0.02
    # connectivity estimate smoothing (EMA over observed per-round CSR);
    # 0.3 reacts within ~2 rounds of a collapse — the ablation showed 0.5
    # lags long enough to eat the first drift excursion
    ema: float = 0.3
    # CSR at/above which the mus decay to their minima
    csr_good: float = 0.8
    # CSR at/below which the mus saturate at their maxima
    csr_bad: float = 0.1


class AdaptiveMuState(NamedTuple):
    csr_est: float          # EMA of observed connection success ratio


def init_state() -> AdaptiveMuState:
    return AdaptiveMuState(csr_est=1.0)


def observe_csr(state: AdaptiveMuState, cfg: AdaptiveMuConfig,
                connected: float, participants: float) -> AdaptiveMuState:
    """Update the connectivity estimate from one round's observation.

    ``connected``/``participants`` can be agent counts or data masses —
    the ratio is what matters (masses weight heavy agents more, matching
    the aggregation the cloud actually performs).
    """
    csr = connected / max(participants, 1e-9)
    csr = min(max(csr, 0.0), 1.0)
    return AdaptiveMuState(csr_est=cfg.ema * state.csr_est
                           + (1.0 - cfg.ema) * csr)


def schedule(state: AdaptiveMuState, cfg: AdaptiveMuConfig,
             base: H2FedParams) -> Tuple[H2FedParams, float]:
    """Map the connectivity estimate to (mu1, mu2).

    Linear interpolation between (csr_good -> minima) and
    (csr_bad -> maxima), clamped outside.
    """
    span = max(cfg.csr_good - cfg.csr_bad, 1e-9)
    # 0 at good connectivity, 1 at bad
    badness = min(max((cfg.csr_good - state.csr_est) / span, 0.0), 1.0)
    mu1 = cfg.mu1_min + badness * (cfg.mu1_max - cfg.mu1_min)
    mu2 = cfg.mu2_min + badness * (cfg.mu2_max - cfg.mu2_min)
    hp = dataclasses.replace(base, mu1=mu1, mu2=mu2)
    return hp, badness
