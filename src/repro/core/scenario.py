"""Declarative experiment scenarios (DESIGN.md §7).

The paper's headline results are *grids* of experiments — CSR sweeps, μ1/μ2
sweeps, two partition scenarios, seed-averaged curves (Fig. 2–4).  A
``ScenarioSpec`` is the single declarative unit of one grid cell: it bundles

  * the fleet shape (agents / RSUs / batch),
  * the synthetic dataset + OEM-pretrain recipe (Sec. VI setup),
  * the partition recipe (scenario I / II / Dirichlet(α) label split),
  * the framework parameters (``H2FedParams``) and the heterogeneity model,
  * the engine choice (flat / tree / sharded / async + fleet dtype, fused
    one-pass rounds, semi-async staleness knobs),
  * the run length and the two seed axes (``seed`` fixes data / partition /
    pretrain; ``sim_seed`` varies only the connectivity / FSR realization —
    seed-averaged comparisons share the dataset).

``resolve()`` turns a spec into the concrete arrays + configs the engines
consume; ``cache_key`` is a stable content hash over EVERY resolved field,
so caches keyed by it can never alias two different experiments (the bug
the old ``benchmarks/common._CACHE`` had: it ignored ``seed``).  The
narrower ``dataset_key`` / ``partition_key`` sub-keys let expensive stages
(pretraining, partitioning) be shared across specs that only differ in
e.g. CSR or μ — exactly the sharing a figure grid wants.

``fedsim/sweep.py`` stacks resolved scenarios along a leading sweep axis
and vmaps the round over it, so a whole grid runs as ONE compiled program;
``benchmarks/common.py`` builds specs for the paper figures and
``launch/train.py --scenario-json`` runs any spec from the CLI.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Optional, Tuple, Union

from repro.core.faults import FaultPlan
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel

# partition-recipe spellings -> data.partition function names
PARTITIONS = ("scenario_one", "scenario_two", "dirichlet")
_PARTITION_ALIASES = {
    "scenario_one": "scenario_one", "1": "scenario_one", 1: "scenario_one",
    "scenario_two": "scenario_two", "2": "scenario_two", 2: "scenario_two",
    "dirichlet": "dirichlet",
}


def _norm_partition(p) -> str:
    if p not in _PARTITION_ALIASES:
        raise ValueError(f"unknown partition {p!r} "
                         f"(want one of {PARTITIONS} or 1|2)")
    return _PARTITION_ALIASES[p]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One declarative experiment cell.  Frozen + hashable; every field is
    part of ``cache_key``."""

    # -- fleet shape -------------------------------------------------------
    n_agents: int = 40
    n_rsus: int = 8
    batch: int = 32

    # -- dataset (synthetic MNIST-class task, Sec. VI) ---------------------
    n_train: int = 9_000
    n_test: int = 1_500
    noise: float = 0.8

    # -- OEM pretrain recipe (the biased "68%" model) ----------------------
    excluded_labels: Tuple[int, ...] = (7, 8, 9)
    pretrain_frac: float = 0.12
    pretrain_target: float = 0.68

    # -- partition recipe --------------------------------------------------
    partition: str = "scenario_two"   # scenario_one | scenario_two | dirichlet
    alpha: float = 0.3                # Dirichlet(α) concentration

    # -- framework + heterogeneity ----------------------------------------
    hp: H2FedParams = dataclasses.field(default_factory=H2FedParams)
    het: HeterogeneityModel = dataclasses.field(
        default_factory=HeterogeneityModel)

    # -- engine ------------------------------------------------------------
    engine: str = "flat"              # flat | tree | sharded | async
    fleet_dtype: str = "float32"      # fleet-buffer storage (DESIGN.md §3)
    fused: bool = True                # one-pass aggregate-and-blend rounds
    rsu_sharded: bool = False         # sharded engine mode (DESIGN.md §4)
    # parameter-axis sharding (DESIGN.md §12, engine="sharded"): > 1 lays
    # a trailing `model` mesh axis and shards the persistent (R, N)/(N,)
    # fleet state along N — ZeRO-style per-device HBM + cross-pod byte win
    model_shards: int = 1
    # cohort streaming (fedsim/streaming, DESIGN.md §8): where the (A, N)
    # fleet rows live, and the streamed chunk size (0 = resident when
    # fleet_store="device", auto chunk otherwise)
    fleet_store: str = "device"       # device | host
    chunk_agents: int = 0
    # two-axis streaming (DESIGN.md §12, fleet_store="host"): > 0 tiles
    # the parameter axis in ~chunk_params-column lane-aligned N-tiles so
    # the device working set is bounded by (A-chunk × N) for training and
    # (R × N-tile) for the aggregation buffers — big-N fleets stream
    # through the same donated chunk_step
    chunk_params: int = 0
    # model-size knob: non-empty overrides the paper MLP's hidden widths
    # (() = configs.mnist_mlp.CONFIG, hidden (40,)); a wide layer pushes N
    # to perception scale (~1e7) through the same engines
    hidden_dims: Tuple[int, ...] = ()
    # semi-async knobs (engine="async"; fedsim.async_engine.AsyncConfig)
    staleness_decay: Union[float, Tuple[float, ...]] = 0.5
    schedule: str = "exp"
    buffer_keep: Union[float, Tuple[float, ...]] = 0.0
    cloud_every: int = 0
    # continuous serving (fedsim/serving, DESIGN.md §9): serve_events > 0
    # replaces the fixed round count with an event-driven loop — updates
    # arrive from a seeded Poisson generator (or a JSONL trace replay) and
    # ticks fire on arrival pressure (core.load_gen.parse_trigger grammar)
    serve_events: int = 0             # 0 = batch mode (rounds drive time)
    arrival_rate: float = 1.0         # base Poisson rate (events / window)
    tick_trigger: str = "auto"        # auto | batch:K | deadline:W | both
    queue_capacity: int = 0           # event-queue bound (0 = unbounded)
    overload_policy: str = "drop_oldest"   # drop_oldest | backpressure
    serve_trace: str = ""             # JSONL trace path ("" = Poisson)

    # -- fault injection (core/faults, DESIGN.md §11): a declarative seeded
    # fault schedule (churn / RSU outages / corrupted updates / queue
    # perturbations) + the quarantine-guard configuration.  None = the
    # fault-free programs; FaultPlan() = the fault-gated programs under the
    # benign all-ones lowering (bit-identical, anchor-pinned).
    faults: Optional[FaultPlan] = None

    # -- run ---------------------------------------------------------------
    rounds: int = 24
    eval_every: int = 1
    seed: int = 0        # data / partition / pretrain seed
    sim_seed: int = 0    # connectivity / FSR realization (seed-averaging)
    # compiled-program caching (core/program_cache, DESIGN.md §10): opt out
    # to force a fresh trace + compile (debugging, profiling compile time)
    program_cache: bool = True

    # -- validation --------------------------------------------------------
    def validate(self) -> "ScenarioSpec":
        assert self.n_agents >= 1 and self.n_rsus >= 1 and self.batch >= 1
        assert self.n_train > 0 and self.n_test > 0
        assert 0.0 < self.pretrain_frac < 1.0
        _norm_partition(self.partition)
        assert self.alpha > 0.0
        self.hp.validate(), self.het.validate()
        assert self.engine in ("flat", "tree", "sharded", "async"), \
            f"unknown engine {self.engine!r}"
        from repro.core.fleet_store import resolve_fleet_store
        resolve_fleet_store(self.fleet_store)
        assert self.chunk_agents >= 0
        if self.fleet_store != "device" or self.chunk_agents:
            assert self.engine in ("flat", "async"), \
                (f"cohort streaming (fleet_store={self.fleet_store!r}, "
                 f"chunk_agents={self.chunk_agents}) requires engine "
                 f"'flat'|'async', got {self.engine!r}")
        assert self.model_shards >= 1
        if self.model_shards > 1:
            assert self.engine == "sharded", \
                (f"model_shards={self.model_shards} is the N-sharded fleet "
                 f"mode — engine 'sharded', got {self.engine!r}")
            assert self.fleet_store == "device" and not self.chunk_agents, \
                "N-sharding needs the device-resident fleet"
        assert self.chunk_params >= 0
        if self.chunk_params:
            assert self.engine == "flat" and self.fleet_store == "host", \
                (f"two-axis streaming (chunk_params={self.chunk_params}) "
                 f"requires engine 'flat' with fleet_store 'host', got "
                 f"engine {self.engine!r} / store {self.fleet_store!r}")
        assert all(int(h) > 0 for h in self.hidden_dims)
        assert self.schedule in ("exp", "poly")
        assert self.cloud_every >= 0
        assert self.serve_events >= 0 and self.queue_capacity >= 0
        assert self.arrival_rate > 0.0
        assert self.overload_policy in ("drop_oldest", "backpressure"), \
            f"unknown overload_policy {self.overload_policy!r}"
        if self.serve_events:
            assert self.engine == "async", \
                "serving (serve_events > 0) runs the async tick engine"
            assert self.fleet_store == "device" and not self.chunk_agents, \
                "serving needs the device-resident fleet"
            assert not self.rsu_sharded, "serving is not rsu-sharded"
            from repro.core.load_gen import parse_trigger
            parse_trigger(self.tick_trigger, self.n_agents)
        if self.faults is not None:
            assert self.engine in ("flat", "async"), \
                (f"fault injection requires engine 'flat'|'async', "
                 f"got {self.engine!r}")
            assert not self.rsu_sharded, \
                "fault injection is not threaded through the rsu-sharded path"
            self.faults.validate(self.n_rsus)
            if self.fleet_store != "device" or self.chunk_agents:
                assert not self.faults.corrupts, \
                    ("corrupted-update injection is not supported on the "
                     "cohort-streamed engines (churn/outage/guards are)")
        assert self.rounds >= 1 and self.eval_every >= 1
        return self

    def replace(self, **kw) -> "ScenarioSpec":
        return dataclasses.replace(self, **kw)

    # -- cache keys --------------------------------------------------------
    def _canonical(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["partition"] = _norm_partition(self.partition)
        return d

    @property
    def cache_key(self) -> str:
        """Stable content hash over EVERY field — two specs share a key iff
        they resolve identically (property-tested in tests/test_scenario)."""
        return _digest(self._canonical())

    @property
    def dataset_key(self) -> str:
        """Sub-key over the dataset + pretrain recipe only: specs differing
        in CSR/μ/engine share the expensive pretrained model."""
        d = self._canonical()
        return _digest({k: d[k] for k in (
            "n_train", "n_test", "noise", "excluded_labels",
            "pretrain_frac", "pretrain_target", "seed")})

    @property
    def partition_key(self) -> str:
        """Sub-key over dataset + partition recipe + fleet shape: specs
        differing only in het/hp/engine share the FederatedData."""
        d = self._canonical()
        return _digest({k: d[k] for k in (
            "n_train", "n_test", "noise", "excluded_labels",
            "pretrain_frac", "partition", "alpha", "n_agents", "n_rsus",
            "seed")})

    # -- resolution --------------------------------------------------------
    def sim_config(self):
        """The engines' SimConfig — same seed discipline as the old
        ``benchmarks/common.run_fed`` (sim_seed folds into the draw key)."""
        from repro.fedsim.simulator import SimConfig
        return SimConfig(n_agents=self.n_agents, n_rsus=self.n_rsus,
                         batch=self.batch,
                         seed=self.seed * 1000 + self.sim_seed,
                         eval_every=self.eval_every)

    def resolve(self) -> "ResolvedScenario":
        """Concrete datasets + partition + configs (cached per sub-key:
        the dataset is built once per ``dataset_key``, the partition once
        per ``partition_key``, shared across a grid's specs)."""
        self.validate()
        from repro.data.partition import (SCENARIOS, dirichlet_partition,
                                          pretrain_split)
        from repro.data.synthetic import mnist_class_task

        dk = self.dataset_key
        if dk not in _DATA_CACHE:
            train, test = mnist_class_task(
                n_train=self.n_train, n_test=self.n_test, noise=self.noise,
                seed=self.seed)
            pre_ds, fed_pool = pretrain_split(
                train, self.excluded_labels, frac=self.pretrain_frac,
                seed=self.seed)
            _DATA_CACHE[dk] = (train, test, pre_ds, fed_pool)
        train, test, pre_ds, fed_pool = _DATA_CACHE[dk]

        pk = self.partition_key
        if pk not in _PART_CACHE:
            part = _norm_partition(self.partition)
            if part == "dirichlet":
                fed = dirichlet_partition(fed_pool, n_agents=self.n_agents,
                                          n_rsus=self.n_rsus,
                                          alpha=self.alpha, seed=self.seed)
            else:
                fed = SCENARIOS[part](fed_pool, n_agents=self.n_agents,
                                      n_rsus=self.n_rsus, seed=self.seed)
            _PART_CACHE[pk] = fed
        return ResolvedScenario(spec=self, train=train, test=test,
                                pretrain_pool=pre_ds, fed_pool=fed_pool,
                                fed=_PART_CACHE[pk])

    # -- serialization (launch/train.py --scenario-json) -------------------
    def to_json(self, **dump_kw) -> str:
        return json.dumps(self._canonical(), **({"indent": 1} | dump_kw))

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScenarioSpec":
        d = dict(d)
        if "hp" in d and isinstance(d["hp"], dict):
            d["hp"] = H2FedParams(**d["hp"])
        if "het" in d and isinstance(d["het"], dict):
            d["het"] = HeterogeneityModel(**d["het"])
        if isinstance(d.get("faults"), dict):
            d["faults"] = FaultPlan.from_dict(d["faults"])
        for k in ("excluded_labels", "staleness_decay", "buffer_keep",
                  "hidden_dims"):
            if isinstance(d.get(k), list):
                d[k] = tuple(d[k])
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown ScenarioSpec fields: {sorted(unknown)}")
        return cls(**d).validate()

    @classmethod
    def from_json(cls, text: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(text))


@dataclasses.dataclass
class ResolvedScenario:
    """A spec made concrete: the arrays + configs the engines consume."""
    spec: ScenarioSpec
    train: Any           # data.synthetic.Dataset
    test: Any            # data.synthetic.Dataset (the eval boundary)
    pretrain_pool: Any   # OEM pretrain Dataset (labels excluded)
    fed_pool: Any        # public-fleet Dataset (pre-partition)
    fed: Any             # data.partition.FederatedData

    @property
    def cfg(self):
        return self.spec.sim_config()

    @property
    def hp(self) -> H2FedParams:
        return self.spec.hp

    @property
    def het(self) -> HeterogeneityModel:
        return self.spec.het

    @property
    def static_key(self) -> Tuple:
        """Everything that must be EQUAL for scenarios to share one
        compiled sweep program (fedsim/sweep grouping): program structure
        (shapes, engine flavor) — NOT the per-scenario scalars
        (csr/fsr/scd/delay_p, μ1/μ2/lr) the sweep batches.

        The cadence knobs — ``hp.lar``, ``hp.local_epochs``,
        ``cloud_every`` — are deliberately ABSENT: the sweep batches them
        as data too, padding its scans to the group-wide maxima with
        per-iteration live masks (DESIGN.md §7 "cadence as data"), so
        mixed-cadence cells land in one program."""
        s = self.spec
        return (s.n_agents, s.n_rsus, s.batch,
                tuple(self.fed.x.shape),
                tuple(self.test.x.shape) if self.test is not None else None,
                s.engine, s.fleet_dtype, s.fused, s.rsu_sharded,
                s.model_shards,
                s.fleet_store, s.chunk_agents, s.chunk_params,
                s.hidden_dims,
                s.hp.n_layers,
                s.het.max_delay,
                s.staleness_decay, s.schedule, s.buffer_keep,
                s.rounds, s.eval_every,
                s.serve_events, s.arrival_rate, s.tick_trigger,
                s.queue_capacity, s.overload_policy, s.serve_trace,
                # fault plans are DATA (lowered masks ride into the vmap);
                # only presence + guard structure shape the program, so a
                # fault grid still groups into ONE compiled sweep.
                None if s.faults is None else s.faults.static_fingerprint)


def _digest(obj: Any) -> str:
    return hashlib.sha256(
        json.dumps(obj, sort_keys=True, default=repr).encode()
    ).hexdigest()[:16]


# resolve() caches — keyed by the content sub-keys above, so (unlike the
# old benchmarks/common._CACHE) a second seed or partition can never be
# served the first one's arrays.
_DATA_CACHE: Dict[str, Tuple] = {}
_PART_CACHE: Dict[str, Any] = {}


def clear_caches() -> None:
    """Drop the resolve() caches (tests / long-lived processes)."""
    _DATA_CACHE.clear()
    _PART_CACHE.clear()
