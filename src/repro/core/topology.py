"""Topology-first hierarchy: agents ↔ RSUs ↔ cloud as ONE object (DESIGN.md §4).

The paper's Fig. 1 hierarchy used to be scattered across the engines: the
simulator carried an ``rsu_assign`` array, ``fedsim/sharded`` derived its own
mesh/shard math, and ``launch/h2fed_round`` re-derived the pod axis and batch
specs.  ``HierarchyTopology`` centralizes all of it:

  * the agent → RSU assignment (``balanced_assignment`` /
    ``unbalanced_assignment`` model the paper's traffic-flow imbalance),
  * the device-mesh layout (``pod`` ↔ RSU groups over the slow DCI, ``data``
    ↔ agents within an RSU group over the fast ICI — DESIGN.md §2),
  * the BLOCK structure of the (R, A) aggregation weight matrix: in
    RSU-sharded mode RSU ``r`` lives on pod ``r // rsu_per_pod`` and
    ``agent_perm`` co-locates every agent with its RSU's pod, so the weight
    matrix is block-diagonal over pods and the RSU aggregation becomes a
    pod-local ``(R_local, A_local) @ (A_local, N)`` matmul
    (``kernels/ops.block_local_agg``) with NO cross-pod traffic,
  * the ``PartitionSpec``s every engine shards its ``(A, N)`` / ``(R, N)`` /
    ``(N,)`` buffers with (``agent_spec`` / ``rsu_spec`` / ``cloud_spec``).

Two modes:

  replicated  (default) — the (R, N) RSU buffer is replicated on every
      device; the RSU layer needs one psum over ALL agent axes.  The small-R
      fast path and the equivalence anchor.
  rsu_sharded — the RSU axis is sharded over the pod axis; agents are
      permuted onto their RSU's pod, the RSU layer psums over the data axis
      ONLY (pod-local), and only the cloud layer pays a cross-pod
      collective — the paper's communication-avoidance insight made literal
      in the device topology (tests pin this via
      ``launch/hlo_analysis.collective_schedule``).

Consumers: ``fedsim/sharded`` (both modes), ``fedsim/async_engine``
(RSU-sharded semi-async round), ``launch/h2fed_round`` (SPMD flavor via
``HierarchyTopology.from_mesh``: one agent per (pod, data) position, one RSU
per pod).
"""
from __future__ import annotations

from math import prod
from typing import Optional, Tuple

import numpy as np
from jax.sharding import PartitionSpec as P

AGENT_AXES = ("pod", "data")


# --------------------------------------------------------------------------
# agent → RSU assignment models (paper Sec. III)
# --------------------------------------------------------------------------

def balanced_assignment(n_agents: int, n_rsus: int) -> np.ndarray:
    """Static a → a mod R assignment (matches the data partitioner)."""
    return (np.arange(n_agents) % n_rsus).astype(np.int32)


def unbalanced_assignment(n_agents: int, n_rsus: int, *, alpha: float = 1.0,
                          seed: int = 0) -> np.ndarray:
    """Dirichlet(alpha) cohort sizes; every RSU keeps >= 1 agent (paper
    Sec. III: "unbalanced agent number at RSUs")."""
    rng = np.random.default_rng(seed)
    props = rng.dirichlet([alpha] * n_rsus)
    counts = np.maximum(np.round(props * n_agents).astype(int), 1)
    while counts.sum() > n_agents:
        counts[np.argmax(counts)] -= 1
    while counts.sum() < n_agents:
        counts[np.argmin(counts)] += 1
    return np.repeat(np.arange(n_rsus), counts).astype(np.int32)


def cohort_sizes(assign: np.ndarray, n_rsus: int) -> np.ndarray:
    return np.bincount(assign, minlength=n_rsus).astype(np.int32)


# --------------------------------------------------------------------------
# fleet mesh construction (moved here from fedsim/sharded)
# --------------------------------------------------------------------------

def make_fleet_mesh(n_devices: Optional[int] = None, *,
                    n_pods: Optional[int] = None,
                    n_model_shards: Optional[int] = None):
    """Lay the fleet out over the available devices.

    Default: >= 4 devices get a ('pod', 'data') mesh (2 x n/2) exercising
    both agent axes of the production topology; fewer get a 1-D ('data',)
    mesh.  ``n_pods`` pins the pod-axis size explicitly (RSU-sharded runs
    sweep it; must divide the device count).

    ``n_model_shards`` > 1 appends a trailing ``model`` axis (DESIGN.md
    §12): the PARAMETER axis of the persistent fleet state — the (R, N)
    staleness buffers and the fp32 cloud master — is sharded over it
    (ZeRO-style), while per-agent training stays full-N (fleet models are
    vmapped per agent, not tensor-parallel; launch/h2fed_round handles
    that regime).  The agent axes keep their layout over the remaining
    ``n / n_model_shards`` devices.
    """
    import jax
    from repro.launch.mesh import make_mesh

    n = n_devices or len(jax.devices())
    m = int(n_model_shards or 1)
    if m > 1:
        if m < 1 or n % m:
            raise ValueError(
                f"n_model_shards={m} must divide the device count {n}")
        base = n // m
        if n_pods is not None:
            if n_pods < 1 or base % n_pods:
                raise ValueError(
                    f"n_pods={n_pods} must divide the device count {base}")
            return make_mesh((n_pods, base // n_pods, m),
                             ("pod", "data", "model"))
        if base >= 4 and base % 2 == 0:
            return make_mesh((2, base // 2, m), ("pod", "data", "model"))
        return make_mesh((base, m), ("data", "model"))
    if n_pods is not None:
        if n_pods < 1 or n % n_pods:
            raise ValueError(
                f"n_pods={n_pods} must divide the device count {n}")
        return make_mesh((n_pods, n // n_pods), ("pod", "data"))
    if n >= 4 and n % 2 == 0:
        return make_mesh((2, n // 2), ("pod", "data"))
    return make_mesh((n,), ("data",))


# --------------------------------------------------------------------------
# the topology object
# --------------------------------------------------------------------------

class HierarchyTopology:
    """Agent ↔ RSU ↔ cloud structure bound to a device mesh (DESIGN.md §4).

    mesh may be a ``jax.sharding.Mesh`` or anything exposing ``.shape``
    (mapping axis → size) and ``.axis_names`` — validation reads only static
    metadata, so errors fire before any device work.
    """

    def __init__(self, n_agents: int, n_rsus: int, mesh, *,
                 rsu_assign: Optional[np.ndarray] = None,
                 rsu_sharded: bool = False):
        if n_agents < 1 or n_rsus < 1:
            raise ValueError(f"need n_agents, n_rsus >= 1 "
                             f"(got {n_agents}, {n_rsus})")
        self.n_agents = int(n_agents)
        self.n_rsus = int(n_rsus)
        self.mesh = mesh
        self.rsu_sharded = bool(rsu_sharded)

        # mesh-derived structure first: the shard-divisibility errors fire
        # before the assignment is even looked at (callers rely on this —
        # tests/test_sharded.py pins the "must divide" message)
        shape = dict(mesh.shape)
        self.agent_axes: Tuple[str, ...] = tuple(
            a for a in mesh.axis_names if a in AGENT_AXES)
        if not self.agent_axes:
            raise ValueError(f"mesh {shape} has no agent axes "
                             f"(want some of {AGENT_AXES})")
        self.pod_axis: Optional[str] = \
            "pod" if "pod" in self.agent_axes else None
        self.data_axes: Tuple[str, ...] = tuple(
            a for a in self.agent_axes if a != "pod")
        # the parameter axis (DESIGN.md §12): N-sharding rides on a
        # trailing `model` mesh axis; AGENT_AXES filtering above already
        # keeps it out of the agent shard count
        self.model_axis: Optional[str] = \
            "model" if "model" in mesh.axis_names else None
        self.model_shards = int(shape.get("model", 1))
        self.n_pods = int(shape.get("pod", 1))
        self.n_shards = int(prod(shape[a] for a in self.agent_axes))
        self.data_shards = self.n_shards // max(self.n_pods, 1)
        if self.n_agents % self.n_shards:
            raise ValueError(
                f"n_agents={self.n_agents} must divide over "
                f"{self.n_shards} shards (mesh {shape})")

        assign = (balanced_assignment(n_agents, n_rsus)
                  if rsu_assign is None
                  else np.asarray(rsu_assign, np.int32))
        if assign.shape != (self.n_agents,):
            raise ValueError(f"rsu_assign must be ({n_agents},), "
                             f"got {assign.shape}")
        if assign.min() < 0 or assign.max() >= n_rsus:
            raise ValueError("rsu_assign ids out of range "
                             f"[0, {n_rsus}): {assign.min()}..{assign.max()}")
        self.rsu_assign = assign

        if self.rsu_sharded:
            if self.n_rsus % self.n_pods:
                raise ValueError(
                    f"rsu_sharded needs the pod axis to divide the RSU "
                    f"axis: n_rsus={self.n_rsus} is not divisible by "
                    f"n_pods={self.n_pods} (mesh {shape})")
            self.rsu_per_pod = self.n_rsus // self.n_pods
            self.pod_of_rsu = (np.arange(self.n_rsus)
                               // self.rsu_per_pod).astype(np.int32)
            pod_of_agent = self.pod_of_rsu[self.rsu_assign]
            counts = np.bincount(pod_of_agent, minlength=self.n_pods)
            if not (counts == counts[0]).all():
                raise ValueError(
                    "rsu_sharded needs equal agents per pod, got "
                    f"per-pod cohorts {counts.tolist()} — rebalance the "
                    "assignment or re-map RSUs to pods")
            if counts[0] % max(self.data_shards, 1):
                raise ValueError(
                    f"agents per pod ({int(counts[0])}) must divide over "
                    f"the data axis ({self.data_shards} shards)")
            # co-locate each agent with its RSU's pod: stable sort keeps
            # the original relative order inside each pod block
            self.agent_perm = np.argsort(
                pod_of_agent, kind="stable").astype(np.int32)
            self.inv_agent_perm = np.argsort(
                self.agent_perm, kind="stable").astype(np.int32)
            assign_p = self.rsu_assign[self.agent_perm]
            self.local_assign = (
                assign_p - self.pod_of_rsu[assign_p] * self.rsu_per_pod
            ).astype(np.int32)
        else:
            self.rsu_per_pod = self.n_rsus
            self.pod_of_rsu = np.zeros((self.n_rsus,), np.int32)
            self.agent_perm = np.arange(self.n_agents, dtype=np.int32)
            self.inv_agent_perm = self.agent_perm
            self.local_assign = self.rsu_assign

    # -- constructors ------------------------------------------------------

    @classmethod
    def from_mesh(cls, mesh) -> "HierarchyTopology":
        """SPMD flavor (launch/h2fed_round): one agent per (pod, data) mesh
        position, one RSU per pod — the agent's shard IS its identity, so
        the permutation is trivially the identity and the topology only
        carries the axis/spec bookkeeping."""
        shape = dict(mesh.shape)
        pods = int(shape.get("pod", 1))
        data = int(prod(shape[a] for a in mesh.axis_names
                        if a in AGENT_AXES and a != "pod"))
        n_agents = pods * data
        assign = np.repeat(np.arange(pods, dtype=np.int32), data)
        return cls(n_agents, max(pods, 1), mesh, rsu_assign=assign,
                   rsu_sharded="pod" in mesh.axis_names)

    # -- axis / spec surface ----------------------------------------------

    @property
    def shard_axes(self):
        """The agent-axis name(s) in the form psum/PartitionSpec take."""
        return (self.agent_axes if len(self.agent_axes) > 1
                else self.agent_axes[0])

    @property
    def data_shard_axes(self):
        """The within-pod (data) axis name(s); None if the mesh is
        pod-only."""
        if not self.data_axes:
            return None
        return (self.data_axes if len(self.data_axes) > 1
                else self.data_axes[0])

    @property
    def agent_spec(self) -> P:
        """(A, ...) buffers: leading axis over all agent mesh axes."""
        return P(self.shard_axes)

    @property
    def rsu_spec(self) -> P:
        """(R, ...) buffers: pod-sharded in rsu_sharded mode, else
        replicated."""
        if self.rsu_sharded and self.pod_axis is not None:
            return P(self.pod_axis)
        return P()

    @property
    def cloud_spec(self) -> P:
        """(N,) cloud buffer: always replicated over the agent axes."""
        return P()

    def stacked_spec(self, n_leading: int = 1) -> P:
        """(T, ..., A, ...) inputs (per-round masks/steps/batches): the
        agent axis sits after ``n_leading`` replicated axes."""
        return P(*([None] * n_leading), self.shard_axes)

    # -- N-sharding surface (DESIGN.md §12) --------------------------------
    #
    # The existing agent/rsu/cloud specs deliberately leave any `model`
    # mesh axis unmentioned (replicated) — launch/h2fed_round keeps it
    # auto for tensor parallelism.  The nshard_* specs below are what the
    # N-sharded fleet engine (fedsim/sharded._make_nsharded_round) uses:
    # the persistent (R, N) / (N,) state is sharded along N over `model`,
    # while the (A, N) training working set stays full-N per agent shard.

    def model_pad(self, n: int) -> int:
        """Pad the parameter axis so it splits into lane-aligned
        (multiple-of-128) model shards; identity at model_shards == 1."""
        if self.model_shards <= 1:
            return int(n)
        from repro.kernels.masked_hier_agg import LANE
        q = self.model_shards * LANE
        return -(-int(n) // q) * q

    @property
    def nshard_cloud_spec(self) -> P:
        """(N,) cloud master: sharded along N over the model axis."""
        if self.model_axis is None:
            return self.cloud_spec
        return P(self.model_axis)

    @property
    def nshard_rsu_spec(self) -> P:
        """(R, N) staleness buffers: N sharded over the model axis, R
        pod-sharded in rsu_sharded mode."""
        if self.model_axis is None:
            return self.rsu_spec
        if self.rsu_sharded and self.pod_axis is not None:
            return P(self.pod_axis, self.model_axis)
        return P(None, self.model_axis)

    def cloud_psum_mean(self, rsu_mass, rsu_flat, fallback, *,
                        reduce_dtype=None):
        """Mass-weighted cloud mean of this shard's RSU block — in
        rsu_sharded mode the ONE cross-pod collective of a round
        (DESIGN.md §4).  rsu_mass: (R_local,); rsu_flat: (R_local, N);
        returns (N,) fp32, ``fallback`` where the global mass is zero.

        ``reduce_dtype`` (the fleet storage dtype, DESIGN.md §3) casts the
        (N,) partial sum before the cross-pod psum — bf16 halves the DCI
        bytes of the round's one expensive collective; None/fp32 keeps the
        exact reduction."""
        import jax
        import jax.numpy as jnp
        part = rsu_mass @ rsu_flat.astype(jnp.float32)
        pmass = jnp.sum(rsu_mass)
        if self.rsu_sharded and self.pod_axis is not None:
            if reduce_dtype is not None:
                part = part.astype(reduce_dtype)
            part = jax.lax.psum(part, self.pod_axis).astype(jnp.float32)
            pmass = jax.lax.psum(pmass, self.pod_axis)
        return jnp.where(pmass > 0,
                         part / jnp.where(pmass > 0, pmass, 1.0), fallback)

    # -- block structure ---------------------------------------------------

    def permute_agents(self, arr, axis: int = 0):
        """Reorder an (..., A, ...) array into pod-block agent order."""
        return np.take(arr, self.agent_perm, axis=axis) \
            if isinstance(arr, np.ndarray) else _jnp_take(
                arr, self.agent_perm, axis)

    def unpermute_agents(self, arr, axis: int = 0):
        """Inverse of ``permute_agents``."""
        return np.take(arr, self.inv_agent_perm, axis=axis) \
            if isinstance(arr, np.ndarray) else _jnp_take(
                arr, self.inv_agent_perm, axis)

    def describe(self) -> str:
        mode = "rsu_sharded" if self.rsu_sharded else "replicated"
        nshard = (f", model_shards={self.model_shards}"
                  if self.model_shards > 1 else "")
        return (f"HierarchyTopology(A={self.n_agents}, R={self.n_rsus}, "
                f"pods={self.n_pods}, shards={self.n_shards}, "
                f"R_local={self.rsu_per_pod}, mode={mode}{nshard})")

    __repr__ = describe


def _jnp_take(arr, idx, axis):
    import jax.numpy as jnp
    return jnp.take(arr, jnp.asarray(idx), axis=axis)
