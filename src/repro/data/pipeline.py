"""Minimal deterministic input pipelines.

``classification_batches``: epoch iterator over a Dataset (host-side numpy,
device-put per batch) — used for centralized pre-training and evaluation.

``agent_minibatch_fn``: a *functional* minibatch selector for the vmapped
federated simulator: given a (A, N, D) data block and a step index, returns
the (A, b, D) minibatch — pure gather, jit/vmap/scan friendly.

``lm_sequences``: chops a token stream into (B, S+1) next-token windows for
the federated LLM finetune example.
"""
from __future__ import annotations

from typing import Iterator, Tuple

import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import Dataset


def classification_batches(ds: Dataset, batch: int, *, seed: int = 0,
                           epochs: int = 1) -> Iterator[Tuple[np.ndarray,
                                                              np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(ds.y)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            take = order[i:i + batch]
            yield ds.x[take], ds.y[take]


def agent_minibatch(x: jnp.ndarray, y: jnp.ndarray, step: jnp.ndarray,
                    batch: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cyclic minibatch from per-agent blocks.  x: (N, D), y: (N,).

    Deterministic cyclic slicing (start = step*b mod N) — inside vmap/scan
    this compiles to a dynamic-slice, no host RNG needed.
    """
    n = x.shape[0]
    start = (step * batch) % n
    idx = (start + jnp.arange(batch)) % n
    return jnp.take(x, idx, axis=0), jnp.take(y, idx, axis=0)


def lm_sequences(tokens: np.ndarray, batch: int, seq: int,
                 *, seed: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        window = np.stack([tokens[s:s + seq + 1] for s in starts])
        yield window[:, :-1].astype(np.int32), window[:, 1:].astype(np.int32)
