"""Synthetic datasets (no downloads in this container).

``mnist_class_task`` is a fixed-seed 10-class generative mixture with the
same dimensionality as MNIST (28x28 = 784).  It preserves every property the
paper's experiments depend on: label-partitionable (Non-IID shardable),
pre-trainable to a deliberately biased accuracy by label exclusion, and
learnable to >95% with the paper's ~130 kB MLP.

Each class c is a smooth prototype image (mixture of 2D Gaussian bumps at
class-keyed positions) plus per-sample elastic brightness jitter and pixel
noise — hard enough that a linear model underfits but a 784-40-10 MLP
reaches high accuracy, mirroring MNIST's role in the paper.

``lm_token_task`` is a synthetic autoregressive token stream (order-2 Markov
chain over a small vocab) used by the federated-LLM-finetune example: it has
learnable structure so federated training measurably reduces loss.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

IMG_SIDE = 28
INPUT_DIM = IMG_SIDE * IMG_SIDE
N_CLASSES = 10


@dataclasses.dataclass(frozen=True)
class Dataset:
    x: np.ndarray          # (N, 784) float32 in [0, 1]
    y: np.ndarray          # (N,)   int32 labels
    n_classes: int = N_CLASSES


def _class_prototypes(rng: np.random.Generator) -> np.ndarray:
    """(10, 28, 28) smooth prototype images, one per class."""
    yy, xx = np.mgrid[0:IMG_SIDE, 0:IMG_SIDE].astype(np.float32)
    protos = []
    for c in range(N_CLASSES):
        img = np.zeros((IMG_SIDE, IMG_SIDE), np.float32)
        n_bumps = 3 + c % 4
        for _ in range(n_bumps):
            cx, cy = rng.uniform(4, IMG_SIDE - 4, size=2)
            sx, sy = rng.uniform(2.0, 5.0, size=2)
            amp = rng.uniform(0.6, 1.0)
            img += amp * np.exp(-(((xx - cx) / sx) ** 2
                                  + ((yy - cy) / sy) ** 2))
        img /= max(img.max(), 1e-6)
        protos.append(img)
    return np.stack(protos)


def mnist_class_task(n_train: int = 22_000, n_test: int = 4_000,
                     noise: float = 0.45, seed: int = 0
                     ) -> Tuple[Dataset, Dataset]:
    """Fixed-seed train/test split of the 10-class mixture."""
    rng = np.random.default_rng(seed)
    protos = _class_prototypes(rng).reshape(N_CLASSES, INPUT_DIM)

    def draw(n, rng):
        y = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
        base = protos[y]
        bright = rng.uniform(0.7, 1.3, size=(n, 1)).astype(np.float32)
        x = base * bright + rng.normal(0.0, noise, size=(n, INPUT_DIM)) \
            .astype(np.float32)
        return np.clip(x, 0.0, 1.5).astype(np.float32), y

    x_tr, y_tr = draw(n_train, rng)
    x_te, y_te = draw(n_test, np.random.default_rng(seed + 1))
    return Dataset(x_tr, y_tr), Dataset(x_te, y_te)


def lm_token_task(vocab: int = 512, n_tokens: int = 1 << 16,
                  seed: int = 0) -> np.ndarray:
    """Order-2 Markov token stream (N,) int32 — learnable AR structure."""
    rng = np.random.default_rng(seed)
    # sparse transition table: each (a, b) context prefers ~4 next tokens
    n_ctx = 4096
    ctx_next = rng.integers(0, vocab, size=(n_ctx, 4)).astype(np.int32)
    toks = np.empty(n_tokens, np.int32)
    toks[0], toks[1] = rng.integers(0, vocab, 2)
    mix = rng.random(n_tokens)
    pick = rng.integers(0, 4, n_tokens)
    for t in range(2, n_tokens):
        ctx = (toks[t - 2] * 31 + toks[t - 1]) % n_ctx
        if mix[t] < 0.9:
            toks[t] = ctx_next[ctx, pick[t]]
        else:
            toks[t] = rng.integers(0, vocab)
    return toks
