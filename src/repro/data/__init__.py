from repro.data.synthetic import Dataset, mnist_class_task, lm_token_task  # noqa: F401
from repro.data.partition import (FederatedData, pretrain_split, scenario_one,  # noqa: F401
                                  scenario_two, dirichlet,
                                  dirichlet_partition, SCENARIOS)
