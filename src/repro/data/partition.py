"""Non-IID partitioning of a dataset across agents and RSUs.

The paper's two evaluation scenarios (Sec. VI):
  Scenario I  — Non-IID *across RSUs*: each RSU sees a label subset; agents
                under one RSU share that subset (IID within the RSU).
  Scenario II — Non-IID *across agents*: every RSU sees all labels, but each
                agent holds a label shard (LEAF-style).

``pretrain_split`` reproduces the paper's setup: the first ``n_pretrain``
agents exclude a few labels and form the OEM pre-training pool; the
remaining agents are the public federated fleet.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.data.synthetic import Dataset


@dataclasses.dataclass(frozen=True)
class FederatedData:
    """Fixed-size per-agent arrays (vmap-friendly)."""
    x: np.ndarray            # (A, n_per_agent, D)
    y: np.ndarray            # (A, n_per_agent)
    n_per_agent: np.ndarray  # (A,) actual data points (rows beyond are pad)
    rsu_assign: np.ndarray   # (A,) int RSU id

    @property
    def n_agents(self) -> int:
        return self.x.shape[0]


def pretrain_split(ds: Dataset, excluded_labels: Sequence[int],
                   frac: float = 0.1, seed: int = 0
                   ) -> Tuple[Dataset, Dataset]:
    """(pretrain pool with labels excluded, remaining federated pool)."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds.y))
    n_pre = int(len(idx) * frac)
    pre, fed = idx[:n_pre], idx[n_pre:]
    keep = ~np.isin(ds.y[pre], np.asarray(excluded_labels))
    pre = pre[keep]
    return (Dataset(ds.x[pre], ds.y[pre], ds.n_classes),
            Dataset(ds.x[fed], ds.y[fed], ds.n_classes))


def _pack(parts_x: List[np.ndarray], parts_y: List[np.ndarray],
          rsu_assign: np.ndarray) -> FederatedData:
    """Pad per-agent shards to a common length (pad rows repeat data so the
    weighted objective is unchanged by construction: weights use true n)."""
    n_max = max(len(p) for p in parts_y)
    A, D = len(parts_y), parts_x[0].shape[1]
    x = np.zeros((A, n_max, D), np.float32)
    y = np.zeros((A, n_max), np.int32)
    n = np.zeros((A,), np.int32)
    for a, (px, py) in enumerate(zip(parts_x, parts_y)):
        reps = int(np.ceil(n_max / max(len(py), 1)))
        x[a] = np.tile(px, (reps, 1))[:n_max]
        y[a] = np.tile(py, reps)[:n_max]
        n[a] = len(py)
    return FederatedData(x=x, y=y, n_per_agent=n,
                         rsu_assign=rsu_assign.astype(np.int32))


def scenario_one(ds: Dataset, n_agents: int = 100, n_rsus: int = 10,
                 labels_per_rsu: int = 2, seed: int = 0) -> FederatedData:
    """Non-IID across RSUs; IID within an RSU cohort."""
    rng = np.random.default_rng(seed)
    rsu_assign = np.arange(n_agents) % n_rsus
    # contiguous label windows per RSU (wrap) -> distinct RSU distributions
    rsu_labels = [np.arange(r, r + labels_per_rsu) % ds.n_classes
                  for r in range(n_rsus)]
    parts_x, parts_y = [], []
    label_pools = {c: rng.permutation(np.where(ds.y == c)[0]).tolist()
                   for c in range(ds.n_classes)}
    for a in range(n_agents):
        labs = rsu_labels[rsu_assign[a]]
        take = []
        per_label = max(len(ds.y) // (n_agents * len(labs) * 2), 8)
        for c in labs:
            pool = label_pools[int(c)]
            take += pool[:per_label]
            label_pools[int(c)] = pool[per_label:] or pool  # recycle if dry
        take = np.asarray(take)
        parts_x.append(ds.x[take])
        parts_y.append(ds.y[take])
    return _pack(parts_x, parts_y, rsu_assign)


def scenario_two(ds: Dataset, n_agents: int = 100, n_rsus: int = 10,
                 labels_per_agent: int = 2, seed: int = 0) -> FederatedData:
    """Non-IID across agents (label shards); RSU cohorts cover all labels."""
    rng = np.random.default_rng(seed)
    rsu_assign = np.arange(n_agents) % n_rsus
    parts_x, parts_y = [], []
    label_pools = {c: rng.permutation(np.where(ds.y == c)[0]).tolist()
                   for c in range(ds.n_classes)}
    for a in range(n_agents):
        # agent label shard chosen so consecutive agents at one RSU differ
        start = (a * labels_per_agent + (a // n_rsus)) % ds.n_classes
        labs = np.arange(start, start + labels_per_agent) % ds.n_classes
        take = []
        per_label = max(len(ds.y) // (n_agents * labels_per_agent * 2), 8)
        for c in labs:
            pool = label_pools[int(c)]
            take += pool[:per_label]
            label_pools[int(c)] = pool[per_label:] or pool
        take = np.asarray(take)
        parts_x.append(ds.x[take])
        parts_y.append(ds.y[take])
    return _pack(parts_x, parts_y, rsu_assign)


def dirichlet_partition(ds: Dataset, n_agents: int = 100, n_rsus: int = 10,
                        alpha: float = 0.3, seed: int = 0) -> FederatedData:
    """Dirichlet(alpha) label-proportion Non-IID split (LEAF-style, the
    common FL benchmark recipe): per class, agent shares are drawn from
    Dirichlet(alpha) — small alpha concentrates each label on few agents
    (strongly Non-IID), large alpha approaches IID.  Declared via
    ``core.scenario.ScenarioSpec(partition="dirichlet", alpha=...)`` — the
    stepping stone for real-dataset partitions (ROADMAP)."""
    rng = np.random.default_rng(seed)
    rsu_assign = np.arange(n_agents) % n_rsus
    props = rng.dirichlet([alpha] * n_agents, size=ds.n_classes)  # (C, A)
    parts: List[List[int]] = [[] for _ in range(n_agents)]
    for c in range(ds.n_classes):
        idx = rng.permutation(np.where(ds.y == c)[0])
        cuts = (np.cumsum(props[c]) * len(idx)).astype(int)[:-1]
        for a, chunk in enumerate(np.split(idx, cuts)):
            parts[a] += chunk.tolist()
    for a in range(n_agents):          # every agent holds >= 8 samples
        if len(parts[a]) < 8:
            parts[a] += rng.integers(0, len(ds.y), 8).tolist()
    parts_x = [ds.x[np.asarray(p)] for p in parts]
    parts_y = [ds.y[np.asarray(p)] for p in parts]
    return _pack(parts_x, parts_y, rsu_assign)


# legacy name (pre-ScenarioSpec callers)
dirichlet = dirichlet_partition

SCENARIOS = {"scenario_one": scenario_one, "scenario_two": scenario_two,
             "dirichlet": dirichlet_partition}
