"""Fused dual-proximal SGD update Pallas kernel (paper Alg. 1 line 4, Eq. 6).

    w ← w − lr·(g + μ1·(w − w_rsu) + μ2·(w − w_cloud))

This is the inner-loop hot-spot of H²-Fed local training: five streams
(w, g, a1, a2 → w') of identical shape, pure elementwise — so it is
HBM-bandwidth-bound.  The fusion matters: the naive jnp expression
materializes the two difference tensors and the penalty-gradient sum
(3 extra HBM round-trips at ~#params·4 bytes each); the fused kernel reads
4 streams and writes 1, the roofline minimum.

Tiling: parameters are flattened and reshaped to (rows, 8·128) — the fp32
TPU native tile — and the grid walks row blocks; each program touches
``block_rows × 1024`` elements (~2 MB × 5 streams in VMEM at the default,
comfortably inside the ~16 MB v5e budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8
TILE = LANE * SUBLANE          # 1024 elements: one fp32 (8, 128) native tile


def _update_kernel(w_ref, g_ref, a1_ref, a2_ref, o_ref, *,
                   lr: float, mu1: float, mu2: float):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    step = g
    if mu1:
        step = step + mu1 * (w - a1_ref[...].astype(jnp.float32))
    if mu2:
        step = step + mu2 * (w - a2_ref[...].astype(jnp.float32))
    o_ref[...] = (w - lr * step).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lr", "mu1", "mu2",
                                             "block_rows", "interpret"))
def dual_proximal_sgd(w: jax.Array, g: jax.Array, a1: jax.Array,
                      a2: jax.Array, *, lr: float, mu1: float, mu2: float,
                      block_rows: int = 512,
                      interpret: bool = False) -> jax.Array:
    """Fused update for one flat array (any shape; flattened internally)."""
    shape, dtype = w.shape, w.dtype
    n = w.size
    pad = (-n) % TILE
    flat = [jnp.pad(x.reshape(-1), (0, pad)) for x in (w, g, a1, a2)]
    rows = flat[0].size // LANE
    tiles = [x.reshape(rows, LANE) for x in flat]
    block_rows = min(block_rows, rows)
    # grid must divide evenly: rows is a multiple of SUBLANE by construction
    while rows % block_rows:
        block_rows //= 2
    grid = (rows // block_rows,)

    kernel = functools.partial(_update_kernel, lr=lr, mu1=mu1, mu2=mu2)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec] * 4, out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), dtype),
        interpret=interpret,
    )(*tiles)
    return out.reshape(-1)[:n].reshape(shape)


def dual_proximal_sgd_tree(w, g, a1, a2, *, lr: float, mu1: float,
                           mu2: float, interpret: bool = False):
    """Apply the fused update leaf-wise over parameter pytrees."""
    return jax.tree.map(
        lambda wl, gl, x1, x2: dual_proximal_sgd(
            wl, gl, x1, x2, lr=lr, mu1=mu1, mu2=mu2, interpret=interpret),
        w, g, a1, a2)
