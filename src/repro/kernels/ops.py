"""Public jit'd wrappers around the Pallas kernels.

On a TPU backend these lower to Mosaic.  Off-TPU the *pointwise/scan*
kernels run in ``interpret=True`` mode (kernel body as jax ops, identical
semantics); the *aggregation matmuls* instead route to the equivalent
XLA ``dot_general`` formulation — interpret-mode grid walking is a
debugging tool, not the CPU deploy path (see benchmarks/kernels_micro), and
the hot simulation loop (fedsim/simulator engine="flat") calls these every
round.  Tests pin both lowerings against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.aggregation import (build_weight_matrix, cohort_mass,
                                    normalized_weights,
                                    scatter_accumulate as _scatter_ref)
from repro.kernels import dual_proximal_sgd as _dps
from repro.kernels import flash_attention as _fa
from repro.kernels import masked_hier_agg as _mha


@functools.lru_cache(maxsize=1)
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _xla_agg_matmul(weight_matrix, stacked):
    """The aggregation matmul as one XLA dot — same contract as
    ``masked_hier_agg.weighted_agg_matmul`` (fp32 accumulate, param dtype
    out)."""
    out = jax.lax.dot_general(
        weight_matrix.astype(jnp.float32), stacked.astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return out.astype(stacked.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def dual_proximal_sgd(w, g, a1, a2, *, lr: float, mu1: float, mu2: float):
    return _dps.dual_proximal_sgd(w, g, a1, a2, lr=lr, mu1=mu1, mu2=mu2,
                                  interpret=_interpret())


def dual_proximal_sgd_tree(w, g, a1, a2, *, lr: float, mu1: float,
                           mu2: float):
    return _dps.dual_proximal_sgd_tree(w, g, a1, a2, lr=lr, mu1=mu1,
                                       mu2=mu2, interpret=_interpret())


def weighted_agg_matmul(weight_matrix, stacked):
    """(R, A) @ (A, N) aggregation matmul — the raw kernel, for callers
    (e.g. the sharded engine) that build their own partial weight matrix."""
    if _interpret():
        return _xla_agg_matmul(weight_matrix, stacked)
    return _mha.weighted_agg_matmul(weight_matrix, stacked, interpret=False)


def masked_hier_agg(stacked_flat, weights, mask, rsu_assign, n_rsus: int):
    W = build_weight_matrix(weights, mask, rsu_assign, n_rsus)
    mass = cohort_mass(weights, mask, rsu_assign, n_rsus)
    return weighted_agg_matmul(W, stacked_flat), mass


def block_local_agg(stacked_flat, weights, local_assign, n_rsus_local: int):
    """Block-local unnormalized RSU aggregation for the RSU-sharded engines
    (DESIGN.md §4): ``(num (R_local, N), mass (R_local,)) = Σ_a w_a·x_a``
    grouped by SHARD-LOCAL RSU id — one pod's diagonal block of the global
    weight matrix, so the RSU layer needs no cross-pod traffic.

    TPU: the Pallas aggregation matmul with the local weight matrix
    resident in VMEM; off-TPU: the XLA ``segment_sum`` reference from
    ``core.aggregation`` (same contract, shard-local ids).
    """
    if _interpret():
        return _scatter_ref(stacked_flat, weights, local_assign,
                            n_rsus_local)
    return _mha.block_local_agg(stacked_flat, weights, local_assign,
                                n_rsus_local, interpret=False)


def masked_scatter_accumulate(stacked_flat, weights, rsu_assign,
                              n_rsus: int):
    """Batched late-merge accumulate for the semi-async engine:
    ``(num (R, N), mass (R,)) = Σ_a w_a·x_a`` grouped by RSU, weights
    unnormalized (mask x data volume x staleness decay folded in).

    TPU: the Pallas aggregation matmul with the unnormalized weight matrix
    resident in VMEM (MXU work); off-TPU: the XLA ``segment_sum``
    scatter-add reference from ``core.aggregation``.
    """
    if _interpret():
        return _scatter_ref(stacked_flat, weights, rsu_assign, n_rsus)
    return _mha.scatter_accumulate(stacked_flat, weights, rsu_assign,
                                   n_rsus, interpret=False)


def cloud_agg(rsu_flat, rsu_weights):
    wn, _ = normalized_weights(rsu_weights)
    return weighted_agg_matmul(wn[None, :], rsu_flat)[0]


def slstm_scan(wx, r_gates, b_gates, *, block_s: int = 256):
    from repro.kernels import slstm_scan as _ss
    return _ss.slstm_scan(wx, r_gates, b_gates, block_s=block_s,
                          interpret=_interpret())
