"""Public jit'd wrappers around the Pallas kernels.

On a TPU backend these lower to Mosaic.  Off-TPU the *pointwise/scan*
kernels run in ``interpret=True`` mode (kernel body as jax ops, identical
semantics); the *aggregation matmuls* instead route to the equivalent
XLA ``dot_general`` formulation — interpret-mode grid walking is a
debugging tool, not the CPU deploy path (see benchmarks/kernels_micro), and
the hot simulation loop (fedsim/simulator engine="flat") calls these every
round.  Tests pin both lowerings against kernels/ref.py.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.aggregation import (build_weight_matrix, buffer_absorb,
                                    cohort_mass, normalized_weights,
                                    scatter_accumulate as _scatter_ref)
from repro.kernels import dual_proximal_sgd as _dps
from repro.kernels import flash_attention as _fa
from repro.kernels import masked_hier_agg as _mha

# explicit backend-route override (None = auto-detect).  Set via
# ``set_interpret`` or the REPRO_INTERPRET env var ("1"/"0"); tests that
# force platforms call ``set_interpret(None)`` to drop back to detection.
_FORCE_INTERPRET: Optional[bool] = None


@functools.lru_cache(maxsize=1)
def _backend_interpret() -> bool:
    return jax.default_backend() != "tpu"


def set_interpret(value: Optional[bool]) -> None:
    """Override the Pallas-vs-XLA route: True forces interpret/XLA
    fallbacks, False forces the compiled Pallas route, None restores
    backend auto-detection (and re-reads the backend, so tests that
    switch ``jax.default_backend`` mid-process stay correct)."""
    global _FORCE_INTERPRET
    _FORCE_INTERPRET = value
    _backend_interpret.cache_clear()


def _interpret() -> bool:
    if _FORCE_INTERPRET is not None:
        return _FORCE_INTERPRET
    env = os.environ.get("REPRO_INTERPRET")
    if env not in (None, ""):
        return env.lower() not in ("0", "false", "no")
    return _backend_interpret()


def interpret_mode() -> bool:
    """The effective Pallas interpret flag (force > env > backend) — part
    of the compiled-program cache key (core/program_cache, DESIGN.md §10):
    programs traced under different interpret modes are different programs.
    """
    return _interpret()


def _xla_agg_matmul(weight_matrix, stacked):
    """The aggregation matmul as one XLA dot — same contract as
    ``masked_hier_agg.weighted_agg_matmul`` (fp32 accumulate, param dtype
    out).  The small (R, A) weight matrix is cast to the FLEET dtype
    instead of widening the dominant (A, N) buffer to fp32 (which would
    materialize a full-precision copy and forfeit the bf16 storage
    policy's HBM savings); fp32 fleets are unchanged bit-for-bit."""
    out = jax.lax.dot_general(
        weight_matrix.astype(stacked.dtype), stacked,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return out.astype(stacked.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def dual_proximal_sgd(w, g, a1, a2, *, lr: float, mu1: float, mu2: float):
    return _dps.dual_proximal_sgd(w, g, a1, a2, lr=lr, mu1=mu1, mu2=mu2,
                                  interpret=_interpret())


def dual_proximal_sgd_tree(w, g, a1, a2, *, lr: float, mu1: float,
                           mu2: float):
    return _dps.dual_proximal_sgd_tree(w, g, a1, a2, lr=lr, mu1=mu1,
                                       mu2=mu2, interpret=_interpret())


def weighted_agg_matmul(weight_matrix, stacked):
    """(R, A) @ (A, N) aggregation matmul — the raw kernel, for callers
    (e.g. the sharded engine) that build their own partial weight matrix."""
    if _interpret():
        return _xla_agg_matmul(weight_matrix, stacked)
    return _mha.weighted_agg_matmul(weight_matrix, stacked, interpret=False)


def masked_hier_agg(stacked_flat, weights, mask, rsu_assign, n_rsus: int):
    W = build_weight_matrix(weights, mask, rsu_assign, n_rsus)
    mass = cohort_mass(weights, mask, rsu_assign, n_rsus)
    return weighted_agg_matmul(W, stacked_flat), mass


def block_local_agg(stacked_flat, weights, local_assign, n_rsus_local: int):
    """Block-local unnormalized RSU aggregation for the RSU-sharded engines
    (DESIGN.md §4): ``(num (R_local, N), mass (R_local,)) = Σ_a w_a·x_a``
    grouped by SHARD-LOCAL RSU id — one pod's diagonal block of the global
    weight matrix, so the RSU layer needs no cross-pod traffic.

    TPU: the Pallas aggregation matmul with the local weight matrix
    resident in VMEM; off-TPU: the XLA ``segment_sum`` reference from
    ``core.aggregation`` (same contract, shard-local ids).
    """
    if _interpret():
        return _scatter_ref(stacked_flat, weights, local_assign,
                            n_rsus_local)
    return _mha.block_local_agg(stacked_flat, weights, local_assign,
                                n_rsus_local, interpret=False)


def masked_scatter_accumulate(stacked_flat, weights, rsu_assign,
                              n_rsus: int):
    """Batched late-merge accumulate for the semi-async engine:
    ``(num (R, N), mass (R,)) = Σ_a w_a·x_a`` grouped by RSU, weights
    unnormalized (mask x data volume x staleness decay folded in).

    TPU: the Pallas aggregation matmul with the unnormalized weight matrix
    resident in VMEM (MXU work); off-TPU: the XLA ``segment_sum``
    scatter-add reference from ``core.aggregation``.
    """
    if _interpret():
        return _scatter_ref(stacked_flat, weights, rsu_assign, n_rsus)
    return _mha.scatter_accumulate(stacked_flat, weights, rsu_assign,
                                   n_rsus, interpret=False)


def chunk_agg(chunk_flat, weights, rsu_assign, n_rsus: int):
    """Chunk-shaped aggregation entry for the cohort-streamed engines
    (fedsim/streaming, DESIGN.md §8): ``(num (R, N), mass (R,)) =
    Σ_a w_a·x_a`` over ONE agent chunk, grouped by GLOBAL RSU id with
    weights unnormalized (mask × data volume × any staleness decay folded
    in).  The caller accumulates num/mass across chunks and normalizes
    once per local round (``core.aggregation.normalize_blend`` /
    ``buffer_absorb``) — the same partial-sum algebra the sharded engines
    psum, so streamed results match the resident fused ``agg_blend`` /
    ``agg_absorb`` rounds to fp32 tolerance.

    TPU: the Pallas aggregation matmul with the (R, chunk) weight matrix
    resident in VMEM; off-TPU: the XLA ``segment_sum`` reference.  Padded
    tail rows ride along with weight 0 (and assignment 0), so the entry is
    shape-static across a round's chunk stream.
    """
    if _interpret():
        return _scatter_ref(chunk_flat, weights, rsu_assign, n_rsus)
    return _mha.scatter_accumulate(chunk_flat, weights, rsu_assign,
                                   n_rsus, interpret=False)


def cloud_agg(rsu_flat, rsu_weights):
    wn, _ = normalized_weights(rsu_weights)
    return weighted_agg_matmul(wn[None, :], rsu_flat)[0]


# --------------------------------------------------------------------------
# fused aggregate-and-blend entry points (one-pass rounds, DESIGN.md §3/§6)
# --------------------------------------------------------------------------

def agg_blend(stacked_flat, weights, mask, rsu_assign, n_rsus: int, prev):
    """Fused RSU aggregation + mass-guard blend:
    ``out[r] = where(mass[r] > 0, W_norm[r] @ X, prev[r])`` with each
    N-tile read/written once.  Returns (rsu' in prev's dtype, mass (R,)).

    TPU: one Pallas grid pass (``masked_hier_agg.agg_blend``); off-TPU the
    exact un-fused XLA composition the flat engine ran before (dot +
    where), so fp32 results are bit-compatible with the two-step path.
    """
    if _interpret():
        W = build_weight_matrix(weights, mask, rsu_assign, n_rsus)
        mass = cohort_mass(weights, mask, rsu_assign, n_rsus)
        new = _xla_agg_matmul(W, stacked_flat)
        out = jnp.where((mass > 0)[:, None], new.astype(jnp.float32),
                        prev.astype(jnp.float32))
        return out.astype(prev.dtype), mass
    return _mha.agg_blend(stacked_flat, weights, mask, rsu_assign, n_rsus,
                          prev, interpret=False)


def agg_absorb(arrivals, rsu_assign, n_rsus: int, buf, buf_mass, *,
               keep=0.0):
    """Fused multi-cohort scatter-accumulate + staleness-buffer merge
    (the semi-async tick's whole RSU layer in one pass).  ``arrivals`` is
    a sequence of (x (A, N), w (A,)) cohorts; returns (buf' in buf's
    dtype, total_mass (R,), new_mass (R,)).

    TPU: one Pallas grid pass; off-TPU: fp32 fleets run the exact
    segment-sum + ``buffer_absorb`` chain the async engine ran before
    (bit-compatible with today), storage-dtype (bf16) fleets run the
    weight-matrix dot formulation instead — the segment-sum route would
    materialize a full fp32 copy of the (A, N) buffer, forfeiting the
    dtype policy's HBM savings; the dot reads the fleet in storage dtype
    and accumulates fp32.
    """
    if _interpret():
        from repro.core.aggregation import unnormalized_weight_matrix
        f32_fleet = all(jnp.dtype(x.dtype) == jnp.dtype(jnp.float32)
                        for x, _ in arrivals)
        num = jnp.zeros(buf.shape, jnp.float32)
        new_mass = jnp.zeros((n_rsus,), jnp.float32)
        for x, w in arrivals:
            if f32_fleet:
                n, m = _scatter_ref(x, w, rsu_assign, n_rsus)
            else:
                wm = unnormalized_weight_matrix(
                    w, jnp.ones_like(w), rsu_assign, n_rsus)
                n = jax.lax.dot_general(
                    wm.astype(x.dtype), x, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                m = jnp.sum(wm, axis=1)
            num = num + n
            new_mass = new_mass + m
        out, total = buffer_absorb(buf, buf_mass, num, new_mass, keep=keep)
        return out, total, new_mass
    return _mha.agg_absorb(arrivals, rsu_assign, n_rsus, buf, buf_mass,
                           keep=keep, interpret=False)


def cloud_blend(rsu_flat, rsu_weights, prev):
    """Fused cloud aggregation + keep-guard:
    ``where(Σ mass > 0, wn @ rsu_flat, prev)`` in one pass; out dtype
    follows ``prev`` (the fp32 cloud master, independent of the fleet
    storage dtype)."""
    if _interpret():
        w = rsu_weights.astype(jnp.float32)
        total = jnp.sum(w)
        wn, _ = normalized_weights(rsu_weights)
        new = jax.lax.dot_general(
            wn[None, :], rsu_flat.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]
        return jnp.where(total > 0, new,
                         prev.astype(jnp.float32)).astype(prev.dtype)
    return _mha.cloud_blend(rsu_flat, rsu_weights, prev, interpret=False)


def slstm_scan(wx, r_gates, b_gates, *, block_s: int = 256):
    from repro.kernels import slstm_scan as _ss
    return _ss.slstm_scan(wx, r_gates, b_gates, block_s=block_s,
                          interpret=_interpret())
