"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) every call runs in ``interpret=True`` mode — the
kernel body executes in Python per grid cell with identical semantics; on a
real TPU backend the same code lowers to Mosaic.  ``INTERPRET`` is resolved
once from the backend so call sites never need to care.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dual_proximal_sgd as _dps
from repro.kernels import flash_attention as _fa
from repro.kernels import masked_hier_agg as _mha


@functools.lru_cache(maxsize=1)
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def dual_proximal_sgd(w, g, a1, a2, *, lr: float, mu1: float, mu2: float):
    return _dps.dual_proximal_sgd(w, g, a1, a2, lr=lr, mu1=mu1, mu2=mu2,
                                  interpret=_interpret())


def dual_proximal_sgd_tree(w, g, a1, a2, *, lr: float, mu1: float,
                           mu2: float):
    return _dps.dual_proximal_sgd_tree(w, g, a1, a2, lr=lr, mu1=mu1,
                                       mu2=mu2, interpret=_interpret())


def masked_hier_agg(stacked_flat, weights, mask, rsu_assign, n_rsus: int):
    return _mha.masked_hier_agg(stacked_flat, weights, mask, rsu_assign,
                                n_rsus, interpret=_interpret())


def cloud_agg(rsu_flat, rsu_weights):
    return _mha.cloud_agg(rsu_flat, rsu_weights, interpret=_interpret())


def slstm_scan(wx, r_gates, b_gates, *, block_s: int = 256):
    from repro.kernels import slstm_scan as _ss
    return _ss.slstm_scan(wx, r_gates, b_gates, block_s=block_s,
                          interpret=_interpret())
