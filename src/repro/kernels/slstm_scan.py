"""Fused sLSTM forward-scan Pallas kernel (§Perf hillclimb A endpoint).

The sLSTM recurrence is inherently sequential in time (the hidden state
feeds the gate pre-activations through the block-diagonal recurrent
weights), so it cannot be chunk-parallelized like the mLSTM.  The XLA
per-step `lax.scan` re-reads the recurrent weights R (H, P, 4P ≈ 2.4 MB
fp32 at d=768) from HBM every timestep — ~10 GB of pure weight re-reads
for a 4096-step sequence per layer.

This kernel keeps R, the gate biases, AND the running state
(c, n, h, m — 4·d floats) resident in VMEM for an entire sequence block:
per timestep the only HBM traffic is the wx input slice (4d) and the h
output slice (d).  Per-device napkin math at (B=1, S=4096, d=768):

    XLA scan : 4096 · (2.4 MB R + 24 KB IO)  ≈ 9.9 GB
    kernel   : 2.4 MB R once + 4096 · 24 KB  ≈ 0.10 GB   (~100×)

Grid: (B, S/block_s); the batch dimension is embarrassingly parallel, the
sequence dimension is sequential with the state carried in VMEM scratch
(TPU grid iteration is sequential over the trailing axis; scratch persists
across grid steps — we re-initialize whenever the sequence index returns
to 0).  Forward-only: the training path uses the jnp scan (the backward
pass wants XLA's rematerialization machinery); this kernel is the
serving/eval hot path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(wx_ref, r_ref, b_ref, o_ref,
                  c_ref, n_ref, h_ref, m_ref, *, H: int, P: int):
    """One (batch, seq-block) program: scan block_s steps in VMEM.

    wx_ref: (1, block_s, 4d) input gate contributions (x @ W, precomputed)
    r_ref:  (H, P, 4P) block-diagonal recurrent weights  [VMEM-resident]
    b_ref:  (1, 4d) gate biases
    o_ref:  (1, block_s, d) hidden-state outputs
    scratch c/n/h/m: (1, d) fp32 running state
    """
    d = H * P
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        h_ref[...] = jnp.zeros_like(h_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    r = r_ref[...].astype(jnp.float32)          # stays in VMEM
    bias = b_ref[...].astype(jnp.float32)       # (1, 4d)
    block_s = wx_ref.shape[1]

    def step(t, _):
        wx_t = wx_ref[0, t, :].astype(jnp.float32)          # (4d,)
        h_prev = h_ref[0, :].reshape(H, P)
        rec = jax.lax.dot_general(
            h_prev[:, None, :], r, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)             # (H, 1, 4P)
        g = wx_t + rec.reshape(4 * d) + bias[0]
        gi, gf, gz, go = jnp.split(g, 4)
        # soft cap (models/xlstm.GATE_CAP) — keep kernel == oracle
        gi = 15.0 * jnp.tanh(gi / 15.0)
        gf = 15.0 * jnp.tanh(gf / 15.0)
        logf = jax.nn.log_sigmoid(gf)
        m_prev = m_ref[0, :]
        m_new = jnp.maximum(logf + m_prev, gi)
        i_p = jnp.exp(gi - m_new)
        f_p = jnp.exp(logf + m_prev - m_new)
        c = f_p * c_ref[0, :] + i_p * jnp.tanh(gz)
        n = f_p * n_ref[0, :] + i_p
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
        c_ref[0, :] = c
        n_ref[0, :] = n
        h_ref[0, :] = h
        m_ref[0, :] = m_new
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, block_s, step, 0)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def slstm_scan(wx: jax.Array, r_gates: jax.Array, b_gates: jax.Array,
               *, block_s: int = 256, interpret: bool = False) -> jax.Array:
    """Fused sLSTM forward scan.

    wx: (B, S, 4d) precomputed input contributions; r_gates: (H, P, 4P);
    b_gates: (4d,).  Returns hidden states (B, S, d) fp32.
    """
    B, S, d4 = wx.shape
    H, P, _ = r_gates.shape
    d = H * P
    assert d4 == 4 * d, (wx.shape, r_gates.shape)
    pad = (-S) % block_s
    if pad:
        wx = jnp.pad(wx, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    b2 = b_gates.reshape(1, 4 * d)
    grid = (B, Sp // block_s)

    kernel = functools.partial(_slstm_kernel, H=H, P=P)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, 4 * d), lambda b, s: (b, s, 0)),
            pl.BlockSpec((H, P, 4 * P), lambda b, s: (0, 0, 0)),
            pl.BlockSpec((1, 4 * d), lambda b, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_s, d), lambda b, s: (b, s, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),   # c  cell
            pltpu.VMEM((1, d), jnp.float32),   # n  normalizer
            pltpu.VMEM((1, d), jnp.float32),   # h  hidden (recurrent input)
            pltpu.VMEM((1, d), jnp.float32),   # m  stabilizer
        ],
        interpret=interpret,
    )(wx, r_gates, b2)
    return out[:, :S]
