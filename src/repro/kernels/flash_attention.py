"""Blocked online-softmax (flash) attention Pallas kernel for TPU.

Implements the same KV-chunked online-softmax blocking as the pure-jnp path
in ``repro.models.attention.chunked_attention`` — but with explicit VMEM
tiling via BlockSpec so q/k/v tiles stream HBM->VMEM and the running
(m, l, acc) state stays resident in VMEM scratch across the KV grid axis.

Grid layout: ``(B, H, nQ, nK)`` — the trailing ``nK`` axis is the sequential
TPU grid dimension, so the scratch carry is the standard flash-attention
accumulator pattern.  GQA is handled in the BlockSpec index maps: the k/v
tile for query head ``h`` comes from kv head ``h // group``.

Masking supports causal and sliding-window (``window > 0``) — the
sliding-window variant is what makes the ``long_500k`` shape sub-quadratic
for the dense architectures (DESIGN.md §Shape-coverage).  Fully-masked KV
tiles are skipped with ``pl.when`` (zero MXU work), which for a window of W
bounds the per-q-block work to O(W + BQ) instead of O(S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128          # TPU vector lane width; scratch minor dim


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, block_q: int, block_k: int, causal: bool,
                 window: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile-level pruning: is any (q, k) pair in this tile live?
    live = jnp.bool_(True)
    if causal:
        q_hi = iq * block_q + block_q - 1      # newest query in tile
        live = jnp.logical_and(live, ik * block_k <= q_hi)
    if window:
        q_lo = iq * block_q                    # oldest query in tile
        k_hi = ik * block_k + block_k - 1      # newest key in tile
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)        # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)        # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_len                     # tail padding
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                      # (BQ, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)            # (BQ, 1)
        p = jnp.exp(s - m_new)                     # (BQ, BK)
        l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _write():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, S, KV, D), H % KV == 0.  Returns (B, S, H, D).

    Block sizes are the VMEM tile shape: the per-tile working set is
    ``(BQ + 2·BK)·D + BQ·BK`` fp32 words — 128×128 tiles with D<=256 stay
    well under the ~16 MB v5e VMEM budget and keep the MXU matmul dims
    hardware-aligned (multiples of 128).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    assert H % KV == 0, (H, KV)
    group = H // KV
    scale = D ** -0.5

    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad_q = (-S) % block_q
    pad_k = (-S) % block_k
    qt = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kt = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vt = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    # (B, H, S, D) layout: heads become a grid axis
    qt = qt.transpose(0, 2, 1, 3)
    kt = kt.transpose(0, 2, 1, 3)
    vt = vt.transpose(0, 2, 1, 3)
    nq = qt.shape[2] // block_q
    nk = kt.shape[2] // block_k

    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_len=S)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, qt.shape[2], D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max m
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :S] if pad_q else out
