"""Masked hierarchical aggregation Pallas kernel (paper Alg. 2 l.8 / Alg. 3 l.6).

The RSU layer aggregates A stacked agent parameter vectors into R RSU
vectors with CSR-masked, data-volume weights; the cloud layer is the R→1
special case.  Both are the same computation:

    out[r, n] = Σ_a  W[r, a] · X[a, n]

where ``W`` is the (R, A) row-normalized masked weight matrix (zero outside
each RSU's cohort; core/aggregation.build_weight_matrix is the reference).
That is a skinny matmul — MXU work, not gather work — which is exactly how
the TPU wants hierarchy aggregation expressed (the GPU-native formulation
would be a segmented reduction; DESIGN.md §2).  The flat-buffer simulation
engine (DESIGN.md §3) calls this every round via the kernels/ops facade,
which routes to the equivalent XLA dot off-TPU.

Tiling: A and R are small (≤ a few hundred agents), so W stays fully
resident in VMEM; the grid walks column blocks of X (the parameter axis,
potentially billions of elements) and each program computes a
(R, block_n) = (R, A) @ (A, block_n) tile on the MXU.

One-pass rounds (DESIGN.md §3): the engines' round programs are
bandwidth-bound on streaming the (A, N)/(R, N) buffers through HBM, so the
consumers of the aggregation output — the mass-guard blend
(``jnp.where(mass>0, new, old)``), the cloud keep-guard, and the semi-async
``buffer_absorb`` renormalizing merge — are folded INTO the grid here:
``agg_blend`` / ``agg_absorb`` / ``cloud_blend`` read each N-tile once
(inputs + previous buffer) and write it once, instead of materializing a
fresh (R, N) numerator that a separate elementwise pass re-reads.  All
three are one shared kernel, ``_fused_agg_blend``:

    out[r, n] = where(guard[r],
                      (retained[r]·buf[r, n] + Σ_i W_i[r, :] @ X_i[:, n])
                        / safe[r],
                      buf[r, n])

with per-row coefficients prepared by the (cheap, O(R)/O(A)) host-side
weighting algebra.  The synchronous blend is the ``retained=0, safe=1,
W`` row-normalized case; the async absorb passes the unnormalized weight
matrices of both arrival cohorts (fresh + due) so ONE grid pass replaces
two scatter-accumulates, a numerator add and the buffer merge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the weighting algebra lives in core.aggregation (the reference
# implementation tests pin this kernel against); re-exported for callers
# that treat this module as the aggregation entry point.
from repro.core.aggregation import (build_weight_matrix, cohort_mass,  # noqa: F401
                                    normalized_weights,
                                    unnormalized_weight_matrix)

LANE = 128


def _tile_plan(n: int, block_n: int):
    """Lane-aligned N-axis tiling: pad N up to the next LANE multiple and
    clamp the tile to a LANE multiple that divides the padded width.  Every
    tile is a full-lane tile (no degrade-to-tiny-tiles fallback for awkward
    N) and the pad waste is bounded by one tile."""
    lane_n = -(-n // LANE) * LANE
    bn = max(min(block_n, lane_n) // LANE * LANE, LANE)
    n_pad = -(-lane_n // bn) * bn
    return n_pad, bn


def _pad_cols(x: jax.Array, n_pad: int) -> jax.Array:
    pad = n_pad - x.shape[1]
    return jnp.pad(x, ((0, 0), (0, pad))) if pad else x


def _agg_kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)            # (R, A)
    x = x_ref[...].astype(jnp.float32)            # (A, BN)
    o_ref[...] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def weighted_agg_matmul(weight_matrix: jax.Array, stacked: jax.Array, *,
                        block_n: int = 2048,
                        interpret: bool = False) -> jax.Array:
    """(R, A) @ (A, N) with N-axis VMEM tiling.  stacked may be any dtype;
    accumulation is fp32."""
    R, A = weight_matrix.shape
    A2, N = stacked.shape
    assert A == A2, (A, A2)
    n_pad, block_n = _tile_plan(N, block_n)
    xs = _pad_cols(stacked, n_pad)
    grid = (n_pad // block_n,)

    out = pl.pallas_call(
        _agg_kernel, grid=grid,
        in_specs=[
            pl.BlockSpec((R, A), lambda i: (0, 0)),          # W resident
            pl.BlockSpec((A, block_n), lambda i: (0, i)),    # X column tile
        ],
        out_specs=pl.BlockSpec((R, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), stacked.dtype),
        interpret=interpret,
    )(weight_matrix, xs)
    return out[:, :N] if n_pad != N else out


def masked_hier_agg(stacked_flat: jax.Array, weights: jax.Array,
                    mask: jax.Array, rsu_assign: jax.Array, n_rsus: int, *,
                    interpret: bool = False):
    """RSU aggregation on flattened stacked params.

    stacked_flat: (A, N) — one row per agent's flattened parameter vector.
    Returns (rsu_params (R, N), mass (R,)).
    """
    W = build_weight_matrix(weights, mask, rsu_assign, n_rsus)
    mass = cohort_mass(weights, mask, rsu_assign, n_rsus)
    return weighted_agg_matmul(W, stacked_flat, interpret=interpret), mass


def block_local_agg(stacked_flat: jax.Array, weights: jax.Array,
                    local_assign: jax.Array, n_rsus_local: int, *,
                    interpret: bool = False):
    """Block-local unnormalized aggregation (DESIGN.md §4, RSU-sharded mode):

        num[r, n] = Σ_{a: assign(a)=r}  w_a · X[a, n],   mass[r] = Σ w_a

    with ``local_assign`` holding SHARD-LOCAL RSU ids in
    ``[0, n_rsus_local)``.  When ``core.topology.HierarchyTopology``
    co-locates agents with their RSU's pod, the global (R, A) weight matrix
    is block-diagonal over pods and this is one pod's
    ``(R_local, A_local) @ (A_local, N)`` diagonal block — the whole RSU
    layer with no cross-pod traffic.  On TPU the small unnormalized weight
    matrix stays resident in VMEM and the grid walks parameter-axis tiles
    (same MXU formulation as the normalized aggregation); weights carry
    mask x data-volume (x staleness decay) folded in, so zero-weight rows
    contribute nothing.  The segment-sum oracle is
    ``core.aggregation.scatter_accumulate`` — the global (replicated) call
    is just this with global ids, and ``scatter_accumulate`` below
    delegates here.
    """
    W = unnormalized_weight_matrix(weights, jnp.ones_like(weights),
                                   local_assign, n_rsus_local)  # (R_loc, A)
    mass = jnp.sum(W, axis=1)
    num = weighted_agg_matmul(W, stacked_flat.astype(jnp.float32),
                              interpret=interpret)
    return num, mass


def scatter_accumulate(stacked_flat: jax.Array, weights: jax.Array,
                       rsu_assign: jax.Array, n_rsus: int, *,
                       interpret: bool = False):
    """Unnormalized batched late-merge (semi-async engine, DESIGN.md §6) —
    the global-ids case of ``block_local_agg`` (kept as the named entry the
    async tests/ops facade pin)."""
    return block_local_agg(stacked_flat, weights, rsu_assign, n_rsus,
                           interpret=interpret)


def cloud_agg(rsu_flat: jax.Array, rsu_weights: jax.Array, *,
              interpret: bool = False) -> jax.Array:
    """Cloud aggregation: the R→1 case.  rsu_flat: (R, N) -> (N,)."""
    wn, _ = normalized_weights(rsu_weights)
    return weighted_agg_matmul(wn[None, :], rsu_flat,
                               interpret=interpret)[0]


# --------------------------------------------------------------------------
# fused aggregate-and-blend (the one-pass round entry points)
# --------------------------------------------------------------------------

def _make_fused_kernel(n_pairs: int):
    """Kernel for ``_fused_agg_blend`` with ``n_pairs`` (W, X) inputs.

    refs layout: coef (R, 3) [retained | safe | guard], then W_i (R, A_i) /
    X_i (A_i, BN) interleaved, then buf (R, BN), then the output tile."""

    def kernel(*refs):
        coef = refs[0][...].astype(jnp.float32)            # (R, 3)
        buf = refs[1 + 2 * n_pairs][...].astype(jnp.float32)
        o_ref = refs[2 + 2 * n_pairs]
        acc = coef[:, 0:1] * buf                           # retained·buf
        for i in range(n_pairs):
            w = refs[1 + 2 * i][...].astype(jnp.float32)   # (R, A_i)
            x = refs[2 + 2 * i][...].astype(jnp.float32)   # (A_i, BN)
            acc += jax.lax.dot_general(
                w, x, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        merged = acc / coef[:, 1:2]                        # / safe
        o_ref[...] = jnp.where(coef[:, 2:3] > 0, merged,
                               buf).astype(o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _fused_agg_blend(coef: jax.Array, weight_mats, stackeds,
                     buf: jax.Array, *, block_n: int = 2048,
                     interpret: bool = False) -> jax.Array:
    """One grid pass of ``out = where(guard, (retained·buf + Σ W_i@X_i)
    / safe, buf)``: each N-tile of every input (and of the previous
    buffer) is read once and the output tile written once.  coef: (R, 3)
    rows of [retained, safe, guard]; out dtype == buf dtype."""
    R, N = buf.shape
    n_pad, block_n = _tile_plan(N, block_n)
    kernel = _make_fused_kernel(len(weight_mats))

    in_specs = [pl.BlockSpec((R, 3), lambda i: (0, 0))]    # coef resident
    args = [coef]
    for w, x in zip(weight_mats, stackeds):
        a = w.shape[1]
        assert x.shape == (a, N), (w.shape, x.shape, buf.shape)
        in_specs.append(pl.BlockSpec((R, a), lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec((a, block_n), lambda i: (0, i)))
        args += [w, _pad_cols(x, n_pad)]
    in_specs.append(pl.BlockSpec((R, block_n), lambda i: (0, i)))
    args.append(_pad_cols(buf, n_pad))

    out = pl.pallas_call(
        kernel, grid=(n_pad // block_n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((R, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), buf.dtype),
        interpret=interpret,
    )(*args)
    return out[:, :N] if n_pad != N else out


def agg_blend(stacked_flat: jax.Array, weights: jax.Array, mask: jax.Array,
              rsu_assign: jax.Array, n_rsus: int, prev: jax.Array, *,
              interpret: bool = False):
    """Fused ``masked_hier_agg`` + mass-guard blend (DESIGN.md §3):

        out[r] = where(mass[r] > 0, W_norm[r] @ X, prev[r])

    in ONE pass over the parameter axis.  Returns (rsu' (R, N) in
    ``prev``'s dtype, mass (R,)).  Oracle: ``kernels/ref.agg_blend_ref``.
    """
    W = build_weight_matrix(weights, mask, rsu_assign, n_rsus)
    mass = cohort_mass(weights, mask, rsu_assign, n_rsus)
    coef = jnp.stack([jnp.zeros_like(mass), jnp.ones_like(mass),
                      (mass > 0).astype(jnp.float32)], axis=1)
    out = _fused_agg_blend(coef, (W,), (stacked_flat,), prev,
                           interpret=interpret)
    return out, mass


def agg_absorb(arrivals, rsu_assign: jax.Array, n_rsus: int,
               buf: jax.Array, buf_mass: jax.Array, *, keep=0.0,
               interpret: bool = False):
    """Fused multi-cohort scatter-accumulate + staleness-buffer merge
    (DESIGN.md §6): for ``arrivals`` = sequence of (x (A, N), w (A,))
    cohorts,

        out[r] = (keep·M[r]·buf[r] + Σ_cohorts Σ_{a∈r} w_a·x_a)
                   / (keep·M[r] + m_new[r])        [buf[r] if zero mass]

    in ONE pass — the semi-async tick's two scatter-accumulates, the
    numerator add and the ``buffer_absorb`` renormalization share each
    N-tile.  Returns (buf' in buf's dtype, total_mass (R,), new_mass (R,)).
    Oracle: ``kernels/ref.agg_absorb_ref``."""
    mats, xs = [], []
    new_mass = jnp.zeros((n_rsus,), jnp.float32)
    for x, w in arrivals:
        wm = unnormalized_weight_matrix(w, jnp.ones_like(w), rsu_assign,
                                        n_rsus)
        mats.append(wm)
        xs.append(x)
        new_mass = new_mass + jnp.sum(wm, axis=1)
    retained = jnp.asarray(keep, jnp.float32) * buf_mass.astype(jnp.float32)
    retained = jnp.broadcast_to(retained, new_mass.shape)
    total = retained + new_mass
    coef = jnp.stack([retained, jnp.where(total > 0, total, 1.0),
                      (total > 0).astype(jnp.float32)], axis=1)
    out = _fused_agg_blend(coef, tuple(mats), tuple(xs), buf,
                           interpret=interpret)
    return out, total, new_mass


def cloud_blend(rsu_flat: jax.Array, rsu_weights: jax.Array,
                prev: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Fused cloud aggregation + keep-guard (Alg. 3 l.6): ``where(Σ mass >
    0, wn @ rsu_flat, prev)`` in one pass; out dtype == prev dtype (the
    fp32 cloud master).  Oracle: ``kernels/ref.cloud_blend_ref``."""
    w = rsu_weights.astype(jnp.float32)
    total = jnp.sum(w)
    wn = jnp.where(total > 0, w / jnp.where(total > 0, total, 1.0),
                   jnp.zeros_like(w))
    guard = (total > 0).astype(jnp.float32)
    coef = jnp.stack([jnp.zeros((1,), jnp.float32),
                      jnp.ones((1,), jnp.float32), guard[None]], axis=1)
    return _fused_agg_blend(coef, (wn[None, :],), (rsu_flat,),
                            prev[None, :], interpret=interpret)[0]
