"""Masked hierarchical aggregation Pallas kernel (paper Alg. 2 l.8 / Alg. 3 l.6).

The RSU layer aggregates A stacked agent parameter vectors into R RSU
vectors with CSR-masked, data-volume weights; the cloud layer is the R→1
special case.  Both are the same computation:

    out[r, n] = Σ_a  W[r, a] · X[a, n]

where ``W`` is the (R, A) row-normalized masked weight matrix (zero outside
each RSU's cohort; core/aggregation.build_weight_matrix is the reference).
That is a skinny matmul — MXU work, not gather work — which is exactly how
the TPU wants hierarchy aggregation expressed (the GPU-native formulation
would be a segmented reduction; DESIGN.md §2).  The flat-buffer simulation
engine (DESIGN.md §3) calls this every round via the kernels/ops facade,
which routes to the equivalent XLA dot off-TPU.

Tiling: A and R are small (≤ a few hundred agents), so W stays fully
resident in VMEM; the grid walks column blocks of X (the parameter axis,
potentially billions of elements) and each program computes a
(R, block_n) = (R, A) @ (A, block_n) tile on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the weighting algebra lives in core.aggregation (the reference
# implementation tests pin this kernel against); re-exported for callers
# that treat this module as the aggregation entry point.
from repro.core.aggregation import (build_weight_matrix, cohort_mass,  # noqa: F401
                                    normalized_weights,
                                    unnormalized_weight_matrix)

LANE = 128


def _agg_kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)            # (R, A)
    x = x_ref[...].astype(jnp.float32)            # (A, BN)
    o_ref[...] = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def weighted_agg_matmul(weight_matrix: jax.Array, stacked: jax.Array, *,
                        block_n: int = 2048,
                        interpret: bool = False) -> jax.Array:
    """(R, A) @ (A, N) with N-axis VMEM tiling.  stacked may be any dtype;
    accumulation is fp32."""
    R, A = weight_matrix.shape
    A2, N = stacked.shape
    assert A == A2, (A, A2)
    pad_n = (-N) % min(block_n, max(N, LANE))
    block_n = min(block_n, N + pad_n)
    xs = jnp.pad(stacked, ((0, 0), (0, pad_n))) if pad_n else stacked
    n_pad = xs.shape[1]
    while n_pad % block_n:
        block_n //= 2
    grid = (n_pad // block_n,)

    out = pl.pallas_call(
        _agg_kernel, grid=grid,
        in_specs=[
            pl.BlockSpec((R, A), lambda i: (0, 0)),          # W resident
            pl.BlockSpec((A, block_n), lambda i: (0, i)),    # X column tile
        ],
        out_specs=pl.BlockSpec((R, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), stacked.dtype),
        interpret=interpret,
    )(weight_matrix, xs)
    return out[:, :N] if pad_n else out


def masked_hier_agg(stacked_flat: jax.Array, weights: jax.Array,
                    mask: jax.Array, rsu_assign: jax.Array, n_rsus: int, *,
                    interpret: bool = False):
    """RSU aggregation on flattened stacked params.

    stacked_flat: (A, N) — one row per agent's flattened parameter vector.
    Returns (rsu_params (R, N), mass (R,)).
    """
    W = build_weight_matrix(weights, mask, rsu_assign, n_rsus)
    mass = cohort_mass(weights, mask, rsu_assign, n_rsus)
    return weighted_agg_matmul(W, stacked_flat, interpret=interpret), mass


def block_local_agg(stacked_flat: jax.Array, weights: jax.Array,
                    local_assign: jax.Array, n_rsus_local: int, *,
                    interpret: bool = False):
    """Block-local unnormalized aggregation (DESIGN.md §4, RSU-sharded mode):

        num[r, n] = Σ_{a: assign(a)=r}  w_a · X[a, n],   mass[r] = Σ w_a

    with ``local_assign`` holding SHARD-LOCAL RSU ids in
    ``[0, n_rsus_local)``.  When ``core.topology.HierarchyTopology``
    co-locates agents with their RSU's pod, the global (R, A) weight matrix
    is block-diagonal over pods and this is one pod's
    ``(R_local, A_local) @ (A_local, N)`` diagonal block — the whole RSU
    layer with no cross-pod traffic.  On TPU the small unnormalized weight
    matrix stays resident in VMEM and the grid walks parameter-axis tiles
    (same MXU formulation as the normalized aggregation); weights carry
    mask x data-volume (x staleness decay) folded in, so zero-weight rows
    contribute nothing.  The segment-sum oracle is
    ``core.aggregation.scatter_accumulate`` — the global (replicated) call
    is just this with global ids, and ``scatter_accumulate`` below
    delegates here.
    """
    W = unnormalized_weight_matrix(weights, jnp.ones_like(weights),
                                   local_assign, n_rsus_local)  # (R_loc, A)
    mass = jnp.sum(W, axis=1)
    num = weighted_agg_matmul(W, stacked_flat.astype(jnp.float32),
                              interpret=interpret)
    return num, mass


def scatter_accumulate(stacked_flat: jax.Array, weights: jax.Array,
                       rsu_assign: jax.Array, n_rsus: int, *,
                       interpret: bool = False):
    """Unnormalized batched late-merge (semi-async engine, DESIGN.md §6) —
    the global-ids case of ``block_local_agg`` (kept as the named entry the
    async tests/ops facade pin)."""
    return block_local_agg(stacked_flat, weights, rsu_assign, n_rsus,
                           interpret=interpret)


def cloud_agg(rsu_flat: jax.Array, rsu_weights: jax.Array, *,
              interpret: bool = False) -> jax.Array:
    """Cloud aggregation: the R→1 case.  rsu_flat: (R, N) -> (N,)."""
    wn, _ = normalized_weights(rsu_weights)
    return weighted_agg_matmul(wn[None, :], rsu_flat,
                               interpret=interpret)[0]
