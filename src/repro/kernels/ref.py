"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the kernel's contract exactly; the per-kernel test
sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """Dense-softmax reference.  q: (B,S,H,D); k/v: (B,S,KV,D)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = D ** -0.5
    qg = q.reshape(B, S, KV, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bskgt", qg, kf) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bskgt,btkd->bskgd", p, vf)
    return out.reshape(B, S, H, D).astype(q.dtype)


def dual_proximal_sgd_ref(w, g, a1, a2, *, lr: float, mu1: float,
                          mu2: float) -> jax.Array:
    wf = w.astype(jnp.float32)
    step = g.astype(jnp.float32) \
        + mu1 * (wf - a1.astype(jnp.float32)) \
        + mu2 * (wf - a2.astype(jnp.float32))
    return (wf - lr * step).astype(w.dtype)


def masked_hier_agg_ref(stacked_flat, weights, mask, rsu_assign, n_rsus):
    """Segment-sum reference for the RSU aggregation matmul."""
    w = weights.astype(jnp.float32) * mask.astype(jnp.float32)
    mass = jax.ops.segment_sum(w, rsu_assign, num_segments=n_rsus)
    num = jax.ops.segment_sum(
        stacked_flat.astype(jnp.float32) * w[:, None], rsu_assign,
        num_segments=n_rsus)
    denom = jnp.where(mass > 0, mass, 1.0)[:, None]
    return (num / denom).astype(stacked_flat.dtype), mass


def slstm_scan_ref(wx, r_gates, b_gates):
    """Per-step scan reference for the fused sLSTM kernel.

    wx: (B, S, 4d); r_gates: (H, P, 4P); b_gates: (4d,) -> (B, S, d) f32.
    Mirrors models/xlstm._slstm_step (incl. the gate soft cap)."""
    B, S, d4 = wx.shape
    H, P, _ = r_gates.shape
    d = H * P
    rf = r_gates.astype(jnp.float32)
    bf = b_gates.astype(jnp.float32)

    def step(state, wx_t):
        c, n, h, m = state
        hr = h.reshape(B, H, P)
        rec = jnp.einsum("bhp,hpq->bhq", hr, rf).reshape(B, 4 * d)
        g = wx_t.astype(jnp.float32) + rec + bf
        gi, gf_, gz, go = jnp.split(g, 4, axis=-1)
        gi = 15.0 * jnp.tanh(gi / 15.0)
        gf_ = 15.0 * jnp.tanh(gf_ / 15.0)
        logf = jax.nn.log_sigmoid(gf_)
        m_new = jnp.maximum(logf + m, gi)
        i_p = jnp.exp(gi - m_new)
        f_p = jnp.exp(logf + m - m_new)
        c = f_p * c + i_p * jnp.tanh(gz)
        n = f_p * n + i_p
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
        return (c, n, h, m_new), h

    z = jnp.zeros((B, d), jnp.float32)
    state = (z, z, z, jnp.full((B, d), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, state, jnp.swapaxes(wx, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def scatter_accumulate_ref(stacked_flat, weights, rsu_assign, n_rsus):
    """Reference for the unnormalized late-merge accumulate.

    The algebra's single source of truth is
    ``core.aggregation.scatter_accumulate`` (segment-sum formulation);
    aliased here so kernel tests keep their one-oracle-per-kernel shape.
    """
    from repro.core.aggregation import scatter_accumulate
    return scatter_accumulate(stacked_flat, weights, rsu_assign, n_rsus)


def block_local_agg_ref(stacked_flat, weights, local_assign, n_rsus_local):
    """Reference for the block-local (R_local, A_local) aggregation: the
    same segment-sum as ``scatter_accumulate_ref`` with shard-local RSU ids
    (the block-diagonal slice of the global weight matrix, DESIGN.md §4)."""
    from repro.core.aggregation import scatter_accumulate
    return scatter_accumulate(stacked_flat, weights, local_assign,
                              n_rsus_local)


def agg_blend_ref(stacked_flat, weights, mask, rsu_assign, n_rsus, prev):
    """Reference for the fused aggregate-and-blend: the un-fused two-pass
    composition (normalized aggregation, then the mass-guard blend) the
    one-pass kernel must reproduce.  Out dtype follows ``prev``."""
    new, mass = masked_hier_agg_ref(stacked_flat, weights, mask, rsu_assign,
                                    n_rsus)
    out = jnp.where((mass > 0)[:, None], new.astype(jnp.float32),
                    prev.astype(jnp.float32))
    return out.astype(prev.dtype), mass


def agg_absorb_ref(arrivals, rsu_assign, n_rsus, buf, buf_mass, *,
                   keep=0.0):
    """Reference for the fused multi-cohort absorb: per-cohort
    ``scatter_accumulate``, numerator add, then ``buffer_absorb`` — the
    exact consumer chain the one-pass kernel folds together."""
    from repro.core.aggregation import buffer_absorb, scatter_accumulate
    num = jnp.zeros(buf.shape, jnp.float32)
    new_mass = jnp.zeros((n_rsus,), jnp.float32)
    for x, w in arrivals:
        n, m = scatter_accumulate(x, w, rsu_assign, n_rsus)
        num = num + n
        new_mass = new_mass + m
    out, total = buffer_absorb(buf, buf_mass, num, new_mass, keep=keep)
    return out, total, new_mass


def cloud_blend_ref(rsu_flat, rsu_weights, prev):
    """Reference for the fused cloud aggregation + keep-guard."""
    new = cloud_agg_ref(rsu_flat, rsu_weights)
    total = jnp.sum(rsu_weights.astype(jnp.float32))
    return jnp.where(total > 0, new.astype(jnp.float32),
                     prev.astype(jnp.float32)).astype(prev.dtype)


def cloud_agg_ref(rsu_flat, rsu_weights):
    w = rsu_weights.astype(jnp.float32)
    mass = jnp.sum(w)
    wn = jnp.where(mass > 0, w / jnp.where(mass > 0, mass, 1.0),
                   jnp.ones_like(w) / w.shape[0])
    return jnp.sum(rsu_flat.astype(jnp.float32) * wn[:, None],
                   axis=0).astype(rsu_flat.dtype)
