"""Property-testing compat layer: real hypothesis when installed, a
deterministic seeded sampler otherwise.

The CI image installs ``hypothesis`` from ``pyproject.toml`` and gets the
real shrinking engine.  Hermetic containers that cannot pip-install still
collect and run the property tests through the fallback below: each
``@given`` test is executed ``max_examples`` times with values drawn from a
``numpy`` Generator seeded by the test's qualified name, so failures are
reproducible run-to-run (no shrinking, but the drawn kwargs appear in the
traceback).

Only the strategy surface this repo uses is implemented:
``st.integers / st.floats / st.sampled_from / st.booleans`` and
``hnp.arrays(dtype, shape, elements=...)``.
"""
from __future__ import annotations

import functools
import inspect
import os
import zlib

import numpy as np

try:  # pragma: no cover - exercised only where hypothesis is installed
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def draw(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, width=64, **_kw):
            def sample(rng):
                v = float(rng.uniform(min_value, max_value))
                if width == 32:
                    v = float(np.float32(v))
                return min(max(v, min_value), max_value)

            return _Strategy(sample)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    class _HypothesisNumpy:
        @staticmethod
        def arrays(dtype, shape, elements=None):
            shape = (shape,) if isinstance(shape, int) else tuple(shape)

            def sample(rng):
                if elements is None:
                    return rng.standard_normal(shape).astype(dtype)
                n = int(np.prod(shape)) if shape else 1
                flat = [elements.draw(rng) for _ in range(n)]
                return np.asarray(flat, dtype).reshape(shape)

            return _Strategy(sample)

    st = _Strategies()
    hnp = _HypothesisNumpy()

    def settings(max_examples: int = 10, **_kw):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_prop_max_examples", 10)
                n = int(os.environ.get("PROP_MAX_EXAMPLES", n))
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)

            # hide the drawn params from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            del wrapper.__wrapped__
            return wrapper

        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "hnp", "settings", "st"]
