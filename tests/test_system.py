"""End-to-end behaviour tests for the paper's system: pre-train -> federate
-> enhance, exercising the full public API the examples use."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core.baselines import h2fed
from repro.core.heterogeneity import HeterogeneityModel
from repro.data.partition import pretrain_split, scenario_two
from repro.data.synthetic import mnist_class_task
from repro.fedsim.pretrain import pretrain_to_target, train_centralized
from repro.fedsim.simulator import SimConfig
from repro.fedsim.sweep import adhoc_scenario, run_scenario
from repro.models import mlp


@pytest.fixture(scope="module")
def pipeline():
    """Miniature version of the paper's full experiment pipeline."""
    train, test = mnist_class_task(n_train=6000, n_test=800, seed=0)
    pre_ds, fed_ds = pretrain_split(train, excluded_labels=[6, 7, 8, 9],
                                    frac=0.25, seed=0)
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    pre_params, pre_acc = pretrain_to_target(
        params, pre_ds, test.x, test.y, target_acc=0.55, max_epochs=6)
    return fed_ds, test, pre_params, pre_acc


class TestEndToEnd:
    def test_pretrain_is_biased(self, pipeline):
        """Label exclusion caps pre-train accuracy below the ceiling —
        the paper's 68%-style deliberately biased initial model."""
        fed_ds, test, pre_params, pre_acc = pipeline
        assert 0.3 < pre_acc < 0.9, pre_acc
        # per-class: excluded labels must be (nearly) unpredicted
        logits = mlp.forward(pre_params, jnp.asarray(test.x))
        pred = np.asarray(jnp.argmax(logits, -1))
        frac_excluded = np.isin(pred, [6, 7, 8, 9]).mean()
        assert frac_excluded < 0.1, frac_excluded

    def test_federation_recovers_excluded_labels(self, pipeline):
        """Federated enhancement with public data lifts accuracy above the
        biased pre-trained level (the paper's 68% -> 90% mechanism)."""
        fed_ds_all, test, pre_params, pre_acc = pipeline
        fed = scenario_two(fed_ds_all, n_agents=20, n_rsus=4, seed=0)
        cfg = SimConfig(n_agents=20, n_rsus=4, batch=16)
        hp = h2fed(mu1=0.01, mu2=0.005, lar=2, lr=0.1)
        het = HeterogeneityModel(csr=0.5, scd=1, lar=hp.lar)
        res = adhoc_scenario(cfg, hp, het, fed, n_rounds=6,
                             x_test=test.x, y_test=test.y)
        _, hist = run_scenario(res, pre_params)
        assert hist["acc"][-1] > pre_acc + 0.05, (pre_acc, hist["acc"])

    def test_centralized_reference_upper_bounds(self, pipeline):
        """Centralized training (Fig. 3's reference) reaches ceiling acc."""
        fed_ds, test, pre_params, _ = pipeline
        p, hist = train_centralized(pre_params, fed_ds, lr=0.1, epochs=2,
                                    x_test=test.x, y_test=test.y)
        acc = float(mlp.accuracy(p, jnp.asarray(test.x), jnp.asarray(test.y)))
        assert acc > 0.85, acc


class TestAEDMetric:
    def test_aed_definition(self):
        """AED = (ΔACC^{mu1>0} − ΔACC^{mu1=0}) / ΔACC^{mu1=0}  (Eq. 7)."""
        from benchmarks.metrics import aed
        assert aed(0.80, 0.75, acc_pre=0.68) == pytest.approx(
            ((0.80 - 0.68) - (0.75 - 0.68)) / (0.75 - 0.68))
        assert aed(0.75, 0.75, acc_pre=0.68) == 0.0
