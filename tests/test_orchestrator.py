"""Adaptive-mu orchestrator (beyond-paper, core/orchestrator.py)."""
from __future__ import annotations

import pytest
from prop_compat import given, settings, st

from repro.core import orchestrator as orch
from repro.core.h2fed import H2FedParams

CFG = orch.AdaptiveMuConfig()
BASE = H2FedParams(mu1=0.001, mu2=0.005)


def test_good_network_decays_mu():
    st_ = orch.AdaptiveMuState(csr_est=0.95)
    hp, badness = orch.schedule(st_, CFG, BASE)
    assert badness == 0.0
    assert hp.mu1 == CFG.mu1_min and hp.mu2 == CFG.mu2_min


def test_collapsed_network_saturates_mu():
    st_ = orch.AdaptiveMuState(csr_est=0.05)
    hp, badness = orch.schedule(st_, CFG, BASE)
    assert badness == 1.0
    assert hp.mu1 == CFG.mu1_max and hp.mu2 == CFG.mu2_max


def test_observation_ema_moves_toward_truth():
    s = orch.init_state()
    for _ in range(20):
        s = orch.observe_csr(s, CFG, connected=10, participants=100)
    assert abs(s.csr_est - 0.1) < 0.01


@settings(max_examples=50, deadline=None)
@given(csr=st.floats(0.0, 1.0))
def test_schedule_monotone_and_bounded(csr):
    """mu2 is a monotone non-increasing function of CSR, within bounds."""
    hp, _ = orch.schedule(orch.AdaptiveMuState(csr_est=csr), CFG, BASE)
    assert CFG.mu2_min <= hp.mu2 <= CFG.mu2_max
    assert CFG.mu1_min <= hp.mu1 <= CFG.mu1_max
    hp_lo, _ = orch.schedule(orch.AdaptiveMuState(csr_est=max(csr - 0.1, 0)),
                             CFG, BASE)
    assert hp_lo.mu2 >= hp.mu2 - 1e-12


def test_other_hp_fields_preserved():
    hp, _ = orch.schedule(orch.init_state(), CFG,
                          H2FedParams(lar=7, local_epochs=3, lr=0.2))
    assert hp.lar == 7 and hp.local_epochs == 3 and hp.lr == 0.2
