"""Unit + property tests for the H²-Fed objective (paper Eq. 4/6, Alg. 1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from prop_compat import given, settings, st

from repro.core.h2fed import (H2FedParams, dual_proximal_penalty,
                              h2fed_objective, proximal_grad_terms,
                              proximal_sgd_step, sq_norm, tree_sub)

F32 = np.float32


def _tree(seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(5, 3)) * scale, F32),
            "b": jnp.asarray(rng.normal(size=(3,)) * scale, F32)}


class TestPenalty:
    def test_zero_when_at_anchors(self):
        w = _tree(0)
        assert float(dual_proximal_penalty(w, w, w, 0.1, 0.2)) == 0.0

    def test_zero_when_mu_zero(self):
        w, a1, a2 = _tree(0), _tree(1), _tree(2)
        assert float(dual_proximal_penalty(w, a1, a2, 0.0, 0.0)) == 0.0

    def test_matches_closed_form(self):
        w, a1, a2 = _tree(0), _tree(1), _tree(2)
        mu1, mu2 = 0.3, 0.7
        expected = 0.5 * mu1 * float(sq_norm(tree_sub(w, a1))) \
            + 0.5 * mu2 * float(sq_norm(tree_sub(w, a2)))
        got = float(dual_proximal_penalty(w, a1, a2, mu1, mu2))
        np.testing.assert_allclose(got, expected, rtol=1e-6)

    @settings(max_examples=30, deadline=None)
    @given(mu1=st.floats(0, 1), mu2=st.floats(0, 1),
           seed=st.integers(0, 100))
    def test_nonnegative(self, mu1, mu2, seed):
        w, a1, a2 = _tree(seed), _tree(seed + 1), _tree(seed + 2)
        assert float(dual_proximal_penalty(w, a1, a2, mu1, mu2)) >= 0.0

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 50), mu1=st.floats(0.001, 1),
           mu2=st.floats(0.001, 1))
    def test_autodiff_matches_closed_form_grad(self, seed, mu1, mu2):
        """∇penalty == mu1(w−a1) + mu2(w−a2) — the fused-kernel identity."""
        w, a1, a2 = _tree(seed), _tree(seed + 1), _tree(seed + 2)
        auto = jax.grad(
            lambda p: dual_proximal_penalty(p, a1, a2, mu1, mu2))(w)
        closed = proximal_grad_terms(w, a1, a2, mu1, mu2)
        for ga, gc in zip(jax.tree.leaves(auto), jax.tree.leaves(closed)):
            np.testing.assert_allclose(ga, gc, atol=1e-5, rtol=1e-5)


class TestObjective:
    def test_reduces_to_task_loss(self):
        """mu1=mu2=0 ⇒ objective == F(w) (FedAvg limit, paper Sec. V(i))."""
        w, a1, a2 = _tree(0), _tree(1), _tree(2)
        task = lambda p: sq_norm(p)
        hp = H2FedParams(mu1=0.0, mu2=0.0)
        obj = h2fed_objective(task, hp)
        np.testing.assert_allclose(float(obj(w, a1, a2)), float(task(w)),
                                   rtol=1e-6)

    def test_penalty_pulls_toward_anchor(self):
        """Gradient step with large mu moves w toward the anchors."""
        w, anchor = _tree(0, scale=2.0), _tree(1, scale=0.1)
        hp = H2FedParams(mu1=5.0, mu2=5.0, lr=0.05)
        zero_g = jax.tree.map(jnp.zeros_like, w)
        before = float(sq_norm(tree_sub(w, anchor)))
        w2 = proximal_sgd_step(w, zero_g, anchor, anchor, hp)
        after = float(sq_norm(tree_sub(w2, anchor)))
        assert after < before

    def test_proximal_step_matches_autodiff(self):
        """proximal_sgd_step == SGD on the full Eq. 6 objective."""
        w, a1, a2 = _tree(0), _tree(1), _tree(2)
        hp = H2FedParams(mu1=0.2, mu2=0.1, lr=0.03)
        task = lambda p: 0.5 * sq_norm(p)
        g = jax.grad(task)(w)
        got = proximal_sgd_step(w, g, a1, a2, hp)
        full = jax.grad(h2fed_objective(task, hp))(w, a1, a2)
        want = jax.tree.map(lambda x, gg: x - hp.lr * gg, w, full)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(a, b, atol=1e-6)


class TestParams:
    def test_validate_accepts_defaults(self):
        H2FedParams().validate()

    @pytest.mark.parametrize("kw", [dict(mu1=-1.0), dict(lar=0),
                                    dict(local_epochs=0), dict(n_layers=3)])
    def test_validate_rejects(self, kw):
        with pytest.raises(AssertionError):
            H2FedParams(**kw).validate()
