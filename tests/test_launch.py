"""Distributed-runtime tests.  Anything needing >1 device runs in a
subprocess via the shared ``run_forced_devices`` helper (tests/conftest.py)
so the main pytest process keeps the single real CPU device (system spec
§Dry-run.0)."""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from conftest import run_forced_devices as _run_sub

# jaxlib < 0.5 hard-aborts (Check failed: sharding.IsManualSubgroup()) when
# the SPMD partitioner meets the transformer h2fed_round's manual(pod,data) x
# auto(model) subgroup program.  The MLP-fleet sharded engine (test_sharded)
# and the model-axis-1 CLI path are unaffected; on jax >= 0.5 these run.
import jax  # noqa: E402

OLD_JAX_SPMD = tuple(
    int(x) for x in jax.__version__.split(".")[:2]) < (0, 5)
needs_spmd_subgroups = pytest.mark.skipif(
    OLD_JAX_SPMD, reason="manual x auto shard_map subgroups crash the XLA "
                         "SPMD partitioner on jaxlib < 0.5")


class TestMesh:
    def test_mesh_shapes(self):
        code = """
        import jax
        from repro.launch.mesh import make_production_mesh, n_agents, \\
            make_test_mesh
        m = make_test_mesh((2, 2, 2))
        assert m.axis_names == ('pod', 'data', 'model')
        assert n_agents(m) == 4
        m2 = make_test_mesh((4, 2), ('data', 'model'))
        assert n_agents(m2) == 4
        print('ok')
        """
        assert "ok" in _run_sub(code)

    def test_import_mesh_module_touches_no_devices(self):
        # importing mesh.py must not initialize jax backends
        code = """
        import jax
        import repro.launch.mesh  # noqa
        # device init would be visible via _backends
        from jax._src import xla_bridge as xb
        assert not xb._backends, 'mesh import initialized a backend'
        print('ok')
        """
        assert "ok" in _run_sub(code, devices=1)


class TestH2FedRoundShardMap:
    @needs_spmd_subgroups
    def test_round_matches_fedsim_semantics(self):
        """The compiled shard_map hierarchical round must be numerically
        equivalent to a replicated-math reference of Algorithms 1-3 (same
        masks, same LAR cadence, same dual-proximal updates)."""
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_test_mesh
        from repro.launch.h2fed_round import make_h2fed_round
        from repro.core.h2fed import H2FedParams
        from repro.configs.registry import get_reduced_config
        from repro.models import model as M

        mesh = make_test_mesh((2, 2, 2))          # 2 pods x 2 agents x 2 TP
        cfg = get_reduced_config('qwen3-0.6b', n_layers=2, d_model=128,
                                 d_ff=256, vocab_size=128, n_heads=4,
                                 n_kv_heads=2)
        hp = H2FedParams(mu1=0.05, mu2=0.01, lar=2, local_epochs=2, lr=0.1)
        A, b, S = 4, 2, 16
        rng = np.random.default_rng(0)
        params = M.init_params(cfg, jax.random.key(0))
        batch = {'tokens': jnp.asarray(rng.integers(0, 128, (hp.lar, A, b, S)), jnp.int32),
                 'labels': jnp.asarray(rng.integers(0, 128, (hp.lar, A, b, S)), jnp.int32)}
        mask = jnp.asarray(rng.integers(0, 2, (hp.lar, A)), jnp.float32)
        # ensure at least one agent survives each LAR round
        mask = mask.at[:, 0].set(1.0)
        n_data = jnp.asarray(rng.uniform(1, 3, (A,)), jnp.float32)

        fn = make_h2fed_round(cfg, hp, mesh)
        with mesh:
            out, metrics = jax.jit(fn)(params, batch, mask, n_data)

        # ---- replicated reference (pure jnp, no mesh) ----
        def local_train(w0, w_rsu, w_cloud, agent_batch):
            w = w0
            for e in range(hp.local_epochs):
                g = jax.grad(lambda p: M.loss_fn(cfg, p, agent_batch)[0])(w)
                w = jax.tree.map(
                    lambda wl, gl, a1, a2:
                    (wl.astype(jnp.float32) - hp.lr * (
                        gl.astype(jnp.float32)
                        + hp.mu1*(wl.astype(jnp.float32)-a1.astype(jnp.float32))
                        + hp.mu2*(wl.astype(jnp.float32)-a2.astype(jnp.float32))
                    )).astype(wl.dtype), w, g, w_rsu, w_cloud)
            return w

        cloud = params
        # pods = RSUs: agents [0,1] -> pod0, [2,3] -> pod1
        rsu_of = [0, 0, 1, 1]
        w_k = [cloud, cloud]
        mass_tot = [0.0, 0.0]
        for r in range(hp.lar):
            new_k = []
            for k in range(2):
                members = [a for a in range(A) if rsu_of[a] == k]
                ws, wts = [], []
                for a in members:
                    ab = {kk: v[r, a] for kk, v in batch.items()}
                    ws.append(local_train(w_k[k], w_k[k], cloud, ab))
                    wts.append(float(n_data[a] * mask[r, a]))
                tot = sum(wts)
                mass_tot[k] += tot
                if tot > 0:
                    agg = jax.tree.map(
                        lambda *ls: sum(float(w_)*l.astype(jnp.float32)
                                        for w_, l in zip(wts, ls)) / tot,
                        *ws)
                    agg = jax.tree.map(lambda a_, old: a_.astype(old.dtype),
                                       agg, w_k[k])
                    new_k.append(agg)
                else:
                    new_k.append(w_k[k])
            w_k = new_k
        tot = sum(mass_tot)
        ref_cloud = jax.tree.map(
            lambda a_, b_: ((mass_tot[0]*a_.astype(jnp.float32)
                             + mass_tot[1]*b_.astype(jnp.float32)) / tot
                            ).astype(a_.dtype), w_k[0], w_k[1])

        for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(ref_cloud)):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       atol=5e-3, rtol=5e-3)
        print('match ok; mass=', float(metrics['surviving_mass']))
        """
        out = _run_sub(code, devices=8, timeout=900)
        assert "match ok" in out

    def test_flat_agg_matches_per_leaf(self):
        """flat_agg=True (one raveled-buffer collective per layer) must be
        numerically identical to the per-leaf reductions.  model-axis size 1
        so the program runs on every supported jax (see needs_spmd_subgroups
        for the TP>1 regime)."""
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.launch.h2fed_round import make_h2fed_round
        from repro.core.h2fed import H2FedParams
        from repro.configs.registry import get_reduced_config
        from repro.models import model as M

        mesh = make_test_mesh((2, 4, 1))
        cfg = get_reduced_config('qwen3-0.6b', n_layers=2, d_model=128,
                                 d_ff=256, vocab_size=128, n_heads=4,
                                 n_kv_heads=2)
        hp = H2FedParams(mu1=0.05, mu2=0.01, lar=2, local_epochs=1, lr=0.1)
        A, b, S = 8, 2, 16
        rng = np.random.default_rng(0)
        params = M.init_params(cfg, jax.random.key(0))
        batch = {'tokens': jnp.asarray(rng.integers(0, 128, (hp.lar, A, b, S)), jnp.int32),
                 'labels': jnp.asarray(rng.integers(0, 128, (hp.lar, A, b, S)), jnp.int32)}
        mask = jnp.asarray(rng.integers(0, 2, (hp.lar, A)), jnp.float32)
        mask = mask.at[:, 0].set(1.0)
        n_data = jnp.asarray(rng.uniform(1, 3, (A,)), jnp.float32)
        with mesh:
            o1, m1 = jax.jit(make_h2fed_round(cfg, hp, mesh))(
                params, batch, mask, n_data)
            o2, m2 = jax.jit(make_h2fed_round(cfg, hp, mesh, flat_agg=True))(
                params, batch, mask, n_data)
        for a, b_ in zip(jax.tree.leaves(o1), jax.tree.leaves(o2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b_, np.float32),
                                       atol=1e-6, rtol=1e-6)
        assert float(m1['surviving_mass']) == float(m2['surviving_mass'])
        # guard rails: unsupported combinations fail fast
        try:
            make_h2fed_round(cfg, hp, mesh, flat_agg=True,
                             quantize_cloud=True)
            raise SystemExit('expected ValueError (quantize)')
        except ValueError:
            pass
        mesh_tp = make_test_mesh((2, 2, 2))
        try:
            make_h2fed_round(cfg, hp, mesh_tp, flat_agg=True)
            raise SystemExit('expected ValueError (TP mesh)')
        except ValueError:
            pass
        print('flat-agg ok')
        """
        out = _run_sub(code, devices=8, timeout=900)
        assert "flat-agg ok" in out

    @needs_spmd_subgroups
    def test_quantized_cloud_agg_close_to_exact(self):
        """int8 cross-pod aggregation stays within quantization error."""
        code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_test_mesh
        from repro.launch.h2fed_round import make_h2fed_round
        from repro.core.h2fed import H2FedParams
        from repro.configs.registry import get_reduced_config
        from repro.models import model as M

        mesh = make_test_mesh((2, 2, 2))
        cfg = get_reduced_config('qwen3-0.6b', n_layers=2, d_model=128,
                                 d_ff=256, vocab_size=128, n_heads=4,
                                 n_kv_heads=2)
        hp = H2FedParams(mu1=0.01, mu2=0.0, lar=1, local_epochs=1, lr=0.05)
        A, b, S = 4, 2, 16
        rng = np.random.default_rng(1)
        params = M.init_params(cfg, jax.random.key(0))
        batch = {'tokens': jnp.asarray(rng.integers(0, 128, (1, A, b, S)), jnp.int32),
                 'labels': jnp.asarray(rng.integers(0, 128, (1, A, b, S)), jnp.int32)}
        mask = jnp.ones((1, A), jnp.float32)
        n_data = jnp.ones((A,), jnp.float32)
        exact = make_h2fed_round(cfg, hp, mesh, quantize_cloud=False)
        quant = make_h2fed_round(cfg, hp, mesh, quantize_cloud=True)
        with mesh:
            o_e, _ = jax.jit(exact)(params, batch, mask, n_data)
            o_q, _ = jax.jit(quant)(params, batch, mask, n_data)
        rel_max = 0.0
        for a, b_ in zip(jax.tree.leaves(o_e), jax.tree.leaves(o_q)):
            a = np.asarray(a, np.float32); b_ = np.asarray(b_, np.float32)
            denom = max(np.abs(a).max(), 1e-6)
            rel_max = max(rel_max, np.abs(a - b_).max() / denom)
        assert rel_max < 0.01, rel_max
        print('quant ok', rel_max)
        """
        out = _run_sub(code, devices=8, timeout=900)
        assert "quant ok" in out


class TestDryRunMini:
    """End-to-end dryrun driver on a reduced arch (fast compile, 8 devices
    stand in for the pod via make_test_mesh monkeypatch is NOT needed —
    we call run pieces directly)."""

    def test_fsdp_train_step_lowers_and_compiles(self):
        code = """
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_test_mesh
        from repro.launch import steps as S
        from repro.configs.registry import get_reduced_config

        mesh = make_test_mesh((2, 2, 2))
        cfg = get_reduced_config('deepseek-v2-lite-16b')
        # miniature shape entry
        S.SHAPES['mini'] = dict(kind='train', seq=32, batch=8)
        spec = S.input_specs(cfg, 'mini', mesh)
        with mesh:
            lowered = jax.jit(spec['fn'], in_shardings=spec['in_shardings']) \\
                .lower(*spec['args'])
            compiled = lowered.compile()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca   # old-jax: list
        assert ca['flops'] > 0
        txt = compiled.as_text()
        assert 'all-reduce' in txt or 'all-gather' in txt
        print('ok')
        """
        assert "ok" in _run_sub(code, devices=8, timeout=900)

    def test_serve_step_lowers_and_compiles(self):
        code = """
        import jax
        from repro.launch.mesh import make_test_mesh
        from repro.launch import steps as S
        from repro.configs.registry import get_reduced_config

        mesh = make_test_mesh((2, 2, 2))
        cfg = get_reduced_config('zamba2-2.7b')
        S.SHAPES['mini_dec'] = dict(kind='decode', seq=64, batch=4)
        spec = S.input_specs(cfg, 'mini_dec', mesh)
        with mesh:
            compiled = jax.jit(spec['fn'], in_shardings=spec['in_shardings']) \\
                .lower(*spec['args']).compile()
        mem = compiled.memory_analysis()
        peak = getattr(mem, 'peak_memory_in_bytes', None)
        if peak is None:                      # old-jax: no peak stat
            peak = mem.temp_size_in_bytes + mem.output_size_in_bytes
        assert peak > 0
        print('ok')
        """
        assert "ok" in _run_sub(code, devices=8, timeout=900)


class TestDryRunResults:
    """The 80-cell dry-run matrix must exist and be healthy (produced by
    ``python -m repro.launch.dryrun --all``; re-run if you delete it)."""

    RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

    def test_all_cells_present(self):
        if not self.RESULTS.exists():
            pytest.skip("dry-run results not generated yet")
        from repro.configs.registry import ARCH_IDS
        missing = []
        for arch in ARCH_IDS:
            for shape in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                for mesh in ("sp", "mp"):
                    p = self.RESULTS / f"{arch}__{shape}__{mesh}.json"
                    if not p.exists():
                        missing.append(p.name)
        assert not missing, missing

    def test_no_failures_and_rooflines_positive(self):
        if not self.RESULTS.exists():
            pytest.skip("dry-run results not generated yet")
        fails = list(self.RESULTS.glob("*.FAIL.txt"))
        assert not fails, [f.name for f in fails]
        for p in self.RESULTS.glob("*__sp.json"):
            rec = json.loads(p.read_text())
            if "skipped" in rec:
                continue
            r = rec["roofline"]
            assert r["compute_s"] > 0, p.name
            assert r["memory_s"] > 0, p.name
            assert r["dominant"] in ("compute_s", "memory_s",
                                     "collective_s"), p.name

    def test_multipod_shards_pod_axis(self):
        """Multi-pod cells must exist for every non-skipped cell — proving
        the `pod` axis lowers (deliverable e)."""
        if not self.RESULTS.exists():
            pytest.skip("dry-run results not generated yet")
        n_mp = len(list(self.RESULTS.glob("*__mp.json")))
        assert n_mp == 40, n_mp
