"""The unified engine API (DESIGN.md §8): ``run_scenario`` is THE entry
point; the legacy ``run_*_simulation`` signatures are deprecated wrappers
over it with unchanged numerics."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.heterogeneity import HeterogeneityModel
from repro.core.scenario import ScenarioSpec
from repro.fedsim import (AsyncConfig, run_async_simulation, run_scenario,
                          run_simulation)
from repro.fedsim.sweep import adhoc_scenario

BASE = ScenarioSpec(n_agents=12, n_rsus=4, batch=8, n_train=400, n_test=100,
                    het=HeterogeneityModel(csr=0.7), rounds=2)


class TestWrapperEquivalence:
    def test_run_simulation_flat(self):
        res = BASE.resolve()
        st_s, h_s = run_scenario(res)
        with pytest.deprecated_call():
            _, h_w = run_simulation(res.cfg, BASE.hp, BASE.het, res.fed,
                                    _params(), BASE.rounds,
                                    x_test=res.test.x, y_test=res.test.y)
        np.testing.assert_array_equal(h_w["acc"], h_s["acc"])
        np.testing.assert_array_equal(h_w["round"], h_s["round"])

    def test_run_simulation_tree(self):
        res = BASE.resolve()
        with pytest.deprecated_call():
            _, h_tree = run_simulation(res.cfg, BASE.hp, BASE.het, res.fed,
                                       _params(), BASE.rounds,
                                       x_test=res.test.x, y_test=res.test.y,
                                       engine="tree")
        _, h_flat = run_scenario(res)
        np.testing.assert_allclose(h_tree["acc"], h_flat["acc"], atol=3e-6)

    def test_run_async_simulation(self):
        spec = BASE.replace(engine="async", staleness_decay=0.7,
                            cloud_every=2,
                            het=HeterogeneityModel(csr=0.6, max_delay=2,
                                                   delay_p=0.5))
        res = spec.resolve()
        st_s, h_s = run_scenario(res)
        acfg = AsyncConfig(staleness_decay=0.7, cloud_every=2)
        with pytest.deprecated_call():
            st_w, h_w = run_async_simulation(
                res.cfg, spec.hp, spec.het, res.fed, _params(), spec.rounds,
                acfg=acfg, x_test=res.test.x, y_test=res.test.y)
        np.testing.assert_array_equal(h_w["acc"], h_s["acc"])
        np.testing.assert_array_equal(h_w["absorbed_mass"],
                                      h_s["absorbed_mass"])
        np.testing.assert_array_equal(np.asarray(st_w.cloud_flat),
                                      np.asarray(st_s.cloud_flat))

    def test_unknown_engine_still_valueerror(self):
        res = BASE.resolve()
        with pytest.raises(ValueError, match="unknown engine"):
            run_simulation(res.cfg, BASE.hp, BASE.het, res.fed, _params(),
                           1, engine="warp")


class TestAdhocScenario:
    def test_seed_mapping_reproduces_cfg(self):
        res = BASE.resolve()
        ad = adhoc_scenario(res.cfg, BASE.hp, BASE.het, res.fed, n_rounds=3)
        assert ad.cfg.seed == res.cfg.seed
        assert ad.cfg.n_agents == res.cfg.n_agents
        assert ad.spec.rounds == 3
        assert ad.test is None and ad.train is None

    def test_fleet_dtype_object_normalized(self):
        import jax.numpy as jnp
        res = BASE.resolve()
        ad = adhoc_scenario(res.cfg, BASE.hp, BASE.het, res.fed,
                            n_rounds=1, fleet_dtype=jnp.bfloat16)
        assert ad.spec.fleet_dtype == "bfloat16"

    def test_eval_optional(self):
        """No test set -> the engines run without an accuracy eval."""
        res = BASE.resolve()
        ad = adhoc_scenario(res.cfg, BASE.hp, BASE.het, res.fed, n_rounds=1)
        _, hist = run_scenario(ad, _params())
        assert hist["acc"].size == 0


class TestSpecValidation:
    def test_streaming_requires_flat_or_async(self):
        with pytest.raises(AssertionError, match="cohort streaming"):
            BASE.replace(engine="sharded", fleet_store="host").validate()
        with pytest.raises(AssertionError, match="cohort streaming"):
            BASE.replace(engine="tree", chunk_agents=4).validate()
        BASE.replace(engine="async", fleet_store="host").validate()

    def test_unknown_fleet_store_rejected(self):
        with pytest.raises(ValueError, match="unknown fleet store"):
            BASE.replace(fleet_store="warp").validate()


def _params():
    import jax
    from repro.configs.mnist_mlp import CONFIG
    from repro.models import mlp
    return mlp.init_params(CONFIG, jax.random.key(BASE.seed))
