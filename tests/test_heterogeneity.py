"""Tests for the CSR/SCD/FSR connectivity model (paper Sec. III, Tab. I)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.heterogeneity import (HeterogeneityModel, connectivity_trace,
                                      init_conn_state, sample_epochs,
                                      step_connectivity)


class TestConnectivity:
    def test_csr_one_always_connected(self):
        het = HeterogeneityModel(csr=1.0, scd=1)
        masks = connectivity_trace(jax.random.key(0), 50, 20, het)
        assert bool(jnp.all(masks))

    def test_csr_zero_never_connected(self):
        het = HeterogeneityModel(csr=0.0, scd=1)
        masks = connectivity_trace(jax.random.key(0), 50, 20, het)
        assert not bool(jnp.any(masks))

    @pytest.mark.parametrize("csr", [0.1, 0.5, 0.9])
    def test_long_run_connection_fraction_tracks_csr(self, csr):
        """With SCD=1 the stationary connected fraction equals CSR."""
        het = HeterogeneityModel(csr=csr, scd=1)
        masks = connectivity_trace(jax.random.key(1), 200, 300, het)
        frac = float(jnp.mean(masks.astype(jnp.float32)))
        assert abs(frac - csr) < 0.03, (frac, csr)

    def test_scd_holds_connection_for_duration(self):
        """Once drawn, the connection persists exactly SCD rounds."""
        het = HeterogeneityModel(csr=1.0, scd=4)
        state = init_conn_state(3)
        runs = []
        key = jax.random.key(0)
        for r in range(9):
            key, k = jax.random.split(key)
            state, mask = step_connectivity(k, state, het)
            runs.append(np.asarray(mask))
        assert np.all(np.stack(runs))  # csr=1: never drops

        # csr=0 after a forced connect: stays up exactly scd-1 more rounds
        state = init_conn_state(2)
        state, m0 = step_connectivity(jax.random.key(2), state,
                                      HeterogeneityModel(csr=1.0, scd=3))
        assert bool(m0.all())
        het0 = HeterogeneityModel(csr=0.0, scd=3)
        ups = []
        for r in range(4):
            state, m = step_connectivity(jax.random.fold_in(key, r), state,
                                         het0)
            ups.append(bool(m.all()))
        assert ups == [True, True, False, False]

    def test_deterministic_given_key(self):
        het = HeterogeneityModel(csr=0.5, scd=2)
        a = connectivity_trace(jax.random.key(7), 30, 40, het)
        b = connectivity_trace(jax.random.key(7), 30, 40, het)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestFSR:
    def test_fsr_one_all_full(self):
        e = sample_epochs(jax.random.key(0), 100,
                          HeterogeneityModel(fsr=1.0), 5)
        assert bool(jnp.all(e == 5))

    def test_fsr_zero_all_partial(self):
        e = sample_epochs(jax.random.key(0), 1000,
                          HeterogeneityModel(fsr=0.0), 5)
        assert bool(jnp.all(e < 5)) and bool(jnp.all(e >= 0))

    def test_fraction_full_tracks_fsr(self):
        e = sample_epochs(jax.random.key(3), 5000,
                          HeterogeneityModel(fsr=0.7), 4)
        frac = float(jnp.mean((e == 4).astype(jnp.float32)))
        # partial draws can also land on 4? no: randint(0, 4) < 4.
        assert abs(frac - 0.7) < 0.03


class TestValidation:
    @pytest.mark.parametrize("kw", [dict(csr=1.5), dict(csr=-0.1),
                                    dict(fsr=2.0), dict(scd=0), dict(lar=0)])
    def test_rejects_bad(self, kw):
        with pytest.raises(AssertionError):
            HeterogeneityModel(**kw).validate()
