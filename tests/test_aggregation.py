"""Property tests for hierarchical CSR-masked aggregation (Alg. 2/3)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from prop_compat import given, hnp, settings, st

from repro.core.aggregation import (blend_on_mass, broadcast_to_agents,
                                    cloud_aggregate, gather_rsu_for_agents,
                                    masked_weighted_mean, rsu_aggregate)

F32 = np.float32


def _stacked(seed, a=8, shape=(4, 3)):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(a,) + shape), F32)}


class TestMaskedWeightedMean:
    def test_uniform_weights_is_mean(self):
        s = _stacked(0)
        got = masked_weighted_mean(s, jnp.ones(8))
        np.testing.assert_allclose(got["w"], np.mean(s["w"], axis=0),
                                   atol=1e-6)

    def test_mask_zero_entries_excluded(self):
        s = _stacked(1)
        mask = jnp.asarray([1, 1, 0, 0, 0, 0, 0, 0], F32)
        got = masked_weighted_mean(s, jnp.ones(8), mask)
        np.testing.assert_allclose(got["w"], np.mean(s["w"][:2], axis=0),
                                   atol=1e-6)

    def test_all_masked_falls_back_to_mean(self):
        s = _stacked(2)
        got = masked_weighted_mean(s, jnp.ones(8), jnp.zeros(8))
        np.testing.assert_allclose(got["w"], np.mean(s["w"], axis=0),
                                   atol=1e-6)

    @settings(max_examples=40, deadline=None)
    @given(w=hnp.arrays(F32, (6,), elements=st.floats(0.0, 10.0, width=32)),
           seed=st.integers(0, 100))
    def test_convex_combination_bounds(self, w, seed):
        """Aggregate lies inside the per-coordinate min/max envelope."""
        s = _stacked(seed, a=6)
        got = np.asarray(masked_weighted_mean(s, jnp.asarray(w))["w"])
        lo = s["w"].min(axis=0) - 1e-5
        hi = s["w"].max(axis=0) + 1e-5
        assert (got >= lo).all() and (got <= hi).all()

    def test_weight_scale_invariance(self):
        s = _stacked(3)
        w = jnp.asarray(np.random.default_rng(0).uniform(0.1, 2, 8), F32)
        a = masked_weighted_mean(s, w)
        b = masked_weighted_mean(s, w * 7.3)
        np.testing.assert_allclose(a["w"], b["w"], atol=1e-5)


class TestRSUAggregate:
    def test_matches_manual_segments(self):
        rng = np.random.default_rng(0)
        A, R = 10, 3
        s = {"w": jnp.asarray(rng.normal(size=(A, 4)), F32)}
        weights = jnp.asarray(rng.uniform(1, 5, A), F32)
        mask = jnp.asarray(rng.integers(0, 2, A), F32)
        assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)
        got, mass = rsu_aggregate(s, weights, mask, assign, R)
        for r in range(R):
            sel = (np.asarray(assign) == r)
            wm = np.asarray(weights) * np.asarray(mask)
            m = (wm * sel).sum()
            np.testing.assert_allclose(float(mass[r]), m, rtol=1e-6)
            if m > 0:
                exp = (np.asarray(s["w"]) * (wm * sel)[:, None]).sum(0) / m
                np.testing.assert_allclose(np.asarray(got["w"])[r], exp,
                                           atol=1e-5)

    def test_blend_keeps_old_on_empty_cohort(self):
        new = {"w": jnp.ones((3, 2))}
        old = {"w": jnp.full((3, 2), 7.0)}
        mass = jnp.asarray([1.0, 0.0, 2.0])
        out = blend_on_mass(new, old, mass)
        np.testing.assert_allclose(out["w"],
                                   [[1, 1], [7, 7], [1, 1]])

    def test_identity_when_single_rsu_full_mask(self):
        """One RSU, all connected, equal weights == plain FedAvg mean."""
        s = _stacked(5, a=4)
        got, _ = rsu_aggregate(s, jnp.ones(4), jnp.ones(4),
                               jnp.zeros(4, jnp.int32), 1)
        np.testing.assert_allclose(np.asarray(got["w"])[0],
                                   np.mean(s["w"], axis=0), atol=1e-6)


class TestHierarchyComposition:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_two_level_equals_flat_when_balanced(self, seed):
        """Balanced cohorts + equal weights: RSU-then-cloud == global mean
        (the hierarchy is lossless in the homogeneous limit)."""
        rng = np.random.default_rng(seed)
        A, R = 12, 3
        s = {"w": jnp.asarray(rng.normal(size=(A, 5)), F32)}
        assign = jnp.asarray(np.arange(A) % R, jnp.int32)
        rsu, mass = rsu_aggregate(s, jnp.ones(A), jnp.ones(A), assign, R)
        cloud = cloud_aggregate(rsu, mass)
        np.testing.assert_allclose(np.asarray(cloud["w"]),
                                   np.mean(s["w"], axis=0), atol=1e-5)

    def test_broadcast_gather_roundtrip(self):
        p = {"w": jnp.arange(6.0).reshape(3, 2)}
        stacked = broadcast_to_agents(p, 5)
        assert stacked["w"].shape == (5, 3, 2)
        picked = gather_rsu_for_agents(
            {"w": jnp.stack([p["w"], p["w"] * 2])},
            jnp.asarray([0, 1, 1], jnp.int32))
        np.testing.assert_allclose(picked["w"][2], p["w"] * 2)
