"""Agent-sharded engine tests (DESIGN.md §4).

Single-device cases run inline on a (1,)-'data' mesh; true multi-device
cases run in subprocesses via the shared ``forced_devices_run`` fixture
(tests/conftest.py) so the main pytest process keeps the single real CPU
device — CI's multi-device smoke step runs this file under 8 forced host
devices, where ``make_fleet_mesh`` becomes a ('pod','data') mesh and the
same equivalence must hold.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

EQUIV_CODE = """
import jax, numpy as np
from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core.baselines import h2fed
from repro.core.heterogeneity import HeterogeneityModel
from repro.data.partition import scenario_two
from repro.data.synthetic import mnist_class_task
from repro.fedsim.simulator import SimConfig, run_simulation
from repro.fedsim.sharded import make_fleet_mesh, run_sharded_simulation
from repro.launch.mesh import agent_axes

train, test = mnist_class_task(n_train=2000, n_test=400, seed=0)
fed = scenario_two(train, n_agents=8, n_rsus=4, seed=0)
from repro.models import mlp
params = mlp.init_params(MLP_CFG, jax.random.key(0))
cfg = SimConfig(n_agents=8, n_rsus=4, batch=16, seed=0)
hp = h2fed(mu1=0.01, mu2=0.005, lar=2, lr=0.1)
het = HeterogeneityModel(csr=0.6, lar=hp.lar)

_, h_flat = run_simulation(cfg, hp, het, fed, params, 3,
                           x_test=test.x, y_test=test.y, engine="flat")
mesh = make_fleet_mesh()
assert len(jax.devices()) == {devices}, len(jax.devices())
_, h_sh = run_sharded_simulation(cfg, hp, het, fed, params, 3, mesh=mesh,
                                 x_test=test.x, y_test=test.y)
np.testing.assert_allclose(h_flat["acc"], h_sh["acc"], atol=2e-3)
print("axes", agent_axes(mesh), "shards-ok")
"""


@pytest.fixture(scope="module")
def small_fed(tiny_task, fed_small):
    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.models import mlp
    train, test = tiny_task
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    return fed_small, test, params


class TestSingleDevice:
    def test_matches_flat_engine(self, small_fed):
        """On a 1-device mesh the shard_map program must reproduce the flat
        engine exactly (same draws, same aggregation algebra)."""
        from repro.core.baselines import h2fed
        from repro.core.heterogeneity import HeterogeneityModel
        from repro.fedsim.sharded import make_fleet_mesh, \
            run_sharded_simulation
        from repro.fedsim.simulator import SimConfig, run_simulation
        fed, test, params = small_fed
        cfg = SimConfig(n_agents=fed.n_agents, n_rsus=4, batch=16, seed=0)
        hp = h2fed(mu1=0.05, mu2=0.01, lar=2, lr=0.1)
        het = HeterogeneityModel(csr=0.5, lar=hp.lar)
        _, h_flat = run_simulation(cfg, hp, het, fed, params, 2,
                                   x_test=test.x, y_test=test.y,
                                   engine="flat")
        mesh = make_fleet_mesh(1)
        _, h_sh = run_sharded_simulation(cfg, hp, het, fed, params, 2,
                                         mesh=mesh, x_test=test.x,
                                         y_test=test.y)
        np.testing.assert_allclose(h_flat["acc"], h_sh["acc"], atol=2e-3)

    def test_indivisible_agents_raise(self, small_fed):
        from repro.core import flatten
        from repro.core.baselines import h2fed
        from repro.core.heterogeneity import HeterogeneityModel
        from repro.fedsim.sharded import make_sharded_global_round
        from repro.fedsim.simulator import SimConfig
        fed, _, params = small_fed
        spec = flatten.spec_of(params)
        cfg = SimConfig(n_agents=7, n_rsus=4)

        # a 2-shard mesh stand-in: the divisibility check reads only
        # .shape/.axis_names, and fires before any device work
        class _Mesh:
            shape = {"data": 2}
            axis_names = ("data",)

        with pytest.raises(ValueError, match="must divide"):
            make_sharded_global_round(
                cfg, h2fed(), HeterogeneityModel(), fed, spec, _Mesh())

    def test_fleet_mesh_shapes(self):
        from repro.fedsim.sharded import make_fleet_mesh, n_shards
        m1 = make_fleet_mesh(1)
        assert m1.axis_names == ("data",) and n_shards(m1) == 1


class TestMultiDevice:
    def test_equivalence_on_8_devices(self, forced_devices_run):
        """Flat vs sharded on a 2x4 ('pod','data') mesh — CI's smoke step."""
        out = forced_devices_run(EQUIV_CODE.format(devices=8), devices=8,
                                 timeout=900)
        assert "shards-ok" in out
        assert "('pod', 'data')" in out

    def test_equivalence_on_2_devices(self, forced_devices_run):
        out = forced_devices_run(EQUIV_CODE.format(devices=2), devices=2,
                                 timeout=900)
        assert "shards-ok" in out
