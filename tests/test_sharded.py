"""Agent-sharded engine tests (DESIGN.md §4).

Single-device cases run inline on a (1,)-'data' mesh; true multi-device
cases run in subprocesses via the shared ``forced_devices_run`` fixture
(tests/conftest.py) so the main pytest process keeps the single real CPU
device — CI's multi-device smoke step runs this file under 8 forced host
devices, where ``make_fleet_mesh`` becomes a ('pod','data') mesh and the
same equivalence must hold.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

EQUIV_CODE = """
import jax, numpy as np
from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core.baselines import h2fed
from repro.core.heterogeneity import HeterogeneityModel
from repro.data.partition import scenario_two
from repro.data.synthetic import mnist_class_task
from repro.fedsim.simulator import SimConfig
from repro.fedsim.sharded import make_fleet_mesh
from repro.fedsim.sweep import adhoc_scenario, run_scenario
from repro.launch.mesh import agent_axes

train, test = mnist_class_task(n_train=2000, n_test=400, seed=0)
fed = scenario_two(train, n_agents=8, n_rsus=4, seed=0)
from repro.models import mlp
params = mlp.init_params(MLP_CFG, jax.random.key(0))
cfg = SimConfig(n_agents=8, n_rsus=4, batch=16, seed=0)
hp = h2fed(mu1=0.01, mu2=0.005, lar=2, lr=0.1)
het = HeterogeneityModel(csr=0.6, lar=hp.lar)

def run(engine, **kw):
    mesh = kw.pop("mesh", None)
    res = adhoc_scenario(cfg, hp, het, fed, n_rounds=3, engine=engine,
                         x_test=test.x, y_test=test.y, **kw)
    return run_scenario(res, params, mesh=mesh)

_, h_flat = run("flat")
mesh = make_fleet_mesh()
assert len(jax.devices()) == {devices}, len(jax.devices())
_, h_sh = run("sharded", mesh=mesh)
np.testing.assert_allclose(h_flat["acc"], h_sh["acc"], atol=2e-3)
print("axes", agent_axes(mesh), "shards-ok")
"""


RSU_EQUIV_CODE = """
import jax, numpy as np
from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core import flatten
from repro.core.baselines import h2fed
from repro.core.heterogeneity import HeterogeneityModel
from repro.data.partition import scenario_two
from repro.data.synthetic import mnist_class_task
from repro.fedsim.simulator import SimConfig, init_flat_state
from repro.fedsim.sharded import (make_fleet_mesh, make_sharded_global_round,
                                  resolve_topology)
from repro.fedsim.sweep import adhoc_scenario, run_scenario
from repro.launch import hlo_analysis as H
from repro.models import mlp

assert len(jax.devices()) == {devices}, len(jax.devices())
train, test = mnist_class_task(n_train=1000, n_test=200, seed=0)
fed = scenario_two(train, n_agents={agents}, n_rsus=4, seed=0)
params = mlp.init_params(MLP_CFG, jax.random.key(0))
cfg = SimConfig(n_agents={agents}, n_rsus=4, batch=16, seed=0)
hp = h2fed(mu1=0.01, mu2=0.005, lar=2, lr=0.1)
het = HeterogeneityModel(csr=0.6, lar=hp.lar)

def run(engine, mesh=None, **kw):
    res = adhoc_scenario(cfg, hp, het, fed, n_rounds=2, engine=engine,
                         x_test=test.x, y_test=test.y, **kw)
    return run_scenario(res, params, mesh=mesh)

_, h_flat = run("flat")

# acceptance: RSU-sharded == flat for every pod count dividing R
for pods in {pod_counts}:
    mesh = make_fleet_mesh({devices}, n_pods=pods)
    _, h_rs = run("sharded", mesh=mesh, rsu_sharded=True)
    np.testing.assert_allclose(h_flat["acc"], h_rs["acc"], atol=2e-3)
    print("pods", pods, "equiv-ok")

# acceptance: zero cross-pod collectives in the RSU (in-loop) step
mesh = make_fleet_mesh({devices}, n_pods=2)
topo = resolve_topology(cfg, fed, mesh, rsu_sharded=True)
spec = flatten.spec_of(params)
rf = make_sharded_global_round(cfg, hp, het, fed, spec, topo)
state = init_flat_state(cfg, spec, params, jax.random.key(0))
with mesh:
    txt = rf.lower(state).compile().as_text()
pods_dev = [[d.id for d in row.ravel()] for row in mesh.devices]
colls = H.collective_schedule(txt)
assert colls, "no collectives found in the compiled round"
in_loop_cross = [c for c in colls
                 if c["in_loop"] and not H.groups_within(c["groups"], pods_dev)]
out_cross = [c for c in colls
             if not c["in_loop"] and not H.groups_within(c["groups"], pods_dev)]
assert not in_loop_cross, in_loop_cross
assert out_cross, colls            # the cloud layer does cross pods
print("collectives-ok", len(colls), "total,", len(out_cross), "cloud-crossing")
"""


NSHARD_EQUIV_CODE = """
import jax, numpy as np
from repro.core.scenario import ScenarioSpec
from repro.fedsim import run_scenario

assert len(jax.devices()) == {devices}, len(jax.devices())
BASE = ScenarioSpec(n_agents=16, n_rsus=4, batch=8, n_train=400,
                    n_test=100, rounds=2, engine="sharded")

# acceptance grid: N-sharded == replicated across (rsu_sharded x shards);
# fp32 fleets are EXACT in replicated mode (collective-free cloud math),
# fp32-tol when the cloud layer psums across pods
for rsu_sharded in (False, True):
    ref, h_ref = run_scenario(BASE.replace(rsu_sharded=rsu_sharded))
    n = ref.cloud_flat.shape[0]
    for shards in {shard_counts}:
        st, h = run_scenario(BASE.replace(rsu_sharded=rsu_sharded,
                                          model_shards=shards))
        # model_shards=1 is the UNTOUCHED dispatch -> bit-identical;
        # replicated nshard is collective-free in the cloud -> exact too
        tol = 0.0 if (shards == 1 or not rsu_sharded) else 1e-5
        np.testing.assert_allclose(np.asarray(st.cloud_flat)[:n],
                                   np.asarray(ref.cloud_flat),
                                   rtol=0, atol=tol)
        np.testing.assert_allclose(h["acc"], h_ref["acc"], atol=1e-3)
        # the padded tail never leaks mass: zero from init through blends
        assert not np.asarray(st.cloud_flat)[n:].any()
        print("rsu_sharded", rsu_sharded, "shards", shards, "equiv-ok")

# bf16 storage: the round's reference all-gather travels in the fleet
# storage dtype, so the nshard round matches replicated to bf16 tolerance
ref_b, h_refb = run_scenario(BASE.replace(fleet_dtype="bf16"))
st_b, h_b = run_scenario(BASE.replace(fleet_dtype="bf16", model_shards=2))
n = ref_b.cloud_flat.shape[0]
np.testing.assert_allclose(np.asarray(st_b.cloud_flat)[:n],
                           np.asarray(ref_b.cloud_flat), rtol=0, atol=2e-2)
np.testing.assert_allclose(h_b["acc"], h_refb["acc"], atol=5e-2)
print("bf16 equiv-ok")
"""


@pytest.fixture(scope="module")
def small_fed(tiny_task, fed_small):
    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.models import mlp
    train, test = tiny_task
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    return fed_small, test, params


class _DuckMesh:
    """Static mesh metadata stand-in: topology validation reads only
    .shape/.axis_names and must fire before any device work."""

    def __init__(self, shape, axes):
        self.shape = dict(zip(axes, shape))
        self.axis_names = tuple(axes)


class TestTopology:
    """HierarchyTopology edge cases (host-side, no devices touched)."""

    def test_block_structure(self):
        from repro.core.topology import HierarchyTopology
        topo = HierarchyTopology(8, 4, _DuckMesh((2, 2), ("pod", "data")),
                                 rsu_sharded=True)
        assert topo.rsu_per_pod == 2
        # pods own contiguous RSU blocks and every permuted agent's RSU
        # lives on its own pod
        pod_of_agent = topo.pod_of_rsu[topo.rsu_assign[topo.agent_perm]]
        assert (pod_of_agent == np.repeat([0, 1], 4)).all()
        assert set(topo.local_assign.tolist()) <= {0, 1}
        # permute/unpermute round-trip
        v = np.arange(8)
        np.testing.assert_array_equal(
            topo.unpermute_agents(topo.permute_agents(v)), v)

    def test_r_not_divisible_by_pods_raises(self):
        """Pinned error message for the R % pods != 0 case."""
        from repro.core.topology import HierarchyTopology
        with pytest.raises(ValueError,
                           match="n_rsus=3 is not divisible by n_pods=2"):
            HierarchyTopology(8, 3, _DuckMesh((2, 2), ("pod", "data")),
                              rsu_sharded=True)

    def test_unequal_pod_cohorts_raise(self):
        from repro.core.topology import HierarchyTopology
        assign = np.asarray([0, 0, 0, 0, 0, 1, 2, 3], np.int32)  # pod0: 6
        with pytest.raises(ValueError, match="equal agents per pod"):
            HierarchyTopology(8, 4, _DuckMesh((2, 2), ("pod", "data")),
                              rsu_assign=assign, rsu_sharded=True)

    def test_single_pod_degenerate_mesh(self):
        """No pod axis: rsu_sharded collapses to one block — identity
        permutation, replicated (R, N) spec."""
        from jax.sharding import PartitionSpec as P
        from repro.core.topology import HierarchyTopology
        topo = HierarchyTopology(8, 4, _DuckMesh((2,), ("data",)),
                                 rsu_sharded=True)
        assert topo.n_pods == 1 and topo.rsu_per_pod == 4
        np.testing.assert_array_equal(topo.agent_perm, np.arange(8))
        np.testing.assert_array_equal(topo.local_assign, topo.rsu_assign)
        assert topo.rsu_spec == P()

    def test_model_axis_surface(self):
        """N-sharding surface (DESIGN.md §12): the model axis is read off
        the mesh, excluded from agent sharding, and the nshard specs lay
        the cloud/RSU buffers out 1/shards per device."""
        from jax.sharding import PartitionSpec as P
        from repro.core.topology import HierarchyTopology
        topo = HierarchyTopology(8, 4, _DuckMesh((2, 2, 2),
                                                 ("pod", "data", "model")))
        assert topo.model_axis == "model" and topo.model_shards == 2
        # agent rows shard over (pod, data) only — 4 shards, not 8
        assert topo.n_shards == 4
        assert topo.nshard_cloud_spec == P("model")
        assert topo.nshard_rsu_spec == P(None, "model")
        rs = HierarchyTopology(8, 4, _DuckMesh((2, 2, 2),
                                               ("pod", "data", "model")),
                               rsu_sharded=True)
        assert rs.nshard_rsu_spec == P("pod", "model")
        # no model axis: the nshard specs collapse to the replicated ones
        flat = HierarchyTopology(8, 4, _DuckMesh((2, 2), ("pod", "data")))
        assert flat.model_axis is None and flat.model_shards == 1
        assert flat.nshard_cloud_spec == flat.cloud_spec

    def test_model_pad(self):
        """model_pad rounds N up so every shard is lane-aligned (128);
        identity at model_shards == 1."""
        from repro.core.topology import HierarchyTopology
        topo = HierarchyTopology(8, 4, _DuckMesh((2, 2, 2),
                                                 ("pod", "data", "model")))
        assert topo.model_pad(31810) == 32000          # 2 * 125 * 128
        assert topo.model_pad(256) == 256
        assert topo.model_pad(1) == 256
        flat = HierarchyTopology(8, 4, _DuckMesh((2, 2), ("pod", "data")))
        assert flat.model_pad(31810) == 31810

    def test_fleet_mesh_model_shards(self):
        """make_fleet_mesh grows the model axis behind n_model_shards and
        rejects counts that do not divide the devices."""
        from repro.fedsim.sharded import make_fleet_mesh, n_shards
        m = make_fleet_mesh(1, n_model_shards=1)
        assert m.axis_names == ("data",)
        with pytest.raises(ValueError, match="must divide the device"):
            make_fleet_mesh(4, n_model_shards=3)

    def test_spmd_flavor_from_mesh(self):
        """launch/h2fed_round's mapping: one agent per (pod, data)
        position, one RSU per pod, identity permutation."""
        from repro.core.topology import HierarchyTopology
        topo = HierarchyTopology.from_mesh(
            _DuckMesh((2, 4, 1), ("pod", "data", "model")))
        assert topo.n_agents == 8 and topo.n_rsus == 2
        assert topo.rsu_per_pod == 1 and topo.pod_axis == "pod"
        np.testing.assert_array_equal(topo.agent_perm, np.arange(8))


class TestSingleDevice:
    def test_matches_flat_engine(self, small_fed):
        """On a 1-device mesh the shard_map program must reproduce the flat
        engine exactly (same draws, same aggregation algebra)."""
        from repro.core.baselines import h2fed
        from repro.core.heterogeneity import HeterogeneityModel
        from repro.fedsim.sharded import make_fleet_mesh
        from repro.fedsim.simulator import SimConfig
        from repro.fedsim.sweep import adhoc_scenario, run_scenario
        fed, test, params = small_fed
        cfg = SimConfig(n_agents=fed.n_agents, n_rsus=4, batch=16, seed=0)
        hp = h2fed(mu1=0.05, mu2=0.01, lar=2, lr=0.1)
        het = HeterogeneityModel(csr=0.5, lar=hp.lar)

        def run(engine, mesh=None, **kw):
            res = adhoc_scenario(cfg, hp, het, fed, n_rounds=2,
                                 engine=engine, x_test=test.x,
                                 y_test=test.y, **kw)
            return run_scenario(res, params, mesh=mesh)

        _, h_flat = run("flat")
        _, h_sh = run("sharded", mesh=make_fleet_mesh(1))
        np.testing.assert_allclose(h_flat["acc"], h_sh["acc"], atol=2e-3)

        # RSU-sharded on the degenerate single-pod mesh: same anchor
        _, h_rs = run("sharded", mesh=make_fleet_mesh(1, n_pods=1),
                      rsu_sharded=True)
        np.testing.assert_allclose(h_flat["acc"], h_rs["acc"], atol=2e-3)

    def test_empty_rsu_keeps_anchor(self, small_fed):
        """An RSU with no agents at all: the topology builds, the engine
        runs, and the empty RSU's buffer row keeps the round's cloud
        anchor (zero-mass blend semantics)."""
        import dataclasses
        from repro.core.baselines import h2fed
        from repro.core.heterogeneity import HeterogeneityModel
        from repro.fedsim.sharded import make_fleet_mesh, resolve_topology
        from repro.fedsim.simulator import SimConfig
        from repro.fedsim.sweep import adhoc_scenario, run_scenario
        fed, test, params = small_fed
        # re-home RSU 1's agents onto RSU 0: RSU 1 has an empty cohort
        assign = np.asarray(fed.rsu_assign).copy()
        assign[assign == 1] = 0
        fed2 = dataclasses.replace(fed, rsu_assign=assign)
        cfg = SimConfig(n_agents=fed.n_agents, n_rsus=4, batch=16, seed=0)
        hp = h2fed(mu1=0.05, mu2=0.01, lar=2, lr=0.1)
        het = HeterogeneityModel(csr=0.8, lar=hp.lar)
        mesh = make_fleet_mesh(1, n_pods=1)
        topo = resolve_topology(cfg, fed2, mesh, rsu_sharded=True)
        assert (np.bincount(topo.rsu_assign, minlength=4) == 0).any()
        s_flat, h_flat = run_scenario(
            adhoc_scenario(cfg, hp, het, fed2, n_rounds=2, engine="flat",
                           x_test=test.x, y_test=test.y), params)
        s_rs, h_rs = run_scenario(
            adhoc_scenario(cfg, hp, het, fed2, n_rounds=2, engine="sharded",
                           x_test=test.x, y_test=test.y), params, mesh=topo)
        np.testing.assert_allclose(h_flat["acc"], h_rs["acc"], atol=2e-3)
        # both engines carry the same (R, N) buffer — including the empty
        # RSU's row, which keeps the round-start cloud anchor (zero-mass
        # blend) rather than going to zero or NaN
        from repro.core import flatten
        spec = flatten.spec_of(params)
        rsu_flat_ref = np.asarray(spec.ravel_stacked(s_flat.rsu_params))
        np.testing.assert_allclose(np.asarray(s_rs.rsu_flat)[1],
                                   rsu_flat_ref[1], atol=1e-4, rtol=1e-4)
        assert np.isfinite(np.asarray(s_rs.rsu_flat)).all()

    def test_indivisible_agents_raise(self, small_fed):
        from repro.core import flatten
        from repro.core.baselines import h2fed
        from repro.core.heterogeneity import HeterogeneityModel
        from repro.fedsim.sharded import make_sharded_global_round
        from repro.fedsim.simulator import SimConfig
        fed, _, params = small_fed
        spec = flatten.spec_of(params)
        cfg = SimConfig(n_agents=7, n_rsus=4)

        # a 2-shard mesh stand-in: the divisibility check reads only
        # .shape/.axis_names, and fires before any device work
        with pytest.raises(ValueError, match="must divide"):
            make_sharded_global_round(
                cfg, h2fed(), HeterogeneityModel(), fed, spec,
                _DuckMesh((2,), ("data",)))

    def test_fleet_mesh_shapes(self):
        from repro.fedsim.sharded import make_fleet_mesh, n_shards
        m1 = make_fleet_mesh(1)
        assert m1.axis_names == ("data",) and n_shards(m1) == 1
        m2 = make_fleet_mesh(1, n_pods=1)
        assert m2.axis_names == ("pod", "data") and n_shards(m2) == 1
        with pytest.raises(ValueError, match="must divide the device"):
            make_fleet_mesh(4, n_pods=3)


class TestMultiDevice:
    def test_equivalence_on_8_devices(self, forced_devices_run):
        """Flat vs sharded on a 2x4 ('pod','data') mesh — CI's smoke step."""
        out = forced_devices_run(EQUIV_CODE.format(devices=8), devices=8,
                                 timeout=900)
        assert "shards-ok" in out
        assert "('pod', 'data')" in out

    def test_equivalence_on_2_devices(self, forced_devices_run):
        out = forced_devices_run(EQUIV_CODE.format(devices=2), devices=2,
                                 timeout=900)
        assert "shards-ok" in out

    def test_rsu_sharded_8_devices(self, forced_devices_run):
        """The acceptance sweep: RSU-sharded == flat for pod counts 1/2/4
        dividing R, AND the compiled round's collective schedule keeps the
        RSU (in-loop) step pod-local — only the cloud layer crosses pods
        (hlo_analysis.collective_schedule)."""
        out = forced_devices_run(
            RSU_EQUIV_CODE.format(devices=8, agents=8,
                                  pod_counts=(1, 2, 4)),
            devices=8, timeout=900)
        for pods in (1, 2, 4):
            assert f"pods {pods} equiv-ok" in out
        assert "collectives-ok" in out

    def test_nshard_equivalence_grid_8_devices(self, forced_devices_run):
        """The PR-10 acceptance grid: N-sharded == replicated across
        (rsu_sharded x model_shards) on a (2,2,2) mesh, exact for fp32
        replicated cells, fp32-tol where the cloud layer psums, bf16-tol
        under bf16 storage; model_shards=1 stays bit-identical and the
        pad-to-lane tail carries no mass (ragged N=31810 -> 32000)."""
        out = forced_devices_run(
            NSHARD_EQUIV_CODE.format(devices=8, shard_counts=(1, 2)),
            devices=8, timeout=900)
        for rsu_sharded in (False, True):
            for shards in (1, 2):
                assert (f"rsu_sharded {rsu_sharded} shards {shards} "
                        f"equiv-ok" in out)
        assert "bf16 equiv-ok" in out

    def test_rsu_sharded_16_devices_2d(self, forced_devices_run):
        """16-forced-host-device 2-D mesh: the 4x4 ('pod','data') layout
        (R_local=1 — one RSU per pod, the production shape)."""
        out = forced_devices_run(
            RSU_EQUIV_CODE.format(devices=16, agents=16, pod_counts=(4,)),
            devices=16, timeout=900)
        assert "pods 4 equiv-ok" in out
        assert "collectives-ok" in out
