"""Trip-count-aware HLO analyzer (launch/hlo_analysis.py): the roofline's
measurement layer must model scans and in-place updates correctly."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestTripCounts:
    def test_scan_body_multiplied(self):
        """A 64-iteration scan of a matmul must count ~64x one matmul."""
        w = jnp.ones((128, 128), jnp.float32)

        def one(x):
            return x @ w

        def scanned(x):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=64)
            return out

        x = jnp.ones((128, 128), jnp.float32)
        f1 = H.analyze(_hlo(one, x))["flops"]
        f64 = H.analyze(_hlo(scanned, x))["flops"]
        assert f1 > 0
        assert 50 * f1 <= f64 <= 80 * f1, (f1, f64)


class TestInPlaceUpdates:
    def test_scan_residual_writes_not_full_buffer(self):
        """A scan stacking per-step outputs writes each SLICE in place —
        the analyzer must not charge trips x full-buffer bytes."""
        S, D = 512, 256

        def stacker(x):
            def body(c, _):
                c = c * 1.0001
                return c, c
            _, ys = jax.lax.scan(body, x, None, length=S)
            return ys

        x = jnp.ones((D,), jnp.float32)
        b = H.analyze(_hlo(stacker, x))["bytes"]
        full_buffer_per_trip = S * S * D * 4     # the overcounting mode
        honest = 4 * S * D * 4                   # slice writes + carry RW
        assert b < full_buffer_per_trip / 10, b
        assert b >= honest / 4, b

    def test_collectives_counted_per_kind(self):
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1,), ("data",))
        # single-device: no collectives expected; analyzer returns zeros
        def f(x):
            return x * 2
        an = H.analyze(_hlo(f, jnp.ones((8, 8))))
        assert an["collective_bytes"] == 0.0


_SCHEDULE_HLO = """\
HloModule m, input_output_alias={}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%body (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,8] get-tuple-element(%p), index=1
  %ar = f32[4,8] all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,8]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[4,8])) -> pred[] {
  %p = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4,8]) -> f32[4,8] {
  %x = f32[4,8] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[4,8]) tuple(%zero, %x)
  %w = (s32[], f32[4,8]) while(%t0), condition=%cond, body=%body
  %y = f32[4,8] get-tuple-element(%w), index=1
  ROOT %out = f32[4,8] all-reduce(%y), replica_groups=[4,2]<=[2,4]T(1,0), to_apply=%add
}
"""


class TestCollectiveSchedule:
    """The topology-first communication contract reader (DESIGN.md §4):
    which collectives run inside the scanned RSU step vs once per round,
    and which replica groups they use."""

    PODS = [[0, 1, 2, 3], [4, 5, 6, 7]]

    def test_in_loop_and_groups_parsed(self):
        sched = H.collective_schedule(_SCHEDULE_HLO)
        assert len(sched) == 2
        in_loop = [c for c in sched if c["in_loop"]]
        out_loop = [c for c in sched if not c["in_loop"]]
        assert len(in_loop) == 1 and len(out_loop) == 1
        # explicit list form: {{0..3},{4..7}} — within the pod partition
        assert in_loop[0]["groups"] == [[0, 1, 2, 3], [4, 5, 6, 7]]
        assert H.groups_within(in_loop[0]["groups"], self.PODS)
        # iota form [4,2]<=[2,4]T(1,0): transposed pairs {0,4},{1,5},...
        assert out_loop[0]["groups"] == [[0, 4], [1, 5], [2, 6], [3, 7]]
        assert not H.groups_within(out_loop[0]["groups"], self.PODS)

    def test_groups_within_edge_cases(self):
        # no spelled-out groups == one group of everything
        assert H.groups_within(None, [[0, 1, 2, 3]])
        assert not H.groups_within(None, self.PODS)
        assert H.groups_within([[0, 1], [2, 3]], [[0, 1, 2, 3]])

    MESH2D = [("pod", 2), ("data", 4)]

    def test_collective_axes(self):
        """Axis attribution (DESIGN.md §12): device ids are row-major over
        the mesh shape, so on (pod=2, data=4) id = pod*4 + data."""
        assert H.collective_axes([[0, 1, 2, 3], [4, 5, 6, 7]],
                                 self.MESH2D) == ["data"]
        assert H.collective_axes([[0, 4], [1, 5], [2, 6], [3, 7]],
                                 self.MESH2D) == ["pod"]
        assert H.collective_axes([[0, 5]], self.MESH2D) == ["pod", "data"]
        assert H.collective_axes(None, self.MESH2D) == ["pod", "data"]
        assert H.collective_axes([[0], [3]], self.MESH2D) == []
        # trivial (size-1) axes never span
        assert H.collective_axes(None, [("pod", 1), ("data", 8)]) \
            == ["data"]

    def test_collective_axis_bytes_rollup(self):
        """Per-axis byte rollup over the schedule: the in-loop all-reduce
        ({0..3},{4..7}) is data-axis (ICI) traffic, the out-of-loop one
        ({0,4},...) is pod-axis (DCI) traffic."""
        res = H.collective_axis_bytes(_SCHEDULE_HLO, self.MESH2D)
        sched = H.collective_schedule(_SCHEDULE_HLO)
        by_loop = {c["in_loop"]: c["bytes"] for c in sched}
        assert res["per_axis"]["data"] == by_loop[True]
        assert res["per_axis"]["pod"] == by_loop[False]
        for e in res["entries"]:
            assert e["axes"] == (["data"] if e["in_loop"] else ["pod"])

    def test_axis_attribution_on_compiled_2d_mesh(self, forced_devices_run):
        """Pin the row-major id assumption against a REAL compiled 2-D
        mesh: a psum over each named axis must attribute its bytes to
        that axis only."""
        out = forced_devices_run("""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.launch.mesh import make_mesh, shard_map
            from repro.launch import hlo_analysis as H

            mesh = make_mesh((2, 2), ("pod", "data"))
            axes = list(zip(mesh.axis_names, mesh.devices.shape))
            x = jnp.ones((4, 4), jnp.float32)
            for ax, spec in (("data", P("pod", None)),
                             ("pod", P(None, "data"))):
                sm = shard_map(lambda v, a=ax: jax.lax.psum(v, a), mesh,
                               in_specs=(P("pod", "data"),),
                               out_specs=spec,
                               axis_names={"pod", "data"})
                txt = jax.jit(sm).lower(x).compile().as_text()
                per = H.collective_axis_bytes(txt, axes)["per_axis"]
                assert per[ax] > 0, (ax, per)
                other = "pod" if ax == "data" else "data"
                assert per[other] == 0.0, (ax, per)
                print("axis", ax, "attributed-ok")
            """, devices=4)
        assert "axis data attributed-ok" in out
        assert "axis pod attributed-ok" in out


class TestBreakdown:
    def test_breakdown_attribution_sums_sanely(self):
        w = jnp.ones((64, 64), jnp.float32)

        def f(x):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=8)
            return y + 1.0

        rows = H.breakdown(_hlo(f, jnp.ones((64, 64))), top=10)
        assert rows, "breakdown returned nothing"
        labels = " ".join(r[0] for r in rows)
        assert "while" in labels
        total_flops = sum(r[2] for r in rows)
        # 8 x (2*64^3) from the scanned matmuls
        assert total_flops >= 8 * 2 * 64**3 * 0.9
