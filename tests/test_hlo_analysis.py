"""Trip-count-aware HLO analyzer (launch/hlo_analysis.py): the roofline's
measurement layer must model scans and in-place updates correctly."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


class TestTripCounts:
    def test_scan_body_multiplied(self):
        """A 64-iteration scan of a matmul must count ~64x one matmul."""
        w = jnp.ones((128, 128), jnp.float32)

        def one(x):
            return x @ w

        def scanned(x):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=64)
            return out

        x = jnp.ones((128, 128), jnp.float32)
        f1 = H.analyze(_hlo(one, x))["flops"]
        f64 = H.analyze(_hlo(scanned, x))["flops"]
        assert f1 > 0
        assert 50 * f1 <= f64 <= 80 * f1, (f1, f64)


class TestInPlaceUpdates:
    def test_scan_residual_writes_not_full_buffer(self):
        """A scan stacking per-step outputs writes each SLICE in place —
        the analyzer must not charge trips x full-buffer bytes."""
        S, D = 512, 256

        def stacker(x):
            def body(c, _):
                c = c * 1.0001
                return c, c
            _, ys = jax.lax.scan(body, x, None, length=S)
            return ys

        x = jnp.ones((D,), jnp.float32)
        b = H.analyze(_hlo(stacker, x))["bytes"]
        full_buffer_per_trip = S * S * D * 4     # the overcounting mode
        honest = 4 * S * D * 4                   # slice writes + carry RW
        assert b < full_buffer_per_trip / 10, b
        assert b >= honest / 4, b

    def test_collectives_counted_per_kind(self):
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1,), ("data",))
        # single-device: no collectives expected; analyzer returns zeros
        def f(x):
            return x * 2
        an = H.analyze(_hlo(f, jnp.ones((8, 8))))
        assert an["collective_bytes"] == 0.0


class TestBreakdown:
    def test_breakdown_attribution_sums_sanely(self):
        w = jnp.ones((64, 64), jnp.float32)

        def f(x):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=8)
            return y + 1.0

        rows = H.breakdown(_hlo(f, jnp.ones((64, 64))), top=10)
        assert rows, "breakdown returned nothing"
        labels = " ".join(r[0] for r in rows)
        assert "while" in labels
        total_flops = sum(r[2] for r in rows)
        # 8 x (2*64^3) from the scanned matmuls
        assert total_flops >= 8 * 2 * 64**3 * 0.9
