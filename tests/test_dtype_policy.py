"""One-pass rounds + fleet dtype policy (DESIGN.md §3).

Pins the PR-4 contracts: the fused aggregate-and-blend round is
bit-compatible with the two-pass program at fp32; bf16 fleet storage keeps
the fp32 cloud master, converges alongside fp32 on the paper task (the
fig-2-smoke anchor at a pinned tolerance), checkpoints exactly, and its
compiled async tick moves >= 1.5x fewer HBM bytes than the pre-fusion fp32
program (``launch/hlo_analysis.round_cost``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flatten
from repro.fedsim.simulator import (FlatSimState, SimConfig,  # noqa: F401
                                    init_flat_state)
from repro.fedsim.sweep import adhoc_scenario, run_scenario

F32 = np.float32


def _run(cfg, hp, het, fed, params, rounds, *, x_test, y_test, **kw):
    res = adhoc_scenario(cfg, hp, het, fed, n_rounds=rounds,
                         x_test=x_test, y_test=y_test, **kw)
    return run_scenario(res, params)


@pytest.fixture(scope="module")
def sim_setup(tiny_task, fed_small):
    from repro.configs.mnist_mlp import CONFIG as MLP_CFG
    from repro.core.baselines import h2fed
    from repro.core.heterogeneity import HeterogeneityModel
    from repro.models import mlp
    train, test = tiny_task
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    cfg = SimConfig(n_agents=fed_small.n_agents, n_rsus=4, batch=16, seed=0)
    hp = h2fed(mu1=0.05, mu2=0.01, lar=2, lr=0.1)
    het = HeterogeneityModel(csr=0.6, lar=hp.lar)
    return fed_small, test, params, cfg, hp, het


class TestFusedRound:
    def test_fused_equals_unfused_fp32_bitwise(self, sim_setup):
        """The one-pass round == the two-pass program BIT-exactly at fp32
        (off-TPU both routes lower to the same XLA ops by construction)."""
        fed, test, params, cfg, hp, het = sim_setup
        sf, hf = _run(cfg, hp, het, fed, params, 2,
                                x_test=test.x, y_test=test.y)
        su, hu = _run(cfg, hp, het, fed, params, 2,
                                x_test=test.x, y_test=test.y, fused=False)
        np.testing.assert_array_equal(hf["acc"], hu["acc"])
        for a, b in zip(jax.tree.leaves(sf.cloud_params),
                        jax.tree.leaves(su.cloud_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fused_equals_unfused_async(self, sim_setup):
        """Same contract for the semi-async engine (fused agg_absorb vs
        scatter+scatter+add+buffer_absorb), with real latencies/decay."""
        from repro.core.heterogeneity import HeterogeneityModel
        from repro.fedsim.async_engine import AsyncConfig
        fed, test, params, cfg, hp, _ = sim_setup
        het = HeterogeneityModel(csr=0.8, lar=hp.lar, max_delay=2,
                                 delay_p=0.5)
        acfg = AsyncConfig(staleness_decay=0.5, buffer_keep=0.5)
        sf, hf = _run(cfg, hp, het, fed, params, 2,
                                x_test=test.x, y_test=test.y,
                                engine="async", async_cfg=acfg)
        su, hu = _run(cfg, hp, het, fed, params, 2,
                                x_test=test.x, y_test=test.y,
                                engine="async", async_cfg=acfg,
                                fused=False)
        np.testing.assert_array_equal(hf["acc"], hu["acc"])
        np.testing.assert_array_equal(np.asarray(sf.cloud_flat),
                                      np.asarray(su.cloud_flat))


class TestBf16FleetStorage:
    def test_state_dtypes(self, sim_setup):
        """bf16 storage mode: (A, N)/(R, N) buffers bf16, cloud master
        fp32 — for the flat and async states."""
        fed, _, params, cfg, hp, het = sim_setup
        spec = flatten.spec_of(params, storage_dtype="bfloat16")
        st = init_flat_state(cfg, spec, params, jax.random.key(0))
        assert st.agent_flat.dtype == jnp.bfloat16
        assert st.rsu_flat.dtype == jnp.bfloat16
        assert st.cloud_flat.dtype == jnp.float32
        from repro.fedsim.async_engine import init_async_state
        sa = init_async_state(cfg, spec, params, jax.random.key(0))
        assert sa.agent_flat.dtype == jnp.bfloat16
        assert sa.pending_x.dtype == jnp.bfloat16
        assert sa.cloud_flat.dtype == jnp.float32

    def test_bf16_round_preserves_policy(self, sim_setup):
        """One compiled round keeps the dtype policy (no silent widening
        of the fleet, no silent narrowing of the cloud master)."""
        from repro.fedsim.simulator import make_flat_global_round
        fed, _, params, cfg, hp, het = sim_setup
        spec = flatten.spec_of(params, storage_dtype="bfloat16")
        st = init_flat_state(cfg, spec, params, jax.random.key(0))
        st = make_flat_global_round(cfg, hp, het, fed, spec)(st)
        assert st.agent_flat.dtype == jnp.bfloat16
        assert st.rsu_flat.dtype == jnp.bfloat16
        assert st.cloud_flat.dtype == jnp.float32

    def test_bf16_converges_with_fp32(self, sim_setup):
        """The fig-2 smoke anchor: bf16 fleet storage reaches the same
        accuracy as fp32 (pinned to 3 points over a short run; the
        acceptance bound is 1 point at the paper-scale run recorded in
        the bench flow)."""
        fed, test, params, cfg, hp, het = sim_setup
        _, hf = _run(cfg, hp, het, fed, params, 4,
                               x_test=test.x, y_test=test.y)
        _, hb = _run(cfg, hp, het, fed, params, 4,
                               x_test=test.x, y_test=test.y,
                               fleet_dtype="bfloat16")
        assert abs(hb["acc"][-1] - hf["acc"][-1]) < 0.03, \
            (hb["acc"], hf["acc"])

    def test_bf16_async_tracks_fp32(self, sim_setup):
        from repro.core.heterogeneity import HeterogeneityModel
        from repro.fedsim.async_engine import AsyncConfig
        fed, test, params, cfg, hp, _ = sim_setup
        het = HeterogeneityModel(csr=0.8, lar=hp.lar, max_delay=2,
                                 delay_p=0.5)
        _, hf = _run(cfg, hp, het, fed, params, 3,
                               x_test=test.x, y_test=test.y,
                               engine="async", async_cfg=AsyncConfig())
        _, hb = _run(cfg, hp, het, fed, params, 3,
                               x_test=test.x, y_test=test.y,
                               engine="async", async_cfg=AsyncConfig(),
                               fleet_dtype="bfloat16")
        assert abs(hb["acc"][-1] - hf["acc"][-1]) < 0.03, \
            (hb["acc"], hf["acc"])

    def test_resolve_storage_dtype(self):
        for name in ("bfloat16", "bf16"):
            assert flatten.resolve_storage_dtype(name) == jnp.bfloat16
        for name in (None, "float32", "f32", "fp32"):
            assert flatten.resolve_storage_dtype(name) == jnp.float32
        with pytest.raises(ValueError):
            flatten.resolve_storage_dtype("fp8")
        # dtype OBJECTS outside the policy are rejected too (fp16's range
        # can overflow weighted numerators — fail at config time)
        with pytest.raises(ValueError):
            flatten.resolve_storage_dtype(jnp.float16)


class TestBf16Checkpoint:
    def test_flat_state_round_trips_exactly(self, sim_setup, tmp_path):
        """bf16 FlatSimState save/load is EXACT: ckpt widens bf16 -> f32
        (lossless) for npz storage and restores the recorded dtype."""
        from repro.checkpoint import ckpt
        fed, _, params, cfg, hp, het = sim_setup
        spec = flatten.spec_of(params, storage_dtype="bfloat16")
        st = init_flat_state(cfg, spec, params, jax.random.key(3))
        # make the buffer contents non-trivial (and non-f32-representable-
        # by-accident): a real compiled round
        from repro.fedsim.simulator import make_flat_global_round
        st = make_flat_global_round(cfg, hp, het, fed, spec)(st)
        # the typed rng key is not an npz-storable leaf — store its data
        st_store = st._replace(rng=jax.random.key_data(st.rng))
        ckpt.save(tmp_path, 1, st_store)
        # the ConnState node cannot be proto-serialized -> like= restore
        with pytest.raises(ValueError, match="like"):
            ckpt.restore(tmp_path, 1)
        restored = ckpt.restore(tmp_path, 1, like=st_store)
        assert restored.agent_flat.dtype == jnp.bfloat16
        assert restored.rsu_flat.dtype == jnp.bfloat16
        assert restored.cloud_flat.dtype == jnp.float32
        for name in ("agent_flat", "rsu_flat", "cloud_flat"):
            np.testing.assert_array_equal(
                np.asarray(getattr(restored, name), np.float32),
                np.asarray(getattr(st, name), np.float32), err_msg=name)
        np.testing.assert_array_equal(np.asarray(restored.rng),
                                      np.asarray(st_store.rng))


class TestRoundBytes:
    def test_round_cost_counts_fleet_bytes(self):
        """hlo_analysis.round_cost on a compiled tick program: sane keys,
        and the fused+bf16 tick moves >= 1.5x fewer HBM bytes than the
        pre-fusion fp32 program (the PR-4 acceptance bound, asserted at
        test scale; benchmarks/async_round records the shipped number)."""
        from repro.core.aggregation import buffer_absorb
        from repro.kernels import ops
        from repro.launch.hlo_analysis import round_cost
        rng = np.random.default_rng(0)
        A, R, N = 16, 4, 4096
        assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)

        def args(dtype):
            return (jnp.asarray(rng.standard_normal((A, N)), dtype),
                    jnp.asarray(rng.standard_normal((A, N)), dtype),
                    jnp.asarray(rng.uniform(0, 2, A), jnp.float32),
                    jnp.asarray(rng.uniform(0, 2, A), jnp.float32),
                    jnp.asarray(rng.standard_normal((R, N)), dtype),
                    jnp.asarray(rng.uniform(0, 5, R), jnp.float32))

        @jax.jit
        def unfused(af, px, wi, wd, rsu, rm):
            ni, mi = ops.masked_scatter_accumulate(af, wi, assign, R)
            nd, md = ops.masked_scatter_accumulate(px, wd, assign, R)
            return buffer_absorb(rsu, rm, ni + nd, mi + md, keep=0.5)

        @jax.jit
        def fused(af, px, wi, wd, rsu, rm):
            out, total, _ = ops.agg_absorb(((af, wi), (px, wd)), assign,
                                           R, rsu, rm, keep=0.5)
            return out, total

        c_unf = round_cost(unfused, *args(jnp.float32), latency_s=1e-3)
        c_fus = round_cost(fused, *args(jnp.bfloat16))
        assert c_unf["bytes"] > 0 and c_fus["bytes"] > 0
        assert c_unf["hbm_gbps"] == pytest.approx(c_unf["bytes"] / 1e6)
        assert c_unf["bytes"] / c_fus["bytes"] >= 1.5, \
            (c_unf["bytes"], c_fus["bytes"])
