"""Fault-injection subsystem tests (DESIGN.md §11).

Pins the robustness layer's four guarantees:

  * the ZERO-FAULT ANCHOR — an empty :class:`FaultPlan` threaded through
    the fault-gated programs is BIT-IDENTICAL to ``faults=None`` on every
    engine (flat / async / streamed / serving / sweep): the benign
    lowering is all-ones up/scale and all-zeros poison masks, and every
    fold the engines apply to those values is an IEEE identity;
  * QUARANTINE — corrupted updates (NaN/Inf payloads, byzantine scale
    blow-ups) are counted, scrubbed and weight-masked, never absorbed:
    a fully-poisoned fleet leaves the cloud master untouched, and the
    guard is what does the work (disabling it lets the NaNs through);
  * ONE-PROGRAM FAULT GRIDS — schedules lower to mask DATA, so a sweep
    over different fault plans (one guard config) traces exactly once;
  * CRASH-RESUME — the serve loop's periodic snapshots restore to a
    bit-identical continuation, including host-side fault randomness
    (per-event seeded duplicates / skew), and a mid-loop exception
    raises :class:`ServeLoopInterrupted` carrying a resumable snapshot.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import program_cache
from repro.core.faults import (ChurnWindow, CorruptSpec, FAULT_FIELDS,
                               FaultPlan, FaultSchedule, RsuOutage)
from repro.core.load_gen import every_agent_once_trace, read_trace
from repro.core.scenario import ScenarioSpec
from repro.fedsim import run_scenario
from repro.fedsim.serving import ServeLoopInterrupted, run_serve_loop
from repro.fedsim.sweep import run_scenarios

BASE = dict(n_agents=8, n_rsus=4, batch=8, n_train=400, n_test=100,
            rounds=2)
SERVE = dict(staleness_decay=1.0, buffer_keep=0.0, cloud_every=0)


def _np(x):
    if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jax.dtypes.prng_key):
        x = jax.random.key_data(x)
    return np.asarray(x)


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = _np(x), _np(y)
        if x.dtype == object:      # host fleet-store handles, not arrays
            continue
        np.testing.assert_array_equal(x, y)


# --------------------------------------------------------------------------
# the plan: validation, serde, lowering
# --------------------------------------------------------------------------

class TestFaultPlan:
    def test_validate_rejects(self):
        with pytest.raises(AssertionError):
            FaultPlan(churn=(ChurnWindow(frac=1.5),)).validate()
        with pytest.raises(AssertionError):
            FaultPlan(churn=(ChurnWindow(frac=0.5, start=-1),)).validate()
        with pytest.raises(AssertionError):
            FaultPlan(outages=(RsuOutage(rsu=7),)).validate(n_rsus=4)
        with pytest.raises(AssertionError):
            FaultPlan(corrupt=(CorruptSpec(kind="gremlin", frac=0.1),)
                      ).validate()
        with pytest.raises(AssertionError):
            FaultPlan(dup_frac=1.0).validate()
        with pytest.raises(AssertionError):
            FaultPlan(clock_skew=-0.1).validate()
        with pytest.raises(AssertionError):
            FaultPlan(norm_clip=-1.0).validate()
        FaultPlan(churn=(ChurnWindow(frac=0.9),),
                  outages=(RsuOutage(rsu=1, start=2, stop=4),),
                  corrupt=(CorruptSpec(kind="nan", frac=0.3),),
                  dup_frac=0.2, clock_skew=0.1).validate(n_rsus=4)

    def test_serde_roundtrip(self):
        plan = FaultPlan(churn=(ChurnWindow(frac=0.9, start=1, seed=3),),
                         outages=(RsuOutage(rsu=1, start=2, stop=4),),
                         corrupt=(CorruptSpec(kind="scale", frac=0.2,
                                              scale=5.0),),
                         dup_frac=0.1, clock_skew=0.2, norm_clip=7.5,
                         seed=11)
        assert FaultPlan.from_dict(plan.to_dict()) == plan
        with pytest.raises(ValueError, match="unknown FaultPlan"):
            FaultPlan.from_dict({"gremlins": 3})

    def test_fingerprint_is_guard_only(self):
        """Schedules are data; only the guard config shapes the program."""
        a = FaultPlan(churn=(ChurnWindow(frac=0.3),))
        b = FaultPlan(outages=(RsuOutage(rsu=0, stop=4),),
                      corrupt=(CorruptSpec(kind="nan", frac=0.9),))
        assert a.static_fingerprint == b.static_fingerprint
        assert (FaultPlan(norm_clip=1.0).static_fingerprint
                != FaultPlan(norm_clip=2.0).static_fingerprint)
        assert (FaultPlan(guard_nonfinite=False).static_fingerprint
                != FaultPlan().static_fingerprint)

    def test_benign_lowering_is_identity_masks(self):
        sched = FaultSchedule.benign(6, 3, 5)
        assert sched.agent_up.shape == (5, 6)
        assert sched.rsu_up.shape == (5, 3)
        np.testing.assert_array_equal(sched.agent_up, 1.0)
        np.testing.assert_array_equal(sched.rsu_up, 1.0)
        np.testing.assert_array_equal(sched.scale, 1.0)
        for k in ("reanchor", "poison_mask", "poison_val", "stale"):
            np.testing.assert_array_equal(getattr(sched, k), 0.0)

    def test_lower_churn_outage_windows(self):
        plan = FaultPlan(churn=(ChurnWindow(frac=0.5, start=2, stop=4),),
                         outages=(RsuOutage(rsu=1, start=1, stop=3),))
        sched = plan.lower(8, 3, 6)
        # half the fleet dark exactly on ticks [2, 4)
        dark = (sched.agent_up == 0.0).sum(axis=1)
        np.testing.assert_array_equal(dark, [0, 0, 4, 4, 0, 0])
        # outage on [1, 3), recovery re-anchor fires at tick 3
        np.testing.assert_array_equal(sched.rsu_up[:, 1],
                                      [1, 0, 0, 1, 1, 1])
        np.testing.assert_array_equal(sched.reanchor[:, 1],
                                      [0, 0, 0, 1, 0, 0])
        assert sched.reanchor[:, [0, 2]].sum() == 0
        # deterministic: the same plan lowers to the same masks
        for k in FAULT_FIELDS:
            np.testing.assert_array_equal(getattr(sched, k),
                                          getattr(plan.lower(8, 3, 6), k))

    def test_tick_slice_clips_past_end(self):
        sched = FaultPlan(churn=(ChurnWindow(frac=1.0, start=3),)
                          ).lower(4, 2, 5)
        for k in FAULT_FIELDS:
            np.testing.assert_array_equal(sched.tick_slice(100)[k],
                                          sched.tick_slice(4)[k])
        rs = sched.round_slice(1, 5)           # ticks 5..9 all clip to 4
        np.testing.assert_array_equal(rs["agent_up"], 0.0)
        stacked = sched.stacked_rounds(2, 5)
        assert stacked["agent_up"].shape == (2, 5, 4)
        np.testing.assert_array_equal(stacked["agent_up"][1],
                                      rs["agent_up"])


# --------------------------------------------------------------------------
# the zero-fault anchor (every engine, bit-identical)
# --------------------------------------------------------------------------

class TestZeroFaultAnchor:
    @pytest.mark.parametrize("kw", [
        dict(engine="flat"),
        dict(engine="async"),
        dict(engine="flat", fleet_store="host", chunk_agents=3),
        dict(engine="async", fleet_store="host", chunk_agents=3),
    ], ids=["flat", "async", "streamed-flat", "streamed-async"])
    def test_empty_plan_bit_identical(self, kw):
        clean_st, clean_h = run_scenario(ScenarioSpec(**BASE, **kw))
        f_st, f_h = run_scenario(
            ScenarioSpec(**BASE, **kw, faults=FaultPlan()))
        _leaves_equal(clean_st, f_st)
        np.testing.assert_array_equal(clean_h["acc"], f_h["acc"])
        assert np.all(np.asarray(f_h["quarantined"]) == 0)

    def test_empty_plan_serving_bit_identical(self):
        A, rounds = BASE["n_agents"], 2
        spec = ScenarioSpec(**BASE, **SERVE, engine="async",
                            serve_events=A * 5 * rounds,
                            tick_trigger=f"batch:{A}").replace(rounds=rounds)
        gen = every_agent_once_trace(A, 5 * rounds)
        st1, h1, s1, _ = run_serve_loop(spec.resolve(), gen=gen)
        st2, h2, s2, _ = run_serve_loop(
            spec.replace(faults=FaultPlan()).resolve(), gen=gen)
        np.testing.assert_array_equal(np.asarray(st1.cloud_flat),
                                      np.asarray(st2.cloud_flat))
        np.testing.assert_array_equal(h1["acc"], h2["acc"])
        assert s1.n_ticks == s2.n_ticks
        assert (s2.events_lost_churn == s2.events_duplicated
                == s2.events_stale_rejected == s2.quarantined_updates == 0)


# --------------------------------------------------------------------------
# quarantine: counted, scrubbed, never absorbed
# --------------------------------------------------------------------------

class TestQuarantine:
    def test_full_poison_never_reaches_cloud(self):
        """Every update NaN every tick: all mass quarantined, the cloud
        master never moves, and accuracy is flat at its initial value."""
        spec = ScenarioSpec(**BASE, engine="flat", faults=FaultPlan(
            corrupt=(CorruptSpec(kind="nan", frac=1.0),)))
        st, hist = run_scenario(spec)
        assert all(q > 0 for q in hist["quarantined"])
        assert np.isfinite(hist["acc"]).all()
        assert len(set(hist["acc"].tolist())) == 1    # cloud never updated
        for leaf in jax.tree_util.tree_leaves(st):
            if _np(leaf).dtype != object:
                assert np.isfinite(_np(leaf).astype(np.float32)).all()

    def test_guard_is_load_bearing(self):
        """With the non-finite screen disabled the same poison reaches the
        fleet — the guard, not luck, keeps the faulted runs finite."""
        spec = ScenarioSpec(**BASE, engine="flat", faults=FaultPlan(
            corrupt=(CorruptSpec(kind="nan", frac=1.0),),
            guard_nonfinite=False))
        st, _ = run_scenario(spec)
        leaves = [_np(l) for l in jax.tree_util.tree_leaves(st)]
        assert any(l.dtype != object
                   and not np.isfinite(l.astype(np.float32)).all()
                   for l in leaves)

    def test_norm_clip_screens_byzantine_scale(self):
        """Scaled blow-ups pass the finite screen but trip the norm clip;
        benign rows survive it."""
        spec = ScenarioSpec(**BASE, engine="flat", faults=FaultPlan(
            corrupt=(CorruptSpec(kind="scale", frac=0.5, scale=1e6),),
            norm_clip=50.0))
        st, hist = run_scenario(spec)
        assert all(q > 0 for q in hist["quarantined"])
        lar, A = spec.hp.lar, spec.n_agents
        assert all(q < lar * A for q in hist["quarantined"])
        assert np.isfinite(hist["acc"]).all()
        for leaf in jax.tree_util.tree_leaves(st):
            if _np(leaf).dtype != object:
                assert np.isfinite(_np(leaf).astype(np.float32)).all()

    def test_rsu_outage_blocks_and_recovers(self):
        """A mid-run RSU outage diverts its cohort mass (blocked, not
        absorbed) and the run stays finite through recovery re-anchor."""
        lar = ScenarioSpec(**BASE).hp.lar
        spec = ScenarioSpec(**BASE, engine="async", faults=FaultPlan(
            outages=(RsuOutage(rsu=0, start=1, stop=lar + 1),)))
        _, hist = run_scenario(spec)
        assert float(np.sum(hist["blocked_mass"])) > 0.0
        assert np.isfinite(hist["acc"]).all()
        assert np.all(np.asarray(hist["quarantined"]) == 0)

    def test_streamed_rejects_corruption_plans(self):
        spec = ScenarioSpec(**BASE, engine="flat", fleet_store="host",
                            chunk_agents=3, faults=FaultPlan(
                                corrupt=(CorruptSpec(kind="nan",
                                                     frac=0.5),)))
        with pytest.raises(AssertionError, match="corrupt"):
            spec.validate()


# --------------------------------------------------------------------------
# serve-loop faults: churn / duplicates / quarantine accounting
# --------------------------------------------------------------------------

class TestServeFaults:
    def test_fault_accounting_identity(self):
        """Nothing leaks under faults: every generated admission (incl.
        injected duplicates) is absorbed, coalesced, dropped, lost to
        churn, or rejected as stale."""
        plan = FaultPlan(churn=(ChurnWindow(frac=0.5),),
                         corrupt=(CorruptSpec(kind="nan", frac=0.3),),
                         dup_frac=0.25, clock_skew=0.05, seed=3)
        spec = ScenarioSpec(**BASE, **SERVE, engine="async",
                            serve_events=96, arrival_rate=2.0, faults=plan)
        st, _, stats, _ = run_serve_loop(spec.resolve())
        assert stats.events_duplicated > 0
        assert stats.events_lost_churn > 0
        assert stats.quarantined_updates > 0
        assert stats.events_generated == 96 + stats.events_duplicated
        assert stats.events_generated == (
            stats.events_absorbed + stats.events_coalesced
            + stats.events_dropped + stats.events_lost_churn
            + stats.events_stale_rejected)
        assert np.isfinite(np.asarray(st.cloud_flat)).all()

    def test_summary_exports_fault_counters(self):
        spec = ScenarioSpec(**BASE, **SERVE, engine="async",
                            serve_events=24, faults=FaultPlan())
        _, _, stats, _ = run_serve_loop(spec.resolve())
        s = stats.summary()
        for k in ("events_lost_churn", "events_duplicated",
                  "events_stale_rejected", "quarantined_updates",
                  "blocked_mass"):
            assert k in s, k


# --------------------------------------------------------------------------
# crash-resume: snapshots, bit-identical continuation, graceful shutdown
# --------------------------------------------------------------------------

class TestServeResume:
    def _spec(self, plan=None):
        A = BASE["n_agents"]
        return ScenarioSpec(**BASE, **SERVE, engine="async",
                            serve_events=A * 10,
                            tick_trigger=f"batch:{A}", faults=plan)

    def test_resume_bit_identical(self, tmp_path):
        """Resume from a mid-run snapshot == the uninterrupted run, bit
        for bit — including replayed host-side fault randomness."""
        plan = FaultPlan(churn=(ChurnWindow(frac=0.25, start=2),),
                         dup_frac=0.2, clock_skew=0.05, seed=5)
        spec = self._spec(plan)
        gen = every_agent_once_trace(BASE["n_agents"], 10)
        d = tmp_path / "snaps"
        st1, h1, s1, _ = run_serve_loop(spec.resolve(), gen=gen,
                                        snapshot_dir=d, snapshot_every=2)
        steps = sorted(p.name for p in d.glob("step_*"))
        assert len(steps) >= 3                    # periodic + final
        mid = 4
        st2, h2, s2, _ = run_serve_loop(spec.resolve(), gen=gen,
                                        resume_from=d, resume_step=mid)
        np.testing.assert_array_equal(np.asarray(st1.cloud_flat),
                                      np.asarray(st2.cloud_flat))
        np.testing.assert_array_equal(np.asarray(st1.rsu_flat),
                                      np.asarray(st2.rsu_flat))
        np.testing.assert_array_equal(h1["acc"], h2["acc"])
        assert s1.n_ticks == s2.n_ticks
        assert s1.events_generated == s2.events_generated
        assert s1.events_duplicated == s2.events_duplicated
        assert s1.events_lost_churn == s2.events_lost_churn
        assert s1.quarantined_updates == s2.quarantined_updates

    def test_interrupt_graceful_and_resumable(self, tmp_path):
        """A mid-loop exception raises ServeLoopInterrupted with finalized
        stats and a last-effort snapshot; resuming it completes the run
        to the uninterrupted cloud master, bit for bit."""
        spec = self._spec()
        gen = every_agent_once_trace(BASE["n_agents"], 10)
        res = spec.resolve()
        x_t, y_t = jnp.asarray(res.test.x), jnp.asarray(res.test.y)
        from repro.models import mlp
        acc = jax.jit(lambda p: mlp.accuracy(p, x_t, y_t))

        st_ref, _, s_ref, _ = run_serve_loop(res, gen=gen, eval_fn=acc)

        calls = {"n": 0}

        def bomb(p):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("simulated crash")
            return acc(p)

        d = tmp_path / "snaps"
        with pytest.raises(ServeLoopInterrupted) as ei:
            run_serve_loop(spec.resolve(), gen=gen, eval_fn=bomb,
                           snapshot_dir=d, snapshot_every=0)
        exc = ei.value
        assert exc.stats is not None and exc.stats.n_ticks > 0
        assert exc.snapshot_path is not None
        assert ckpt.latest_step(d) == exc.stats.n_ticks

        st2, _, s2, _ = run_serve_loop(spec.resolve(), gen=gen,
                                       eval_fn=acc, resume_from=d)
        np.testing.assert_array_equal(np.asarray(st_ref.cloud_flat),
                                      np.asarray(st2.cloud_flat))
        assert s_ref.n_ticks == s2.n_ticks

    def test_validation_errors_pass_through(self):
        """Input/config mistakes are ValueErrors, not operational
        interrupts — graceful shutdown must not swallow them."""
        from repro.core.load_gen import Event, TraceLoadGen
        spec = self._spec()
        with pytest.raises(ValueError, match="outside the fleet"):
            run_serve_loop(spec.resolve(),
                           gen=TraceLoadGen([Event(0.1, 99, 0)]))


# --------------------------------------------------------------------------
# crash-safe checkpoint store (atomic temp-file + os.replace)
# --------------------------------------------------------------------------

class TestCkptCrashSafety:
    TREE = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "s": np.asarray(3, np.int64)}

    def test_kill_mid_write_keeps_prior_step(self, tmp_path, monkeypatch):
        """Dying before the rename never tears a checkpoint: the prior
        step stays intact and the torn temp is never promoted."""
        d = tmp_path / "ck"
        ckpt.save(d, 1, self.TREE)

        def die(*a, **kw):
            raise OSError("simulated kill mid-commit")

        monkeypatch.setattr(os, "replace", die)
        with pytest.raises(OSError, match="simulated kill"):
            ckpt.save(d, 2, {"w": self.TREE["w"] * 7.0,
                             "s": np.asarray(4, np.int64)})
        monkeypatch.undo()
        assert ckpt.latest_step(d) == 1
        back = ckpt.restore(d)
        np.testing.assert_array_equal(back["w"], self.TREE["w"])

    def test_orphan_temp_files_are_invisible(self, tmp_path):
        """A hard kill can leave a temp behind (no unlink ran) — readers
        must never see it as a checkpoint."""
        d = tmp_path / "ck"
        ckpt.save(d, 3, self.TREE)
        (d / ".tmp_step_00000009_dead.npz").write_bytes(b"torn garbage")
        assert ckpt.latest_step(d) == 3
        np.testing.assert_array_equal(ckpt.restore(d)["w"], self.TREE["w"])

    def test_overwrite_crash_keeps_old_payload(self, tmp_path, monkeypatch):
        """Re-writing an existing step is atomic too: a crash mid-write
        (before commit) leaves the OLD payload fully readable."""
        d = tmp_path / "ck"
        ckpt.save(d, 5, self.TREE)

        def die(fd):
            raise OSError("simulated power loss")

        monkeypatch.setattr(os, "fsync", die)
        with pytest.raises(OSError, match="power loss"):
            ckpt.save(d, 5, {"w": np.full((2, 3), -1.0, np.float32),
                             "s": np.asarray(9, np.int64)})
        monkeypatch.undo()
        back = ckpt.restore(d, step=5)
        np.testing.assert_array_equal(back["w"], self.TREE["w"])
        assert not list(d.glob(".tmp_*"))          # failed save cleaned up


# --------------------------------------------------------------------------
# trace input validation (line-numbered, fail-loud)
# --------------------------------------------------------------------------

class TestTraceValidation:
    def _write(self, tmp_path, lines):
        p = tmp_path / "trace.jsonl"
        p.write_text("\n".join(lines) + "\n")
        return p

    def test_unparseable_json_names_the_line(self, tmp_path):
        p = self._write(tmp_path, ['{"t": 0.1, "agent": 0}', "{not json"])
        with pytest.raises(ValueError, match=r"bad trace record at .*:2"):
            read_trace(p)

    def test_missing_key_names_the_line(self, tmp_path):
        p = self._write(tmp_path, ['{"t": 0.1}'])
        with pytest.raises(ValueError, match=r"bad trace record at .*:1"):
            read_trace(p)

    def test_nonfinite_timestamp_rejected(self, tmp_path):
        p = self._write(tmp_path, ['{"t": 0.1, "agent": 0}',
                                   '{"t": NaN, "agent": 1}'])
        with pytest.raises(ValueError,
                           match=r"non-finite timestamp.*:2"):
            read_trace(p)

    def test_agent_out_of_fleet_rejected(self, tmp_path):
        p = self._write(tmp_path, ['{"t": 0.1, "agent": 12}'])
        with pytest.raises(ValueError, match=r"outside the fleet"):
            read_trace(p, n_agents=8)
        assert len(read_trace(p)) == 1            # unbounded without fleet

    def test_negative_agent_always_rejected(self, tmp_path):
        p = self._write(tmp_path, ['{"t": 0.1, "agent": -1}'])
        with pytest.raises(ValueError, match=r"outside the fleet"):
            read_trace(p)


# --------------------------------------------------------------------------
# sweeps: fault schedules as vmapped data, ONE program per grid
# --------------------------------------------------------------------------

class TestSweepFaults:
    # distinctive shapes so no other test's program registry entry aliases
    SWEEP = dict(n_agents=12, n_rsus=3, batch=8, n_train=416, n_test=96,
                 rounds=2, seed=9)

    @pytest.fixture(scope="class")
    def params(self):
        from repro.configs.mnist_mlp import CONFIG
        from repro.models import mlp
        return mlp.init_params(CONFIG, jax.random.key(0))

    def _grid(self, engine):
        plans = [FaultPlan(churn=(ChurnWindow(frac=0.5, seed=s),))
                 for s in range(2)]
        plans.append(FaultPlan(
            outages=(RsuOutage(rsu=0, start=2, stop=6),),
            corrupt=(CorruptSpec(kind="nan", frac=0.3),)))
        return [ScenarioSpec(engine=engine, faults=p, **self.SWEEP)
                for p in plans]

    @pytest.mark.parametrize("engine", ["flat", "async"])
    def test_fault_grid_traces_once(self, engine, params):
        before = program_cache.trace_count("sweep_round")
        hists = run_scenarios(self._grid(engine), params)
        assert program_cache.trace_count("sweep_round") - before == 1
        for h in hists:
            assert np.isfinite(h["acc"]).all()
            assert "quarantined" in h
        # the NaN-corrupting cell quarantines, the churn-only cells don't
        assert np.sum(hists[2]["quarantined"]) > 0
        assert np.sum(hists[0]["quarantined"]) == 0

    def test_zero_fault_sweep_anchor(self, params):
        clean = ScenarioSpec(engine="flat", **self.SWEEP)
        empty = clean.replace(faults=FaultPlan())
        h_clean, h_empty = run_scenarios([clean, empty], params)
        np.testing.assert_array_equal(h_clean["acc"], h_empty["acc"])
        assert np.all(h_empty["quarantined"] == 0)

    @pytest.mark.parametrize("engine", ["flat", "async"])
    def test_sweep_matches_sequential(self, engine, params):
        spec = self._grid(engine)[2]
        h_sweep = run_scenarios([spec] * 2, params)[1]  # a real (S>1) sweep
        _, h_seq = run_scenario(spec, params)
        np.testing.assert_allclose(h_sweep["acc"], h_seq["acc"],
                                   rtol=2e-5, atol=2e-6)
        np.testing.assert_array_equal(h_sweep["quarantined"],
                                      h_seq["quarantined"])
