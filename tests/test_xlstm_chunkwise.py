"""Chunkwise-parallel mLSTM (§Perf hillclimb A) == per-step scan oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from prop_compat import given, settings, st

from repro.configs.registry import get_reduced_config
from repro.models import model as M
from repro.models import xlstm as X

CFG = get_reduced_config("xlstm-125m")


@pytest.fixture(scope="module")
def mlstm_params():
    return X.mlstm_init(CFG, jax.random.key(0))


@pytest.mark.parametrize("chunk", [8, 32, 64, 128])
@pytest.mark.parametrize("seq", [1, 7, 64, 100])
def test_chunkwise_matches_scan(mlstm_params, chunk, seq):
    x = jax.random.normal(jax.random.key(1), (2, seq, CFG.d_model),
                          jnp.float32) * 0.5
    y_scan = X.mlstm_prefill(CFG, mlstm_params, x)
    y_chunk = X.mlstm_prefill(CFG.replace(mlstm_chunk=chunk),
                              mlstm_params, x)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_scan, np.float32),
                               atol=5e-5, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seq=st.integers(1, 96), chunk=st.sampled_from([4, 16, 48]),
       scale=st.floats(0.1, 3.0))
def test_chunkwise_property(seq, chunk, scale):
    """Property: parity holds for arbitrary (seq, chunk, input scale) —
    incl. seq not a multiple of chunk and saturated gates (large scale)."""
    p = X.mlstm_init(CFG, jax.random.key(2))
    x = jax.random.normal(jax.random.key(3), (1, seq, CFG.d_model),
                          jnp.float32) * scale
    y_scan = X.mlstm_prefill(CFG, p, x)
    y_chunk = X.mlstm_prefill(CFG.replace(mlstm_chunk=chunk), p, x)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_scan, np.float32),
                               atol=1e-4, rtol=1e-3)


def test_full_model_parity():
    """End-to-end xlstm-125m (reduced) logits parity: scan vs chunkwise."""
    params = M.init_params(CFG, jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 33)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    y0, _ = M.forward(CFG, params, batch)
    y1, _ = M.forward(CFG.replace(mlstm_chunk=16), params, batch)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y0, np.float32),
                               atol=0.05, rtol=0.05)  # bf16 activations
