"""core/program_cache: persistent XLA cache wiring, ProgramKey identity,
registry semantics and the per-spec opt-out (DESIGN.md §10)."""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core import program_cache
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.core.scenario import ScenarioSpec
from repro.fedsim import sweep
from repro.kernels import ops
from repro.models import mlp

BASE = ScenarioSpec(n_agents=8, n_rsus=4, batch=8, n_train=400, n_test=100,
                    hp=H2FedParams(mu1=0.01, mu2=0.005, lar=2,
                                   local_epochs=1, lr=0.1),
                    het=HeterogeneityModel(csr=0.8, scd=1), rounds=2)


@pytest.fixture(scope="module")
def params():
    return mlp.init_params(MLP_CFG, jax.random.key(42))


def _key(**overrides):
    base = dict(kind="sweep", static_key=("flat",), n_scenarios=2,
                dyn_names=("hp.mu1",), baked=(("hp.lr", 0.1),),
                cadence=None, data_axes=((("x", 0),), 0, 0),
                donation=(0,),
                devices=program_cache.device_fingerprint(),
                mesh=None, flags=program_cache.ops_flags(True))
    base.update(overrides)
    return program_cache.ProgramKey(**base)


class TestProgramKey:
    def test_key_is_hashable_and_stable(self):
        assert _key() == _key()
        assert hash(_key()) == hash(_key())

    def test_key_changes_with_interpret_flag(self):
        """An interpret flip MUST miss the registry: the traced program
        routes through different kernel lowerings."""
        prev = ops._FORCE_INTERPRET
        try:
            ops.set_interpret(True)
            k_interp = _key(flags=program_cache.ops_flags(True))
            ops.set_interpret(False)
            k_pallas = _key(flags=program_cache.ops_flags(True))
        finally:
            ops.set_interpret(prev)
        assert k_interp != k_pallas

    def test_key_changes_with_fused_flag(self):
        assert _key(flags=program_cache.ops_flags(True)) != \
            _key(flags=program_cache.ops_flags(False))

    def test_key_changes_with_mesh_fingerprint(self):
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("sweep",))
        assert _key(mesh=program_cache.mesh_fingerprint(mesh)) != \
            _key(mesh=None)

    def test_mesh_fingerprint_carries_axes_and_devices(self):
        mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("sweep",))
        axes, devs = program_cache.mesh_fingerprint(mesh)
        assert axes == (("sweep", 1),)
        assert devs == program_cache.device_fingerprint(jax.devices()[:1])
        assert program_cache.mesh_fingerprint(None) is None


class TestRegistry:
    def test_get_or_build_memoizes(self):
        program_cache.clear()
        calls = []
        k = _key()
        a = program_cache.get_or_build(k, lambda: calls.append(1) or "A")
        b = program_cache.get_or_build(k, lambda: calls.append(1) or "B")
        assert (a, b) == ("A", "A") and len(calls) == 1
        s = program_cache.stats()
        assert (s["misses"], s["hits"], s["entries"]) == (1, 1, 1)

    def test_disabled_never_touches_registry(self):
        program_cache.clear()
        k = _key()
        out = program_cache.get_or_build(k, lambda: "fresh", enabled=False)
        assert out == "fresh"
        assert program_cache.stats()["entries"] == 0

    def test_build_sweep_registry_hit_returns_same_program(self, params):
        program_cache.clear()
        specs = [BASE.replace(
            hp=dataclasses.replace(BASE.hp, mu1=m)) for m in (0.0, 0.02)]
        resolved = [s.resolve() for s in specs]
        p1 = sweep.build_sweep(resolved, params)
        p2 = sweep.build_sweep(resolved, params)
        # the jitted round program is the registry entry; eval_fn is a
        # thin per-build closure over the test set around a cached jit
        assert p2.round_fn is p1.round_fn
        assert program_cache.stats()["hits"] >= 1

    def test_program_cache_opt_out_builds_fresh(self, params):
        program_cache.clear()
        specs = [BASE.replace(
            hp=dataclasses.replace(BASE.hp, mu1=m),
            program_cache=False) for m in (0.0, 0.02)]
        resolved = [s.resolve() for s in specs]
        p1 = sweep.build_sweep(resolved, params)
        p2 = sweep.build_sweep(resolved, params)
        assert p2.round_fn is not p1.round_fn
        assert program_cache.stats()["entries"] == 0

    def test_trace_counters(self):
        program_cache.reset_stats()
        program_cache.note_trace("x")
        program_cache.note_trace("x")
        assert program_cache.trace_count("x") == 2
        assert program_cache.stats()["traces/x"] == 2
        program_cache.reset_stats()
        assert program_cache.trace_count("x") == 0


class TestPersistentCache:
    def test_enable_persistent_cache_writes_entries(self, tmp_path):
        """Fresh process (config flags are process-global): enabling the
        cache and running a jitted program must land entries on disk, and
        a second process must load them (the cold/warm contract CI pins)."""
        cache = tmp_path / "xla-cache"
        code = textwrap.dedent("""
            import sys
            import jax, jax.numpy as jnp
            from repro.core import program_cache
            d = program_cache.enable_persistent_cache(sys.argv[1])
            assert d is not None
            x = jax.jit(lambda v: (v * 2.0 + 1.0).sum())(jnp.ones((8, 8)))
            x.block_until_ready()
            print("PERSIST_OK")
        """)
        env = dict(os.environ,
                   PYTHONPATH="src" + os.pathsep + os.environ.get(
                       "PYTHONPATH", ""))
        for _ in range(2):      # cold run writes, warm run reads
            out = subprocess.run(
                [sys.executable, "-c", code, str(cache)],
                cwd="/root/repo", env=env, capture_output=True, text=True)
            assert out.returncode == 0, out.stderr
            assert "PERSIST_OK" in out.stdout
            assert any(cache.iterdir()), "no cache entries written"

    def test_env_var_unset_is_noop(self, monkeypatch):
        monkeypatch.delenv(program_cache.ENV_CACHE_DIR, raising=False)
        before = program_cache.persistent_cache_dir()
        assert program_cache.enable_persistent_cache() == before
