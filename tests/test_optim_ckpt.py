"""Tests for proximal-aware optimizers and the checkpoint store."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.optim import adam, sgd


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(6, 4)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}


class TestSGD:
    def test_plain_step(self):
        p, g = _tree(0), _tree(1)
        cfg = sgd.SGDConfig(lr=0.1)
        new, _ = sgd.step(cfg, p, g, sgd.init(cfg, p))
        for a, b, c in zip(jax.tree.leaves(p), jax.tree.leaves(g),
                           jax.tree.leaves(new)):
            np.testing.assert_allclose(np.asarray(c),
                                       np.asarray(a) - 0.1 * np.asarray(b),
                                       atol=1e-6)

    def test_anchors_match_h2fed_core(self):
        from repro.core.h2fed import H2FedParams, proximal_sgd_step
        p, g, a1, a2 = _tree(0), _tree(1), _tree(2), _tree(3)
        hp = H2FedParams(mu1=0.05, mu2=0.01, lr=0.07)
        cfg = sgd.SGDConfig(lr=hp.lr)
        got, _ = sgd.step(cfg, p, g, sgd.init(cfg, p),
                          anchors=((hp.mu1, a1), (hp.mu2, a2)))
        want = proximal_sgd_step(p, g, a1, a2, hp)
        for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-6)

    def test_momentum_accumulates(self):
        p = _tree(0)
        g = jax.tree.map(jnp.ones_like, p)
        cfg = sgd.SGDConfig(lr=1.0, momentum=0.9)
        st = sgd.init(cfg, p)
        p1, st = sgd.step(cfg, p, g, st)
        p2, st = sgd.step(cfg, p1, g, st)
        # second step is larger: 1 then 1.9
        d1 = np.asarray(p["w"] - p1["w"])
        d2 = np.asarray(p1["w"] - p2["w"])
        np.testing.assert_allclose(d2, d1 * 1.9, rtol=1e-5)


class TestAdam:
    def test_descends_quadratic(self):
        p = {"w": jnp.asarray([5.0, -3.0])}
        cfg = adam.AdamConfig(lr=0.1)
        st = adam.init(cfg, p)
        for _ in range(200):
            g = jax.tree.map(lambda w: 2 * w, p)
            p, st = adam.step(cfg, p, g, st)
        assert float(jnp.abs(p["w"]).max()) < 0.1

    def test_anchor_pull_converges_to_anchor(self):
        p = {"w": jnp.asarray([5.0, 5.0])}
        anchor = {"w": jnp.asarray([1.0, -1.0])}
        cfg = adam.AdamConfig(lr=0.05)
        st = adam.init(cfg, p)
        zero = jax.tree.map(jnp.zeros_like, p)
        for _ in range(500):
            p, st = adam.step(cfg, p, zero, st, anchors=((1.0, anchor),))
        np.testing.assert_allclose(np.asarray(p["w"]),
                                   np.asarray(anchor["w"]), atol=0.05)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
                "nested": {"b": np.ones(5, np.int32)}}
        ckpt.save(tmp_path, 3, tree)
        out = ckpt.restore(tmp_path, 3)
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["nested"]["b"], tree["nested"]["b"])

    def test_latest_step(self, tmp_path):
        tree = {"x": np.zeros(2)}
        for s in (1, 5, 12):
            ckpt.save(tmp_path, s, tree)
        assert ckpt.latest_step(tmp_path) == 12
        out = ckpt.restore(tmp_path)        # picks latest
        np.testing.assert_array_equal(out["x"], tree["x"])

    def test_restore_like_treedef(self, tmp_path):
        tree = {"w": np.ones((2, 2), np.float32)}
        ckpt.save(tmp_path, 0, tree)
        out = ckpt.restore(tmp_path, 0, like=tree)
        assert set(out) == {"w"}

    def test_missing_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            ckpt.restore(tmp_path / "nope")

    def test_overwrite_same_step(self, tmp_path):
        ckpt.save(tmp_path, 1, {"v": np.zeros(1)})
        ckpt.save(tmp_path, 1, {"v": np.ones(1)})
        out = ckpt.restore(tmp_path, 1)
        np.testing.assert_array_equal(out["v"], np.ones(1))

    def test_jax_arrays_roundtrip(self, tmp_path):
        tree = {"p": jnp.asarray([1.5, 2.5], jnp.bfloat16)}
        ckpt.save(tmp_path, 0, tree)
        out = ckpt.restore(tmp_path, 0)
        np.testing.assert_array_equal(np.asarray(out["p"], np.float32),
                                      np.asarray(tree["p"], np.float32))
