"""Integration + property tests for the paper-faithful fedsim simulator:
baseline equivalences (paper Sec. V), learning progress, determinism."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core.baselines import BASELINES, fedavg, fedprox, h2fed, hierfavg
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.fedsim.simulator import SimConfig, init_state, make_global_round
from repro.fedsim.sweep import adhoc_scenario, run_scenario
from repro.models import mlp


@pytest.fixture(scope="module")
def setup(tiny_task, fed_small):
    train, test = tiny_task
    params = mlp.init_params(MLP_CFG, jax.random.key(0))
    cfg = SimConfig(n_agents=fed_small.n_agents, n_rsus=4, batch=16, seed=0)
    return cfg, fed_small, params, test


def _run(cfg, fed, params, test, hp, het, rounds=3):
    res = adhoc_scenario(cfg, hp, het, fed, n_rounds=rounds,
                         x_test=test.x, y_test=test.y)
    return run_scenario(res, params)


class TestLearning:
    def test_accuracy_improves(self, setup):
        cfg, fed, params, test = setup
        hp = h2fed(mu1=0.01, mu2=0.005, lar=2, lr=0.1)
        het = HeterogeneityModel(csr=1.0, lar=hp.lar)
        acc0 = float(mlp.accuracy(params, jnp.asarray(test.x),
                                  jnp.asarray(test.y)))
        _, hist = _run(cfg, fed, params, test, hp, het, rounds=5)
        assert hist["acc"][-1] > acc0 + 0.1, (acc0, hist["acc"])

    def test_learns_under_low_csr(self, setup):
        """The paper's headline property: convergence even at CSR=0.1."""
        cfg, fed, params, test = setup
        hp = h2fed(mu1=0.1, mu2=0.005, lar=3, lr=0.1)
        het = HeterogeneityModel(csr=0.1, scd=1, lar=hp.lar)
        acc0 = float(mlp.accuracy(params, jnp.asarray(test.x),
                                  jnp.asarray(test.y)))
        _, hist = _run(cfg, fed, params, test, hp, het, rounds=6)
        assert hist["acc"][-1] > acc0, (acc0, hist["acc"])

    def test_deterministic(self, setup):
        cfg, fed, params, test = setup
        hp = h2fed(lar=2)
        het = HeterogeneityModel(csr=0.5, lar=2)
        _, h1 = _run(cfg, fed, params, test, hp, het)
        _, h2 = _run(cfg, fed, params, test, hp, het)
        np.testing.assert_array_equal(h1["acc"], h2["acc"])


class TestBaselineEquivalences:
    """Paper Sec. V: FedAvg / FedProx / HierFAVG are parameterizations."""

    def test_fedavg_is_mu_zero(self, setup):
        cfg, fed, params, test = setup
        het = HeterogeneityModel(csr=1.0)
        _, ha = _run(cfg, fed, params, test, fedavg(lr=0.05), het, 2)
        _, hb = _run(cfg, fed, params, test,
                     H2FedParams(mu1=0.0, mu2=0.0, lar=1, lr=0.05,
                                 n_layers=2), het, 2)
        np.testing.assert_allclose(ha["acc"], hb["acc"], atol=1e-6)

    def test_fedprox_equals_h2fed_mu2_zero_lar1(self, setup):
        cfg, fed, params, test = setup
        het = HeterogeneityModel(csr=1.0)
        _, ha = _run(cfg, fed, params, test, fedprox(mu=0.05), het, 2)
        _, hb = _run(cfg, fed, params, test,
                     h2fed(mu1=0.05, mu2=0.0, lar=1), het, 2)
        np.testing.assert_allclose(ha["acc"], hb["acc"], atol=1e-6)

    def test_mu1_mu2_equivalent_when_lar1_e1(self, setup):
        """With LAR=1 and E=1 both anchors equal the cloud model at training
        time, so (mu1=c, mu2=0) == (mu1=0, mu2=c) — the layers only separate
        through pre-aggregation."""
        cfg, fed, params, test = setup
        het = HeterogeneityModel(csr=0.6)
        hp_a = H2FedParams(mu1=0.08, mu2=0.0, lar=1, local_epochs=1, lr=0.05)
        hp_b = H2FedParams(mu1=0.0, mu2=0.08, lar=1, local_epochs=1, lr=0.05)
        sa, _ = _run(cfg, fed, params, test, hp_a, het, 2)
        sb, _ = _run(cfg, fed, params, test, hp_b, het, 2)
        for x, y in zip(jax.tree.leaves(sa.cloud_params),
                        jax.tree.leaves(sb.cloud_params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5)

    def test_hierfavg_differs_from_fedavg_by_lar(self, setup):
        """LAR>1 changes the trajectory (pre-aggregation is real work)."""
        cfg, fed, params, test = setup
        het1 = HeterogeneityModel(csr=1.0, lar=1)
        _, ha = _run(cfg, fed, params, test, fedavg(), het1, 2)
        _, hb = _run(cfg, fed, params, test, hierfavg(lar=4), het1, 2)
        assert not np.allclose(ha["acc"], hb["acc"])

    def test_all_baselines_registered(self):
        assert set(BASELINES) == {"fedavg", "fedprox", "hierfavg", "h2fed"}


class TestAggregationSemantics:
    def test_full_mask_lar1_single_epoch_matches_manual(self, setup):
        """One global round at CSR=1, LAR=1, E=1, mu=0: the cloud model must
        equal the data-weighted average of one-epoch-per-agent SGD results."""
        cfg, fed, params, test = setup
        hp = H2FedParams(mu1=0.0, mu2=0.0, lar=1, local_epochs=1, lr=0.05)
        het = HeterogeneityModel(csr=1.0, scd=1, fsr=1.0)
        round_fn = make_global_round(cfg, hp, het, fed)
        state = init_state(cfg, params, jax.random.key(cfg.seed))
        new_state = round_fn(state)

        # manual: per-agent SGD for one epoch from `params`
        x_all, y_all = jnp.asarray(fed.x), jnp.asarray(fed.y)
        spe = fed.x.shape[1] // cfg.batch

        def train_one(x, y):
            w = params
            for s in range(spe):
                xb = jax.lax.dynamic_slice_in_dim(x, (s * cfg.batch) % x.shape[0],
                                                  cfg.batch)
                yb = jax.lax.dynamic_slice_in_dim(y, (s * cfg.batch) % y.shape[0],
                                                  cfg.batch)
                g = jax.grad(mlp.loss_fn)(w, xb, yb)
                w = jax.tree.map(lambda a, b: a - hp.lr * b, w, g)
            return w

        agent_ws = jax.vmap(train_one)(x_all, y_all)
        wts = jnp.asarray(fed.n_per_agent, jnp.float32)
        # hierarchical mean with balanced weights == flat weighted mean
        flat_mean = jax.tree.map(
            lambda l: jnp.sum(l * (wts / wts.sum()).reshape(
                (-1,) + (1,) * (l.ndim - 1)), axis=0), agent_ws)

        # NOTE: RSU-then-cloud weighted means compose to the flat weighted
        # mean because cloud weights are the surviving RSU masses.
        for a, b in zip(jax.tree.leaves(new_state.cloud_params),
                        jax.tree.leaves(flat_mean)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-5, rtol=1e-4)

    def test_zero_connectivity_keeps_cloud_model(self, setup):
        cfg, fed, params, test = setup
        hp = h2fed()
        het = HeterogeneityModel(csr=0.0)
        round_fn = make_global_round(cfg, hp, het, fed)
        state = init_state(cfg, params, jax.random.key(0))
        out = round_fn(state)
        for a, b in zip(jax.tree.leaves(out.cloud_params),
                        jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
