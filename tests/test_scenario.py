"""ScenarioSpec: cache-key properties, resolution caching, serialization
(DESIGN.md §7)."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core.faults import ChurnWindow, FaultPlan
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.core.scenario import ScenarioSpec

BASE = ScenarioSpec(n_agents=8, n_rsus=4, batch=8, n_train=400, n_test=100,
                    rounds=2)

# one admissible perturbation per field — the "cache_key changes iff a
# resolved field changes" property walks every field through this table
PERTURB = {
    "n_agents": 16, "n_rsus": 2, "batch": 16,
    "n_train": 500, "n_test": 120, "noise": 0.5,
    "excluded_labels": (7, 8), "pretrain_frac": 0.2,
    "pretrain_target": 0.5,
    "partition": "dirichlet", "alpha": 1.0,
    "hp": H2FedParams(mu1=0.123),
    "het": HeterogeneityModel(csr=0.321),
    "engine": "async", "fleet_dtype": "bfloat16", "fused": False,
    "rsu_sharded": True,
    "fleet_store": "host", "chunk_agents": 64,
    "chunk_params": 1 << 18, "model_shards": 2, "hidden_dims": (64,),
    "staleness_decay": 0.9, "schedule": "poly", "buffer_keep": 0.5,
    "cloud_every": 3,
    "serve_events": 64, "arrival_rate": 2.0,
    "tick_trigger": "deadline:1.0", "queue_capacity": 128,
    "overload_policy": "backpressure", "serve_trace": "trace.jsonl",
    "rounds": 5, "eval_every": 2, "seed": 1, "sim_seed": 1,
    "program_cache": False,
    "faults": FaultPlan(churn=(ChurnWindow(frac=0.5),)),
}


class TestCacheKey:
    def test_every_field_perturbation_changes_key(self):
        fields = {f.name for f in dataclasses.fields(ScenarioSpec)}
        assert fields == set(PERTURB), \
            f"PERTURB table out of date: {fields ^ set(PERTURB)}"
        base_key = BASE.cache_key
        for name, val in PERTURB.items():
            assert getattr(BASE, name) != val, name
            assert BASE.replace(**{name: val}).cache_key != base_key, name

    def test_equal_specs_share_key(self):
        clone = ScenarioSpec(**{f.name: getattr(BASE, f.name)
                                for f in dataclasses.fields(ScenarioSpec)})
        assert clone.cache_key == BASE.cache_key

    def test_partition_aliases_share_key(self):
        """1 / "1" / "scenario_one" are the same recipe, not three caches."""
        keys = {BASE.replace(partition=p).cache_key
                for p in (1, "1", "scenario_one")}
        assert len(keys) == 1

    def test_dataset_key_ignores_experiment_knobs(self):
        """Specs differing only in het/hp/engine share the pretrain."""
        assert BASE.replace(
            het=HeterogeneityModel(csr=0.2), engine="async",
            hp=H2FedParams(mu1=0.5)).dataset_key == BASE.dataset_key

    def test_dataset_key_tracks_seed(self):
        """THE old pipeline-cache bug: a second seed must get its own key."""
        assert BASE.replace(seed=1).dataset_key != BASE.dataset_key
        assert BASE.replace(n_train=500).dataset_key != BASE.dataset_key


class TestResolve:
    def test_partition_cache_shares_across_het(self):
        a = BASE.replace(het=HeterogeneityModel(csr=0.5)).resolve()
        b = BASE.replace(het=HeterogeneityModel(csr=0.1)).resolve()
        assert a.fed is b.fed

    def test_seed_gets_own_data(self):
        """Regression for the seed-ignoring cache: different seeds resolve
        to different realizations."""
        a, b = BASE.resolve(), BASE.replace(seed=1).resolve()
        assert a.fed is not b.fed
        assert not np.array_equal(a.fed.x, b.fed.x)
        assert not np.array_equal(a.train.x, b.train.x)

    def test_dirichlet_partition(self):
        res = BASE.replace(partition="dirichlet", alpha=0.3).resolve()
        assert res.fed.n_agents == BASE.n_agents
        assert (res.fed.n_per_agent >= 1).all()
        assert res.fed.rsu_assign.max() < BASE.n_rsus

    def test_shapes_and_configs(self):
        res = BASE.resolve()
        assert res.fed.x.shape[0] == BASE.n_agents
        assert res.test.x.shape[0] == BASE.n_test
        cfg = res.cfg
        assert (cfg.n_agents, cfg.n_rsus) == (8, 4)
        assert cfg.seed == BASE.seed * 1000 + BASE.sim_seed

    def test_static_key_splits_on_program_structure(self):
        a = BASE.resolve()
        assert a.static_key == BASE.replace(
            het=HeterogeneityModel(csr=0.2)).resolve().static_key
        # cadence knobs batch as data (DESIGN.md §7): lar / local_epochs /
        # cloud_every do NOT split a group anymore
        assert a.static_key == BASE.replace(
            hp=H2FedParams(lar=3)).resolve().static_key
        assert a.static_key == BASE.replace(
            hp=H2FedParams(local_epochs=2)).resolve().static_key
        assert a.static_key == BASE.replace(
            cloud_every=3).resolve().static_key
        # true program structure still splits
        assert a.static_key != BASE.replace(
            hp=H2FedParams(n_layers=1)).resolve().static_key
        assert a.static_key != BASE.replace(engine="async").resolve() \
            .static_key

    def test_validate_rejects_unknowns(self):
        with pytest.raises(ValueError, match="unknown partition"):
            BASE.replace(partition="nope").validate()
        with pytest.raises(AssertionError):
            BASE.replace(engine="warp").validate()


class TestSerialization:
    def test_json_round_trip(self):
        spec = BASE.replace(engine="async", partition="dirichlet",
                            staleness_decay=(0.5, 0.6, 0.7, 0.8),
                            hp=H2FedParams(mu1=0.004, lar=3),
                            het=HeterogeneityModel(csr=0.2, max_delay=2,
                                                   delay_p=0.5))
        back = ScenarioSpec.from_json(spec.to_json())
        assert back == spec
        assert back.cache_key == spec.cache_key

    def test_from_dict_rejects_unknown_fields(self):
        with pytest.raises(ValueError, match="unknown ScenarioSpec"):
            ScenarioSpec.from_dict({"n_agents": 4, "warp_factor": 9})
