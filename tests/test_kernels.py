"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.dual_proximal_sgd import dual_proximal_sgd, \
    dual_proximal_sgd_tree
from repro.kernels.flash_attention import flash_attention
from repro.kernels.masked_hier_agg import (build_weight_matrix, cloud_agg,
                                           masked_hier_agg,
                                           weighted_agg_matmul)

INTERP = dict(interpret=True)


def _rand(shape, dtype, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

ATTN_SWEEP = [
    # (B, S, H, KV, D, window, causal)
    (1, 64, 2, 2, 32, 0, True),        # MHA
    (2, 128, 4, 2, 64, 0, True),       # GQA 2:1
    (1, 100, 8, 2, 64, 0, True),       # ragged S (padding path)
    (1, 128, 4, 1, 64, 0, True),       # MQA
    (2, 96, 4, 2, 32, 40, True),       # sliding window
    (1, 80, 2, 2, 32, 16, True),       # small window, ragged
    (1, 64, 2, 2, 32, 0, False),       # non-causal (cross-attn style)
]


@pytest.mark.parametrize("B,S,H,KV,D,window,causal", ATTN_SWEEP)
def test_flash_attention_matches_ref(B, S, H, KV, D, window, causal):
    q = _rand((B, S, H, D), jnp.float32, 0)
    k = _rand((B, S, KV, D), jnp.float32, 1)
    v = _rand((B, S, KV, D), jnp.float32, 2)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, **INTERP)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, atol):
    q = _rand((1, 64, 4, 64), dtype, 3)
    k = _rand((1, 64, 2, 64), dtype, 4)
    v = _rand((1, 64, 2, 64), dtype, 5)
    out = flash_attention(q, k, v, block_q=32, block_k=32, **INTERP)
    exp = ref.flash_attention_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=atol, rtol=atol)


@pytest.mark.parametrize("bq,bk", [(16, 64), (64, 16), (128, 128)])
def test_flash_attention_block_shape_invariance(bq, bk):
    """Output must not depend on the VMEM tile shape."""
    q = _rand((1, 130, 4, 32), jnp.float32, 6)
    k = _rand((1, 130, 2, 32), jnp.float32, 7)
    v = _rand((1, 130, 2, 32), jnp.float32, 8)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, **INTERP)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_path():
    """Kernel vs the model's chunked_attention (the XLA production path)."""
    from repro.models.attention import chunked_attention
    q = _rand((2, 64, 4, 32), jnp.float32, 9)
    k = _rand((2, 64, 2, 32), jnp.float32, 10)
    v = _rand((2, 64, 2, 32), jnp.float32, 11)
    pos = jnp.arange(64)
    a = flash_attention(q, k, v, window=20, block_q=32, block_k=32, **INTERP)
    b = chunked_attention(q, k, v, pos, pos, window=20, chunk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# dual-proximal SGD
# --------------------------------------------------------------------------

DPS_SWEEP = [
    ((17,), jnp.float32),              # tiny, heavy padding
    ((1024,), jnp.float32),            # exactly one tile
    ((1000, 3), jnp.float32),          # 2D, padded
    ((8, 128), jnp.bfloat16),          # bf16 params
    ((5, 7, 11), jnp.float32),         # 3D odd
]


@pytest.mark.parametrize("shape,dtype", DPS_SWEEP)
def test_dual_proximal_sgd_sweep(shape, dtype):
    w = _rand(shape, dtype, 0)
    g = _rand(shape, dtype, 1, 0.1)
    a1 = _rand(shape, dtype, 2)
    a2 = _rand(shape, dtype, 3)
    kw = dict(lr=0.05, mu1=0.01, mu2=0.005)
    out = dual_proximal_sgd(w, g, a1, a2, **kw, **INTERP)
    exp = ref.dual_proximal_sgd_ref(w, g, a1, a2, **kw)
    assert out.shape == shape and out.dtype == dtype
    atol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=atol)


@pytest.mark.parametrize("mu1,mu2", [(0.0, 0.0), (0.3, 0.0), (0.0, 0.3),
                                     (1.0, 1.0)])
def test_dual_proximal_sgd_mu_grid(mu1, mu2):
    """mu=0 branches (FedAvg / FedProx limits) share the same kernel."""
    shape = (333,)
    w, g, a1, a2 = (_rand(shape, jnp.float32, i) for i in range(4))
    out = dual_proximal_sgd(w, g, a1, a2, lr=0.1, mu1=mu1, mu2=mu2, **INTERP)
    exp = ref.dual_proximal_sgd_ref(w, g, a1, a2, lr=0.1, mu1=mu1, mu2=mu2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


def test_dual_proximal_sgd_tree_matches_core():
    """Kernel tree update == repro.core.h2fed.proximal_sgd_step."""
    from repro.core.h2fed import H2FedParams, proximal_sgd_step
    tree = {"a": _rand((40, 10), jnp.float32, 0),
            "b": _rand((10,), jnp.float32, 1)}
    g = jax.tree.map(lambda l: l * 0.01, tree)
    a1 = jax.tree.map(lambda l: l + 0.1, tree)
    a2 = jax.tree.map(lambda l: l - 0.1, tree)
    hp = H2FedParams(mu1=0.05, mu2=0.02, lr=0.03)
    got = dual_proximal_sgd_tree(tree, g, a1, a2, lr=hp.lr, mu1=hp.mu1,
                                 mu2=hp.mu2, interpret=True)
    want = proximal_sgd_step(tree, g, a1, a2, hp)
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


# --------------------------------------------------------------------------
# masked hierarchical aggregation
# --------------------------------------------------------------------------

AGG_SWEEP = [
    (4, 1, 64, jnp.float32),           # tiny
    (100, 10, 2000, jnp.float32),      # the paper's topology (A=100, R=10)
    (32, 4, 777, jnp.float32),         # ragged N
    (16, 4, 512, jnp.bfloat16),        # bf16 params
    (7, 7, 130, jnp.float32),          # R == A
]


@pytest.mark.parametrize("A,R,N,dtype", AGG_SWEEP)
def test_masked_hier_agg_sweep(A, R, N, dtype):
    rng = np.random.default_rng(A * 7 + R)
    x = jnp.asarray(rng.standard_normal((A, N))).astype(dtype)
    w = jnp.asarray(rng.uniform(1, 5, A), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, A), jnp.float32)
    assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)
    got, mass_g = masked_hier_agg(x, w, mask, assign, R, **INTERP)
    exp, mass_e = ref.masked_hier_agg_ref(x, w, mask, assign, R)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32),
                               atol=atol, rtol=atol)
    np.testing.assert_allclose(np.asarray(mass_g), np.asarray(mass_e),
                               rtol=1e-6)


@pytest.mark.parametrize("A,R,N,dtype", AGG_SWEEP)
def test_block_local_agg_matches_ref(A, R, N, dtype):
    """The block-local (unnormalized) variant vs its segment-sum oracle —
    and against the global kernel restricted to one pod's RSU block."""
    from repro.kernels.masked_hier_agg import block_local_agg
    rng = np.random.default_rng(A * 13 + R)
    x = jnp.asarray(rng.standard_normal((A, N))).astype(dtype)
    w = jnp.asarray(rng.uniform(0, 4, A) * (rng.random(A) < 0.8),
                    jnp.float32)
    assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)
    num, mass = block_local_agg(x, w, assign, R, **INTERP)
    num_e, mass_e = ref.block_local_agg_ref(x, w, assign, R)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(num, np.float32),
                               np.asarray(num_e, np.float32),
                               atol=atol, rtol=atol)
    np.testing.assert_allclose(np.asarray(mass), np.asarray(mass_e),
                               rtol=1e-6)


def test_block_local_agg_is_weight_matrix_block():
    """A pod's block-local call == the matching row-block of the global
    unnormalized weight-matrix matmul (the block-diagonal structure the
    RSU-sharded engine exploits, DESIGN.md §4)."""
    from repro.core.aggregation import unnormalized_weight_matrix
    from repro.core.topology import HierarchyTopology
    from repro.kernels.masked_hier_agg import block_local_agg
    rng = np.random.default_rng(3)
    A, R, N, pods = 12, 4, 96, 2

    class _Mesh:
        shape = {"pod": pods, "data": 2}
        axis_names = ("pod", "data")

    topo = HierarchyTopology(A, R, _Mesh(), rsu_sharded=True)
    x = jnp.asarray(rng.standard_normal((A, N)), jnp.float32)
    w = jnp.asarray(rng.uniform(1, 2, A), jnp.float32)
    W = unnormalized_weight_matrix(
        w, jnp.ones((A,)), jnp.asarray(topo.rsu_assign), R)   # (R, A)
    full = np.asarray(W @ x)
    x_p = np.asarray(x)[topo.agent_perm]
    w_p = np.asarray(w)[topo.agent_perm]
    a_pp, r_pp = A // pods, topo.rsu_per_pod
    for p in range(pods):
        sl = slice(p * a_pp, (p + 1) * a_pp)
        num, _ = block_local_agg(
            jnp.asarray(x_p[sl]), jnp.asarray(w_p[sl]),
            jnp.asarray(topo.local_assign[sl]), r_pp, **INTERP)
        np.testing.assert_allclose(np.asarray(num),
                                   full[p * r_pp:(p + 1) * r_pp],
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("N", [96, 130, 333, 1100, 3333])
def test_weighted_agg_matmul_ragged_n(N):
    """Non-multiple-of-128 N must stay full-lane tiled (pad-up plan, no
    degrade-to-tiny-tiles fallback) on BOTH routes: the Pallas kernel
    (interpret) and the XLA dot the ops facade uses off-TPU."""
    from repro.kernels.masked_hier_agg import _tile_plan
    from repro.kernels import ops
    rng = np.random.default_rng(N)
    R, A = 5, 23
    W = jnp.asarray(rng.standard_normal((R, A)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((A, N)), jnp.float32)
    exp = np.asarray(W) @ np.asarray(x)
    got_pl = weighted_agg_matmul(W, x, **INTERP)
    np.testing.assert_allclose(np.asarray(got_pl), exp, atol=2e-5,
                               rtol=2e-5)
    got_ops = ops.weighted_agg_matmul(W, x)        # XLA route on CPU
    np.testing.assert_allclose(np.asarray(got_ops), exp, atol=2e-5,
                               rtol=2e-5)
    n_pad, bn = _tile_plan(N, 2048)
    assert bn % 128 == 0 and n_pad % bn == 0 and n_pad >= N
    assert n_pad - N < bn + 128                    # bounded pad waste


# --------------------------------------------------------------------------
# fused aggregate-and-blend (one-pass rounds)
# --------------------------------------------------------------------------

FUSED_SWEEP = [
    (4, 1, 64, jnp.float32),
    (100, 10, 2000, jnp.float32),
    (32, 4, 777, jnp.float32),          # ragged N
    (16, 4, 512, jnp.bfloat16),         # bf16 fleet storage
    (7, 7, 130, jnp.float32),
]


@pytest.mark.parametrize("A,R,N,dtype", FUSED_SWEEP)
def test_agg_blend_matches_ref(A, R, N, dtype):
    """Fused aggregate+blend == the un-fused two-pass oracle on both the
    Pallas (interpret) and the ops XLA routes, incl. kept (zero-mass)
    rows."""
    from repro.kernels import ops
    from repro.kernels.masked_hier_agg import agg_blend
    rng = np.random.default_rng(A + R + N)
    x = jnp.asarray(rng.standard_normal((A, N))).astype(dtype)
    w = jnp.asarray(rng.uniform(1, 5, A), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, A), jnp.float32)
    assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)
    prev = jnp.asarray(rng.standard_normal((R, N))).astype(dtype)
    exp, mass_e = ref.agg_blend_ref(x, w, mask, assign, R, prev)
    atol = 2e-5 if dtype == jnp.float32 else 5e-2
    for got, mass in (agg_blend(x, w, mask, assign, R, prev, **INTERP),
                      ops.agg_blend(x, w, mask, assign, R, prev)):
        assert got.dtype == prev.dtype
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=atol, rtol=atol)
        np.testing.assert_allclose(np.asarray(mass), np.asarray(mass_e),
                                   rtol=1e-6)
    # zero-mass rows keep prev EXACTLY (no arithmetic touches them)
    dead = np.asarray(mass_e) == 0
    got_pl, _ = agg_blend(x, w, mask, assign, R, prev, **INTERP)
    np.testing.assert_array_equal(np.asarray(got_pl)[dead],
                                  np.asarray(prev)[dead])


@pytest.mark.parametrize("A,R,N,dtype", FUSED_SWEEP)
@pytest.mark.parametrize("keep", [0.0, 0.6])
def test_agg_absorb_matches_ref(A, R, N, dtype, keep):
    """Fused two-cohort scatter-absorb == scatter+scatter+add+absorb
    oracle on both routes (the semi-async tick's RSU layer)."""
    from repro.kernels import ops
    from repro.kernels.masked_hier_agg import agg_absorb
    rng = np.random.default_rng(A * 3 + R + N + int(keep * 10))
    x1 = jnp.asarray(rng.standard_normal((A, N))).astype(dtype)
    x2 = jnp.asarray(rng.standard_normal((A, N))).astype(dtype)
    w1 = jnp.asarray(rng.uniform(0, 4, A) * (rng.random(A) < 0.7),
                     jnp.float32)
    w2 = jnp.asarray(rng.uniform(0, 2, A) * (rng.random(A) < 0.4),
                     jnp.float32)
    assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)
    buf = jnp.asarray(rng.standard_normal((R, N))).astype(dtype)
    bmass = jnp.asarray(rng.uniform(0, 5, R), jnp.float32)
    arr = ((x1, w1), (x2, w2))
    exp, total_e, new_e = ref.agg_absorb_ref(arr, assign, R, buf, bmass,
                                             keep=keep)
    atol = 2e-5 if dtype == jnp.float32 else 6e-2
    for got, total, new in (
            agg_absorb(arr, assign, R, buf, bmass, keep=keep, **INTERP),
            ops.agg_absorb(arr, assign, R, buf, bmass, keep=keep)):
        assert got.dtype == buf.dtype
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=atol, rtol=atol)
        np.testing.assert_allclose(np.asarray(total), np.asarray(total_e),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(new), np.asarray(new_e),
                                   rtol=1e-5)


def test_agg_absorb_per_rsu_keep_vector():
    """(R,)-vector keep (per-RSU adaptive retention) matches the oracle."""
    from repro.kernels.masked_hier_agg import agg_absorb
    rng = np.random.default_rng(5)
    A, R, N = 12, 3, 200
    x = jnp.asarray(rng.standard_normal((A, N)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 2, A), jnp.float32)
    assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)
    buf = jnp.asarray(rng.standard_normal((R, N)), jnp.float32)
    bmass = jnp.asarray(rng.uniform(1, 4, R), jnp.float32)
    keep = jnp.asarray([0.0, 0.5, 1.0], jnp.float32)
    got, total, _ = agg_absorb(((x, w),), assign, R, buf, bmass,
                               keep=keep, **INTERP)
    exp, total_e, _ = ref.agg_absorb_ref(((x, w),), assign, R, buf, bmass,
                                         keep=keep)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5)
    np.testing.assert_allclose(np.asarray(total), np.asarray(total_e),
                               rtol=1e-6)


def test_cloud_blend_matches_ref():
    from repro.kernels import ops
    from repro.kernels.masked_hier_agg import cloud_blend
    rng = np.random.default_rng(6)
    R, N = 6, 777
    x = jnp.asarray(rng.standard_normal((R, N)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 3, R), jnp.float32)
    prev = jnp.asarray(rng.standard_normal((N,)), jnp.float32)
    exp = ref.cloud_blend_ref(x, w, prev)
    for got in (cloud_blend(x, w, prev, **INTERP),
                ops.cloud_blend(x, w, prev)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)
    # dead fleet: the cloud master is kept bit-exactly, even from a bf16
    # RSU buffer (the fp32-master dtype policy)
    xb = x.astype(jnp.bfloat16)
    got0 = cloud_blend(xb, jnp.zeros((R,)), prev, **INTERP)
    assert got0.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(got0), np.asarray(prev))


def test_ops_interpret_override(monkeypatch):
    """ops._interpret: explicit override > env var > backend detection,
    and reset-safe for tests that force platforms."""
    from repro.kernels import ops
    try:
        ops.set_interpret(True)
        assert ops._interpret() is True
        ops.set_interpret(False)
        assert ops._interpret() is False
        ops.set_interpret(None)                       # back to detection
        auto = ops._interpret()
        assert auto == (jax.default_backend() != "tpu")
        monkeypatch.setenv("REPRO_INTERPRET", "0")
        assert ops._interpret() is False
        monkeypatch.setenv("REPRO_INTERPRET", "1")
        assert ops._interpret() is True
        monkeypatch.delenv("REPRO_INTERPRET")
        assert ops._interpret() == auto
        # explicit override beats the env var
        monkeypatch.setenv("REPRO_INTERPRET", "0")
        ops.set_interpret(True)
        assert ops._interpret() is True
    finally:
        ops.set_interpret(None)


def test_cloud_agg_matches_ref():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((10, 333)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 3, 10), jnp.float32)
    got = cloud_agg(x, w, **INTERP)
    exp = ref.cloud_agg_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=2e-5)


def test_weight_matrix_rows_normalized():
    rng = np.random.default_rng(1)
    A, R = 30, 5
    w = jnp.asarray(rng.uniform(1, 2, A), jnp.float32)
    mask = jnp.ones((A,))
    assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)
    W = build_weight_matrix(w, mask, assign, R)
    sums = np.asarray(W).sum(axis=1)
    live = np.asarray(
        jax.ops.segment_sum(w, assign, num_segments=R)) > 0
    np.testing.assert_allclose(sums[live], 1.0, rtol=1e-6)


def test_agg_kernel_matches_core_aggregation():
    """Kernel path == repro.core.aggregation.rsu_aggregate on a real pytree."""
    from repro.core.aggregation import rsu_aggregate
    rng = np.random.default_rng(2)
    A, R = 12, 3
    tree = {"w": jnp.asarray(rng.standard_normal((A, 6, 4)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((A, 4)), jnp.float32)}
    wts = jnp.asarray(rng.uniform(1, 2, A), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, A), jnp.float32)
    assign = jnp.asarray(rng.integers(0, R, A), jnp.int32)

    core_out, core_mass = rsu_aggregate(tree, wts, mask, assign, R)

    # flatten agent-stacked tree -> (A, N), run kernel, unflatten
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(A, -1) for l in leaves], axis=1)
    k_out, k_mass = masked_hier_agg(flat, wts, mask, assign, R, **INTERP)
    np.testing.assert_allclose(np.asarray(core_mass), np.asarray(k_mass),
                               rtol=1e-6)
    off = 0
    # jax.tree.leaves sorts dict keys: "b" before "w"
    for l, name in zip(leaves, ("b", "w")):
        n = int(np.prod(l.shape[1:]))
        krec = np.asarray(k_out[:, off:off + n]).reshape((R,) + l.shape[1:])
        mass_pos = np.asarray(core_mass) > 0
        np.testing.assert_allclose(
            krec[mass_pos], np.asarray(core_out[name])[mass_pos], atol=2e-5)
        off += n
