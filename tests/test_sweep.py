"""Vmapped multi-scenario sweep engine vs sequential runs (DESIGN.md §7).

The hard contract: an S-scenario sweep is ONE jitted program (the sweep
axis is visible in the compiled HLO) and matches S sequential
``run_scenario`` calls to fp32 tolerance — for the flat engine, the
semi-async engine (latencies + staleness buffers live), and across
partitions (including Dirichlet).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.mnist_mlp import CONFIG as MLP_CFG
from repro.core import program_cache
from repro.core.h2fed import H2FedParams
from repro.core.heterogeneity import HeterogeneityModel
from repro.core.scenario import ScenarioSpec
from repro.fedsim import sweep
from repro.launch import hlo_analysis
from repro.models import mlp

BASE = ScenarioSpec(n_agents=8, n_rsus=4, batch=8, n_train=400, n_test=100,
                    hp=H2FedParams(mu1=0.01, mu2=0.005, lar=2,
                                   local_epochs=1, lr=0.1),
                    het=HeterogeneityModel(csr=0.8, scd=1), rounds=2)


@pytest.fixture(scope="module")
def params():
    return mlp.init_params(MLP_CFG, jax.random.key(42))


def _assert_matches_sequential(specs, params, atol=2e-5):
    seq = [sweep.run_scenario(s, params)[1] for s in specs]
    hists = sweep.run_scenarios(specs, params)
    assert len(hists) == len(specs)
    for a, b in zip(seq, hists):
        np.testing.assert_array_equal(a["round"], b["round"])
        np.testing.assert_allclose(a["acc"], b["acc"], atol=atol)
    return seq, hists


class TestFlatSweep:
    def test_csr_grid_matches_sequential(self, params):
        """The fig-2-shaped grid: csr × mu1 batched, shared dataset."""
        specs = [BASE.replace(
            het=dataclasses.replace(BASE.het, csr=c),
            hp=dataclasses.replace(BASE.hp, mu1=m), sim_seed=s)
            for (c, m, s) in ((1.0, 0.0, 0), (0.5, 0.01, 1), (0.2, 0.02, 2))]
        _assert_matches_sequential(specs, params)

    def test_seed_average_shares_data(self, params):
        """Pure sim_seed sweep: no dynamic scalars, data unbatched."""
        specs = [BASE.replace(sim_seed=s) for s in range(3)]
        resolved = [s.resolve() for s in specs]
        assert all(r.fed is resolved[0].fed for r in resolved)
        prog = sweep.build_sweep(resolved, params)
        assert prog.dyn == {}                      # nothing varies
        assert prog.data["x"].ndim == 3            # (A, n, D), no S axis
        _assert_matches_sequential(specs, params)

    def test_dirichlet_partition_sweep(self, params):
        """Sweep across partitions: scenario II vs Dirichlet stacks the
        data blocks (same padded shape enforced via static_key grouping —
        here they differ, so run_scenarios splits groups and still matches
        sequential, order preserved)."""
        specs = [BASE,
                 BASE.replace(partition="dirichlet", alpha=0.5),
                 BASE.replace(partition="dirichlet", alpha=0.5,
                              het=dataclasses.replace(BASE.het, csr=0.5))]
        _assert_matches_sequential(specs, params)

    def test_sweep_axis_in_compiled_hlo(self, params):
        """Acceptance: one jit trace whose params carry the leading S."""
        specs = [BASE.replace(
            het=dataclasses.replace(BASE.het, csr=c)) for c in (1.0, 0.5)]
        prog = sweep.build_sweep([s.resolve() for s in specs], params)
        txt = prog.round_fn.lower(prog.state, prog.data,
                                  prog.dyn).compile().as_text()
        shapes = hlo_analysis.param_shapes(txt).values()
        n = prog.fspec.n
        assert any(f"f32[2,8,{n}]" in v for v in shapes), sorted(shapes)

    def test_dyn_scalars_only_batch_differing_fields(self):
        specs = [BASE,
                 BASE.replace(hp=dataclasses.replace(BASE.hp, mu1=0.02))]
        dyn = sweep._dyn_scalars([s for s in specs])
        assert set(dyn) == {"hp.mu1"}
        assert dyn["hp.mu1"].shape == (2,)


class TestAsyncSweep:
    def test_async_sweep_matches_sequential(self, params):
        """Semi-async case: in-flight buffers, staleness decay and the
        decoupled cloud cadence all live; delay_p and mu1 batched."""
        base = BASE.replace(
            engine="async",
            het=dataclasses.replace(BASE.het, max_delay=2, delay_p=0.5),
            staleness_decay=0.6, buffer_keep=0.25, cloud_every=2,
            hp=dataclasses.replace(BASE.hp, lar=3))
        specs = [base.replace(
            het=dataclasses.replace(base.het, delay_p=p),
            hp=dataclasses.replace(base.hp, mu1=m))
            for (p, m) in ((0.0, 0.0), (0.5, 0.01), (1.0, 0.02))]
        seq, hists = _assert_matches_sequential(specs, params)
        for a, b in zip(seq, hists):
            np.testing.assert_allclose(a["absorbed_mass"],
                                       b["absorbed_mass"], rtol=1e-5)
            np.testing.assert_allclose(a["pending_mass"],
                                       b["pending_mass"], rtol=1e-5)

    def test_mixed_engine_grid_preserves_order(self, params):
        """flat + async specs in one grid: separate groups, input order."""
        specs = [BASE.replace(sim_seed=1), BASE.replace(engine="async"),
                 BASE]
        _assert_matches_sequential(specs, params)


class TestSweepSharded:
    def test_sweep_axis_over_devices(self, forced_devices_run):
        """S=4 sweep laid over a 4-device ('sweep',) mesh (DESIGN.md §7
        device-mapping table) still matches sequential runs."""
        forced_devices_run("""
            import dataclasses, numpy as np, jax
            assert len(jax.devices()) == 4
            from repro.core.scenario import ScenarioSpec
            from repro.core.h2fed import H2FedParams
            from repro.core.heterogeneity import HeterogeneityModel
            from repro.configs.mnist_mlp import CONFIG
            from repro.models import mlp
            from repro.fedsim import sweep

            base = ScenarioSpec(
                n_agents=8, n_rsus=4, batch=8, n_train=400, n_test=100,
                hp=H2FedParams(mu1=0.01, mu2=0.005, lar=2, local_epochs=1,
                               lr=0.1),
                het=HeterogeneityModel(csr=0.8, scd=1), rounds=2)
            specs = [base.replace(
                het=dataclasses.replace(base.het, csr=c), sim_seed=i)
                for i, c in enumerate((1.0, 0.5, 0.2, 0.1))]
            params = mlp.init_params(CONFIG, jax.random.key(0))
            resolved = [s.resolve() for s in specs]
            prog = sweep.build_sweep(resolved, params, shard=True)
            assert "sweep" in str(prog.state.agent_flat.sharding)
            hists = sweep.run_sweep(resolved, params, shard=True)
            seq = [sweep.run_scenario(r, params)[1] for r in resolved]
            for a, b in zip(seq, hists):
                np.testing.assert_allclose(a["acc"], b["acc"], atol=2e-5)
            print("SWEEP_SHARDED_OK")
        """, devices=4)


class TestEngineDispatch:
    def test_all_engines_agree_through_specs(self, params):
        """run_fed-style A/B across engines without editing any module:
        the spec's engine/fleet_dtype knobs reach the engines (the old
        run_fed hardwired the flat engine)."""
        _, flat = sweep.run_scenario(BASE, params)
        for engine, atol in (("sharded", 2e-5), ("tree", 2e-4)):
            _, h = sweep.run_scenario(BASE.replace(engine=engine), params)
            np.testing.assert_allclose(flat["acc"], h["acc"], atol=atol)
        # bf16 fleet storage threads through and still learns the task
        _, h16 = sweep.run_scenario(
            BASE.replace(fleet_dtype="bfloat16"), params)
        assert h16["acc"].shape == flat["acc"].shape


class TestGrouping:
    def test_group_split_on_static_key(self):
        specs = [BASE, BASE.replace(engine="async"),
                 BASE.replace(het=dataclasses.replace(BASE.het, csr=0.3))]
        groups = sweep.group_indices([s.resolve() for s in specs])
        assert sorted(map(sorted, groups)) == [[0, 2], [1]]

    def test_non_sweepable_engine_rejected(self, params):
        res = BASE.replace(engine="tree").resolve()
        with pytest.raises(ValueError, match="not sweepable"):
            sweep.build_sweep([res], params)


class TestMixedCadence:
    """The PR-8 contract: cadence knobs (lar / local_epochs / cloud_every)
    batch as data under masked static upper bounds, so a mixed-cadence grid
    is ONE traced program that matches sequential runs exactly."""

    def test_flat_mixed_cadence_one_trace(self, params):
        program_cache.clear()
        specs = [BASE.replace(
            hp=dataclasses.replace(BASE.hp, lar=l, local_epochs=e),
            het=dataclasses.replace(BASE.het, csr=c))
            for (l, e, c) in ((2, 1, 0.8), (3, 2, 0.5), (1, 2, 1.0))]
        assert len(sweep.group_indices([s.resolve() for s in specs])) == 1
        _assert_matches_sequential(specs, params)
        assert program_cache.trace_count("sweep_round") == 1

    def test_async_mixed_cadence_one_trace(self, params):
        """lar, local_epochs AND cloud_every (incl. the 0 = per-round
        anchor) all vary inside one vmapped async program; staleness
        buffers and in-flight mass still match sequential."""
        program_cache.clear()
        base = BASE.replace(
            engine="async",
            het=dataclasses.replace(BASE.het, max_delay=2, delay_p=0.4),
            staleness_decay=0.6, buffer_keep=0.25)
        specs = [base.replace(
            hp=dataclasses.replace(base.hp, lar=l, local_epochs=e),
            cloud_every=ce)
            for (l, e, ce) in ((2, 1, 0), (3, 2, 2), (1, 2, 3))]
        assert len(sweep.group_indices([s.resolve() for s in specs])) == 1
        seq, hists = _assert_matches_sequential(specs, params)
        for a, b in zip(seq, hists):
            np.testing.assert_allclose(a["absorbed_mass"],
                                       b["absorbed_mass"], rtol=1e-5)
            np.testing.assert_allclose(a["pending_mass"],
                                       b["pending_mass"], rtol=1e-5)
        assert program_cache.trace_count("sweep_round") == 1

    def test_mixed_cadence_hlo_is_one_program(self, params):
        """The cadence scalars enter the compiled program as (S,) params,
        not as baked constants — the whole group shares one HLO."""
        specs = [BASE.replace(
            hp=dataclasses.replace(BASE.hp, lar=l, local_epochs=e))
            for (l, e) in ((1, 1), (2, 2), (3, 1))]
        prog = sweep.build_sweep([s.resolve() for s in specs], params)
        assert set(prog.dyn) == {"hp.lar", "hp.local_epochs"}
        txt = prog.round_fn.lower(prog.state, prog.data,
                                  prog.dyn).compile().as_text()
        shapes = hlo_analysis.param_shapes(txt).values()
        n = prog.fspec.n
        assert any(f"f32[3,8,{n}]" in v for v in shapes), sorted(shapes)
        assert any("s32[3]" in v for v in shapes), sorted(shapes)

    def test_max_sweep_tail_padding_reuses_program(self, params):
        """5 cells at max_sweep=2: the odd tail chunk is padded to width 2
        (results sliced off), so every chunk replays one trace."""
        program_cache.clear()
        specs = [BASE.replace(
            het=dataclasses.replace(BASE.het, csr=c))
            for c in (1.0, 0.8, 0.6, 0.4, 0.2)]
        seq = [sweep.run_scenario(s, params)[1] for s in specs]
        hists = sweep.run_scenarios(specs, params, max_sweep=2)
        assert len(hists) == len(specs)
        for a, b in zip(seq, hists):
            np.testing.assert_allclose(a["acc"], b["acc"], atol=2e-5)
        assert program_cache.trace_count("sweep_round") == 1

    def test_singleton_routes_through_cached_program(self, params):
        """A 1-cell group runs as an S=1 sweep; a re-run is a registry hit
        (no retrace) and reproduces the exact same history."""
        program_cache.clear()
        spec = BASE.replace(sim_seed=3)
        h1 = sweep.run_scenarios([spec], params)[0]
        assert program_cache.trace_count("sweep_round") == 1
        h2 = sweep.run_scenarios([spec], params)[0]
        assert program_cache.trace_count("sweep_round") == 1
        assert program_cache.stats()["hits"] >= 1
        np.testing.assert_array_equal(h1["acc"], h2["acc"])
