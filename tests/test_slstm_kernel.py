"""Fused sLSTM scan Pallas kernel vs the jnp oracle (interpret mode)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import slstm_scan_ref
from repro.kernels.slstm_scan import slstm_scan


def _inputs(B, S, H, P, seed=0, scale=1.0):
    d = H * P
    ks = jax.random.split(jax.random.key(seed), 3)
    wx = jax.random.normal(ks[0], (B, S, 4 * d), jnp.float32) * scale
    r = jax.random.normal(ks[1], (H, P, 4 * P), jnp.float32) * P ** -0.5
    b = jax.random.normal(ks[2], (4 * d,), jnp.float32) * 0.1
    return wx, r, b


@pytest.mark.parametrize("B,S,H,P", [(1, 17, 2, 32), (2, 100, 4, 64),
                                     (3, 256, 4, 32), (1, 64, 8, 16)])
@pytest.mark.parametrize("block_s", [16, 64])
def test_matches_oracle_shape_sweep(B, S, H, P, block_s):
    wx, r, b = _inputs(B, S, H, P)
    out = slstm_scan(wx, r, b, block_s=block_s, interpret=True)
    ref = slstm_scan_ref(wx, r, b)
    assert out.shape == (B, S, H * P)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)


def test_saturated_gates_stable():
    """Large pre-activations: the soft cap + stabilizer must prevent
    overflow in both kernel and oracle, and they must still agree."""
    wx, r, b = _inputs(2, 48, 4, 32, seed=1, scale=25.0)
    out = slstm_scan(wx, r, b, block_s=16, interpret=True)
    ref = slstm_scan_ref(wx, r, b)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=1e-4)


def test_batch_blocks_independent():
    """Grid over batch: each batch row must equal its standalone scan
    (state re-initialized between batch programs)."""
    wx, r, b = _inputs(3, 40, 2, 32, seed=2)
    out = slstm_scan(wx, r, b, block_s=8, interpret=True)
    for i in range(3):
        solo = slstm_scan(wx[i:i + 1], r, b, block_s=8, interpret=True)
        np.testing.assert_allclose(np.asarray(out[i:i + 1]),
                                   np.asarray(solo), atol=1e-6)
